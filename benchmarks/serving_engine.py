"""Serving-engine bench: tokens/s and scrubbed-bytes/token across repair
granularities AND decode data paths, across BER points.

The paper's claim at serving granularity: reactive repair should pay
proportionally to what *faulted*, not to what is *resident*.  The engine
runs the same mixed prefill/decode workload (more concurrent requests than
the page pool can hold at once — admission control + preemption active)
under five arms:

  whole          any fault among the touched pages scrubs the entire pool
                 (the pre-engine ``scrub_cache`` baseline); gathered-view
                 prefill + decode
  page           only the faulted pages are scrubbed (reactive,
                 page-granular); gathered-view prefill + decode — the
                 PR-2/PR-4 gather path
  paged          page repair + the fused paged-attention kernel: decode
                 straight off the pool, detection fused into the read; the
                 prefill still gathers (the PR-5 half-fused row)
  prefill_paged  the full kernel family: chunked paged prefill + fused
                 decode — ZERO full-view copies across the whole request
                 lifecycle (README §Serving engine)
  split_k        the full family with split-K flash decoding: the 8-page
                 walk partitioned across grid cells, merged by log-sum-exp

CSV: name,us_per_call,derived — us_per_call is us/token (wall-clock);
derived carries scrubbed-bytes/token, the event counters, and the
pool-copy counts.  Asserted every run: at BER > 0 the page arm comes in
strictly below the whole arm on scrubbed-bytes/token; every fused arm is
*no worse* than the gather path — identical tokens emitted and no more
scrubbed bytes/token; the fully-fused arms issue ZERO full-view copies;
the split-K arm really resolves >1 splits.  Wall-clock is reported but not
asserted for the fused arms: off-TPU the Pallas kernels run in interpret
mode (a Python-level simulator), which says nothing about the lowered
kernels these arms exist for.

A fourth comparison runs the tiered-KV arms (README §Serving engine —
"Tiered KV"): the same storm workload with preemption resolved by
recompute (``host_pages=0``) vs swap through the host exact tier
(``swap_policy="swap"``).  Asserted every run: identical token streams at
BER=0 and the swap arm re-prefills *strictly fewer* tokens than the
recompute arm — the cost the tier exists to avoid.  A BER>0 swap row
records the boundary-scrub bytes/token the crossings ledger.

``main(out=...)`` merges ``serving`` and ``tiered_kv`` sections into the
shared bench record (``benchmarks/run.py --out BENCH_repair.json``),
validated by ``scripts/check_bench.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import ApproxConfig
from repro.serving import Engine, ServingConfig

# single-bit flips on healthy f32 lanes only rarely land in the exponent's
# fatal pattern, so the BER points sit high enough that every run fires
# repair events (the zero point pins the no-fault overhead)
BERS = (0.0, 1e-4, 1e-3)
SMOKE_BERS = (0.0, 1e-3)

ARMS = ("whole", "page", "paged", "prefill_paged", "split_k")

# per-arm engine switches: (repair, paged_decode, paged_prefill, split_k)
_ARM_CFG = {
    "whole": ("whole", "off", "off", 1),
    "page": ("page", "off", "off", 1),
    "paged": ("page", "auto", "off", 1),
    "prefill_paged": ("page", "auto", "auto", 1),
    "split_k": ("page", "auto", "auto", 0),     # auto: M=8 -> 4 splits
}


def _model():
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=97,
        repair=ApproxConfig(mode="off"),   # the engine space owns repair
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _workload(engine: Engine, n_requests: int, max_new: int):
    for i in range(n_requests):
        prompt = jax.random.randint(
            jax.random.PRNGKey(100 + i), (5 + i % 3,), 1, 96
        )
        engine.add_request(prompt, max_new=max_new)


def run(smoke: bool = False):
    model, params = _model()
    n_requests, max_new = (8, 6) if smoke else (10, 12)
    rows = []
    arm_metrics = {}
    for ber in SMOKE_BERS if smoke else BERS:
        per_mode = {}
        for arm in ARMS:
            repair, paged_decode, paged_prefill, split_k = _ARM_CFG[arm]
            engine = Engine(
                model,
                params,
                ServingConfig(
                    page_size=4, n_pages=10, max_batch=4,
                    max_pages_per_request=8,
                    repair=repair, paged_decode=paged_decode,
                    paged_prefill=paged_prefill, split_k=split_k,
                    ber=ber, sweep_interval=16, sweep_pages=2, seed=7,
                ),
            )
            if paged_decode == "auto":
                assert engine.paged_plan is not None, (
                    "fused decode must engage on the bench config"
                )
            if paged_prefill == "auto":
                assert engine._prefill_fn is not None, (
                    "fused prefill must engage on the bench config"
                )
            if arm == "split_k":
                assert engine._split_k > 1, (
                    "split-K must resolve >1 splits on the bench config"
                )
            _workload(engine, n_requests, max_new)
            # the first step pays trace + compile for every executable the
            # workload touches; timing it apart keeps us_per_token a
            # steady-state number instead of a compile-time artifact
            t0 = time.perf_counter()
            engine.step()
            warmup_us = 1e6 * (time.perf_counter() - t0)
            warm_toks = engine.tokens_emitted
            t0 = time.perf_counter()
            results = engine.run()
            dt = time.perf_counter() - t0
            assert len(results) == n_requests
            m = engine.metrics()
            d = engine.stats_dict()
            per_mode[arm] = {**m, "tokens": {
                rid: results[rid]["tokens"] for rid in results
            }}
            us_per_token = 1e6 * dt / max(m["tokens_emitted"] - warm_toks, 1)
            name = f"serving_{arm}_ber{ber:g}"
            rows.append((
                name,
                us_per_token,
                f"warmup_us={warmup_us:.0f};"
                f"scrubbed_bytes_per_token={m['scrubbed_bytes_per_token']:.0f};"
                f"tokens={m['tokens_emitted']};"
                f"preempt={m['n_preemptions']};events={d['events']};"
                f"flips={d['flips']};gathers={m['pool_gathers']};"
                f"scatters={m['pool_scatters']}",
            ))
            arm_metrics[name] = {
                "us_per_token": us_per_token,
                "warmup_us": warmup_us,
                "scrubbed_bytes_per_token": m["scrubbed_bytes_per_token"],
                "tokens_emitted": m["tokens_emitted"],
                "pool_gathers": m["pool_gathers"],
                "pool_scatters": m["pool_scatters"],
                "events": d["events"],
            }
        if ber > 0.0:
            assert (
                per_mode["page"]["scrubbed_bytes_per_token"]
                < per_mode["whole"]["scrubbed_bytes_per_token"]
            ), "page-granular repair must scrub strictly fewer bytes/token"
        # every fused arm is NO WORSE than the gather path: identical token
        # streams (same repair math, fused into the read) and no more
        # repair traffic
        for arm in ("paged", "prefill_paged", "split_k"):
            assert per_mode[arm]["tokens"] == per_mode["page"]["tokens"], (
                f"{arm} drifted from the gathered path"
            )
            assert (
                per_mode[arm]["scrubbed_bytes_per_token"]
                <= per_mode["page"]["scrubbed_bytes_per_token"]
            ), f"{arm} must not scrub more bytes/token than the gather path"
        assert per_mode["paged"]["pool_gathers"] < per_mode["page"]["pool_gathers"]
        # the fully-fused arms retire EVERY full-view copy — admission,
        # prefill and decode all run straight off the pool
        for arm in ("prefill_paged", "split_k"):
            assert per_mode[arm]["pool_gathers"] == 0, arm
            assert per_mode[arm]["pool_scatters"] == 0, arm
    return rows, arm_metrics


def _tiered_engine(ber: float, host_pages: int):
    return ServingConfig(
        page_size=4, n_pages=10, max_batch=4, max_pages_per_request=6,
        repair="page", ber=ber, sweep_interval=16, sweep_pages=2, seed=7,
        host_pages=host_pages,
    )


def run_tiered(smoke: bool = False):
    """Swap-vs-recompute under page pressure.  The BER=0 pair carries the
    acceptance assert (identical tokens, strictly fewer re-prefilled
    tokens); the BER>0 swap row records what the boundary scrubs cost."""
    model, params = _model()
    n_requests, max_new = (8, 6) if smoke else (10, 12)
    rows = []
    arm_metrics = {}
    tokens = {}

    def one(name: str, ber: float, host_pages: int):
        engine = Engine(model, params, _tiered_engine(ber, host_pages))
        _workload(engine, n_requests, max_new)
        # same warmup split as the serving rows: the first step carries
        # trace + compile, us_per_token reports the steady state
        t0 = time.perf_counter()
        engine.step()
        warmup_us = 1e6 * (time.perf_counter() - t0)
        warm_toks = engine.tokens_emitted
        t0 = time.perf_counter()
        results = engine.run()
        dt = time.perf_counter() - t0
        assert len(results) == n_requests
        m = engine.metrics()
        ts = engine.tier_stats()
        toks = max(m["tokens_emitted"], 1)
        row = {
            "us_per_token": 1e6 * dt / max(m["tokens_emitted"] - warm_toks, 1),
            "warmup_us": warmup_us,
            "tokens_emitted": m["tokens_emitted"],
            "prefill_tokens_recomputed": m["prefill_tokens_recomputed"],
            "boundary_scrub_bytes_per_token":
                ts.get("boundary_scrub_bytes", 0) / toks,
            "swap_outs": ts.get("swap_outs", 0),
            "swap_ins": ts.get("swap_ins", 0),
            "recompute_fallbacks": ts.get("recompute_fallbacks", 0),
            "n_preemptions": m["n_preemptions"],
        }
        tokens[name] = {rid: results[rid]["tokens"] for rid in results}
        rows.append((
            f"tiered_{name}_ber{ber:g}",
            row["us_per_token"],
            f"recomputed={row['prefill_tokens_recomputed']};"
            f"tokens={row['tokens_emitted']};"
            f"boundary_bytes_per_token="
            f"{row['boundary_scrub_bytes_per_token']:.0f};"
            f"swaps={row['swap_outs']}/{row['swap_ins']};"
            f"fallbacks={row['recompute_fallbacks']};"
            f"preempt={row['n_preemptions']}",
        ))
        arm_metrics[name] = row
        return row

    rec = one("tiered_recompute", 0.0, 0)
    swp = one("tiered_swap", 0.0, 12)
    # the storm must actually preempt, or the comparison measures nothing
    assert rec["n_preemptions"] > 0 and swp["n_preemptions"] > 0
    assert tokens["tiered_swap"] == tokens["tiered_recompute"], (
        "swap-in drifted from recompute at BER=0"
    )
    assert (
        swp["prefill_tokens_recomputed"] < rec["prefill_tokens_recomputed"]
    ), "the swap arm must re-prefill strictly fewer tokens than recompute"
    assert swp["swap_outs"] == swp["swap_ins"] > 0
    # under faults the crossings pay (and ledger) the boundary scrub
    faulted = one("tiered_swap_ber", 1e-3, 12)
    assert faulted["boundary_scrub_bytes_per_token"] > 0 or (
        faulted["swap_outs"] == 0
    )
    return rows, arm_metrics


def main(smoke: bool = False, out: Optional[str] = None):
    print("# serving_engine: continuous batching over the paged KV pool;")
    print("# us_per_call is us/token; page must beat whole on bytes/token;")
    print("# fused arms must match page tokens; prefill_paged/split_k run the")
    print("# whole lifecycle off the pool (zero full-view copies)")
    print("name,us_per_call,derived")
    rows, arm_metrics = run(smoke=smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    print("# tiered_kv: preemption swap vs recompute (README §Tiered KV);")
    print("# swap must re-prefill strictly fewer tokens at identical output")
    tiered_rows, tiered_metrics = run_tiered(smoke=smoke)
    for name, us, derived in tiered_rows:
        print(f"{name},{us:.1f},{derived}")
    if out:
        from ._record import merge_record

        merge_record(out, "serving", {
            "rows": arm_metrics,
            "paged_vs_gather_bytes_ok": True,   # asserted above
        }, smoke=smoke)
        merge_record(out, "tiered_kv", {
            "rows": tiered_metrics,
            "swap_beats_recompute_ok": True,    # asserted in run_tiered
        }, smoke=smoke)


if __name__ == "__main__":
    main()
