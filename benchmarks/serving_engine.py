"""Serving-engine bench: tokens/s and scrubbed-bytes/token, whole-cache vs
page-granular reactive repair, across BER points.

The paper's claim at serving granularity: reactive repair should pay
proportionally to what *faulted*, not to what is *resident*.  The engine
runs the same mixed prefill/decode workload (more concurrent requests than
the page pool can hold at once — admission control + preemption active)
under two repair granularities:

  whole   any fault among the touched pages scrubs the entire pool (the
          pre-engine ``scrub_cache`` baseline)
  page    only the faulted pages are scrubbed (reactive, page-granular)

CSV: name,us_per_call,derived — us_per_call is us/token (wall-clock);
derived carries scrubbed-bytes/token and the event counters.  At every
BER > 0 the page row must come in strictly below the whole row on
scrubbed-bytes/token (asserted, like table3's count invariants).
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import ApproxConfig
from repro.serving import Engine, ServingConfig

# single-bit flips on healthy f32 lanes only rarely land in the exponent's
# fatal pattern, so the BER points sit high enough that every run fires
# repair events (the zero point pins the no-fault overhead)
BERS = (0.0, 1e-4, 1e-3)
SMOKE_BERS = (0.0, 1e-3)


def _model():
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=97,
        repair=ApproxConfig(mode="off"),   # the engine space owns repair
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _workload(engine: Engine, n_requests: int, max_new: int):
    for i in range(n_requests):
        prompt = jax.random.randint(
            jax.random.PRNGKey(100 + i), (5 + i % 3,), 1, 96
        )
        engine.add_request(prompt, max_new=max_new)


def run(smoke: bool = False):
    model, params = _model()
    n_requests, max_new = (8, 6) if smoke else (10, 12)
    rows = []
    for ber in SMOKE_BERS if smoke else BERS:
        per_mode = {}
        for repair in ("whole", "page"):
            engine = Engine(
                model,
                params,
                ServingConfig(
                    page_size=4, n_pages=10, max_batch=4,
                    max_pages_per_request=6, repair=repair, ber=ber,
                    sweep_interval=16, sweep_pages=2, seed=7,
                ),
            )
            _workload(engine, n_requests, max_new)
            t0 = time.perf_counter()
            results = engine.run()
            dt = time.perf_counter() - t0
            assert len(results) == n_requests
            m = engine.metrics()
            d = engine.stats_dict()
            per_mode[repair] = m
            rows.append((
                f"serving_{repair}_ber{ber:g}",
                1e6 * dt / max(m["tokens_emitted"], 1),
                f"scrubbed_bytes_per_token={m['scrubbed_bytes_per_token']:.0f};"
                f"tokens={m['tokens_emitted']};"
                f"preempt={m['n_preemptions']};events={d['events']};"
                f"flips={d['flips']}",
            ))
        if ber > 0.0:
            assert (
                per_mode["page"]["scrubbed_bytes_per_token"]
                < per_mode["whole"]["scrubbed_bytes_per_token"]
            ), "page-granular repair must scrub strictly fewer bytes/token"
    return rows


def main(smoke: bool = False):
    print("# serving_engine: continuous batching over the paged KV pool;")
    print("# us_per_call is us/token; page must beat whole on bytes/token")
    print("name,us_per_call,derived")
    for name, us, derived in run(smoke=smoke):
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
