"""Traffic bench: production-shaped load against the serving engine.

``repro.serving.workload`` generates a seed-deterministic arrival trace
(Poisson arrivals, bimodal prompt mix, optional burst); ``drive`` replays
it open-loop against a live engine — every arrival is submitted at its
trace step whether or not the engine has headroom, so admission control,
chunked prefill and preemption all run for a living — and reports the
latency distribution (p50/p99 wall-clock per token, time-to-first-token),
throughput, repair traffic per token, and the engine's host-sync count.

Four arms per run:

  traffic_ber0          the no-fault baseline
  traffic_ber0.001      the same trace under injected flips
  traffic_storm_ber0.001  a synchronized burst on top — the preemption
                        storm; asserted to actually preempt
  traffic_desync_ber0.001  the BER arm re-run with ``drain_interval=1``:
                        asserted token-identical to traffic_ber0.001 with
                        STRICTLY fewer blocking host syncs — the
                        desynchronized drain's whole claim, measured

Also asserted every run: regenerating the trace from the same seed gives
the identical arrival list, and driving a fresh engine with it gives the
identical token streams (the property the sharded-vs-single-device CI
parity lane leans on).  Wall-clock numbers are reported but not asserted:
off-TPU the Pallas kernels run in interpret mode.

``main(out=...)`` merges a ``traffic`` section into the shared bench
record (``benchmarks/run.py --out BENCH_repair.json``), validated by
``scripts/check_bench.py``.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.serving import (
    Engine, ServingConfig, WorkloadConfig, generate_arrivals,
)

from .serving_engine import _model


def drive(
    engine: Engine,
    arrivals: Sequence,
    max_idle_steps: int = 200,
) -> Dict[str, Any]:
    """Replay ``arrivals`` open-loop and collect the serving report.

    One harness tick = one engine step.  Arrivals whose trace step has
    come are submitted before the tick; the engine only actually steps
    while it has work (idle gaps between sparse arrivals fast-forward).
    Per-token latency samples charge each emitted token with its step's
    wall time; TTFT spans submission → first emitted token.
    """
    pending = deque(sorted(arrivals, key=lambda a: a.step))
    order: List[int] = []               # rids in submission order
    submit_wall: Dict[int, float] = {}
    ttft_s: Dict[int, float] = {}
    tok_lat_s: List[float] = []
    t0 = time.perf_counter()
    step_idx = 0
    idle = 0
    while pending or engine.has_work:
        while pending and pending[0].step <= step_idx:
            a = pending.popleft()
            rid = engine.add_request(list(a.prompt), a.max_new)
            order.append(rid)
            submit_wall[rid] = time.perf_counter()
        if engine.has_work:
            s0 = time.perf_counter()
            out = engine.step()
            s1 = time.perf_counter()
            for rid, toks in out["emitted"].items():
                if rid not in ttft_s:
                    ttft_s[rid] = s1 - submit_wall[rid]
                tok_lat_s.extend([s1 - s0] * len(toks))
            idle = 0 if (out["emitted"] or out["finished"]) else idle + 1
            if idle > max_idle_steps:
                raise RuntimeError(
                    f"engine made no progress in {max_idle_steps} steps"
                )
        step_idx += 1
    engine.drain()
    wall_s = time.perf_counter() - t0
    m = engine.metrics()
    toks = max(m["tokens_emitted"], 1)
    lat = np.asarray(tok_lat_s) if tok_lat_s else np.zeros(1)
    ttft = np.asarray(sorted(ttft_s.values())) if ttft_s else np.zeros(1)
    return {
        "token_streams": [engine.results[rid]["tokens"] for rid in order],
        "tokens_emitted": m["tokens_emitted"],
        "n_requests": len(order),
        "steps": step_idx,
        "wall_s": wall_s,
        "tokens_per_s": m["tokens_emitted"] / max(wall_s, 1e-9),
        "p50_ms_per_token": float(np.percentile(lat, 50) * 1e3),
        "p99_ms_per_token": float(np.percentile(lat, 99) * 1e3),
        "ttft_p50_ms": float(np.percentile(ttft, 50) * 1e3),
        "ttft_p99_ms": float(np.percentile(ttft, 99) * 1e3),
        "scrubbed_bytes_per_token": m["scrubbed_bytes_per_token"],
        "n_preemptions": m["n_preemptions"],
        "n_host_syncs": m["n_host_syncs"],
        "host_syncs_per_step": m["host_syncs_per_step"],
        "stage_wall_s": m["stage_wall_s"],
    }


def _serving_cfg(ber: float, drain_interval: int = 0) -> ServingConfig:
    return ServingConfig(
        page_size=4, n_pages=10, max_batch=4, max_pages_per_request=8,
        prefill_chunk=4, sweep_interval=16, sweep_pages=2,
        ber=ber, seed=7, drain_interval=drain_interval,
    )


def _workloads(smoke: bool) -> Dict[str, WorkloadConfig]:
    n = 8 if smoke else 20
    base = WorkloadConfig(
        n_requests=n, arrival_rate=0.8,
        prompt_len=(2, 6), long_prompt_len=(8, 14), long_frac=0.25,
        output_len=(2, 6) if smoke else (3, 10),
        vocab=97, seed=11,
    )
    import dataclasses

    storm = dataclasses.replace(
        base, burst_at=1, burst_n=5 if smoke else 8
    )
    return {"base": base, "storm": storm}


def run(smoke: bool = False):
    model, params = _model()
    wl = _workloads(smoke)
    base_trace = generate_arrivals(wl["base"])
    # seed-determinism: regeneration is bit-equal
    seed_det = [
        (a.step, a.prompt, a.max_new) for a in generate_arrivals(wl["base"])
    ] == [(a.step, a.prompt, a.max_new) for a in base_trace]
    assert seed_det, "workload regeneration drifted from its seed"

    rows: Dict[str, Dict[str, Any]] = {}
    reports: Dict[str, Dict[str, Any]] = {}

    def one(name: str, trace, ber: float, drain_interval: int = 0):
        engine = Engine(model, params, _serving_cfg(ber, drain_interval))
        rep = drive(engine, trace)
        reports[name] = rep
        rows[name] = {
            k: rep[k] for k in (
                "tokens_per_s", "p50_ms_per_token", "p99_ms_per_token",
                "ttft_p50_ms", "ttft_p99_ms", "scrubbed_bytes_per_token",
                "tokens_emitted", "n_preemptions", "n_host_syncs",
                "host_syncs_per_step", "steps",
            )
        }
        return rep

    rep0 = one("traffic_ber0", base_trace, 0.0)
    # determinism across fresh engines, not just fresh traces
    rep0b = drive(
        Engine(model, params, _serving_cfg(0.0)), generate_arrivals(wl["base"])
    )
    assert rep0b["token_streams"] == rep0["token_streams"], (
        "same seed + same config must replay the same tokens"
    )
    rep_ber = one("traffic_ber0.001", base_trace, 1e-3)
    rep_storm = one(
        "traffic_storm_ber0.001", generate_arrivals(wl["storm"]), 1e-3
    )
    assert rep_storm["n_preemptions"] > 0, (
        "the storm arm must actually preempt"
    )
    rep_desync = one(
        "traffic_desync_ber0.001", base_trace, 1e-3, drain_interval=1
    )
    # the desynchronized drain's contract, measured under real traffic:
    # identical tokens, strictly fewer blocking device->host readbacks
    desync_parity = rep_desync["token_streams"] == rep_ber["token_streams"]
    desync_fewer = rep_desync["n_host_syncs"] < rep_ber["n_host_syncs"]
    assert desync_parity, "drain_interval=1 drifted from the lockstep tokens"
    assert desync_fewer, (
        "the deferred drain must issue strictly fewer host syncs "
        f"({rep_desync['n_host_syncs']} vs {rep_ber['n_host_syncs']})"
    )
    flags = {
        "seed_deterministic": bool(seed_det),
        "desync_token_parity_ok": bool(desync_parity),
        "desync_fewer_syncs_ok": bool(desync_fewer),
    }
    return rows, flags


def main(smoke: bool = False, out: Optional[str] = None):
    print("# traffic: open-loop Poisson load over the serving engine;")
    print("# per-arm p50/p99 wall-clock per token, tokens/s, scrubbed")
    print("# bytes/token, host syncs; the desync arm must match the")
    print("# lockstep tokens with strictly fewer syncs")
    print("name,us_per_call,derived")
    rows, flags = run(smoke=smoke)
    for name, row in rows.items():
        us = 1e3 * row["p50_ms_per_token"]
        print(
            f"{name},{us:.1f},"
            f"tokens_per_s={row['tokens_per_s']:.1f};"
            f"p99_ms={row['p99_ms_per_token']:.2f};"
            f"ttft_p50_ms={row['ttft_p50_ms']:.2f};"
            f"scrubbed_bytes_per_token="
            f"{row['scrubbed_bytes_per_token']:.0f};"
            f"preempt={row['n_preemptions']};"
            f"syncs={row['n_host_syncs']};"
            f"syncs_per_step={row['host_syncs_per_step']:.2f}"
        )
    if out:
        from ._record import merge_record

        merge_record(out, "traffic", {"rows": rows, **flags}, smoke=smoke)


if __name__ == "__main__":
    main()
