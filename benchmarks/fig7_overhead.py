"""Fig. 7 reproduction: elapsed time of N×N matmul under three conditions.

Paper setup (§4): (1) normal — no NaN; (2) a NaN injected, repaired by the
register-repairing mechanism (at every consumption); (3) NaN injected,
repaired by register+memory mechanisms (once, at its origin).

TPU/JAX mapping (README §Runtime): one matmul reuses its operand across R
consuming calls (the iterative-workload pattern — every training/serving
step re-reads the same resident weights):

  normal    R × matmul(a, b)
  register  R × matmul(repair(a), b)     — detect+select on EVERY call
  memory    scrub(a) once; R × matmul(a, b)  — one repair, then clean calls

Sizes are CPU-scaled (paper used 1000–5000 on a Core i7; wall-clock here is
CPU, the structural claim — register ≈ normal + R·ε, memory ≈ normal + ε —
is hardware-independent).  CSV: name,us_per_call,derived.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.paper_mmm import CONFIG
from repro.core import injection
from repro.core import stats as stats_lib
from repro.runtime import ApproxSpace


def _time(fn, *args, repeats=None, batches=5):
    """Median of ``batches`` timed batches of ``repeats`` calls (CPU
    wall-clock jitter on a shared host easily exceeds the paper's ~1 %
    effect size; the median is the robust estimator)."""
    repeats = repeats or CONFIG.repeats
    for _ in range(2):                      # compile + cache warmup
        jax.block_until_ready(fn(*args))
    samples = []
    for _ in range(batches):
        t0 = time.perf_counter()
        out = None
        for _ in range(repeats):
            out = fn(*args)
        jax.block_until_ready(out)
        samples.append((time.perf_counter() - t0) / repeats)
    samples.sort()
    return samples[len(samples) // 2]


# The runtimes under test: per-use repair (register) vs write-back (memory).
_REGISTER = ApproxSpace(mode="register", policy="zero", max_magnitude=None)
_MEMORY = ApproxSpace(mode="memory", policy="zero", max_magnitude=None)


@jax.jit
def _mm(a, b):
    return a @ b


@jax.jit
def _mm_register(a, b):
    fixed, _ = _REGISTER.use(a, stats_lib.zeros())
    return fixed @ b


@jax.jit
def _scrub(a):
    fixed, _ = _MEMORY.scrub(a, stats_lib.zeros())
    return fixed


def run(sizes=None, reuse=8, repeats=None, batches=5):
    rows = []
    for n in sizes or CONFIG.sizes:
        key = jax.random.PRNGKey(n)
        k1, k2, k3 = jax.random.split(key, 3)
        a = jax.random.normal(k1, (n, n), jnp.float32)
        b = jax.random.normal(k2, (n, n), jnp.float32)
        a_bad = injection.inject_nan(k3, a, CONFIG.n_injected)

        kw = dict(repeats=repeats, batches=batches)
        t_normal = _time(lambda: _mm(a, b), **kw) * reuse
        t_register = _time(lambda: _mm_register(a_bad, b), **kw) * reuse
        a_fixed = _scrub(a_bad)                    # memory repair, once
        t_scrub = _time(lambda: _scrub(a_bad), **kw)
        t_memory = t_scrub + _time(lambda: _mm(a_fixed, b), **kw) * reuse

        rows.append((n, t_normal, t_register, t_memory))
    return rows


def main(smoke: bool = False):
    print("# fig7_overhead: R=8 reuses per buffer; times in ms")
    print("name,us_per_call,derived")
    rows = run(sizes=(64,), reuse=2, repeats=2, batches=1) if smoke else run()
    for n, t_n, t_r, t_m in rows:
        print(f"fig7_normal_N{n},{t_n*1e6:.1f},baseline")
        print(f"fig7_register_N{n},{t_r*1e6:.1f},overhead={100*(t_r/t_n-1):.1f}%")
        print(f"fig7_memory_N{n},{t_m*1e6:.1f},overhead={100*(t_m/t_n-1):.1f}%")


if __name__ == "__main__":
    main()
