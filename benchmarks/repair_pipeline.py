"""Repair-pipeline bench: eager vs compiled scrub/inject, 1 vs 8 devices.

The PR-3 trajectory bootstrap (ISSUE 3): wall-time per scrub/inject call and
scrubbed-bytes/step for

  * the pre-refactor **eager** path (per-leaf jnp dispatch: `scrub_tree` /
    `inject_tree` called op-by-op from the host), vs
  * the mesh-native **compiled** path (`ApproxSpace` dispatching one cached
    donated executable per state layout),

on this process's devices and — via a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — on 8 fake host
devices with the state FSDP-sharded, where the executable repairs
shard-locally.  Acceptance: compiled ≤ eager at smoke shapes (asserted).

CSV: ``name,us_per_call,scrubbed_mb_per_step``; ``main(out=...)`` writes the
full record to JSON (``benchmarks/run.py --out BENCH_repair.json``).
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp


def _tree(n: int, key) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {
        "params": {"w": jax.random.normal(k1, (n, n), jnp.float32)},
        "opt": {"mu": jax.random.normal(k2, (n, n), jnp.float32),
                "step": jnp.zeros((), jnp.int32)},
    }


def _sharded(tree):
    """FSDP-style placement over all local devices (row-sharded matrices)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((jax.device_count(),), ("data",))

    def put(leaf):
        spec = P("data") if (
            leaf.ndim and leaf.shape[0] % jax.device_count() == 0
        ) else P()
        return jax.device_put(leaf, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree), mesh


def _time(fn, reps: int) -> float:
    """Median wall-time per call in µs (one untimed warmup)."""
    fn()
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        times.append((time.perf_counter() - t0) * 1e6)
    return statistics.median(times)


def measure(n: int, reps: int, *, shard: bool = False) -> Dict[str, Any]:
    from repro.core import stats as stats_lib
    from repro.runtime import ApproxConfig, ApproxSpace
    from repro.runtime.space import inject_tree, scrub_tree

    ber = 1e-6
    tree = _tree(n, jax.random.PRNGKey(0))
    mesh = None
    if shard:
        tree, mesh = _sharded(tree)
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero", ber=ber))
    if mesh is not None:
        space.use_mesh(mesh)
    regions = space.regions_for(tree)
    key = jax.random.PRNGKey(1)

    def eager_scrub():
        out, _ = scrub_tree(tree, space.config, stats_lib.zeros(), regions)
        jax.block_until_ready(out)

    def eager_inject():
        out, flips = inject_tree(tree, key, ber, regions)
        jax.block_until_ready((out, flips))

    # compiled: ping-pong with donated buffers — the production pattern
    # (the scrubbed/flipped tree replaces the resident state)
    state = {"scrub": jax.tree.map(jnp.copy, tree),
             "inject": jax.tree.map(jnp.copy, tree)}

    def compiled_scrub():
        state["scrub"], _ = space.scrub(
            state["scrub"], stats_lib.zeros(), donate=True
        )
        jax.block_until_ready(state["scrub"])

    def compiled_inject():
        state["inject"], _ = space.inject(
            state["inject"], key, ber, record=False, donate=True
        )
        jax.block_until_ready(state["inject"])

    bytes0 = space.scrubbed_bytes
    res = {
        "devices": jax.device_count(),
        "placement": space.plan_for(tree).placement,
        "shape": [n, n],
        "eager_scrub_us": _time(eager_scrub, reps),
        "compiled_scrub_us": _time(compiled_scrub, reps),
        "eager_inject_us": _time(eager_inject, reps),
        "compiled_inject_us": _time(compiled_inject, reps),
        "traces": space.n_traces,
    }
    res["scrubbed_bytes_per_step"] = (
        (space.scrubbed_bytes - bytes0) // (reps + 1)
    )
    return res


def _measure_subprocess(n: int, reps: int, devices: int) -> Optional[Dict]:
    """Re-run this module under ``devices`` fake host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + env.get("XLA_FLAGS", "")
    ).strip()
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", ""
    )
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.repair_pipeline",
         "--emit-json", "--n", str(n), "--reps", str(reps), "--shard"],
        capture_output=True, text=True, env=env, cwd=root, timeout=600,
    )
    if proc.returncode != 0:
        print(f"# 8-device subprocess failed:\n{proc.stderr[-2000:]}",
              file=sys.stderr)
        return None
    return json.loads(proc.stdout.splitlines()[-1])


def main(smoke: bool = False, out: Optional[str] = None) -> Dict[str, Any]:
    n = 256 if smoke else 1024
    reps = 10 if smoke else 30
    record: Dict[str, Any] = {"smoke": smoke, "sections": {}}

    one = measure(n, reps)
    record["sections"]["devices_1"] = one
    eight = _measure_subprocess(n, reps, devices=8)
    if eight is None:
        # the 8-device half of the acceptance criterion must never be
        # skipped silently — fail the section so CI fails
        raise RuntimeError(
            "8-fake-device bench subprocess failed (stderr above); the "
            "compiled<=eager criterion is unverified on the multidev config"
        )
    record["sections"]["devices_8"] = eight

    for name, sec in record["sections"].items():
        mb = sec["scrubbed_bytes_per_step"] / 1e6
        for kind in ("scrub", "inject"):
            print(f"{name}/eager_{kind},{sec[f'eager_{kind}_us']:.1f},{mb:.3f}")
            print(
                f"{name}/compiled_{kind},"
                f"{sec[f'compiled_{kind}_us']:.1f},{mb:.3f}"
            )

    # acceptance: the compiled pipeline is never slower than the eager
    # per-leaf dispatch it replaced (ISSUE 3)
    for name, sec in record["sections"].items():
        for kind in ("scrub", "inject"):
            eager, compiled = sec[f"eager_{kind}_us"], sec[f"compiled_{kind}_us"]
            assert compiled <= eager, (
                f"{name}: compiled {kind} ({compiled:.1f}us) slower than "
                f"eager ({eager:.1f}us)"
            )
    print(f"# compiled <= eager holds on {len(record['sections'])} device "
          "configurations")

    if out:
        # merge alongside the other sections' records (serving engine) —
        # these keys stay at the top level for check_bench compatibility
        from ._record import merge_record

        for name, sec in record["sections"].items():
            merge_record(out, name, sec, smoke=smoke)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-json", action="store_true",
                    help="measure this process only; print one JSON line")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--n", type=int, default=256)
    ap.add_argument("--reps", type=int, default=10)
    ap.add_argument("--shard", action="store_true")
    args = ap.parse_args()
    if args.emit_json:
        print(json.dumps(measure(args.n, args.reps, shard=args.shard)))
    else:
        main(smoke=args.smoke, out=args.out)
