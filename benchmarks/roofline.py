"""§Roofline table: read the dry-run JSON records and print the three-term
analysis per (arch × shape × mesh).

Sources: launch/dryrun.py wrote one record per cell under
benchmarks/results/.  The terms are static HLO-derived seconds-per-step per
chip (launch/hlo.py accounting, launch/roofline.py constants).

CSV: name,us_per_call,derived — us_per_call carries the dominant-term
seconds; derived carries the full breakdown.
"""
from __future__ import annotations

import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def load(mesh="16x16"):
    rows = []
    for fn in sorted(glob.glob(os.path.join(RESULTS, "dryrun_*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        if rec.get("mesh") != mesh or not rec.get("ok") or not rec.get("report"):
            continue
        rows.append(rec["report"])
    return rows


def main():
    rows = load()
    if not rows:
        print("# roofline: no dry-run records found — run:")
        print("#   PYTHONPATH=src python -m repro.launch.dryrun --all --out benchmarks/results")
        print("name,us_per_call,derived")
        return
    print("# roofline (single-pod 16x16, per-chip seconds/step, static HLO)")
    print("name,us_per_call,derived")
    for r in rows:
        name = f"roofline_{r['arch']}_{r['cell']}"
        print(
            f"{name},{r['bound_s']*1e6:.0f},"
            f"comp={r['compute_s']*1e3:.1f}ms;mem={r['memory_s']*1e3:.1f}ms;"
            f"coll={r['collective_s']*1e3:.1f}ms;dom={r['dominant']};"
            f"useful={r['useful_ratio']:.3f};frac={r['roofline_fraction']:.3f}"
        )


if __name__ == "__main__":
    main()
