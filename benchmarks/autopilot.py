"""Autopilot bench: the profiled frontier, quality-vs-refresh per region
group, for the transformer and recurrent presets (README §Autopilot).

Each preset runs its profiling campaign (short injected greedy-serve
episodes, flips confined to one group at a time) and solves the frontier
against the preset's quality budget.  The section records the full grid —
the EDEN story in numbers: how far each data structure's refresh can be
relaxed before measured quality leaves the budget, and where the solver
places each group.

CSV: name,us_per_call,derived — one row per (model, group, refresh point);
us_per_call is campaign wall-time per profiled cell, derived carries
BER / quality / flips / energy saving.  ASSIGN rows follow with the solved
per-group refresh.

Asserted every run: on the recurrent preset the solved refresh for the
recurrent-state group is STRICTLY shorter (more conservative) than the
projection-weights group's — the compounding-state asymmetry the frontier
exists to discover; and every group's assignment meets the budget or is
collapsed to the exact island.

``main(out=...)`` merges an ``autopilot`` section into the shared bench
record (``benchmarks/run.py --out BENCH_repair.json``), validated by
``scripts/check_bench.py``.
"""
from __future__ import annotations

import math
import time
from typing import Any, Dict, Optional

from repro.autopilot import run_campaign, solve_frontier
from repro.configs import get_preset

# smoke mode: the two separating points only, shorter episodes — the
# full four-point sweep is the default-mode (and README) story
SMOKE_POINTS = (1.0, 2.0)
SMOKE_STEPS = 6


def _finite(x: Any) -> Any:
    """JSON-safe float: non-finite (a diverged metric) becomes None rather
    than a bare NaN token downstream parsers reject."""
    if isinstance(x, float) and not math.isfinite(x):
        return None
    return x


def _preset(name: str, smoke: bool):
    import dataclasses

    p = get_preset(name, steps=SMOKE_STEPS if smoke else 8)
    if smoke:
        p = dataclasses.replace(
            p, campaign=dataclasses.replace(
                p.campaign, refresh_points=SMOKE_POINTS
            )
        )
    return p


def run(smoke: bool = False):
    rows = []
    models: Dict[str, Any] = {}
    budgets: Dict[str, float] = {}
    for name in ("transformer", "recurrent"):
        p = _preset(name, smoke)
        model = p.build_model()
        t0 = time.perf_counter()
        profile = run_campaign(model, p.campaign)
        dt = time.perf_counter() - t0
        us_per_cell = 1e6 * dt / max(len(profile.cells), 1)
        frontier = solve_frontier(profile, p.budget)
        budgets[name] = p.budget

        for c in profile.cells:
            rows.append((
                f"{name}_{c.group}_r{c.refresh_s:g}",
                us_per_cell,
                f"ber={c.ber:.2e};quality={_finite(c.quality)};"
                f"flips={c.flips};saving={c.energy_saving:.3f};"
                f"faults_per_step={c.faults_per_step:.2f}",
            ))
        for a in frontier.assignments:
            rows.append((
                f"{name}_ASSIGN_{a.group}",
                0.0,
                f"refresh_s={a.refresh_s:g};collapsed={a.collapsed};"
                f"quality={_finite(a.quality)};saving={a.energy_saving:.3f}",
            ))

        # every assignment meets the budget or collapsed to the exact island
        for a in frontier.assignments:
            assert a.collapsed or (
                math.isfinite(a.quality) and a.quality <= p.budget
            ), f"{name}/{a.group}: assignment violates the quality budget"

        models[name] = {
            "model": profile.model,
            "metric": profile.metric,
            "steps": profile.steps,
            "budget": p.budget,
            "frontier": [
                {
                    "group": c.group,
                    "refresh_s": c.refresh_s,
                    "ber": c.ber,
                    "quality": _finite(c.quality),
                    "flips": c.flips,
                    "faults_per_step": c.faults_per_step,
                    "energy_saving": c.energy_saving,
                }
                for c in profile.cells
            ],
            "assignments": {
                a.group: {
                    "refresh_s": a.refresh_s,
                    "ber": a.ber,
                    "collapsed": a.collapsed,
                    "quality": _finite(a.quality),
                    "energy_saving": a.energy_saving,
                    "expected_faults_per_step": a.expected_faults_per_step,
                }
                for a in frontier.assignments
            },
            "energy_saving": frontier.energy_saving,
        }

    # the acceptance asymmetry: recurrent state strictly more conservative
    # than the projection weights on the recurrent preset
    rec = models["recurrent"]["assignments"]
    assert (
        rec["recurrent_state"]["refresh_s"] < rec["proj_weights"]["refresh_s"]
    ), (
        "recurrent state was not assigned a strictly more conservative "
        f"refresh than the projection weights: {rec}"
    )
    return rows, models


def main(smoke: bool = False, out: Optional[str] = None):
    print("# autopilot: per-region tolerance campaign + frontier solve;")
    print("# us_per_call is campaign wall-time per profiled cell; ASSIGN")
    print("# rows carry the solved per-group refresh.  Asserted: recurrent")
    print("# state lands strictly more conservative than proj weights")
    print("name,us_per_call,derived")
    rows, models = run(smoke=smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if out:
        from ._record import merge_record

        merge_record(out, "autopilot", {
            "models": models,
            "recurrent_state_more_conservative": True,  # asserted above
        }, smoke=smoke)


if __name__ == "__main__":
    main()
