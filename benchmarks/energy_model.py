"""§2.1 energy claim, modeled: refresh interval → BER → memory-energy saving
(RAIDR/Flikker anchor points the paper cites), applied to each architecture's
actual exact/approximate byte split.

The saving applies only to the approximate region; the exact region (step
counters, RNG keys, router tables — regions.DEFAULT_RULES) stays at nominal
refresh.  Output: effective memory-energy saving per arch at each anchor.

CSV: name,us_per_call,derived (count column = effective saving %).
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import REGISTRY
from repro.core.injection import ApproxMemoryModel
from repro.core.regions import Region, annotate
from repro.models import build_model
from repro.nn import module as module_lib
from repro.launch.train import abstract_train_state, make_optimizer

REFRESH_POINTS = (0.256, 1.0, 4.0)


def byte_split(arch_cfg):
    """(approx_bytes, exact_bytes) over params + optimizer state."""
    model = build_model(arch_cfg.reduced())
    opt = make_optimizer()
    state = abstract_train_state(model, opt)
    regions = annotate(state)
    approx = exact = 0
    for leaf, region in zip(jax.tree.leaves(state), jax.tree.leaves(regions)):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        b = n * np.dtype(leaf.dtype).itemsize
        if region is Region.APPROX:
            approx += b
        else:
            exact += b
    return approx, exact


def main():
    print("# energy_model: effective memory-energy saving (approx fraction ×")
    print("# refresh-relaxation saving); anchors: RAIDR 16.1%@256ms,")
    print("# Flikker 22.5%@1s, extrapolated 30%@4s")
    print("name,us_per_call,derived")
    for name, cfg in REGISTRY.items():
        approx, exact = byte_split(cfg)
        frac = approx / max(approx + exact, 1)
        for t in REFRESH_POINTS:
            m = ApproxMemoryModel.from_refresh(t)
            eff = 100.0 * frac * m.energy_saving
            print(
                f"energy_{name}_refresh{t:g}s,{eff:.2f},"
                f"approx_frac={frac:.4f},ber={m.ber:.1e}"
            )


if __name__ == "__main__":
    main()
