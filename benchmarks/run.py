"""Benchmark harness — one section per paper table/figure + the roofline +
the serving engine + the repair pipeline.

    PYTHONPATH=src python -m benchmarks.run [--smoke] [--out BENCH_repair.json]

Each section prints ``name,us_per_call,derived`` CSV (see the individual
modules for the exact semantics of the middle column).

``--smoke`` runs every section at tiny shapes with fixed seeds — the CI
mode (scripts/ci.sh): every section executes end to end on every run, so a
broken bench fails CI instead of rotting silently.  Sections whose ``main``
accepts a ``smoke`` kwarg shrink themselves; the rest are already tiny.

``--out FILE`` records the bench trajectory: sections whose ``main``
accepts an ``out`` kwarg (``serving_engine``: tokens/s + bytes/token per
arm; ``prefix_cache``: prefill-tokens-saved + gated-vs-always reuse-scrub
bytes; ``repair_pipeline``: eager-vs-compiled scrub/inject wall-time and
scrubbed-bytes/step on 1 and 8 fake devices; ``autopilot``: the profiled
quality-vs-refresh frontier per region group) MERGE their JSON record
there (benchmarks/_record.py) — the per-PR perf baseline.

The top-level ``sections`` always holds the LATEST run; the prior record's
``history`` list is carried across the rewrite and this run is appended to
it under ``--timestamp`` (default: current UTC time — the only clock in
the bench path lives here in the CLI layer, keeping the benchmark code
itself deterministic).  ``scripts/check_bench.py`` validates both shapes.
"""
from __future__ import annotations

import argparse
import inspect
import os
import sys
import traceback
from datetime import datetime, timezone

from . import (
    autopilot,
    energy_model,
    fig6_provenance,
    fig7_overhead,
    prefix_cache,
    repair_pipeline,
    roofline,
    serving_engine,
    table3_counts,
    traffic,
)

SECTIONS = (
    ("fig7_overhead (paper Fig. 7)", fig7_overhead.main),
    ("table3_counts (paper Table 3)", table3_counts.main),
    ("fig6_provenance (paper Fig. 6)", fig6_provenance.main),
    ("energy_model (paper §2.1)", energy_model.main),
    ("roofline (assignment §Roofline)", roofline.main),
    ("serving_engine (README §Serving engine)", serving_engine.main),
    ("traffic (README §Serving engine — load testing)", traffic.main),
    ("prefix_cache (README §Serving engine)", prefix_cache.main),
    ("repair_pipeline (README §Distributed repair)", repair_pipeline.main),
    ("autopilot (README §Autopilot)", autopilot.main),
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes + fixed seeds (CI mode)",
    )
    ap.add_argument(
        "--out", default=None,
        help="JSON record path for sections that support it "
        "(repair_pipeline)",
    )
    ap.add_argument(
        "--timestamp", default=None,
        help="history entry label for this run (default: current UTC time;"
        " the bench record keeps every run under 'history')",
    )
    args = ap.parse_args(argv)
    prior_history = []
    if args.out:
        from ._record import append_history, read_history

        prior_history = read_history(args.out)
        if os.path.exists(args.out):
            os.unlink(args.out)        # fresh record: sections merge into it
    timestamp = args.timestamp
    if timestamp is None:
        # the bench path's only clock: benchmark modules stay deterministic
        timestamp = datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ")

    failures = 0
    for title, fn in SECTIONS:
        print(f"\n===== {title} =====")
        try:
            params = inspect.signature(fn).parameters
            kwargs = {}
            if "smoke" in params:
                kwargs["smoke"] = args.smoke
            if "out" in params and args.out:
                kwargs["out"] = args.out
            fn(**kwargs)
        except Exception:
            failures += 1
            traceback.print_exc()
    if args.out:
        append_history(args.out, timestamp, prior_history)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
