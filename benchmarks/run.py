"""Benchmark harness — one section per paper table/figure + the roofline.

    PYTHONPATH=src python -m benchmarks.run

Each section prints ``name,us_per_call,derived`` CSV (see the individual
modules for the exact semantics of the middle column).
"""
from __future__ import annotations

import sys
import traceback

from . import energy_model, fig6_provenance, fig7_overhead, roofline, table3_counts

SECTIONS = (
    ("fig7_overhead (paper Fig. 7)", fig7_overhead.main),
    ("table3_counts (paper Table 3)", table3_counts.main),
    ("fig6_provenance (paper Fig. 6)", fig6_provenance.main),
    ("energy_model (paper §2.1)", energy_model.main),
    ("roofline (assignment §Roofline)", roofline.main),
)


def main() -> None:
    failures = 0
    for title, fn in SECTIONS:
        print(f"\n===== {title} =====")
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
