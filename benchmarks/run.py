"""Benchmark harness — one section per paper table/figure + the roofline +
the serving engine + the repair pipeline.

    PYTHONPATH=src python -m benchmarks.run [--smoke] [--out BENCH_repair.json]

Each section prints ``name,us_per_call,derived`` CSV (see the individual
modules for the exact semantics of the middle column).

``--smoke`` runs every section at tiny shapes with fixed seeds — the CI
mode (scripts/ci.sh): every section executes end to end on every run, so a
broken bench fails CI instead of rotting silently.  Sections whose ``main``
accepts a ``smoke`` kwarg shrink themselves; the rest are already tiny.

``--out FILE`` records the bench trajectory: sections whose ``main``
accepts an ``out`` kwarg (``serving_engine``: tokens/s + bytes/token per
arm; ``prefix_cache``: prefill-tokens-saved + gated-vs-always reuse-scrub
bytes; ``repair_pipeline``: eager-vs-compiled scrub/inject wall-time and
scrubbed-bytes/step on 1 and 8 fake devices) MERGE their JSON record there
(benchmarks/_record.py) — the per-PR perf baseline.  The file is removed
at the start of a run so a record never mixes two runs' sections.
"""
from __future__ import annotations

import argparse
import inspect
import os
import sys
import traceback

from . import (
    energy_model,
    fig6_provenance,
    fig7_overhead,
    prefix_cache,
    repair_pipeline,
    roofline,
    serving_engine,
    table3_counts,
)

SECTIONS = (
    ("fig7_overhead (paper Fig. 7)", fig7_overhead.main),
    ("table3_counts (paper Table 3)", table3_counts.main),
    ("fig6_provenance (paper Fig. 6)", fig6_provenance.main),
    ("energy_model (paper §2.1)", energy_model.main),
    ("roofline (assignment §Roofline)", roofline.main),
    ("serving_engine (README §Serving engine)", serving_engine.main),
    ("prefix_cache (README §Serving engine)", prefix_cache.main),
    ("repair_pipeline (README §Distributed repair)", repair_pipeline.main),
)


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--smoke", action="store_true",
        help="tiny shapes + fixed seeds (CI mode)",
    )
    ap.add_argument(
        "--out", default=None,
        help="JSON record path for sections that support it "
        "(repair_pipeline)",
    )
    args = ap.parse_args(argv)
    if args.out and os.path.exists(args.out):
        os.unlink(args.out)            # fresh record: sections merge into it

    failures = 0
    for title, fn in SECTIONS:
        print(f"\n===== {title} =====")
        try:
            params = inspect.signature(fn).parameters
            kwargs = {}
            if "smoke" in params:
                kwargs["smoke"] = args.smoke
            if "out" in params and args.out:
                kwargs["out"] = args.out
            fn(**kwargs)
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
