"""Shared JSON-record merging for bench sections.

``benchmarks/run.py --out FILE`` hands the same path to every section that
accepts an ``out`` kwarg; each section merges its own entry under
``sections`` instead of overwriting the file, so the record accumulates
(serving engine + repair pipeline today).  ``run.py`` removes the file at
the start of a run — a record never mixes two runs' sections.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict


def merge_record(path: str, name: str, section: Dict[str, Any],
                 **top_level: Any) -> None:
    """Merge ``section`` under ``sections[name]`` of the JSON record at
    ``path`` (created if absent), updating any ``top_level`` keys."""
    record: Dict[str, Any] = {"sections": {}}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
        record.setdefault("sections", {})
    record.update(top_level)
    record["sections"][name] = section
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# merged section {name!r} into {path}")
