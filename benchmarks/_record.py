"""Shared JSON-record merging for bench sections.

``benchmarks/run.py --out FILE`` hands the same path to every section that
accepts an ``out`` kwarg; each section merges its own entry under
``sections`` instead of overwriting the file, so the record accumulates
(serving engine + repair pipeline today).  ``run.py`` removes the file at
the start of a run — the top-level ``sections`` never mixes two runs.

The record also carries the bench *trajectory*: before unlinking, ``run.py``
reads the prior record's ``history`` (``read_history``) and, after the
sections finish, appends the fresh run under a caller-supplied timestamp
(``append_history``).  Top-level ``sections`` stays "latest"; ``history``
is the append-only run log.  This module stays clock-free on purpose —
timestamps are injected by the CLI layer, so benchmark code itself is
deterministic and replayable.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List


def merge_record(path: str, name: str, section: Dict[str, Any],
                 **top_level: Any) -> None:
    """Merge ``section`` under ``sections[name]`` of the JSON record at
    ``path`` (created if absent), updating any ``top_level`` keys."""
    record: Dict[str, Any] = {"sections": {}}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
        record.setdefault("sections", {})
    record.update(top_level)
    record["sections"][name] = section
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# merged section {name!r} into {path}")


def read_history(path: str) -> List[Dict[str, Any]]:
    """The prior record's run log (empty when the file or key is absent, or
    the file is unreadable — a corrupt record must not block a fresh run)."""
    if not os.path.exists(path):
        return []
    try:
        with open(path) as f:
            record = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []
    history = record.get("history", [])
    return history if isinstance(history, list) else []


def append_history(path: str, timestamp: str,
                   prior: List[Dict[str, Any]]) -> None:
    """Append this run's ``sections`` to the trajectory: ``history`` becomes
    ``prior`` + one ``{"timestamp", "sections"}`` entry for the record's
    current (latest) sections."""
    if not os.path.exists(path):
        return
    with open(path) as f:
        record = json.load(f)
    record["history"] = list(prior) + [{
        "timestamp": timestamp,
        "sections": record.get("sections", {}),
    }]
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# appended run {timestamp!r} to history ({len(record['history'])}"
          f" runs) in {path}")
