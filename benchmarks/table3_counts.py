"""Table 3 reproduction: number of repair events (SIGFPE analogue) per
mechanism, measured with the REAL kernel counters (Pallas, interpret mode).

Paper: register-only repair of one NaN in an N×N matmul fires N traps (one
per reuse of the poisoned element); register+memory fires exactly 1.

Kernel mapping: the poisoned operand is consumed across R calls (training /
serving steps).  Register mode re-detects on every call AND on every tile
visit within a call (the paper's per-reuse trap, tile-granular); memory mode
scrubs the origin on the first event and never fires again.

CSV: name,us_per_call,derived  (us_per_call column carries the event count —
this table is about counts, not time).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import injection
from repro.kernels import ops
from repro.runtime import ApproxSpace


def run(n=256, blocks=(64, 64, 64), reuse=5):
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.normal(k1, (n, n), jnp.float32)
    b = jax.random.normal(k2, (n, n), jnp.float32)
    a_bad = injection.inject_nan(k3, a, 1)

    # one unified stats stream per mechanism: the kernel counter vectors are
    # folded into the core.stats Table-3 analogue by the runtime
    reg_space = ApproxSpace(mode="register", policy="zero")
    mem_space = ApproxSpace(mode="memory", policy="zero")

    # per-call tile-visit events (intra-call Table 3: one poisoned a-tile is
    # visited n/bn times inside ONE matmul — the paper's N-traps-per-matmul)
    first = ops.repair_matmul(a_bad, b, mode="register", blocks=blocks)
    per_call_visits = int(first.counts[ops.MM_EV_A])

    reg_events = []
    mem_events = []
    a_reg = a_mem = a_bad
    for _ in range(reuse):
        r = ops.repair_matmul(a_reg, b, mode="register", blocks=blocks)
        a_reg = r.a
        reg_space.record_kernel(r.counts)
        reg_events.append(int(r.counts[ops.MM_EV_A]))
        m = ops.repair_matmul(a_mem, b, mode="memory", blocks=blocks)
        a_mem = m.a                               # functional write-back
        mem_space.record_kernel(m.counts)
        mem_events.append(int(m.counts[ops.MM_EV_A]))
    return per_call_visits, reg_events, mem_events, reg_space, mem_space


def main(smoke: bool = False):
    n, bn, reuse = (128, 64, 3) if smoke else (256, 64, 5)
    per_call, reg, mem, reg_space, mem_space = run(
        n=n, blocks=(bn, bn, bn), reuse=reuse
    )
    n_over_bn = n // bn
    print("# table3_counts: repair events per mechanism (kernel counters)")
    print("name,us_per_call,derived")
    print(f"table3_intracall_visits,{per_call},expected={n_over_bn}")
    print(f"table3_register_total,{sum(reg)},per_call={reg}")
    print(f"table3_memory_total,{sum(mem)},per_call={mem}")
    print(f"table3_register_unified,{reg_space.stats_dict()['events']},"
          f"stats={reg_space.stats_dict()}")
    print(f"table3_memory_unified,{mem_space.stats_dict()['events']},"
          f"stats={mem_space.stats_dict()}")
    assert reg == [per_call] * len(reg), "register mode must re-fire every call"
    assert sum(m > 0 for m in mem) == 1, "memory mode must fire exactly once"
    # fused-kernel events must land in the unified core.stats stream (only
    # operand a is poisoned, so ev_total == ev_a call by call)
    assert reg_space.stats_dict()["events"] == sum(reg), (
        "kernel counters did not reach unified stats"
    )
    assert mem_space.stats_dict()["events"] == sum(mem), (
        "kernel counters did not reach unified stats"
    )


if __name__ == "__main__":
    main()
