"""Fig. 6 analogue: origin-traceability of protected operands, per arch.

Paper: >95 % of FP arithmetic instructions in SPEC binaries can be
back-traced to the ``mov`` that loaded the faulting operand, enabling
memory-origin repair; the rest fall back to (costlier) register-mode repair.

Here the program is a dataflow graph, so the measurement is structural
(core/provenance.py): the fraction of FLOP-carrying ops whose protected
operand reaches them through address-preserving ops only.  Measured over the
REDUCED config of every assigned architecture's forward pass with the
parameters marked protected.

CSV: name,us_per_call,derived  (the count column carries the percentage).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.core import provenance
from repro.data import batch_for_step
from repro.models import build_model


def run():
    rows = []
    for name, full in REGISTRY.items():
        cfg = full.reduced()
        model = build_model(cfg)
        batch = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            batch_for_step(cfg, jax.random.PRNGKey(0), 0, batch=2, seq=32),
        )
        params = model.abstract_params()
        report = provenance.analyze(
            lambda p, b: model.forward(p, b), [0], params, batch
        )
        rows.append((name, report))
    return rows


def main():
    print("# fig6_provenance: % of FLOP-carrying ops whose protected operand")
    print("# is repairable at its memory origin (paper: >95% on SPEC)")
    print("name,us_per_call,derived")
    for name, r in run():
        print(
            f"fig6_{name},{100.0 * r.fraction:.1f},"
            f"traceable={r.origin_traceable}/{r.total_arith}"
        )


if __name__ == "__main__":
    main()
