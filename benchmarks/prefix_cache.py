"""Prefix-cache bench: tokens/s, prefill-tokens-saved, and scrubbed-bytes/
token with and without the repair-aware prefix cache, across prefix-share
ratios.

The cache's two claims (README §Serving engine):

  1. *Sharing is free of error.*  At zero BER a cache-hit serve emits
     tokens bit-identical to the no-cache baseline — suffix prefill over
     shared pages reproduces the full-prefill stream exactly.
  2. *Dwell-charged scrub-on-reuse pays only for risk.*  Under injected
     BER the dwell gate (``ServingConfig.dwell_threshold``) scrubs a hit
     page only when its expected-fault estimate since the last scrub
     crosses the threshold, so scrubbed-bytes/token with the gate is no
     more than the always-scrub-on-hit arm (``dwell_threshold=0``).

Workload: ``N`` requests served as sequential waves (each wave completes
before the next is queued, so later waves hit the residue the earlier
ones left in the cache).  Every prompt shares its first
``ratio * prompt_len`` tokens with the others; the rest is per-request
random.  Ratios {0, 0.5, 0.9} span no-share → near-total-share.

CSV: name,us_per_call,derived — us_per_call is us/token (wall-clock);
derived carries prefill-tokens-saved, scrubbed-bytes/token, and the cache
counters (hits / reuse_scrubs / reuse_skips / cow_forks).  Asserted every
run: zero-BER cache arms match the no-cache token streams bit for bit at
every ratio, and at BER > 0 the gated arm both exercises the gate in each
direction (some skips, some scrubs) and comes in at or below the
always-scrub arm on scrubbed-bytes/token.

``main(out=...)`` merges a ``prefix_cache`` section into the shared bench
record (``benchmarks/run.py --out BENCH_repair.json``), validated by
``scripts/check_bench.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import ApproxConfig
from repro.serving import Engine, ServingConfig

RATIOS = (0.0, 0.5, 0.9)
SMOKE_RATIOS = (0.0, 0.9)

# f32 lanes per KV page of the bench model: n_layers × (k + v) × page_size
# × n_kv × head_dim — fixed here so the dwell threshold below can be
# stated in expected faults without building a pool first
_N_LAYERS, _N_KV, _HEAD_DIM, _PAGE_SIZE = 2, 2, 16, 4
_PAGE_BYTES = _N_LAYERS * 2 * _PAGE_SIZE * _N_KV * _HEAD_DIM * 4

# high enough that every page faults essentially every window (the probe
# and decode scrub traffic is then identical across arms, so the bytes
# comparison isolates the reuse-scrub policy itself)
BER = 2e-4

# gate at ~7 dwell steps (expected faults per page per step is
# page_bits × BER).  In-use pages scrub reactively every step at this
# BER, so dwell at reuse is set by the idle gap between waves: the BER
# section alternates short and long gaps around the threshold so the
# gate demonstrably skips cheap reuses AND scrubs long-dwelled ones
DWELL_THRESHOLD = 6.5 * _PAGE_BYTES * 8 * BER
IDLE_GAPS = (2, 9)


def _model():
    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        n_layers=_N_LAYERS, d_model=64, n_heads=4, n_kv=_N_KV,
        head_dim=_HEAD_DIM, d_ff=128, vocab=97,
        repair=ApproxConfig(mode="off"),   # the engine space owns repair
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(n: int, prompt_len: int, ratio: float) -> List[List[int]]:
    shared_len = int(round(ratio * prompt_len))
    shared = jax.random.randint(
        jax.random.PRNGKey(1), (shared_len,), 1, 96
    ).tolist()
    out = []
    for i in range(n):
        suffix = jax.random.randint(
            jax.random.PRNGKey(200 + i), (prompt_len - shared_len,), 1, 96
        ).tolist()
        out.append(shared + suffix)
    return out


def _serve(
    model, params, cfg: ServingConfig, prompts: List[List[int]],
    max_new: int, idle_gaps: Tuple[int, ...] = (),
) -> Tuple[Dict[str, float], Dict[int, List[int]]]:
    """Serve ``prompts`` as sequential waves; returns (row metrics, the
    per-request token streams).  ``idle_gaps`` cycles per wave: idle
    engine steps run after the wave, growing the cached pages' dwell (and
    accumulating injected faults) before the next wave reuses them."""
    engine = Engine(model, params, cfg)
    tokens: Dict[int, List[int]] = {}
    t0 = time.perf_counter()
    for i, prompt in enumerate(prompts):
        engine.add_request(prompt, max_new=max_new)
        for rid, res in engine.run().items():
            tokens[rid] = res["tokens"]
        if idle_gaps:
            for _ in range(idle_gaps[i % len(idle_gaps)]):
                engine.step()
    dt = time.perf_counter() - t0
    assert len(tokens) == len(prompts)
    m = engine.metrics()
    c = engine.cache_stats()
    row = {
        "us_per_token": 1e6 * dt / max(m["tokens_emitted"], 1),
        "tokens_emitted": m["tokens_emitted"],
        "prefill_tokens_saved": m["prefill_tokens_saved"],
        "scrubbed_bytes_per_token": m["scrubbed_bytes_per_token"],
        "hits": c.get("hits", 0),
        "hit_tokens": c.get("hit_tokens", 0),
        "cow_forks": c.get("cow_forks", 0),
        "reuse_scrubs": c.get("reuse_scrubs", 0),
        "reuse_ref_repairs": c.get("reuse_ref_repairs", 0),
        "reuse_skips": c.get("reuse_skips", 0),
        "evictions": c.get("evictions", 0),
    }
    return row, tokens


def run(smoke: bool = False):
    model, params = _model()
    n_requests, prompt_len, max_new = (4, 8, 3) if smoke else (8, 12, 4)
    base = ServingConfig(
        page_size=_PAGE_SIZE, n_pages=32, max_batch=4,
        max_pages_per_request=5, repair="page", paged_decode="off",
        sweep_interval=0, seed=7,
    )
    rows = []
    row_metrics = {}

    def record(name: str, row: Dict[str, float]) -> None:
        row_metrics[name] = row
        rows.append((
            name,
            row["us_per_token"],
            f"saved={row['prefill_tokens_saved']};"
            f"scrubbed_bytes_per_token={row['scrubbed_bytes_per_token']:.0f};"
            f"hits={row['hits']};hit_tokens={row['hit_tokens']};"
            f"cow={row['cow_forks']};reuse_scrubs={row['reuse_scrubs']};"
            f"ref_repairs={row['reuse_ref_repairs']};"
            f"skips={row['reuse_skips']}",
        ))

    # --- zero BER: the cache must be invisible in the token streams -------
    for ratio in SMOKE_RATIOS if smoke else RATIOS:
        prompts = _prompts(n_requests, prompt_len, ratio)
        baseline, base_tokens = _serve(
            model, params, base, prompts, max_new
        )
        record(f"share{ratio:g}_nocache", baseline)
        cached, cache_tokens = _serve(
            model, params,
            dataclasses.replace(
                base, prefix_cache=True, dwell_threshold=DWELL_THRESHOLD
            ),
            prompts, max_new,
        )
        record(f"share{ratio:g}_cached", cached)
        assert cache_tokens == base_tokens, (
            f"cache-hit serving drifted from the no-cache stream at "
            f"ratio {ratio}"
        )
        if ratio >= 0.5:
            assert cached["prefill_tokens_saved"] > 0, (
                f"shared prefixes at ratio {ratio} produced no cache reuse"
            )

    # --- injected BER: the dwell gate must not out-scrub always-on --------
    prompts = _prompts(n_requests, prompt_len, 0.9)
    faulty = dataclasses.replace(base, ber=BER, prefix_cache=True)
    always, _ = _serve(
        model, params,
        dataclasses.replace(faulty, dwell_threshold=0.0),
        prompts, max_new, idle_gaps=IDLE_GAPS,
    )
    record("ber_always_scrub", always)
    gated, _ = _serve(
        model, params,
        dataclasses.replace(faulty, dwell_threshold=DWELL_THRESHOLD),
        prompts, max_new, idle_gaps=IDLE_GAPS,
    )
    record("ber_gated_scrub", gated)
    n_always = always["reuse_scrubs"] + always["reuse_ref_repairs"]
    n_gated = gated["reuse_scrubs"] + gated["reuse_ref_repairs"]
    assert always["reuse_skips"] == 0 and n_always > 0, (
        "dwell_threshold=0 must scrub every hit"
    )
    assert gated["reuse_skips"] > 0 and n_gated > 0, (
        "the dwell gate should skip some reuses and scrub others on this "
        "workload"
    )
    assert (
        gated["scrubbed_bytes_per_token"]
        <= always["scrubbed_bytes_per_token"]
    ), "dwell-gated scrub-on-reuse must not scrub more bytes/token than " \
       "always-scrub-on-hit"
    return rows, row_metrics


def main(smoke: bool = False, out: Optional[str] = None):
    print("# prefix_cache: refcounted CoW prefix sharing over the KV pool;")
    print("# us_per_call is us/token; zero-BER cache arms must match the")
    print("# no-cache token streams; gated reuse-scrub must not exceed")
    print("# always-scrub-on-hit on scrubbed-bytes/token")
    print("name,us_per_call,derived")
    rows, row_metrics = run(smoke=smoke)
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
    if out:
        from ._record import merge_record

        merge_record(out, "prefix_cache", {
            "rows": row_metrics,
            "zero_ber_parity_ok": True,        # asserted above
            "gated_vs_always_bytes_ok": True,  # asserted above
        }, smoke=smoke)


if __name__ == "__main__":
    main()
