"""EDEN-style BER autopilot end to end (README §Autopilot).

Three layers, one story:

  1. **campaign** — group the state tree by path regex (here: FFN weights
     vs the KV cache), sweep a ladder of DRAM refresh points, and measure
     each group's quality degradation in isolation (injected episodes
     teacher-forced against the clean trajectory);
  2. **frontier** — pick the most aggressive refresh each group tolerates
     within one quality budget; a group that fails everywhere collapses to
     an exact-ECC island.  The solver emits the per-region refresh map, a
     concrete `RuleSet`, the expected-fault rates, and the byte-weighted
     energy saving;
  3. **guard** — deploy those expectations online: the serving engine (or
     train loop) watches per-rule fault counters per window and tightens a
     drifting group's rule with hysteresis — stricter detection first,
     exact-ECC demotion second.

Run:  PYTHONPATH=src python examples/autopilot.py
"""
import dataclasses

from repro.autopilot import run_campaign, solve_frontier
from repro.configs import get_preset


def main():
    # -- 1. the profiling campaign ---------------------------------------
    # the transformer preset: a tiny qwen2 with two region groups.  Keep
    # the sweep short for the demo — two refresh points, six decode steps.
    preset = get_preset("transformer", steps=6)
    preset = dataclasses.replace(
        preset,
        campaign=dataclasses.replace(
            preset.campaign, refresh_points=(1.0, 2.0)
        ),
    )
    print(f"profiling {preset.name!r}: "
          f"{[g.name for g in preset.campaign.groups]} x "
          f"{list(preset.campaign.refresh_points)} s refresh")
    profile = run_campaign(preset.build_model(), preset.campaign)
    for c in profile.cells:
        print(f"  {c.group:<12} refresh={c.refresh_s:>5.2f}s "
              f"ber={c.ber:.0e} quality={c.quality:.3f} "
              f"flips={c.flips} saving={c.energy_saving:.3f}")

    # -- 2. the frontier solve -------------------------------------------
    frontier = solve_frontier(profile, budget=preset.budget)
    print(f"\nbudget {preset.budget}: per-group assignment")
    for a in sorted(frontier.assignments, key=lambda a: a.group):
        tag = "EXACT ISLAND" if a.collapsed else f"{a.refresh_s:.2f}s"
        print(f"  {a.group:<12} -> {tag:<12} quality={a.quality:.3f} "
              f"expected_faults/step={a.expected_faults_per_step:.2f}")
    print(f"byte-weighted energy saving: {frontier.energy_saving:.3f}")

    # the artifacts are deployable objects, not a report: a refresh map,
    # a RuleSet, and the guard's expected-rate table
    print(f"refresh map: {frontier.refresh_map()}")
    print(f"rules: {[(p, r.label, r.exact) for p, r in frontier.ruleset().entries]}")
    auto = frontier.autopilot()
    print(f"guard expectations: {auto.expected}")

    # -- 3. the online guard ---------------------------------------------
    # serve with the solved ruleset, but simulate MORE faults than the
    # profile promised (a drifting DRAM module): the guard notices the
    # excess within a few windows and tightens the drifting group's rule.
    import jax

    from repro.models import build_model
    from repro.runtime import ApproxConfig
    from repro.serving import Engine, ServingConfig

    arch = dataclasses.replace(
        preset.arch,
        repair=ApproxConfig(mode="memory", rules=frontier.ruleset()),
    )
    model = build_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    cfg = ServingConfig(
        page_size=4, n_pages=16, max_batch=2, max_pages_per_request=8,
        repair="page", ber=2e-3, seed=0,       # ~100x the profiled BER
        paged_decode="off",   # gathered path: repairs land in rule counters
        # short windows + no slack so the drift shows within one request
        autopilot=dataclasses.replace(auto, window=2, patience=1, floor=0.0),
    )
    eng = Engine(model, params, cfg)
    eng.add_request(list(range(1, 9)), max_new=8)
    eng.run()
    print(f"\nserved under drift: autopilot_trips="
          f"{eng.metrics()['autopilot_trips']}")
    for trip in eng.guard.trips:
        print(f"  tightened {trip['label']!r}: {trip['action']} "
              f"(observed {trip['observed']} faults vs "
              f"threshold {trip['threshold']:.1f} in window {trip['window']})")


if __name__ == "__main__":
    main()
