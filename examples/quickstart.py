"""Quickstart: the paper's experiment in 60 seconds, on the `ApproxSpace` API.

Reproduces the core demonstration (paper §4 / Fig. 1 / Table 3):

  1. a single bit-flip NaN in a matrix operand poisons a whole output row;
  2. the fused-repair matmul kernel prevents it, pre-MXU, for free;
  3. register mode re-fires on every reuse, memory mode repairs the origin
     exactly once (Table 3) — and every event, jnp-level or fused-kernel,
     lands in ONE unified stats stream owned by the `ApproxSpace`.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.runtime import ApproxConfig, ApproxSpace


def main():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    n = 512
    a = jax.random.normal(k1, (n, n), jnp.float32)
    b = jax.random.normal(k2, (n, n), jnp.float32)

    # One runtime object owns regions, repair, injection, and stats.
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero", ber=1e-6))

    # -- 0. the simulation boundary --------------------------------------
    # inject() flips bits over the approximate region at the config's BER
    # and records the ground-truth count in the unified `flips` counter.
    _, flips = space.inject(a, jax.random.fold_in(key, 7), ber=1e-5)
    print(f"one approximate-memory window at BER 1e-5: {int(flips)} bit "
          f"flips (ground truth, recorded in unified stats)")

    # -- 1. the failure the paper describes ------------------------------
    # Force exactly one NaN pattern (paper §4 setup: a flip completing the
    # all-ones exponent) so the poisoning is deterministic.
    from repro.core import injection
    a_bad = injection.inject_nan(k3, a, 1)          # one flipped exponent
    c_poisoned = a_bad @ b
    n_nan = int(jnp.isnan(c_poisoned).sum())
    print(f"plain matmul with ONE NaN operand -> {n_nan} NaN outputs "
          f"({100.0 * n_nan / c_poisoned.size:.1f}% of the result)")

    # -- 2. reactive fused repair (kernel events -> unified stats) -------
    res = ops.repair_matmul(a_bad, b, mode="memory", policy="zero",
                            blocks=(128, 128, 256))
    space.record_kernel(res.counts)
    print(f"repair_matmul      -> finite: {bool(jnp.isfinite(res.c).all())}, "
          f"events: {int(res.counts[ops.MM_EV_TOTAL])}, "
          f"origin scrubbed: {not bool(jnp.isnan(res.a).any())}")

    # deviation from the clean product: bounded, amortizable drift
    err = float(jnp.max(jnp.abs(res.c - a @ b)))
    print(f"max |error| vs clean product: {err:.3f} "
          f"(bounded by the repaired lane's contribution)")

    # -- 3. Table 3: register vs memory over repeated consumption --------
    print("\nreuse  register-events  memory-events   (paper Table 3)")
    a_reg = a_mem = a_bad
    for i in range(4):
        r = ops.repair_matmul(a_reg, b, mode="register", blocks=(128, 128, 256))
        m = ops.repair_matmul(a_mem, b, mode="memory", blocks=(128, 128, 256))
        space.record_kernel(r.counts)
        space.record_kernel(m.counts)
        a_reg, a_mem = r.a, m.a
        print(f"  {i}        {int(r.counts[ops.MM_EV_TOTAL]):3d}             "
              f"{int(m.counts[ops.MM_EV_TOTAL]):3d}")
    print("\nregister mode pays on every reuse; memory mode paid once.")

    # -- 4. the memory-mode mechanism at the pytree level ----------------
    # scrub() is the same write-back the train step installs at its boundary.
    clean = space.scrub({"w": a_bad})
    print(f"space.scrub repaired the resident buffer: "
          f"{not bool(jnp.isnan(clean['w']).any())}")

    print(f"\nunified stats (flips + jnp + fused-kernel events in one "
          f"stream): {space.stats_dict()}")


if __name__ == "__main__":
    main()
