"""Serving-engine quickstart: continuous batching over a paged KV pool in
approximate memory, with page-granular reactive repair.

A mixed workload — more concurrent requests than the page pool can hold at
once — runs through the full lifecycle (admit -> prefill -> decode ->
finish, with preemption under page pressure) while bit flips strike the
pool between steps.  Repair granularity is the knob under study:

  --repair page    scrub only the faulted pages among those each step
                   touched (the paper's reactive design, page-granular)
  --repair whole   scrub the entire pool whenever anything faulted (the
                   pre-engine scrub_cache baseline)

Run:  PYTHONPATH=src python examples/serve_engine.py [--ber 1e-3] [--requests 8]
"""
import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.models import build_model
from repro.runtime import ApproxConfig
from repro.serving import Engine, ServingConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=10)
    ap.add_argument("--ber", type=float, default=1e-3)
    ap.add_argument("--repair", default="page", choices=["page", "whole", "off"])
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=97,
        repair=ApproxConfig(mode="off"),   # the engine space owns repair
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # pool deliberately smaller than worst-case demand: 8 requests of up to
    # 5 pages each over a 10-page pool — admission control + preemption live
    engine = Engine(
        model,
        params,
        ServingConfig(
            page_size=4, n_pages=10, max_batch=4, max_pages_per_request=5,
            repair=args.repair, ber=args.ber,
            sweep_interval=8, sweep_pages=2, seed=3,
        ),
    )
    rids = []
    for i in range(args.requests):
        prompt = jax.random.randint(
            jax.random.PRNGKey(i), (5 + i % 3,), 1, 96
        )
        rids.append(engine.add_request(prompt, max_new=args.max_new))

    t0 = time.time()
    results = engine.run()
    dt = time.time() - t0

    m = engine.metrics()
    d = engine.stats_dict()
    print(f"arch={cfg.name} repair={args.repair} BER={args.ber:g}")
    print(
        f"served {len(results)} requests / {m['tokens_emitted']} tokens in "
        f"{dt:.1f}s ({1000 * dt / max(m['tokens_emitted'], 1):.0f} ms/token); "
        f"preemptions={m['n_preemptions']}"
    )
    print(
        f"pool: flips={d['flips']} repairs nan={d['nan_found']} "
        f"inf={d['inf_found']} events={d['events']}"
    )
    print(
        f"repair: {m['scrub_calls']} scrub calls "
        f"({m['reactive_scrubs']} reactive, {m['sweep_scrubs']} sweep), "
        f"{m['scrubbed_bytes_per_token']:.0f} scrubbed bytes/token, "
        f"{m['hot_pages']} pages ever charged an event"
    )
    first = results[rids[0]]
    print(f"request 0 continuation: {first['generated']}")


if __name__ == "__main__":
    main()
