"""Heterogeneous protection with the `RepairRule` API (README §RepairRule).

One `RuleSet` expresses what used to take three deployments' worth of
config: optimizer state range-guarded and conservatively filled, KV-style
cache leaves NaN-only with cheap zero fill repaired reactively, and an
embedding table pinned to an ECC-like exact island — then the SAME rules
drive a boundary scrub, a reactive pass, and an injection window, with
per-rule counters in one ledger.

Run:  PYTHONPATH=src python examples/repair_rules.py
"""
import jax
import jax.numpy as jnp

from repro.core import stats as stats_lib
from repro.runtime import (
    ApproxConfig, ApproxSpace, Detector, RepairRule, RuleSet,
)


def main():
    rules = RuleSet((
        # optimizer moments: a flipped high exponent bit yields ~1e38 — a
        # legal float that destroys training.  Range-guard + tile-mean fill.
        (r"(^|/)opt(/|$)",
         RepairRule(detect=Detector(max_magnitude=1e3),
                    fill="neighbor_mean")),
        # KV pages: activations are not O(1), so NaN-only detection; zero
        # fill is fine (masked softmax lanes); repair reactively, not at
        # every step boundary.
        (r"(^|/)(k|v)(/|$)",
         RepairRule(detect=Detector(inf=False), fill="zero",
                    trigger="reactive")),
        # embeddings: "exact via stronger correction" as just another rule.
        (r"(^|/)embed(/|$)", RepairRule.exact_rule(label="embed-exact")),
    ))
    space = ApproxSpace(ApproxConfig(mode="memory", rules=rules, ber=1e-4))

    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    state = {
        "params": {"w": jax.random.normal(k1, (64, 64))},
        "opt": {"mu": jax.random.normal(k2, (64, 64))},
        "k": jax.random.normal(k3, (16, 64)),
        "embed": {"table": jnp.ones((32, 16))},
    }

    # one injection window — the exact island is never struck
    state, flips = space.inject(state, jax.random.fold_in(key, 1))
    print(f"injection window: {int(flips)} flips "
          f"(embed untouched: "
          f"{bool((state['embed']['table'] == 1.0).all())})")

    # poison representative lanes per protection class
    state["opt"]["mu"] = state["opt"]["mu"].at[0, 0].set(4e4)   # legal float!
    state["k"] = state["k"].at[1, 2].set(jnp.nan)
    state["params"]["w"] = state["params"]["w"].at[3, 3].set(jnp.inf)

    # boundary pass: the reactive KV rule holds its fire
    state, st = space.scrub(state, stats_lib.zeros(), trigger="boundary")
    print(f"boundary scrub: opt range-guard fired "
          f"(|mu[0,0]| now {abs(float(state['opt']['mu'][0, 0])):.3f}), "
          f"kv NaN still resident: {bool(jnp.isnan(state['k'][1, 2]))}")

    # reactive pass: now the KV rule repairs
    state, st = space.scrub(state, st, trigger="reactive")
    print(f"reactive pass: kv clean: "
          f"{bool(jnp.isfinite(state['k']).all())}")

    space.record(st)                 # fold the threaded stream back in
    print("\nper-rule ledger (one unified definition across passes):")
    for label, counters in space.rule_stats().items():
        print(f"  {label:24s} {counters}")
    print(f"aggregate stream: {space.stats_dict()}")


if __name__ == "__main__":
    main()
