"""Serving under approximate memory: batched greedy decoding with a
protected KV cache.

The KV cache is the dominant approximate-memory resident in serving
(DESIGN.md §4).  This example decodes a token batch while bit flips strike
the cache between steps, in two conditions:

  --repair register   every cache read repairs in-flight (per-step cost)
  --repair memory     reactive scrub of the cache when repairs fired
                      (one-shot, then clean — serving Table 3)

Run:  PYTHONPATH=src python examples/serve_approx.py [--tokens 48] [--ber 1e-6]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import repair as repair_lib
from repro.core import stats as stats_lib
from repro.core.regions import annotate
from repro.core.repair import RepairConfig
from repro.launch.serve import build_serve_step, scrub_cache
from repro.models import build_model


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--ber", type=float, default=1e-4)
    ap.add_argument("--repair", default="memory", choices=["register", "memory"])
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        repair=RepairConfig(mode=args.repair, policy="neighbor_mean",
                            max_magnitude=1e3),
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = args.tokens + 8

    cache = model.init_cache(args.batch, max_seq)
    region_tree = annotate(cache)
    step_fn = jax.jit(build_serve_step(model))
    stats = stats_lib.zeros()

    tok = jnp.ones((args.batch, 1), jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    n_scrubs = 0
    for t in range(args.tokens):
        # approximate-memory window strikes the resident cache (simulation)
        cache = repair_lib.inject_pytree(
            cache, jax.random.fold_in(jax.random.PRNGKey(9), t), args.ber,
            region_tree,
        )
        if args.repair == "memory":
            # reactive: scrub only when the previous step found something
            cache, stats2 = scrub_cache(model, cache, stats)
            fired = int(stats2["events"]) > int(stats["events"])
            n_scrubs += int(fired)
            stats = stats2
        nxt, logits, cache = step_fn(
            params, cache, {"tokens": tok}, jnp.asarray(t, jnp.int32)
        )
        assert bool(jnp.isfinite(logits).all()), "NaN reached the logits!"
        tok = nxt[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0

    seq = jnp.concatenate(out_tokens, axis=1)
    d = stats_lib.as_dict(stats)
    print(f"arch={cfg.name} repair={args.repair} BER={args.ber:g}")
    print(f"decoded {args.tokens} tokens x batch {args.batch} in {dt:.1f}s "
          f"({1000 * dt / args.tokens:.0f} ms/token)")
    print(f"cache repairs: nan={d['nan_found']} inf={d['inf_found']} "
          f"events={d['events']} scrub_passes={n_scrubs}")
    print(f"sample continuation (batch 0): {seq[0, :16].tolist()} ...")
    print("all logits finite: True")


if __name__ == "__main__":
    main()
