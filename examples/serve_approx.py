"""Serving under approximate memory: batched greedy decoding with a
protected KV cache, on the `ApproxSpace` API.

The KV cache is the dominant approximate-memory resident in serving
(README §Serving).  This example decodes a token batch while bit flips
strike the cache between steps, in two conditions:

  --repair register   every cache read repairs in-flight (per-step cost)
  --repair memory     reactive scrub of the cache when repairs fired
                      (one-shot, then clean — serving Table 3)

Run:  PYTHONPATH=src python examples/serve_approx.py [--tokens 48] [--ber 1e-6]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import build_serve_step, serve_space
from repro.models import build_model
from repro.runtime import ApproxConfig

from repro.core import stats as stats_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=48)
    ap.add_argument("--ber", type=float, default=1e-4)
    ap.add_argument("--repair", default="memory", choices=["register", "memory"])
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(),
        repair=ApproxConfig(mode=args.repair, policy="neighbor_mean",
                            max_magnitude=1e3, ber=args.ber),
    )
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = args.tokens + 8

    # One runtime object for the serving cache: regions cached by treedef,
    # injection + scrub + stats unified.  serve_space() memory-forces the
    # scrub path so a poisoned cache is repairable in both conditions.
    space = serve_space(model)
    cache = model.init_cache(args.batch, max_seq)
    step_fn = jax.jit(space.wrap_serve_step(build_serve_step(model)))
    stats = stats_lib.zeros()

    tok = jnp.ones((args.batch, 1), jnp.int32)
    out_tokens = [tok]
    t0 = time.time()
    n_scrubs = 0
    for t in range(args.tokens):
        # approximate-memory window strikes the resident cache (simulation);
        # the ground-truth flip count lands in the unified `flips` counter
        cache, _ = space.inject(
            cache, jax.random.fold_in(jax.random.PRNGKey(9), t), args.ber
        )
        if args.repair == "memory":
            # reactive: scrub only when the previous step found something
            cache, stats2 = space.scrub(cache, stats)
            fired = int(stats2["events"]) > int(stats["events"])
            n_scrubs += int(fired)
            stats = stats2
        nxt, logits, cache, stats = step_fn(
            params, cache, {"tokens": tok}, jnp.asarray(t, jnp.int32), stats
        )
        assert bool(jnp.isfinite(logits).all()), "NaN reached the logits!"
        tok = nxt[:, None]
        out_tokens.append(tok)
    dt = time.time() - t0
    space.record(stats)        # fold the loop's functional stream into the space

    seq = jnp.concatenate(out_tokens, axis=1)
    d = space.stats_dict()
    print(f"arch={cfg.name} repair={args.repair} BER={args.ber:g}")
    print(f"decoded {args.tokens} tokens x batch {args.batch} in {dt:.1f}s "
          f"({1000 * dt / args.tokens:.0f} ms/token)")
    print(f"cache: flips={d['flips']} repairs nan={d['nan_found']} "
          f"inf={d['inf_found']} events={d['events']} scrub_passes={n_scrubs}")
    print(f"sample continuation (batch 0): {seq[0, :16].tolist()} ...")
    print("all logits finite: True")


if __name__ == "__main__":
    main()
