"""End-to-end driver: train a ~100M-parameter LM under approximate memory.

Three conditions over the same data/seed (paper §4 structure, applied to a
full training loop instead of one matmul):

  --repair off       bit flips accumulate; the run NaN-poisons
  --repair register  per-use repair: survives, pays detect+select every read
  --repair memory    step-boundary scrub + write-back: survives, one repair
                     per flip (the paper's recommendation)

The approximate-memory window (BER) strikes params + optimizer moments
between steps (core/injection.py simulates the relaxed-refresh DRAM the
paper targets; see the refresh→BER→energy table in benchmarks/energy_model).

Run:  PYTHONPATH=src python examples/train_approx_lm.py \
          [--steps 300] [--ber 1e-7] [--repair memory] [--arch qwen2-1.5b]
"""
import argparse
import dataclasses
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data import SyntheticStream
from repro.launch.train import make_optimizer, train_loop
from repro.models import build_model
from repro.runtime import ApproxConfig, ApproxSpace


def build_100m(arch: str, repair_mode: str) -> "ArchConfig":
    """~100M-param variant of the chosen family (CPU-trainable)."""
    cfg = get_config(arch)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-100m",
        n_layers=min(cfg.n_layers, 8),
        d_model=768,
        n_heads=12,
        n_kv=min(cfg.n_kv, 4) if cfg.n_kv < cfg.n_heads else 8,
        head_dim=64,
        d_ff=3072 if cfg.d_ff else 0,
        vocab=32768,
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        dtype_name="float32",
        mamba_per_attn=2,
        slstm_every=4,
        repair=ApproxConfig(
            mode=repair_mode, policy="neighbor_mean", max_magnitude=1e3
        ),
        attn_q_block=128,
        attn_kv_block=128,
        ssm_chunk=64,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ber", type=float, default=1e-8)
    ap.add_argument("--repair", default="memory",
                    choices=["off", "register", "memory"])
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = build_100m(args.arch, args.repair)
    model = build_model(cfg)
    print(f"arch={cfg.name}  params={model.param_count():,}  "
          f"repair={args.repair}  BER={args.ber:g}")

    opt = make_optimizer(peak_lr=1e-3, warmup=20, total=args.steps)
    data = SyntheticStream(cfg, seed=0, batch=args.batch, seq=args.seq)
    mgr = CheckpointManager(args.ckpt_dir, keep=2, scrub=True)

    # One ApproxSpace owns the run: boundary scrub inside the jitted step,
    # injection window between steps, regions cached by treedef, one stats
    # stream (incl. the injection ground truth in `flips`).
    space = ApproxSpace(cfg.repair, ber=args.ber)

    t0 = time.time()
    state, hist = train_loop(
        model, opt, data,
        steps=args.steps,
        key=jax.random.PRNGKey(0),
        ber=args.ber,
        checkpoint_manager=mgr,
        checkpoint_every=args.ckpt_every,
        log_every=10,
        space=space,
    )
    dt = time.time() - t0

    print(f"\n{'step':>6} {'loss':>9} {'acc':>7} {'flips':>7} "
          f"{'repairs(nan/inf)':>18}")
    for h in hist:
        print(f"{h['step']:>6} {h['loss']:>9.4f} {h['accuracy']:>7.4f} "
              f"{h['flips']:>7} {h['nan_found']:>9}/{h['inf_found']}")
    print(f"\n{args.steps} steps in {dt:.1f}s "
          f"({1000 * dt / args.steps:.0f} ms/step); "
          f"final checkpoint: step {mgr.latest_step()}")


if __name__ == "__main__":
    main()
