"""Tiered KV: host-memory exact tier with repair-at-the-boundary swap.

Covers the PR acceptance contract: swap round trips are bit-identical at
BER=0; under injected flips a swapped-in page equals the detector-scrubbed
device page (the boundary scrub IS the reactive detector pass) with every
crossing ledgered through ``ApproxSpace.scrubbed_bytes``; host copies
survive device-page recycling and shared refcounts (the PR-6 double-free
discipline extends to the host tier); preemption storms produce identical
tokens whether victims swap or recompute, with the swap arm re-prefilling
zero tokens; a full host store falls back to recompute without deadlock;
and prefix-cache eviction demotes through — and promotes back from — the
host tier.
"""
import jax
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.core import stats as stats_lib
from repro.runtime import ApproxSpace
from repro.serving import (
    Engine,
    PagedKVPool,
    ServingConfig,
    TierManager,
)


@pytest.fixture(scope="module")
def model_params():
    return tiny_transformer()


def _cfg(**kw):
    base = dict(page_size=4, n_pages=10, max_batch=4,
                max_pages_per_request=5, seed=3)
    base.update(kw)
    return ServingConfig(**base)


def _tiers(model, **kw):
    space = ApproxSpace(mode="memory")
    cfg = _cfg(host_pages=kw.pop("host_pages", 6), **kw)
    pool = PagedKVPool(model, space, cfg)
    return pool, space, TierManager(pool, space, cfg)


def _random_views(pool, pages, seed):
    """A pool-shaped views tree (leading axis = len(pages)) of finite
    random rows — distinct per seed, so recycled pages are detectably
    overwritten."""
    template = pool.pages_view(pages)
    leaves, treedef = jax.tree.flatten(template)
    keys = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    fresh = [
        np.asarray(jax.random.normal(k, leaf.shape, leaf.dtype))
        for k, leaf in zip(keys, leaves)
    ]
    return jax.tree.unflatten(treedef, fresh)


def _assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------------- config
def test_tier_config_validation():
    with pytest.raises(ValueError, match="swap_policy"):
        ServingConfig(swap_policy="parachute")
    with pytest.raises(ValueError, match="host_pages"):
        ServingConfig(host_pages=-1)
    # host DRAM is the cheap tier: it may exceed the device pool
    assert ServingConfig(n_pages=8, host_pages=64).host_pages == 64


# -------------------------------------------------------------- round trips
def test_swap_round_trip_bit_identical_at_zero_ber(model_params):
    """swap_out -> device churn -> swap_in restores the exact bits: the
    boundary scrub over clean pages is the identity, and host copies are
    independent of the device pages they came from."""
    model, _ = model_params
    pool, space, tiers = _tiers(model)
    pages = pool.alloc(3)
    pool.write_pages(pages, _random_views(pool, pages, seed=1))
    before = pool.pages_view(pages)

    handle = tiers.swap_out(pages)
    assert handle is not None and handle.n_pages == 3
    pool.free(pages)
    # churn: recycle the freed pages under different contents, then free
    churn = pool.alloc(5)
    pool.write_pages(churn, _random_views(pool, churn, seed=2))
    pool.free(churn)

    fresh = pool.alloc(3)
    tiers.swap_in(handle, fresh)
    _assert_trees_equal(before, pool.pages_view(fresh))
    assert tiers.host.n_used == 0                 # slots came back
    assert tiers.swap_outs == tiers.swap_ins == 1
    assert tiers.swapped_pages_out == tiers.swapped_pages_in == 3


def test_swap_in_equals_detector_scrubbed_page_under_flips(model_params):
    """The boundary-scrub invariant: write the SAME poisoned rows into two
    pages, round-trip one through the tier, scrub the other directly —
    bit-identical results, and the crossing is ledgered per tier AND in
    ``ApproxSpace.scrubbed_bytes``."""
    model, _ = model_params
    pool, space, tiers = _tiers(model)
    p0, p1 = pool.alloc(2)
    poisoned = jax.tree.map(
        lambda v: np.array(v), _random_views(pool, [p0], seed=3)
    )
    for leaf in jax.tree.leaves(poisoned):
        leaf[0, 0, 1, 0, 3] = np.nan
        leaf[0, 1, 0, 1, 0] = np.inf
    pool.write_pages([p0], poisoned)
    pool.write_pages([p1], poisoned)
    pool.now = 7                                  # accumulated dwell
    assert pool.dwell(p0) == 7

    handle = tiers.swap_out([p0])
    pool.free([p0])
    fresh = pool.alloc(1)
    tiers.swap_in(handle, fresh)

    pool.scrub_pages([p1], stats_lib.zeros(), trigger="boundary")
    swapped = pool.pages_view(fresh)
    scrubbed = pool.pages_view([p1])
    _assert_trees_equal(swapped, scrubbed)
    for leaf in jax.tree.leaves(swapped):
        assert np.isfinite(np.asarray(leaf)).all()

    # ledger: the tier charged exactly one page row, mirrored globally
    assert tiers.boundary_scrub_bytes > 0
    assert pool.scrubbed_bytes >= tiers.boundary_scrub_bytes
    assert space.scrubbed_bytes >= tiers.boundary_scrub_bytes
    # the boundary pass's findings reached the unified stats
    d = stats_lib.as_dict(space.stats)
    assert d["nan_found"] >= 2 and d["inf_found"] >= 2
    # dwell restarts from a known-clean state after swap-in
    assert pool.dwell(fresh[0]) == 0


def test_host_copy_survives_recycling_and_shared_refcounts(model_params):
    """Satellite: freeing (or re-writing) the device page after swap-out
    must never invalidate the host copy, and the PR-6 refcount discipline
    still holds around a swap."""
    model, _ = model_params
    pool, space, tiers = _tiers(model)
    (page,) = pool.alloc(1)
    pool.write_pages([page], _random_views(pool, [page], seed=5))
    expected = pool.pages_view([page])

    pool.share([page])                            # a second holder
    handle = tiers.swap_out([page])
    pool.free([page])                             # rc 1 — still resident
    assert not pool.is_free(page)
    # the surviving holder keeps writing: host copy must be unaffected
    pool.write_pages([page], _random_views(pool, [page], seed=6))
    pool.free([page])                             # rc 0 — recycled
    assert pool.is_free(page)
    with pytest.raises(RuntimeError, match="double free"):
        pool.free([page])

    fresh = pool.alloc(1)                         # the normal alloc path
    tiers.swap_in(handle, fresh)
    _assert_trees_equal(expected, pool.pages_view(fresh))


# ------------------------------------------------------------------- guards
def test_host_store_and_pool_guards(model_params):
    model, _ = model_params
    pool, space, tiers = _tiers(model, host_pages=2)
    pages = pool.alloc(3)
    one = pool.pages_view([pages[2]])

    # oversize swap-out declines and counts the fallback
    assert tiers.swap_out(pages) is None
    assert tiers.recompute_fallbacks == 1

    handle = tiers.swap_out(pages[:2])
    assert handle is not None and tiers.host.n_free == 0
    with pytest.raises(RuntimeError, match="host store full"):
        tiers.host.put(one, 1)
    assert tiers.demote_page(pages[2]) is None    # cache path declines too
    assert tiers.stash_views(one) is None

    tiers.host.free(handle.slots)
    with pytest.raises(RuntimeError, match="double free"):
        tiers.host.free(handle.slots)
    with pytest.raises(RuntimeError, match="freed host slot"):
        tiers.host.get(handle.slots)
    with pytest.raises(ValueError, match="bad host slot"):
        tiers.host.free([99])

    # device-side mirror: writing into a freed/bad page is a hard error
    pool.free(pages)
    with pytest.raises(RuntimeError, match="free page"):
        pool.write_pages([pages[2]], one)
    with pytest.raises(ValueError, match="bad page"):
        pool.write_pages([pool.null_page + 1], one)


# ------------------------------------------------------------------- engine
def _storm_engine(model, params, **kw):
    """8 staggered-length requests over a 10-page pool: page pressure
    guarantees preemptions (the PR-5/6 storm workload)."""
    eng = Engine(model, params, _cfg(
        sweep_interval=8, sweep_pages=2, **kw
    ))
    for i in range(8):
        prompt = jax.random.randint(jax.random.PRNGKey(i), (5 + i % 3,), 1, 96)
        eng.add_request(prompt, max_new=6)
    return eng


def test_preemption_storm_swap_matches_recompute_tokens(model_params):
    """Token parity between the swap and recompute arms at BER=0, with the
    swap arm re-prefilling ZERO tokens — the cost swap-out exists to
    avoid — and every crossing ledgered."""
    model, params = model_params
    swap = _storm_engine(model, params, host_pages=12)
    res_s = swap.run()
    rec = _storm_engine(model, params, host_pages=0)
    res_r = rec.run()

    assert rec.sched.n_preemptions > 0            # the storm really hit
    assert rec.prefill_tokens_recomputed > 0
    assert rec.tier_stats() == {
        "enabled": False, "swap_policy": "swap",
        "n_swap_preemptions": 0,
        "prefill_tokens_recomputed": rec.prefill_tokens_recomputed,
    }

    ts = swap.tier_stats()
    assert ts["enabled"] and ts["n_swap_preemptions"] > 0
    assert ts["swap_outs"] == ts["swap_ins"] > 0
    assert ts["swapped_pages_out"] == ts["swapped_pages_in"] > 0
    assert ts["host_used"] == 0                   # every parked page returned
    assert ts["recompute_fallbacks"] == 0
    assert swap.prefill_tokens_recomputed == 0
    assert ts["boundary_scrub_bytes"] > 0
    assert swap.pool.scrubbed_bytes >= ts["boundary_scrub_bytes"]
    assert swap.space.scrubbed_bytes >= ts["boundary_scrub_bytes"]

    for rid in res_s:
        assert res_s[rid]["tokens"] == res_r[rid]["tokens"]


def test_swap_policy_recompute_keeps_pre_tier_preemption(model_params):
    """swap_policy="recompute" with a host store is the comparison arm:
    preemption drops pages exactly as before tiers existed."""
    model, params = model_params
    eng = _storm_engine(model, params, host_pages=12,
                        swap_policy="recompute")
    eng.run()
    ts = eng.tier_stats()
    assert ts["enabled"] and ts["n_swap_preemptions"] == 0
    assert ts["swap_outs"] == 0 and ts["swap_ins"] == 0
    assert eng.sched.n_preemptions > 0
    assert eng.prefill_tokens_recomputed > 0


def test_host_store_full_falls_back_to_recompute(model_params):
    """A one-slot host store cannot hold any multi-page victim: every
    preemption falls back to recompute, the run still terminates, and
    tokens match the pure-recompute arm."""
    model, params = model_params
    tiny = _storm_engine(model, params, host_pages=1)
    res_t = tiny.run()                            # no deadlock
    rec = _storm_engine(model, params, host_pages=0)
    res_r = rec.run()

    ts = tiny.tier_stats()
    assert ts["recompute_fallbacks"] > 0
    assert ts["n_swap_preemptions"] == 0 and ts["swap_outs"] == 0
    assert tiny.prefill_tokens_recomputed == rec.prefill_tokens_recomputed
    for rid in res_t:
        assert res_t[rid]["tokens"] == res_r[rid]["tokens"]


# ------------------------------------------------------------- prefix cache
def test_cache_demotes_and_promotes_through_host_tier(model_params):
    """LRU eviction demotes cold entries to the host tier; a later hit on
    the demoted prefix promotes the pages back through the normal alloc
    path and still skips the prefix prefill — token-identical to the
    no-cache engine at BER=0."""
    model, params = model_params
    shared_a = [1, 2, 3, 4, 5, 6, 7, 8]
    shared_b = [11, 12, 13, 14, 15, 16, 17, 18]
    prompts = [
        shared_a + [9],
        shared_b + [19],                          # insert evicts A's pages
        shared_a + [10],                          # ... which promote back
    ]

    def run(cfg):
        eng = Engine(model, params, cfg)
        outs = []
        for p in prompts:
            rid = eng.add_request(p, max_new=3)
            eng.run()
            outs.append(eng.results[rid]["tokens"])
        return eng, outs

    tiered, toks_t = run(_cfg(n_pages=16, prefix_cache=True,
                              max_cached_pages=2, host_pages=8))
    plain, toks_p = run(_cfg(n_pages=16))
    assert toks_t == toks_p

    s = tiered.cache_stats()
    assert s["demotions"] > 0 and s["promotions"] > 0
    assert s["evictions"] > 0
    assert tiered.prefill_tokens_saved > 0
    ts = tiered.tier_stats()
    assert ts["demotions"] == s["demotions"]
    assert ts["promotions"] == s["promotions"]
