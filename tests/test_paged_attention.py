"""Paged-attention Pallas kernel + the fused serving decode path.

Covers the PR acceptance contract: kernel-vs-oracle parity (values allclose,
per-page fatal counters bit-exact) including injected NaN/Inf pages and
null-page tail masking; `Attention.paged_decode` parity with the gathered
`decode`; engine-level — fused decode issues ZERO full-view pool copies
while tokens, stats, byte accounting, and the per-page fault ledger stay
identical to the PR-4 gathered path under injected bit-flips; plan-level —
the `kernel` placement lowers tree scrubs through the Pallas kernels with
bit parity against the jnp path.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.core import rules as rules_lib
from repro.core import stats as stats_lib
from repro.kernels import paged_attention as pa
from repro.kernels import ref
from repro.runtime import ApproxConfig, ApproxSpace
from repro.serving import Engine, ServingConfig


# ------------------------------------------------------------------ kernel
def _pool(key, P=9, L=2, pg=4, Kh=2, Dh=16):
    k1, k2 = jax.random.split(key)
    k_pages = jax.random.normal(k1, (P, L, pg, Kh, Dh), jnp.float32)
    v_pages = jax.random.normal(k2, (P, L, pg, Kh, Dh), jnp.float32)
    return k_pages, v_pages


@pytest.mark.parametrize("policy,constant", [("zero", 0.0), ("constant", 0.5)])
def test_kernel_matches_oracle_with_poisoned_pages(policy, constant):
    key = jax.random.PRNGKey(0)
    k_pages, v_pages = _pool(key)
    q = jax.random.normal(jax.random.fold_in(key, 1), (3, 4, 16), jnp.float32)
    # poison pages the block tables reference AND one they do not
    k_pages = k_pages.at[2, 1, 1, 0, 3].set(jnp.nan)
    v_pages = v_pages.at[5, 1, 0, 1, 0].set(jnp.inf)
    k_pages = k_pages.at[7, 1, 0, 0, 0].set(jnp.nan)   # unreferenced page
    bt = jnp.asarray([[0, 2, 8], [5, 8, 8], [8, 8, 8]], jnp.int32)
    pos = jnp.asarray([9, 5, 0], jnp.int32)

    out, page_counts, counts = pa.paged_attention(
        q, k_pages, v_pages, bt, pos, layer=1,
        policy=policy, constant=constant,
    )
    ref_out, slot = ref.paged_attention_ref(
        q, k_pages, v_pages, bt, pos, layer=1,
        policy=policy, constant=constant,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_out), atol=1e-5
    )
    ref_pages = np.zeros(9, np.int64)
    np.add.at(ref_pages, np.asarray(bt), np.asarray(slot))
    np.testing.assert_array_equal(np.asarray(page_counts), ref_pages)
    # fatal pages 2 (NaN-K) and 5 (Inf-V) detected; unreferenced page 7 not
    assert int(page_counts[2]) == 1 and int(page_counts[5]) == 1
    assert int(page_counts[7]) == 0
    # AT_* layout totals
    assert int(counts[pa.NAN_K]) == 1 and int(counts[pa.INF_V]) == 1
    assert int(counts[pa.EV_TOTAL]) == 2


def test_kernel_per_operand_fills_match_oracle():
    """Per-tile operand-indexed fill selection: K repairs with zero, V with
    a constant — one kernel call, bit-exact against the oracle given the
    same per-operand fills."""
    key = jax.random.PRNGKey(11)
    k_pages, v_pages = _pool(key)
    q = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 16), jnp.float32)
    k_pages = k_pages.at[2, 1, 1, 0, 3].set(jnp.nan)
    v_pages = v_pages.at[5, 1, 0, 1, 0].set(jnp.inf)
    bt = jnp.asarray([[0, 2, 8], [5, 8, 8]], jnp.int32)
    pos = jnp.asarray([9, 3], jnp.int32)

    out, page_counts, counts = pa.paged_attention(
        q, k_pages, v_pages, bt, pos, layer=1,
        policy_k="zero", constant_k=0.0,
        policy_v="constant", constant_v=0.75,
    )
    ref_out, slot = ref.paged_attention_ref(
        q, k_pages, v_pages, bt, pos, layer=1,
        policy_k="zero", constant_k=0.0,
        policy_v="constant", constant_v=0.75,
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out), atol=1e-5)
    assert int(page_counts[2]) == 1 and int(page_counts[5]) == 1
    # a mixed-fill call must differ from the all-zero-fill one on the V
    # operand (the Inf lane sits at a position the second request attends)
    out_zero, _, _ = pa.paged_attention(
        q, k_pages, v_pages, bt, pos, layer=1, policy="zero",
    )
    assert not np.allclose(np.asarray(out), np.asarray(out_zero))


def test_kernel_null_tail_masking():
    """Null-padded tail slots must not influence the output: garbage (even
    huge finite values) parked in the null page stays masked by position."""
    key = jax.random.PRNGKey(3)
    k_pages, v_pages = _pool(key, P=5, L=1, pg=4)
    null = 4
    k_pages = k_pages.at[null].set(1e9)
    v_pages = v_pages.at[null].set(-1e9)
    q = jax.random.normal(jax.random.fold_in(key, 1), (1, 4, 16), jnp.float32)
    pos = jnp.asarray([6], jnp.int32)                # 7 valid positions

    bt_padded = jnp.asarray([[1, 2, null]], jnp.int32)
    out_p, _, _ = pa.paged_attention(
        q, k_pages, v_pages, bt_padded, pos, layer=0, policy="zero",
    )
    # oracle over only the real pages (no padding at all)
    out_ref, _ = ref.paged_attention_ref(
        q, k_pages, v_pages, jnp.asarray([[1, 2]], jnp.int32), pos,
        layer=0, policy="zero",
    )
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_ref), atol=1e-5
    )


def test_kernel_detector_none_is_bit_transparent():
    """A ``None`` detector row disables repair entirely: NaNs flow through
    (the exact-region / non-reactive-rule case) and nothing is counted."""
    key = jax.random.PRNGKey(4)
    k_pages, v_pages = _pool(key, P=4, L=1)
    k_pages = k_pages.at[1, 0, 0, 0, 0].set(jnp.nan)
    q = jax.random.normal(jax.random.fold_in(key, 9), (1, 4, 16), jnp.float32)
    bt = jnp.asarray([[1, 3]], jnp.int32)
    pos = jnp.asarray([5], jnp.int32)
    out, page_counts, counts = pa.paged_attention(
        q, k_pages, v_pages, bt, pos, layer=0,
        detector_k=None, detector_v=None,
    )
    assert int(np.asarray(page_counts).sum()) == 0
    assert int(np.asarray(counts).sum()) == 0
    assert not bool(jnp.isfinite(out).all())         # the NaN was consumed


def test_paged_decode_matches_gathered_decode():
    """`Attention.paged_decode` == `Attention.decode` over the gathered view
    on clean pools: same new-KV write, same tokens-level context math."""
    from repro.nn import module as nn_module
    from repro.nn.attention import Attention

    attn = Attention(
        d_model=32, n_heads=4, n_kv=2, head_dim=8, dtype=jnp.float32,
    )
    params = nn_module.init_params(attn.defs(), jax.random.PRNGKey(0))
    B, pg, M, P, L = 2, 4, 3, 7, 1
    null = P - 1
    key = jax.random.PRNGKey(7)
    k_pages = jax.random.normal(key, (P, L, pg, 2, 8), jnp.float32)
    v_pages = jax.random.normal(
        jax.random.fold_in(key, 1), (P, L, pg, 2, 8), jnp.float32
    )
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, 1, 32), jnp.float32)
    bt = np.asarray([[0, 2, null], [4, null, null]], np.int32)
    pos = np.asarray([6, 2], np.int32)

    out_p, kp, vp, slot, counts = attn.paged_decode(
        params, x, k_pages, v_pages, jnp.asarray(bt), jnp.asarray(pos),
        jnp.zeros((), jnp.int32), policy="zero",
        detector_k=rules_lib.Detector(), detector_v=rules_lib.Detector(),
    )

    # gathered reference: build the contiguous per-request view by hand
    def gather(leaf):
        v = leaf[bt][:, :, 0]                       # (B, M, pg, K, Dh)
        return v.reshape(B, M * pg, 2, 8)

    cache = {"k": gather(k_pages), "v": gather(v_pages)}
    out_g, new_cache = attn.decode(params, x, cache, jnp.asarray(pos))
    np.testing.assert_allclose(
        np.asarray(out_p), np.asarray(out_g), atol=1e-5
    )
    # the single-slot write landed where the gathered path wrote it
    for b in range(B):
        page, off = bt[b][pos[b] // pg], pos[b] % pg
        np.testing.assert_allclose(
            np.asarray(kp[page, 0, off]),
            np.asarray(new_cache["k"][b, pos[b]]),
            atol=1e-6,
        )


# ------------------------------------------------------------------ engine
@pytest.fixture(scope="module")
def model_params():
    return tiny_transformer()


def _engine(model, params, *, ber, repair="page", seed=3, max_new=6):
    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=10, max_batch=4, max_pages_per_request=5,
        repair=repair, ber=ber, sweep_interval=8, sweep_pages=2, seed=seed,
    ))
    for i in range(8):
        prompt = jax.random.randint(jax.random.PRNGKey(i), (5 + i % 3,), 1, 96)
        eng.add_request(prompt, max_new=max_new)
    return eng


def test_engine_decode_issues_zero_pool_copies(model_params):
    """The acceptance criterion: with the full kernel family engaged the
    engine never gathers/scatters a full view — admission, prefill AND
    decode all run straight off the pool."""
    model, params = model_params
    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=8, max_batch=2, max_pages_per_request=4,
    ))
    assert eng.paged_plan is not None and eng._paged_fn is not None
    assert eng._prefill_fn is not None
    rid = eng.add_request([5, 6, 7], max_new=8)
    results = eng.run()
    assert len(results[rid]["generated"]) == 8
    assert eng.pool.n_gathers == 0
    assert eng.pool.n_scatters == 0
    assert eng.metrics()["paged_decode"] is True
    assert eng.metrics()["paged_prefill"] is True


def test_fused_path_bit_identical_to_gathered_under_flips(model_params):
    """Tokens, unified stats, scrubbed bytes, and the per-page fault ledger
    of the fused path are identical to the PR-4 gathered path under the
    same injected bit-flips (same seed => same fault exposure)."""
    model, params = model_params
    fused = _engine(model, params, ber=1e-3)
    assert fused._paged_fn is not None
    res_f = fused.run()

    legacy = _engine(model, params, ber=1e-3)
    legacy._paged_fn = None                      # force the gathered path
    res_g = legacy.run()

    assert fused.stats_dict()["events"] > 0      # faults actually fired
    for rid in res_f:
        assert res_f[rid]["tokens"] == res_g[rid]["tokens"]
    assert fused.stats_dict() == legacy.stats_dict()
    assert fused.pool.scrubbed_bytes == legacy.pool.scrubbed_bytes
    np.testing.assert_array_equal(
        fused.pool.page_events, legacy.pool.page_events
    )
    # and the fused engine really skipped the decode copies
    assert fused.pool.n_gathers < legacy.pool.n_gathers


def test_fused_eligibility_falls_back(model_params):
    """Configurations the kernel cannot reproduce bit-for-bit keep the
    gathered path: neighbor_mean fill, repair="off"."""
    model, params = model_params
    cfg = ServingConfig(page_size=4, n_pages=8, max_batch=2,
                        max_pages_per_request=4)
    nm = Engine(model, params, cfg, space=ApproxSpace(
        ApproxConfig(mode="memory", policy="neighbor_mean",
                     max_magnitude=None)
    ))
    assert nm.paged_plan is None
    off = Engine(model, params, dataclasses.replace(cfg, repair="off"))
    assert off.paged_plan is None
    # and the fallback still serves correctly
    rid = nm.add_request([4, 5], max_new=3)
    assert len(nm.run()[rid]["generated"]) == 3


def test_fused_respects_reactive_rule_gating(model_params):
    """A pool rule that never fires reactively gets a ``None`` detector in
    the fused plan — the kernel reads it bit-transparently, matching the
    probe gate of ``pool.fatal_pages``."""
    model, params = model_params
    rules = rules_lib.RuleSet(entries=(
        (r".*", rules_lib.RepairRule(fill="zero", trigger="on-read")),
    ))
    eng = Engine(
        model, params,
        ServingConfig(page_size=4, n_pages=8, max_batch=2,
                      max_pages_per_request=4),
        space=ApproxSpace(ApproxConfig(mode="memory", rules=rules)),
    )
    assert eng.paged_plan is not None
    assert all(d is None for d in eng.paged_plan.detectors.values())


def test_mixed_fill_ruleset_stays_fused(model_params):
    """A RuleSet whose K and V rules fill differently no longer forces the
    gathered fallback: the plan carries per-leaf fills and the fused path
    stays token-identical to the gathered one under injected flips."""
    model, params = model_params
    rules = rules_lib.RuleSet(entries=(
        (r".*/k$", rules_lib.RepairRule(fill="zero")),
        (r".*", rules_lib.RepairRule(fill=0.5)),
    ))

    def build():
        eng = Engine(
            model, params,
            ServingConfig(page_size=4, n_pages=10, max_batch=4,
                          max_pages_per_request=5, ber=1e-3, seed=3,
                          sweep_interval=8, sweep_pages=2),
            space=ApproxSpace(ApproxConfig(mode="memory", rules=rules)),
        )
        for i in range(8):
            prompt = jax.random.randint(
                jax.random.PRNGKey(i), (5 + i % 3,), 1, 96
            )
            eng.add_request(prompt, max_new=6)
        return eng

    fused = build()
    assert fused.paged_plan is not None and fused._paged_fn is not None
    assert fused.paged_plan.fills == {
        "k": ("zero", 0.0), "v": ("constant", 0.5),
    }
    res_f = fused.run()

    legacy = build()
    legacy._paged_fn = None                      # force the gathered path
    res_g = legacy.run()

    assert fused.stats_dict()["events"] > 0      # mixed fills actually fired
    for rid in res_f:
        assert res_f[rid]["tokens"] == res_g[rid]["tokens"]
    assert fused.stats_dict() == legacy.stats_dict()


# ----------------------------------------------------------- plan placement
def test_kernel_placement_bit_parity(monkeypatch):
    """REPRO_KERNEL_PLANS=1 routes tree-scope scrubs through the Pallas
    kernels (interpret mode on CPU) with values and stats bit-identical to
    the jnp lowering; non-representable fills keep the jnp path."""
    tree = {
        "w": jnp.ones((16, 32)).at[3, 4].set(jnp.nan).at[0, 1].set(jnp.inf),
        "mu": jnp.ones((8, 8)).at[2, 2].set(jnp.nan),
        "step": jnp.zeros((), jnp.int32),
    }
    monkeypatch.setenv("REPRO_KERNEL_PLANS", "1")
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    plan = space.plan_for(tree, scope="tree")
    assert plan.placement == "kernel"
    out, stats = space.scrub(tree, stats_lib.zeros())

    monkeypatch.setenv("REPRO_KERNEL_PLANS", "0")
    ref_space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    assert ref_space.plan_for(tree, scope="tree").placement == "local"
    ref_out, ref_stats = ref_space.scrub(tree, stats_lib.zeros())

    for k in ("w", "mu"):
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.asarray(ref_out[k])
        )
    assert stats_lib.as_dict(stats) == stats_lib.as_dict(ref_stats)
    # per-rule ledgers agree too
    assert space.rule_stats() == ref_space.rule_stats()

    # neighbor_mean has no bit-identical kernel analogue -> jnp fallback
    monkeypatch.setenv("REPRO_KERNEL_PLANS", "1")
    nm = ApproxSpace(ApproxConfig(mode="memory", policy="neighbor_mean"))
    assert nm.plan_for(tree, scope="tree").placement == "local"
