"""Fused chunked-mLSTM Pallas kernel vs the jnp oracle (nn/xlstm.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import injection
from repro.kernels.mlstm_chunk import mlstm_chunked
from repro.nn.xlstm import _chunked_mlstm


def make(B, S, H, P, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (B, S, H, P), jnp.float32) / P ** 0.5
    k = jax.random.normal(ks[1], (B, S, H, P), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, P), jnp.float32)
    log_i = jax.random.normal(ks[3], (B, S, H)) * 0.5
    log_f = jax.nn.log_sigmoid(jax.random.normal(ks[4], (B, S, H)) + 2.0)
    return q, k, v, log_i, log_f


@pytest.mark.parametrize("dims,chunk", [
    ((2, 256, 2, 64), 64),
    ((1, 128, 4, 128), 32),
    ((2, 128, 1, 32), 128),     # single chunk (no carry)
])
def test_matches_oracle(dims, chunk):
    q, k, v, li, lf = make(*dims, seed=sum(dims))
    ref = _chunked_mlstm(q, k, v, li, lf, chunk=chunk)
    got, counts = mlstm_chunked(q, k, v, li, lf, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)
    assert counts.tolist()[:7] == [0] * 7


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtype_sweep(dtype):
    q, k, v, li, lf = make(1, 128, 2, 64, seed=7)
    q, k, v = (t.astype(dtype) for t in (q, k, v))
    ref = _chunked_mlstm(q, k, v, li, lf, chunk=32)
    got, _ = mlstm_chunked(q, k, v, li, lf, chunk=32)
    tol = 3e-2 if dtype == jnp.bfloat16 else 5e-4
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=tol, atol=tol)


def test_repairs_poisoned_kv_state_stays_clean():
    """A NaN in k would reach the carried (P,P) state and poison every
    future chunk (temporal Fig. 1) — the fused repair prevents it."""
    q, k, v, li, lf = make(2, 256, 2, 64, seed=3)
    k_bad = injection.inject_nan(jax.random.PRNGKey(9), k, 3)
    # unprotected oracle: poison propagates to the end of the sequence
    poisoned = _chunked_mlstm(q, k_bad, v, li, lf, chunk=64)
    assert bool(jnp.isnan(poisoned).any())
    last_chunk = poisoned[:, -64:]
    assert bool(jnp.isnan(last_chunk).any())         # temporal amplification
    # kernel: finite everywhere, counters fired
    got, counts = mlstm_chunked(q, k_bad, v, li, lf, chunk=64)
    assert bool(jnp.isfinite(got).all())
    assert int(counts[6]) > 0
