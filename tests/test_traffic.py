"""Traffic harness + desynchronized drain + scheduler fairness (ISSUE 10).

Three contracts:

* workload/harness determinism — ``generate_arrivals`` is a pure function
  of its config, and replaying one trace against two fresh engines gives
  identical token streams (the property the CI parity lanes lean on);

* the desynchronized stats drain (``ServingConfig.drain_interval``) —
  token streams never change (the fused kernels repair on read with a
  value-independent fill), ``drain_interval=1`` replays the lockstep
  engine's scrub trajectory bit-for-bit (same final pool bits, same
  unified stats, same per-page ledger), and every desync point issues
  STRICTLY fewer blocking host syncs;

* scheduler fairness under load — chunked prefill must not starve a
  decoding request (vllm-style mixed batching), and a preemption storm
  must resolve FIFO-fair: the oldest request is never evicted and every
  victim still finishes.
"""
import dataclasses

import jax
import numpy as np
import pytest
from conftest import tiny_transformer

from repro.serving import (
    Engine, ServingConfig, WorkloadConfig, generate_arrivals,
)


@pytest.fixture(scope="module")
def model_params():
    return tiny_transformer()


# ------------------------------------------------------------- workload
def test_workload_regenerates_bit_equal():
    cfg = WorkloadConfig(
        n_requests=12, arrival_rate=0.6, prompt_len=(2, 6),
        long_prompt_len=(8, 12), long_frac=0.4, output_len=(2, 5), seed=3,
    )
    a = generate_arrivals(cfg)
    b = generate_arrivals(cfg)
    assert [(x.step, x.prompt, x.max_new) for x in a] == [
        (x.step, x.prompt, x.max_new) for x in b
    ]
    assert all(a[i].step <= a[i + 1].step for i in range(len(a) - 1))
    # a different seed is a different trace
    c = generate_arrivals(dataclasses.replace(cfg, seed=4))
    assert [(x.step, x.prompt) for x in a] != [(x.step, x.prompt) for x in c]


def test_workload_burst_lands_on_one_step():
    cfg = WorkloadConfig(
        n_requests=4, arrival_rate=0.5, prompt_len=(2, 4),
        output_len=(2, 3), burst_at=2, burst_n=5, seed=9,
    )
    arrivals = generate_arrivals(cfg)
    assert len(arrivals) == 9
    assert sum(1 for a in arrivals if a.step == 2) >= 5


def test_workload_validation():
    with pytest.raises(ValueError):
        WorkloadConfig(arrival_rate=0.0)
    with pytest.raises(ValueError):
        WorkloadConfig(prompt_len=(5, 2))
    with pytest.raises(ValueError):
        WorkloadConfig(long_frac=1.5)
    with pytest.raises(ValueError):
        ServingConfig(drain_interval=-1)


# -------------------------------------------------------------- harness
def _cfg(**kw) -> ServingConfig:
    base = dict(
        page_size=4, n_pages=10, max_batch=4, max_pages_per_request=4,
        prefill_chunk=4, ber=0.0, seed=7,
    )
    base.update(kw)
    return ServingConfig(**base)


def test_harness_seed_deterministic(model_params):
    """The CI `traffic` lane's single-device half: same seed + same config
    => same arrivals => same token streams from two fresh engines."""
    from benchmarks.traffic import drive

    model, params = model_params
    wl = WorkloadConfig(
        n_requests=5, arrival_rate=0.8, prompt_len=(2, 5),
        long_prompt_len=(6, 9), long_frac=0.3, output_len=(2, 4), seed=13,
    )
    rep_a = drive(Engine(model, params, _cfg()), generate_arrivals(wl))
    rep_b = drive(Engine(model, params, _cfg()), generate_arrivals(wl))
    assert rep_a["token_streams"] == rep_b["token_streams"]
    assert rep_a["tokens_emitted"] == rep_b["tokens_emitted"] > 0
    assert rep_a["n_requests"] == 5
    for key in (
        "p50_ms_per_token", "p99_ms_per_token", "ttft_p50_ms",
        "tokens_per_s", "scrubbed_bytes_per_token", "n_host_syncs",
    ):
        assert key in rep_a, key


# ------------------------------------------------- desynchronized drain
def _pool_bits(engine: Engine):
    return [
        np.asarray(leaf, np.float32).view(np.uint32)
        for leaf in jax.tree.leaves(engine.pool.tree)
    ]


def _one_request_pair(model, params, drain_interval):
    """Two engines, one request each, identical flips — prefill and decode
    never share a step, so drain_interval=1 replays the lockstep scrub
    trajectory exactly."""
    out = []
    for di in (0, drain_interval):
        eng = Engine(
            model, params,
            _cfg(ber=2e-3, prefill_chunk=0, drain_interval=di, n_pages=7),
        )
        assert eng._paged_fn is not None and eng._prefill_fn is not None
        eng.add_request([5, 9, 2, 14, 3, 7], max_new=8)
        eng.run()
        out.append(eng)
    return out


def test_desync_interval1_bit_replays_lockstep(model_params):
    model, params = model_params
    lock, desync = _one_request_pair(model, params, drain_interval=1)
    assert lock._desync is False and desync._desync is True
    # the run actually exercised repair (the test has teeth)
    assert lock.stats_dict()["events"] > 0
    assert desync.results[0]["tokens"] == lock.results[0]["tokens"]
    # identical scrub trajectory: unified stats, kernel totals, per-page
    # ledger, and the final pool bits all replay
    assert desync.stats_dict() == lock.stats_dict()
    np.testing.assert_array_equal(desync.kernel_counts, lock.kernel_counts)
    np.testing.assert_array_equal(
        desync.pool.page_events, lock.pool.page_events
    )
    for a, b in zip(_pool_bits(desync), _pool_bits(lock)):
        np.testing.assert_array_equal(a, b)
    # and the whole point: strictly fewer blocking device->host readbacks
    assert desync.n_host_syncs < lock.n_host_syncs


def test_desync_wide_interval_token_parity_under_load(model_params):
    """drain_interval=3 under mixed chunked-prefill + decode traffic: the
    scrub happens steps later, but the kernels repair on read — tokens and
    throughput accounting must not move, syncs must drop further."""
    from benchmarks.traffic import drive

    model, params = model_params
    wl = WorkloadConfig(
        n_requests=5, arrival_rate=0.9, prompt_len=(2, 5),
        long_prompt_len=(6, 10), long_frac=0.4, output_len=(2, 5), seed=21,
    )
    reps = {}
    for di in (0, 1, 3):
        eng = Engine(model, params, _cfg(ber=1e-3, drain_interval=di))
        reps[di] = drive(eng, generate_arrivals(wl))
    assert reps[1]["token_streams"] == reps[0]["token_streams"]
    assert reps[3]["token_streams"] == reps[0]["token_streams"]
    assert reps[0]["tokens_emitted"] > 0
    assert reps[1]["n_host_syncs"] < reps[0]["n_host_syncs"]
    assert reps[3]["n_host_syncs"] < reps[1]["n_host_syncs"]


def test_metrics_expose_syncs_and_stage_walls(model_params):
    model, params = model_params
    eng = Engine(model, params, _cfg())
    eng.add_request([4, 8, 15], max_new=3)
    eng.run()
    m = eng.metrics()
    assert m["n_host_syncs"] > 0
    assert m["host_syncs_per_step"] > 0
    assert m["drain_interval"] == 0
    assert m["sharded_kernels"] is False
    walls = m["stage_wall_s"]
    assert set(walls) == {"admit", "prefill", "decode", "repair", "guard"}
    assert all(v >= 0.0 for v in walls.values())
    assert walls["prefill"] > 0.0 and walls["decode"] > 0.0


# -------------------------------------------------- scheduler fairness
def test_chunked_prefill_does_not_starve_decode(model_params):
    """vllm-style mixed batching: while a long prompt streams 2-token
    chunks, the already-running request must emit exactly one decode token
    EVERY step — no decode starvation behind prefill."""
    model, params = model_params
    eng = Engine(
        model, params,
        _cfg(n_pages=8, max_batch=2, prefill_chunk=2),
    )
    assert eng._prefill_fn is not None
    rid_a = eng.add_request([3, 4], max_new=8)            # 1 chunk
    rid_b = eng.add_request(list(range(1, 13)), max_new=2)  # 6 chunks
    out0 = eng.step()
    # step 0: A finishes its prefill and emits; B streams its first chunk
    assert rid_a in out0["emitted"] and rid_b not in out0["emitted"]
    for t in range(1, 5):
        out = eng.step()
        assert out["emitted"].get(rid_a) is not None and len(
            out["emitted"][rid_a]
        ) == 1, f"decode starved at step {t}"
        assert rid_b not in out["emitted"]
        assert rid_b in {r.rid for r in eng._prefilling}
    out5 = eng.step()          # B's last chunk lands: both emit
    assert rid_b in out5["emitted"] and rid_a in out5["emitted"]
    res = eng.run()
    assert len(res[rid_a]["generated"]) == 8
    assert len(res[rid_b]["generated"]) == 2


def test_preemption_storm_stays_fifo_fair(model_params):
    """Page pressure must evict the NEWEST request, never the oldest, and
    every victim still finishes with its full output."""
    model, params = model_params
    eng = Engine(
        model, params,
        _cfg(page_size=4, n_pages=5, max_batch=2, prefill_chunk=0),
    )
    rid_old = eng.add_request([2, 3, 4, 5], max_new=12)   # grows to 4 pages
    rid_new = eng.add_request([6, 7, 8, 9], max_new=8)    # grows to 3 pages
    res = eng.run()
    assert eng.sched.n_preemptions > 0, "the storm must actually preempt"
    assert res[rid_old]["n_preempted"] == 0, "FIFO: the elder is never evicted"
    assert res[rid_new]["n_preempted"] > 0
    assert len(res[rid_old]["generated"]) == 12
    assert len(res[rid_new]["generated"]) == 8


def test_burst_workload_all_requests_complete(model_params):
    """A synchronized burst over a small pool: admission control + FIFO
    preemption must drain the whole trace — nobody starves."""
    from benchmarks.traffic import drive

    model, params = model_params
    wl = WorkloadConfig(
        n_requests=3, arrival_rate=0.8, prompt_len=(2, 5),
        long_prompt_len=(6, 10), long_frac=0.5, output_len=(2, 4),
        burst_at=1, burst_n=4, seed=17,
    )
    eng = Engine(model, params, _cfg(max_batch=2, n_pages=6))
    rep = drive(eng, generate_arrivals(wl))
    assert rep["n_requests"] == 7
    assert len(rep["token_streams"]) == 7
    assert all(len(s) > 0 for s in rep["token_streams"])
    # the oldest arrival is never a preemption victim
    assert eng.results[0]["n_preempted"] == 0
