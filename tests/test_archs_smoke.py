"""Per-architecture smoke tests (assignment requirement): REDUCED config of
each family, one forward + one train step + one decode step on CPU, asserting
output shapes and finiteness — with the paper's technique enabled."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY, get_config
from repro.data import batch_for_step
from repro.launch.serve import build_serve_step
from repro.launch.train import build_train_step, init_train_state, make_optimizer
from repro.models import build_model

ARCHS = list(REGISTRY)


def make_batch(cfg, B=2, S=64):
    return batch_for_step(cfg, jax.random.PRNGKey(0), 0, batch=B, seq=S)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    logits = jax.jit(model.forward)(params, batch)
    B = batch["tokens"].shape[0]
    S_text = batch["tokens"].shape[1]
    assert logits.shape == (B, S_text, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    opt = make_optimizer(peak_lr=1e-3, warmup=2, total=10)
    state = init_train_state(model, opt, jax.random.PRNGKey(1))
    step = jax.jit(build_train_step(model, opt))
    state, metrics = step(state, make_batch(cfg))
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params stay finite after the update
    for leaf in jax.tree.leaves(state["params"]):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(2))
    B, T = 2, 32
    cache = model.init_cache(B, T)
    serve = jax.jit(build_serve_step(model))
    tok = {"tokens": jnp.ones((B, 1), jnp.int32)}
    nxt, logits, cache = serve(params, cache, tok, jnp.asarray(0, jnp.int32))
    assert nxt.shape == (B,)
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    # a second step consumes the updated cache
    nxt2, logits2, cache = serve(params, cache, {"tokens": nxt[:, None]},
                                 jnp.asarray(1, jnp.int32))
    assert bool(jnp.isfinite(logits2).all())


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "zamba2-7b", "xlstm-1.3b"])
def test_decode_matches_forward(arch):
    """Teacher-forced decode logits ≈ forward logits (cache correctness).
    One dense, one hybrid, one ssm — the stateful decode paths."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    B, S = 1, 16
    tokens = jax.random.randint(jax.random.PRNGKey(4), (B, S), 0, cfg.vocab)
    full = jax.jit(model.forward)(params, {"tokens": tokens})

    cache = model.init_cache(B, S)
    serve = jax.jit(model.serve_step)
    outs = []
    for t in range(S):
        logits, cache = serve(params, cache, {"tokens": tokens[:, t:t+1]},
                              jnp.asarray(t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    import numpy as np
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full), rtol=2e-2, atol=2e-2
    )
