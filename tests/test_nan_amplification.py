"""Fig. 1 reproduction: a single NaN poisons a whole matmul row / the
determinant — and the repair machinery prevents exactly that."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import injection
from repro.kernels import ops, ref


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(0, 63), st.integers(0, 63))
def test_single_nan_poisons_full_row(seed, i, j):
    """Paper Fig. 1 top: X[i,j] = NaN ⇒ (X @ Y)[i, :] all NaN."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    x = jax.random.normal(k1, (64, 64), jnp.float32).at[i, j].set(jnp.nan)
    y = jax.random.normal(k2, (64, 64), jnp.float32)
    z = x @ y
    assert bool(jnp.isnan(z[i]).all())            # the whole row is gone
    frac = float(jnp.isnan(z).mean())
    assert frac >= 1.0 / 64                       # ≥ one row of the output


def test_determinant_poisoned():
    """Paper Fig. 1 bottom: det of a matrix with one NaN is NaN."""
    x = jnp.eye(8).at[3, 2].set(jnp.nan)
    assert bool(jnp.isnan(jnp.linalg.det(x)))


def test_fused_repair_prevents_amplification():
    """With the repair-matmul kernel the same single NaN yields a fully
    finite product whose poisoned lane was repaired pre-MXU."""
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    a = jax.random.normal(k1, (128, 128), jnp.float32)
    b = jax.random.normal(k2, (128, 128), jnp.float32)
    a_bad = injection.inject_nan(k3, a, 1)
    res = ops.repair_matmul(a_bad, b, mode="memory", policy="zero",
                            blocks=(64, 64, 64))
    assert bool(jnp.isfinite(res.c).all())
    # and the result equals the matmul over the zero-repaired operand
    c_ref, _ = ref.repair_matmul_ref(a_bad, b, policy="zero",
                                     blocks=(64, 64, 64))
    np.testing.assert_allclose(np.asarray(res.c), np.asarray(c_ref),
                               rtol=1e-5, atol=1e-5)


def test_error_magnitude_bounded_after_repair():
    """Repairing one lane to 0 perturbs the product by at most that lane's
    contribution — the 'amortizable drift' the paper relies on."""
    key = jax.random.PRNGKey(8)
    k1, k2 = jax.random.split(key)
    a = jax.random.normal(k1, (64, 64), jnp.float32)
    b = jax.random.normal(k2, (64, 64), jnp.float32)
    clean = a @ b
    a_bad = a.at[5, 9].set(jnp.nan)
    res = ops.repair_matmul(a_bad, b, mode="register", policy="zero",
                            blocks=(32, 32, 32))
    # only row 5 differs, by exactly a[5,9]*b[9,:]
    diff = np.abs(np.asarray(res.c) - np.asarray(clean))
    assert diff[:5].max() < 1e-4 and diff[6:].max() < 1e-4
    expect = np.abs(np.asarray(a)[5, 9] * np.asarray(b)[9, :])
    np.testing.assert_allclose(diff[5], expect, rtol=1e-4, atol=1e-4)
