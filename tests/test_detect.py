"""Bit-pattern NaN/Inf detection — unit + hypothesis property tests.

The paper's definition (§2.2): a NaN is "all bits of the exponent part
flipped to 1" (+ non-zero mantissa).  core.detect must agree with IEEE
semantics (jnp.isnan/isinf) bit-for-bit on every dtype the framework stores.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import detect

DTYPES = [jnp.float32, jnp.float64, jnp.bfloat16, jnp.float16]


@pytest.mark.parametrize("dtype", DTYPES)
def test_masks_match_ieee(dtype):
    lay = detect.layout_of(dtype)
    # build every interesting pattern: 0, -0, 1, inf, -inf, several NaNs,
    # denormals, max finite
    bits = np.array(
        [
            0,
            lay.sign_mask,
            lay.exp_mask,                          # +inf
            lay.exp_mask | lay.sign_mask,          # -inf
            lay.exp_mask | 1,                      # NaN (quiet-ish)
            lay.exp_mask | lay.man_mask,           # NaN all-ones mantissa
            1,                                     # smallest denormal
            lay.exp_mask - 1,                      # max finite
            (lay.exp_mask | lay.man_mask) & ~lay.sign_mask,
        ],
        dtype=np.dtype(lay.int_dtype),
    )
    x = jax.lax.bitcast_convert_type(jnp.asarray(bits), dtype)
    np.testing.assert_array_equal(np.asarray(detect.nan_mask(x)), np.isnan(np.asarray(x, np.float64)))
    np.testing.assert_array_equal(np.asarray(detect.inf_mask(x)), np.isinf(np.asarray(x, np.float64)))


@settings(max_examples=200, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=2**32 - 1), min_size=1, max_size=64))
def test_f32_property_random_bits(bit_list):
    """Any u32 pattern: our bit classification == IEEE classification."""
    bits = np.array(bit_list, dtype=np.uint32)
    x = bits.view(np.float32)
    jx = jnp.asarray(bits)
    got_nan = np.asarray(detect.is_nan_bits(jx, jnp.float32))
    got_inf = np.asarray(detect.is_inf_bits(jx, jnp.float32))
    np.testing.assert_array_equal(got_nan, np.isnan(x))
    np.testing.assert_array_equal(got_inf, np.isinf(x))


@settings(max_examples=100, deadline=None)
@given(
    st.integers(min_value=0, max_value=2**16 - 1),
)
def test_bf16_exhaustive_slices(base):
    """bf16 is small enough to check real slices of the 2^16 pattern space."""
    bits = np.arange(base, min(base + 256, 2**16), dtype=np.uint16)
    x32 = (bits.astype(np.uint32) << 16).view(np.float32)
    jx = jnp.asarray(bits)
    got_nan = np.asarray(detect.is_nan_bits(jx, jnp.bfloat16))
    got_inf = np.asarray(detect.is_inf_bits(jx, jnp.bfloat16))
    np.testing.assert_array_equal(got_nan, np.isnan(x32))
    np.testing.assert_array_equal(got_inf, np.isinf(x32))


def test_bits_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,), jnp.float32)
    rt = detect.from_bits(detect.bits_of(x), jnp.float32)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(rt))


def test_nonfinite_mask_modes():
    x = jnp.array([1.0, jnp.nan, jnp.inf, -jnp.inf, 0.0], jnp.float32)
    with_inf = detect.nonfinite_mask(x, include_inf=True)
    no_inf = detect.nonfinite_mask(x, include_inf=False)
    assert with_inf.tolist() == [False, True, True, True, False]
    assert no_inf.tolist() == [False, True, False, False, False]
    assert int(detect.count_nonfinite(x)) == 3
    assert int(detect.count_nonfinite(x, include_inf=False)) == 1
