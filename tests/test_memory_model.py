"""Edge cases of the refresh→BER→energy model and the expected-fault charge
(`ApproxMemoryModel.from_refresh`, `ApproxConfig.expected_faults`) — the
numbers the autopilot campaign, the frontier solver, and the prefix cache's
dwell gate all budget against."""
import math

import pytest

from repro.core.injection import _ANCHORS, ApproxMemoryModel
from repro.runtime import ApproxConfig


# ------------------------------------------------------------- from_refresh
def test_from_refresh_hits_every_anchor_exactly():
    for t, log_ber, saving in _ANCHORS:
        mm = ApproxMemoryModel.from_refresh(t)
        assert mm.refresh_interval_s == t
        assert mm.ber == pytest.approx(10.0 ** log_ber)
        assert mm.energy_saving == pytest.approx(saving)


def test_from_refresh_clamps_below_first_anchor():
    """Shorter-than-nominal refresh stays at the nominal BER/saving — the
    model never extrapolates to negative savings or sub-physical BER."""
    lo = ApproxMemoryModel.from_refresh(1e-6)
    first = ApproxMemoryModel.from_refresh(_ANCHORS[0][0])
    assert lo.ber == first.ber
    assert lo.energy_saving == first.energy_saving == 0.0


def test_from_refresh_clamps_above_last_anchor():
    """A huge dwell window clamps at the last anchor instead of running the
    log-linear slope off to BER ~1."""
    hi = ApproxMemoryModel.from_refresh(1e9)
    last = ApproxMemoryModel.from_refresh(_ANCHORS[-1][0])
    assert hi.ber == last.ber == pytest.approx(1e-4)
    assert hi.energy_saving == last.energy_saving == pytest.approx(0.30)


def test_from_refresh_monotone_in_refresh_interval():
    """Relaxing refresh never lowers BER or the energy saving — the
    monotonicity the frontier solver's 'longest refresh within budget'
    argmax relies on."""
    points = [0.01, 0.064, 0.1, 0.256, 0.5, 1.0, 1.7, 2.0, 3.0, 4.0, 10.0]
    models = [ApproxMemoryModel.from_refresh(t) for t in points]
    for a, b in zip(models, models[1:]):
        assert a.ber <= b.ber
        assert a.energy_saving <= b.energy_saving


def test_from_refresh_interpolates_log_linear_between_anchors():
    """Midpoint (geometric) between the 1 s and 4 s anchors lands on the
    geometric-mean BER and the arithmetic-mean saving."""
    mm = ApproxMemoryModel.from_refresh(2.0)
    assert mm.ber == pytest.approx(1e-5, rel=1e-9)
    assert mm.energy_saving == pytest.approx((0.225 + 0.30) / 2)


def test_from_refresh_fractional_interval():
    """Fractional windows interpolate smoothly (no int truncation)."""
    a = ApproxMemoryModel.from_refresh(0.3)
    b = ApproxMemoryModel.from_refresh(0.31)
    assert _ANCHORS[1][0] < 0.3 < 0.31 < _ANCHORS[2][0]
    assert a.ber < b.ber
    assert 10.0 ** -9 < a.ber < 10.0 ** -6


# ---------------------------------------------------------- expected_faults
def test_expected_faults_zero_bytes_is_zero():
    cfg = ApproxConfig(mode="memory", refresh_interval_s=4.0)
    assert cfg.expected_faults(0, 100.0) == 0.0


def test_expected_faults_zero_or_negative_windows_clamp_to_zero():
    cfg = ApproxConfig(mode="memory", refresh_interval_s=4.0)
    assert cfg.expected_faults(1024, 0.0) == 0.0
    # a page scrubbed this very step has non-positive dwell — never a
    # negative expectation
    assert cfg.expected_faults(1024, -3.0) == 0.0


def test_expected_faults_ber_override_beats_resolved_refresh_ber():
    """The explicit ``ber=`` argument (the serving engine's simulation BER)
    takes precedence over the config's refresh-resolved BER."""
    cfg = ApproxConfig(mode="memory", refresh_interval_s=4.0)   # 1e-4
    assert cfg.resolved_ber == pytest.approx(1e-4)
    n_bytes, windows, sim_ber = 64, 2.0, 1e-2
    got = cfg.expected_faults(n_bytes, windows, ber=sim_ber)
    assert got == pytest.approx(n_bytes * 8 * sim_ber * windows)
    assert got != pytest.approx(
        cfg.expected_faults(n_bytes, windows)
    )


def test_expected_faults_linear_in_bytes_and_windows():
    cfg = ApproxConfig(mode="memory", ber=1e-6)
    base = cfg.expected_faults(128, 1.0)
    assert cfg.expected_faults(256, 1.0) == pytest.approx(2 * base)
    assert cfg.expected_faults(128, 3.5) == pytest.approx(3.5 * base)


def test_expected_faults_fractional_and_huge_dwell():
    """Fractional windows scale linearly; a huge dwell stays finite (a plain
    product, never an overflow or a capped probability)."""
    cfg = ApproxConfig(mode="memory", ber=1e-6)
    frac = cfg.expected_faults(1024, 0.25)
    assert frac == pytest.approx(1024 * 8 * 1e-6 * 0.25)
    huge = cfg.expected_faults(1 << 30, 1e12)
    assert math.isfinite(huge) and huge > 0
    assert huge == pytest.approx((1 << 30) * 8 * 1e-6 * 1e12, rel=1e-12)


def test_expected_faults_zero_ber_override_charges_nothing():
    """An explicit ``ber=0.0`` silences the charge even though the config's
    default refresh point (1.0 s) resolves to a nonzero BER — exact-memory
    deployments must never gate a scrub on dwell."""
    cfg = ApproxConfig(mode="memory")
    assert cfg.resolved_ber > 0.0                       # default 1 s point
    assert cfg.expected_faults(1 << 20, 1e6) > 0.0
    assert cfg.expected_faults(1 << 20, 1e6, ber=0.0) == 0.0
    zeroed = ApproxConfig(mode="memory", ber=0.0)
    assert zeroed.expected_faults(1 << 20, 1e6) == 0.0
