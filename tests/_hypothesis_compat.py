"""Fixed-example fallback for ``hypothesis`` when the real package is absent.

This container cannot install ``hypothesis``, which made 6 of 11 test modules
error at import.  The shim provides just the surface our tests use —
``given``, ``settings``, and ``strategies`` (``integers`` / ``sampled_from``
/ ``lists``) — replaying a small deterministic set of representative examples
instead of random search.  It is registered as ``sys.modules["hypothesis"]``
by ``conftest.py`` ONLY when the real package cannot be imported, so
environments with hypothesis installed get full property-based testing
unchanged.
"""
from __future__ import annotations

import itertools
import sys
import types
from typing import Any, List

_MAX_COMBOS = 12      # cap on the fixed-example cartesian product per test


class _Strategy:
    """A hypothesis strategy stand-in: a fixed, deterministic example list."""

    def __init__(self, examples: List[Any]):
        self._examples = list(examples)

    def examples(self) -> List[Any]:
        return list(self._examples)


def _dedupe(xs):
    seen, out = set(), []
    for x in xs:
        key = repr(x)
        if key not in seen:
            seen.add(key)
            out.append(x)
    return out


def integers(min_value: int = 0, max_value: int = 2**31 - 1) -> _Strategy:
    span = max_value - min_value
    return _Strategy(
        _dedupe(
            [
                min_value,
                max_value,
                min_value + span // 2,
                min_value + span // 3,
                min_value + min(1, span),
                min_value + (span * 7) // 8,
            ]
        )
    )


def sampled_from(options) -> _Strategy:
    return _Strategy(list(options))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10,
          **_kw) -> _Strategy:
    ex = elements.examples()
    lo = ex[0] if ex else 0
    hi = ex[1] if len(ex) > 1 else lo
    n = max(min_size, min(max_size, 8))
    ramp = list(itertools.islice(itertools.cycle(ex), n))
    out = [
        [lo] * max(min_size, 1),
        [hi] * max(min_size, 1),
        ramp,
    ]
    return _Strategy(_dedupe(x for x in out if min_size <= len(x) <= max_size))


def settings(**_kw):
    """`@settings(max_examples=..., deadline=...)` — a no-op wrapper; the
    fixed example set is already small and has no deadline."""

    def deco(fn):
        return fn

    return deco


def given(*strategies: _Strategy):
    """Replay the cartesian product of each strategy's fixed examples
    (capped at ``_MAX_COMBOS``) through the test body."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            combos = itertools.islice(
                itertools.product(*(s.examples() for s in strategies)),
                _MAX_COMBOS,
            )
            for combo in combos:
                fn(*args, *combo, **kwargs)

        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.hypothesis_compat_shim = True
        return wrapper

    return deco


def install() -> None:
    """Register this shim as the ``hypothesis`` package (call only when the
    real one is absent)."""
    mod = types.ModuleType("hypothesis")
    strategies_mod = types.ModuleType("hypothesis.strategies")
    for name, fn in (
        ("integers", integers),
        ("sampled_from", sampled_from),
        ("lists", lists),
    ):
        setattr(strategies_mod, name, fn)
    mod.given = given
    mod.settings = settings
    mod.strategies = strategies_mod
    mod.HealthCheck = types.SimpleNamespace(too_slow="too_slow")
    mod.__version__ = "0.0-compat-shim"
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies_mod
