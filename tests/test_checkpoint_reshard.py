"""Checkpoint restore + reference repair on the single-device lane.

The elastic cross-mesh reshard itself is exercised by the multidev lane
(tests/multidev/test_distributed_repair.py); here we pin the mesh-free
contract: restore round-trips, ``repair=True`` runs the reference pass
after the device_put, and ``reference_repair`` heals post-restore flips
from the checkpointed shards through the runtime's reference-scope plan.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.manager import CheckpointManager


def make_state():
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    return {
        "params": {"w": jax.random.normal(k1, (8, 16))},
        "opt": {"mu": jax.random.normal(k2, (8, 16)),
                "step": jnp.zeros((), jnp.int32)},
    }


def test_restore_with_repair_roundtrips(tmp_path):
    state = make_state()
    mgr = CheckpointManager(str(tmp_path), scrub=True)
    mgr.save(3, state, blocking=True)

    like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
    restored, step = mgr.restore(like=like, repair=True)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_repair_requires_treedef(tmp_path):
    state = make_state()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, state, blocking=True)
    try:
        mgr.restore(repair=True)
        assert False, "repair=True without `like` must raise"
    except ValueError:
        pass


def test_reference_repair_heals_post_restore_flips(tmp_path):
    """Flips that strike AFTER the restore are healed exactly from the
    checkpoint (the ``last_checkpoint`` answer to paper §5.2), and the
    events land in the manager's unified stream."""
    state = make_state()
    mgr = CheckpointManager(str(tmp_path), scrub=True)
    mgr.save(5, state, blocking=True)

    like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
    restored, _ = mgr.restore(like=like)
    poisoned = {
        "params": {"w": restored["params"]["w"].at[2, 3].set(jnp.nan)},
        "opt": {"mu": restored["opt"]["mu"].at[0, 0].set(jnp.inf),
                "step": restored["opt"]["step"]},
    }
    healed = mgr.reference_repair(poisoned)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(healed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    d = mgr.space.stats_dict()
    assert d["nan_found"] >= 1 and d["inf_found"] >= 1


def test_save_donates_copy_and_live_state_survives(tmp_path):
    """Donation audit (ROADMAP leftover): the save scrub runs over the
    eagerly-taken host copy with donated buffers — the live train state is
    never an input to the donated executable, so it survives bit-for-bit
    (fatal lanes included), while the serialized checkpoint is clean."""
    state = make_state()
    state["params"]["w"] = state["params"]["w"].at[2, 3].set(jnp.nan)
    before = jax.device_get(state)

    mgr = CheckpointManager(str(tmp_path), scrub=True)
    mgr.save(7, state, blocking=True)

    # live state untouched: buffers readable, NaN still resident
    for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert bool(jnp.isnan(state["params"]["w"][2, 3]))

    like = jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
    restored, step = mgr.restore(like=like)
    assert step == 7
    for leaf in jax.tree.leaves(restored):
        if jnp.issubdtype(leaf.dtype, jnp.floating):
            assert bool(jnp.isfinite(leaf).all())
    # scrub-on-save events landed in the manager's unified stream
    assert mgr.space.stats_dict()["nan_found"] == 1
