"""Approximate-memory simulator: BER model + bit-flip injection."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import detect, injection


def test_refresh_model_anchors():
    m = injection.ApproxMemoryModel.from_refresh(0.256)
    assert abs(m.energy_saving - 0.161) < 1e-6 and abs(m.ber - 1e-9) < 1e-12
    m = injection.ApproxMemoryModel.from_refresh(1.0)
    assert abs(m.energy_saving - 0.225) < 1e-6
    # monotone interpolation between anchors
    a = injection.ApproxMemoryModel.from_refresh(0.5)
    assert 1e-9 < a.ber < 1e-6 and 0.161 < a.energy_saving < 0.225


def test_flip_bits_count_scales_with_ber():
    key = jax.random.PRNGKey(0)
    x = jnp.ones((512, 512), jnp.float32)
    ber = 1e-5
    flipped = injection.flip_bits(key, x, ber)
    n_changed = int(jnp.sum(flipped != x))
    lam = x.size * 32 * ber   # ≈ 84 expected flips
    assert 0.3 * lam < n_changed <= 2.0 * lam


def test_flip_bits_zero_collision_xor():
    """Two flips on the same bit restore it — verified statistically by
    injecting a huge BER on a tiny buffer and checking closure under XOR."""
    key = jax.random.PRNGKey(1)
    x = jnp.zeros((4,), jnp.float32)
    flipped = injection.flip_bits(key, x, 0.2)
    bits = np.asarray(detect.bits_of(flipped))
    assert bits.dtype == np.uint32          # still a valid bit view


def test_inject_nan_exact_count():
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (64, 64), jnp.float32)
    for n in (1, 5):
        y = injection.inject_nan(key, x, n)
        assert int(jnp.isnan(y).sum()) == n
        # non-injected lanes are bit-identical
        same = np.asarray(detect.bits_of(y)) == np.asarray(detect.bits_of(x))
        assert same.sum() == x.size - n


@settings(max_examples=20, deadline=None)
@given(st.sampled_from([jnp.float32, jnp.bfloat16]), st.integers(0, 1000))
def test_property_flips_preserve_shape_dtype(dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (32, 32), jnp.float32).astype(dtype)
    y = injection.flip_bits(jax.random.PRNGKey(seed + 1), x, 1e-4)
    assert y.shape == x.shape and y.dtype == x.dtype


def test_nan_rate_from_flips_bf16():
    """The paper's premise: flips sometimes produce NaNs.  bf16 (8-bit
    exponent near all-ones for normal weights) shows a measurable rate."""
    key = jax.random.PRNGKey(3)
    x = (jax.random.normal(key, (2048, 512), jnp.float32) * 0.02).astype(jnp.bfloat16)
    y = injection.flip_bits(jax.random.PRNGKey(4), x, 1e-4)
    n_fatal = int(jnp.sum(~jnp.isfinite(y.astype(jnp.float32))))
    n_flips = int(jnp.sum(detect.bits_of(y) != detect.bits_of(x)))
    assert n_flips > 100          # enough statistics
    # a flip lands on the exponent with p≈8/16 and only the all-ones
    # completion makes a NaN — the rate must be small but non-zero over
    # this many flips with near-zero weights it is dominated by sign/high
    # mantissa flips, so just assert the machinery counts consistently
    assert 0 <= n_fatal <= n_flips
