"""Substrate tests: data pipeline, optimizer, compression, sharding rules,
checkpointing (incl. scrub-on-save + elastic reshard + preemption hook)."""
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data import SyntheticStream, batch_for_step
from repro.distributed import compression as comp
from repro.distributed import sharding as sh
from repro.optim import AdamW, cosine_with_warmup


# ------------------------------------------------------------------- data
def test_data_is_pure_in_seed_and_step():
    cfg = get_config("qwen2-1.5b").reduced()
    seed = jax.random.PRNGKey(7)
    a = batch_for_step(cfg, seed, 3, batch=4, seq=32)
    b = batch_for_step(cfg, seed, 3, batch=4, seq=32)
    c = batch_for_step(cfg, seed, 4, batch=4, seq=32)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))
    assert int(a["tokens"].max()) < cfg.vocab and int(a["tokens"].min()) >= 0


def test_data_host_slicing_partitions_batch():
    cfg = get_config("qwen2-1.5b").reduced()
    full = SyntheticStream(cfg, seed=1, batch=8, seq=16)
    parts = [
        SyntheticStream(cfg, seed=1, batch=8, seq=16,
                        process_index=i, process_count=4)
        for i in range(4)
    ]
    whole = full(0)["tokens"]
    got = jnp.concatenate([p(0)["tokens"] for p in parts], axis=0)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(got))


def test_data_modalities():
    vlm = get_config("llava-next-mistral-7b").reduced()
    b = batch_for_step(vlm, jax.random.PRNGKey(0), 0, batch=2, seq=64)
    assert "patch_embeds" in b and b["patch_embeds"].shape[1] == 8
    audio = get_config("seamless-m4t-large-v2").reduced()
    b = batch_for_step(audio, jax.random.PRNGKey(0), 0, batch=2, seq=64)
    assert b["frames"].shape == (2, 64, audio.d_model)


# ---------------------------------------------------------------- optim
def test_adamw_reduces_quadratic_loss():
    opt = AdamW(lr=cosine_with_warmup(0.1, 5, 200), weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    l0 = float(loss(params))
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, m = opt.update(g, state, params)
    assert float(loss(params)) < 0.05 * l0
    assert int(state.step) == 100


def test_adamw_clips_global_norm():
    opt = AdamW(lr=lambda s: 1e-3, clip_norm=1.0)
    params = {"w": jnp.zeros((4,))}
    state = opt.init(params)
    g = {"w": jnp.full((4,), 100.0)}
    _, _, metrics = opt.update(g, state, params)
    assert float(metrics["grad_norm"]) > 100.0   # pre-clip norm reported


def test_schedule_shape():
    s = cosine_with_warmup(1.0, 10, 100, final_fraction=0.1)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 1e-6
    assert abs(float(s(100)) - 0.1) < 1e-3
    assert float(s(55)) < 1.0


# ----------------------------------------------------------- compression
@settings(max_examples=30, deadline=None)
@given(st.integers(0, 10_000))
def test_int8_roundtrip_error_bounded(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10
    q, scale, err = comp.compress_int8(x, jnp.zeros_like(x))
    back = comp.decompress_int8(q, scale)
    assert float(jnp.max(jnp.abs(back - x))) <= float(scale) * 0.5 + 1e-6
    np.testing.assert_allclose(np.asarray(x - back), np.asarray(err), rtol=1e-5, atol=1e-6)


def test_error_feedback_converges():
    """EF property: the RUNNING MEAN of compressed grads → true grad."""
    g = jax.random.normal(jax.random.PRNGKey(0), (64,))
    err = {"g": jnp.zeros_like(g)}
    acc = jnp.zeros_like(g)
    n = 200
    for _ in range(n):
        ghat, err = comp.compressed_allreduce_tree({"g": g}, err)
        acc = acc + ghat["g"]
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g),
                               rtol=1e-3, atol=1e-3)


# -------------------------------------------------------------- sharding
def test_spec_for_leaf_divisibility_and_reuse():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    rules = {"embed": "data", "heads": "model", "batch": "data"}
    # both shardable (axis size 1 divides anything)
    spec = sh.spec_for_leaf(("embed", "heads"), (8, 8), mesh, rules)
    assert spec == jax.sharding.PartitionSpec("data", "model")
    # same mesh axis twice: second dim degrades
    spec = sh.spec_for_leaf(("batch", "embed"), (8, 8), mesh, rules)
    assert spec[1] is None


def test_spec_for_leaf_degrades_non_divisible():
    # fake a 16-wide axis via axis-size arithmetic on a 1-device mesh is not
    # possible; validate the arithmetic path directly instead
    mesh = jax.make_mesh((1,), ("model",))
    rules = {"kv": "model"}
    spec = sh.spec_for_leaf(("kv",), (3,), mesh, rules)   # 3 % 1 == 0 -> ok
    assert spec == jax.sharding.PartitionSpec("model")


def test_constrain_is_identity_without_context():
    x = jnp.ones((4, 4))
    assert sh.constrain(x, ("act_batch", None)) is x


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_scrub_on_save(tmp_path):
    tree = {
        "params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
        "step": jnp.asarray(5, jnp.int32),
    }
    tree["params"]["w"] = tree["params"]["w"].at[0, 0].set(jnp.nan)
    path = save_checkpoint(str(tmp_path), 5, tree)
    assert os.path.isdir(path)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored, step = load_checkpoint(str(tmp_path), like=like)
    assert step == 5
    # scrub-on-save: the NaN was repaired before persisting
    assert bool(jnp.isfinite(restored["params"]["w"]).all())
    assert float(restored["params"]["w"][0, 1]) == 1.0


def test_checkpoint_manager_retention_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3):
        mgr.save(s, tree, blocking=True)
    assert mgr.latest_step() == 3
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000002", "step_00000003"]


def test_checkpoint_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save(7, {"w": jnp.ones((8,))})
    mgr.wait()
    assert mgr.latest_step() == 7


def test_elastic_reshard_roundtrip(tmp_path):
    """Save from one 'mesh', restore with explicit shardings onto another
    (single-device here; the API path is identical)."""
    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(16, dtype=jnp.float32).reshape(4, 4)}
    save_checkpoint(str(tmp_path), 1, tree, scrub=False)
    shard = {"w": jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))}
    like = {"w": jax.ShapeDtypeStruct((4, 4), jnp.float32)}
    restored, _ = load_checkpoint(str(tmp_path), like=like, shardings=shard)
    assert restored["w"].sharding.spec == jax.sharding.PartitionSpec("data")
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


def test_preemption_hook_saves_on_sigterm(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    state = {"w": jnp.full((4,), 3.0)}
    handler = mgr.install_preemption_hook(lambda: (42, state))
    try:
        handler(signal.SIGTERM, None)       # simulate scheduler eviction
    finally:
        signal.signal(signal.SIGTERM, signal.SIG_DFL)
    assert mgr.latest_step() == 42
    restored, step = load_checkpoint(
        str(tmp_path), like={"w": jax.ShapeDtypeStruct((4,), jnp.float32)}
    )
    assert step == 42 and float(restored["w"][0]) == 3.0
