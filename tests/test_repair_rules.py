"""The `RepairRule` API (README §RepairRule): rule grammar, path binding,
trigger gating, exact islands, per-rule counters, plan caching per
(layout, rule-set), legacy single-knob parity, and the acceptance
end-to-end — one mixed RuleSet shared by train scrub, serving page repair,
and checkpoint-restore repair."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.core import stats as stats_lib
from repro.core.regions import Region
from repro.core.repair import repair_tensor
from repro.core.rules import Detector, RepairRule, RuleSet, ruleset_of
from repro.runtime import ApproxConfig, ApproxSpace


# One mixed rule set, used across the whole module (the acceptance shape):
# range-guarded neighbor_mean for optimizer state, NaN-only zero-fill for
# KV pages, an exact island for embeddings, and a conservative default.
MIXED = RuleSet((
    (r"(^|/)opt(/|$)",
     RepairRule(detect=Detector(max_magnitude=1e3), fill="neighbor_mean")),
    (r"(^|/)(k|v)(/|$)",
     RepairRule(detect=Detector(inf=False), fill="zero", trigger="reactive")),
    (r"(^|/)embed(/|$)", RepairRule.exact_rule()),
))


def mixed_state():
    k = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(k, 3)
    return {
        "params": {"w": jax.random.normal(k1, (8, 16))},
        "opt": {"mu": jax.random.normal(k2, (8, 16))},
        "k": jax.random.normal(k3, (4, 8)),
        "embed": {"table": jnp.ones((4, 4))},
    }


# ----------------------------------------------------------------- grammar
def test_rule_grammar_and_validation():
    r = RepairRule(detect=Detector(inf=False), fill="zero", trigger="reactive")
    assert not r.exact
    assert r.fires("reactive") and r.fires("forced")
    assert not r.fires("boundary") and not r.fires("interval")
    b = RepairRule()                       # the legacy-shaped default
    assert all(b.fires(p) for p in ("boundary", "interval", "reactive", "forced"))
    i = RepairRule(trigger="interval")
    assert not i.fires("boundary") and i.fires("interval") and i.fires("reactive")
    o = RepairRule(trigger="on-read")
    assert not o.fires("boundary") and o.fires("forced")
    e = RepairRule.exact_rule()
    assert not e.fires("forced")           # exact islands never repair
    with pytest.raises(ValueError):
        RepairRule(trigger="bogus")


def test_path_binding_first_match_wins_and_fallback():
    idx_opt, rule_opt = MIXED.rule_for("opt/mu")
    idx_kv, rule_kv = MIXED.rule_for("layers/k/0")
    idx_e, rule_e = MIXED.rule_for("embed/table")
    idx_d, rule_d = MIXED.rule_for("params/w")
    assert (idx_opt, idx_kv, idx_e) == (0, 1, 2)
    assert rule_opt.detect.max_magnitude == 1e3
    assert rule_kv.detect.inf is False and rule_kv.fill == "zero"
    assert rule_e.exact
    assert idx_d == len(MIXED.entries) and rule_d.fill == "neighbor_mean"
    assert MIXED.labels()[0] == r"(^|/)opt(/|$)"       # auto-labeled


def test_detector_masks_per_kind():
    x = jnp.array([1.0, jnp.nan, jnp.inf, -jnp.inf, 2e4], jnp.float32)
    nan_only = Detector(inf=False)
    n, i = nan_only.masks(x)
    assert n.tolist() == [False, True, False, False, False]
    assert i.tolist() == [False] * 5
    ranged = Detector(max_magnitude=1e3)
    n, i = ranged.masks(x)
    assert n.tolist() == [False, True, False, False, False]
    assert i.tolist() == [False, False, True, True, True]   # inf subsumed
    # custom bit pattern: treat exact -0.0 as fatal (mask = value = sign bit)
    negzero = Detector(nan=False, inf=False,
                       bitpatterns=(("float32", 0xFFFFFFFF, 0x80000000),))
    n, i = negzero.masks(jnp.array([0.0, -0.0, 1.0], jnp.float32))
    assert n.tolist() == [False, True, False]


def test_exact_rule_is_region_override_and_skips_injection():
    space = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED, ber=1e-3))
    tree = mixed_state()
    regions = space.regions_for(tree)
    assert regions["embed"]["table"] is Region.EXACT
    assert regions["params"]["w"] is Region.APPROX
    out, flips = space.inject(tree, jax.random.PRNGKey(1), 1e-2)
    np.testing.assert_array_equal(                 # exact island: no flips
        np.asarray(out["embed"]["table"]), np.asarray(tree["embed"]["table"])
    )
    assert int(flips) > 0                          # the rest was struck


# ---------------------------------------------------------------- triggers
def test_trigger_gating_across_pass_tags():
    """A reactive-only KV rule skips boundary passes but fires on reactive
    and forced passes; the boundary-trigger default fires everywhere."""
    space = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED))
    tree = mixed_state()
    tree = {
        **tree,
        "k": tree["k"].at[0, 0].set(jnp.nan),
        "params": {"w": tree["params"]["w"].at[1, 1].set(jnp.nan)},
    }
    out, st = space.scrub(tree, stats_lib.zeros(), trigger="boundary")
    assert bool(jnp.isfinite(out["params"]["w"]).all())   # default rule fired
    assert bool(jnp.isnan(out["k"][0, 0]))                # reactive rule held
    assert stats_lib.as_dict(st)["nan_found"] == 1

    out, st = space.scrub(tree, stats_lib.zeros(), trigger="reactive")
    assert bool(jnp.isfinite(out["k"]).all())             # now it fires
    assert stats_lib.as_dict(st)["nan_found"] == 2

    out, st = space.scrub(tree, stats_lib.zeros())        # forced default
    assert bool(jnp.isfinite(out["k"]).all())
    assert bool(jnp.isfinite(out["params"]["w"]).all())


def test_nan_only_kv_rule_leaves_inf_resident():
    """The "kv" rule is NaN-only: a stored Inf is not fatal under it, while
    the default rule (include_inf) would have repaired it."""
    space = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED))
    tree = mixed_state()
    tree["k"] = tree["k"].at[1, 2].set(jnp.inf)
    out, st = space.scrub(tree, stats_lib.zeros(), trigger="reactive")
    assert bool(jnp.isinf(out["k"][1, 2]))
    assert stats_lib.as_dict(st)["inf_found"] == 0


def test_range_guarded_opt_rule_uses_neighbor_mean():
    space = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED))
    tree = mixed_state()
    tree["opt"]["mu"] = tree["opt"]["mu"].at[0, 0].set(2.0e4)   # legal float
    out, st = space.scrub(tree, stats_lib.zeros(), trigger="boundary")
    fixed = float(out["opt"]["mu"][0, 0])
    assert abs(fixed) < 1e3                         # range guard fired
    assert stats_lib.as_dict(st)["inf_found"] == 1  # range bucket
    # params/w falls to the default rule: no range guard there
    tree2 = mixed_state()
    tree2["params"]["w"] = tree2["params"]["w"].at[0, 0].set(2.0e4)
    out2, st2 = space.scrub(tree2, stats_lib.zeros(), trigger="boundary")
    assert float(out2["params"]["w"][0, 0]) == 2.0e4


# ---------------------------------------------------------- per-rule stats
def test_per_rule_counters_in_unified_stats():
    space = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED))
    tree = mixed_state()
    tree["opt"]["mu"] = tree["opt"]["mu"].at[0, 0].set(jnp.nan)
    tree["k"] = tree["k"].at[0, 0].set(jnp.nan).at[1, 1].set(jnp.nan)
    space.scrub(tree)                              # forced host-side pass
    rs = space.rule_stats()
    labels = space.ruleset.labels()
    assert rs[labels[0]]["nan_found"] == 1         # opt rule
    assert rs[labels[0]]["events"] == 1
    assert rs[labels[1]]["nan_found"] == 2         # kv rule
    assert rs[labels[2]] == {"nan_found": 0, "inf_found": 0, "events": 0}
    assert rs["default"]["nan_found"] == 0
    # aggregate stream agrees with the per-rule ledger
    assert space.stats_dict()["nan_found"] == 3


# ------------------------------------------------------------ plan caching
def test_one_trace_per_layout_and_ruleset():
    """Same layout + same rule set reuses the executable; a different
    trigger (different gating) and a different rule set each trace once."""
    space = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED))
    tree = mixed_state()
    out, _ = space.scrub(tree, stats_lib.zeros(), trigger="boundary")
    assert space.n_traces == 1
    for _ in range(3):
        out, _ = space.scrub(out, stats_lib.zeros(), trigger="boundary")
    assert space.n_traces == 1, "same (layout, rule-set) must never retrace"
    space.scrub(tree, stats_lib.zeros(), trigger="reactive")
    assert space.n_traces == 2, "a new trigger tag is a new gating"

    # a value-equal rule set on a fresh space shares nothing (fresh cache)
    # but still traces exactly once per layout
    other = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED))
    other.scrub(tree, stats_lib.zeros(), trigger="boundary")
    assert other.n_traces == 1


# ------------------------------------------------------------ legacy parity
@pytest.mark.parametrize("policy", ["zero", "neighbor_mean"])
@pytest.mark.parametrize("max_magnitude", [None, 1e3])
def test_legacy_single_knob_bit_exact_parity(policy, max_magnitude):
    """A legacy scalar config through the rules machinery reproduces the
    pre-redesign per-leaf repair_tensor loop bit for bit, and matches an
    explicitly-constructed one-rule RuleSet."""
    cfg = ApproxConfig(mode="memory", policy=policy,
                       max_magnitude=max_magnitude)
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    tree = {
        "w": jax.random.normal(k1, (16, 32)).at[0, 0].set(jnp.nan)
        .at[3, 4].set(jnp.inf).at[5, 5].set(4e4),
        "mu": jax.random.normal(k2, (64,)).at[7].set(jnp.nan),
        "step": jnp.zeros((), jnp.int32),
    }

    # pre-redesign reference: the scalar-knob per-leaf loop
    ref, nan_tot, inf_tot = {}, 0, 0
    for key in ("w", "mu"):
        fixed, n, i = repair_tensor(
            tree[key], policy=cfg.resolved_policy(),
            include_inf=cfg.include_inf, max_magnitude=cfg.max_magnitude,
        )
        ref[key] = fixed
        nan_tot += int(n)
        inf_tot += int(i)

    space = ApproxSpace(cfg)
    out, st = space.scrub(tree, stats_lib.zeros())
    for key in ("w", "mu"):
        np.testing.assert_array_equal(
            np.asarray(out[key]).view(np.uint32),
            np.asarray(ref[key]).view(np.uint32),
        )
    assert stats_lib.as_dict(st)["nan_found"] == nan_tot
    assert stats_lib.as_dict(st)["inf_found"] == inf_tot

    # the explicit one-rule lift is the same rule set (same digest)
    explicit = ApproxConfig(
        mode="memory",
        rules=RuleSet.single(RepairRule(
            detect=Detector(inf=True, max_magnitude=max_magnitude),
            fill=policy,
        )),
    )
    assert explicit.ruleset.digest() == cfg.ruleset.digest()


def test_ruleset_of_accepts_legacy_repair_config():
    from repro.core.repair import RepairConfig

    rs = ruleset_of(RepairConfig(mode="memory", policy="zero",
                                 include_inf=False))
    rule = rs.read_rule()
    assert rule.fill == "zero" and rule.detect.inf is False
    assert ruleset_of(ApproxConfig(rules=MIXED)) is not None


def test_space_rules_kwarg_routes_to_config():
    """ApproxSpace(rules=RuleSet) must configure REPAIR rules, not be
    silently captured by the mesh sharding-rules slot."""
    space = ApproxSpace(mode="memory", rules=MIXED)
    assert space.ruleset.digest() == MIXED.digest()
    assert space.rules is None                      # sharding slot untouched
    # raw (pattern, rule) bindings route the same way
    space2 = ApproxSpace(mode="memory", rules=tuple(MIXED.entries))
    assert space2.ruleset.digest() == MIXED.digest()
    # exact island actually applies
    regions = space.regions_for(mixed_state())
    assert regions["embed"]["table"] is Region.EXACT


def test_on_read_rule_repairs_at_use_in_memory_mode():
    """An on-read rule's leaves are skipped by scheduled scrubs; use() is
    their only repair point — so use() must fire for it even in memory
    mode (identity stays identity for boundary-trigger rule sets)."""
    on_read = RuleSet.single(
        RepairRule(detect=Detector(), fill="zero", trigger="on-read")
    )
    space = ApproxSpace(ApproxConfig(mode="memory", rules=on_read))
    x = jnp.array([1.0, jnp.nan, 3.0])
    out, st = space.scrub({"w": x}, stats_lib.zeros(), trigger="boundary")
    assert bool(jnp.isnan(out["w"][1]))             # scheduled scrub skips
    fixed, st = space.use(x, stats_lib.zeros())
    assert bool(jnp.isfinite(fixed).all())          # use-site repairs
    assert stats_lib.as_dict(st)["nan_found"] == 1
    # legacy memory-mode configs keep the identity fast path
    legacy = ApproxSpace(ApproxConfig(mode="memory"))
    assert legacy.use(x) is x


def test_pool_ledger_not_charged_for_gated_noop_pass():
    """A sweep (interval pass) over a pool whose every rule is
    reactive-only repairs nothing — the byte/scrub ledgers must not charge
    phantom work."""
    from repro.serving import PagedKVPool, ServingConfig

    model, _ = tiny_transformer()
    reactive_only = RuleSet.single(
        RepairRule(detect=Detector(inf=False), fill="zero",
                   trigger="reactive")
    )
    space = ApproxSpace(ApproxConfig(mode="memory", rules=reactive_only))
    pool = PagedKVPool(model, space, ServingConfig(
        page_size=4, n_pages=4, max_batch=1, max_pages_per_request=2,
    ))
    stats = pool.scrub_scope("pages", [0, 1], stats_lib.zeros(),
                             trigger="interval")
    assert pool.scrubbed_bytes == 0 and pool.scrub_calls == 0
    assert stats_lib.as_dict(stats)["events"] == 0
    # the reactive pass itself is charged normally
    pool.scrub_scope("pages", [0, 1], stats_lib.zeros(), trigger="reactive")
    assert pool.scrubbed_bytes > 0 and pool.scrub_calls == 1


def test_duplicate_rule_labels_do_not_shadow():
    rs = RuleSet((
        (r"(^|/)a(/|$)", RepairRule(fill="zero", label="x")),
        (r"(^|/)b(/|$)", RepairRule(fill="zero", label="x")),
    ))
    assert rs.labels() == ("x", "x#1", "default")
    space = ApproxSpace(ApproxConfig(mode="memory", rules=rs))
    tree = {"a": jnp.array([jnp.nan, 1.0]), "b": jnp.array([jnp.nan, jnp.nan])}
    space.scrub(tree)
    rstats = space.rule_stats()
    assert rstats["x"]["nan_found"] == 1
    assert rstats["x#1"]["nan_found"] == 2


def test_config_replace_keeps_rules():
    cfg = ApproxConfig(mode="memory", rules=MIXED)
    forced = cfg.memory_forced()
    assert forced.ruleset.digest() == MIXED.digest()
    lifted = ApproxConfig.from_legacy(cfg, ber=1e-5)
    assert lifted.ruleset.digest() == MIXED.digest()


# ------------------------------------------------------------- end to end
def test_mixed_ruleset_end_to_end(tmp_path):
    """The acceptance scenario: ONE RuleSet drives (1) the train boundary
    scrub, (2) the serving engine's page repair, and (3) the
    checkpoint-restore repair; per-rule counters land in the unified
    ledger."""
    from repro.checkpoint.manager import CheckpointManager
    from repro.core import stats as stats_lib
    from repro.serving import Engine, ServingConfig

    # (1) train: boundary scrub through wrap_train_step resolves the rules
    space = ApproxSpace(ApproxConfig(mode="memory", rules=MIXED))

    def raw_step(state, batch):
        return state, {"ok": jnp.isfinite(state["params"]["w"]).all()}

    step = jax.jit(space.wrap_train_step(raw_step))
    state = {
        "params": {"w": jnp.ones((4, 4)).at[0, 0].set(jnp.nan)},
        "opt": {"mu": jnp.ones((4,)).at[1].set(4e4)},
        "stats": stats_lib.zeros(),
    }
    out, metrics = step(state, {})
    assert bool(metrics["ok"])
    assert float(out["opt"]["mu"][1]) < 1e3          # opt rule range guard
    assert int(out["stats"]["nan_found"]) == 1
    assert int(out["stats"]["inf_found"]) == 1       # range bucket

    # (2) serving: the same rules flow through the engine via the model cfg
    model, params = tiny_transformer()
    model = type(model)(dataclasses.replace(
        model.cfg, repair=ApproxConfig(mode="off", rules=MIXED),
    ))
    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=8, max_batch=2, max_pages_per_request=4,
        repair="page", ber=1e-3, seed=1,
    ))
    assert eng.space.ruleset.digest() == MIXED.digest()
    rid = eng.add_request([5, 6, 7], max_new=6)
    results = eng.run()
    assert len(results[rid]["generated"]) == 6
    # pool leaves are "layers/k|v" -> the NaN-only reactive kv rule; any
    # repaired lane must be charged to that rule, none to the others
    rs = eng.rule_stats()
    kv_label = eng.space.ruleset.labels()[1]
    assert rs[kv_label]["inf_found"] == 0            # NaN-only detector
    for label, counters in rs.items():
        if label != kv_label:
            assert counters["events"] == 0

    # (3) checkpoint: restore repair against the same rules
    mgr = CheckpointManager(
        str(tmp_path), scrub=True,
        repair_cfg=ApproxConfig(mode="memory", rules=MIXED),
    )
    tree = mixed_state()
    mgr.save(1, tree, blocking=True)
    like = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )
    restored, _ = mgr.restore(like=like, repair=True)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # post-restore flips healed from the checkpoint under the same rules
    poisoned = dict(restored)
    poisoned["opt"] = {"mu": restored["opt"]["mu"].at[0, 0].set(jnp.nan)}
    healed = mgr.reference_repair(poisoned)
    np.testing.assert_array_equal(
        np.asarray(healed["opt"]["mu"]), np.asarray(tree["opt"]["mu"])
    )
    opt_label = mgr.space.ruleset.labels()[0]
    assert mgr.space.rule_stats()[opt_label]["nan_found"] >= 1


def test_rule_counts_thread_through_jitted_boundary_scrub():
    """ROADMAP leftover from PR 4: rule vectors cannot escape a trace, so
    the train state carries an int32[n_rules, 3] block the in-jit boundary
    scrub accumulates; train_loop folds it into space.rule_stats()."""
    import dataclasses as _dc

    from repro.configs import get_config
    from repro.launch.train import init_train_state, make_optimizer, train_loop
    from repro.models import build_model

    cfg = _dc.replace(
        get_config("qwen2-1.5b").reduced(),
        n_layers=1, d_model=32, n_heads=2, n_kv=2, head_dim=16, d_ff=64,
        vocab=31,
    )
    model = build_model(cfg)
    rules = RuleSet(entries=(
        (r"opt/", RepairRule(fill="zero", label="opt")),
        (r".*", RepairRule(fill="zero", label="rest")),
    ))
    space = ApproxSpace(ApproxConfig(mode="memory", rules=rules))
    opt = make_optimizer()
    state = init_train_state(model, opt, jax.random.PRNGKey(0), space=space)
    assert state["rule_counts"].shape == (3, 3)      # 2 rules + fallback

    # poison one param lane and one optimizer-moment lane
    state["params"]["embed"]["table"] = (
        state["params"]["embed"]["table"].at[0, 0].set(jnp.nan)
    )
    opt_state = state["opt"]
    mu = dict(opt_state.mu)
    mu["embed"] = dict(
        opt_state.mu["embed"],
        table=opt_state.mu["embed"]["table"].at[1, 1].set(jnp.inf),
    )
    state["opt"] = opt_state._replace(mu=mu)

    state, _ = train_loop(
        model, opt, lambda i: {"tokens": jnp.ones((2, 8), jnp.int32)},
        steps=2, key=jax.random.PRNGKey(1), state=state, space=space,
    )
    rs = space.rule_stats()
    assert rs["rest"]["nan_found"] == 1 and rs["rest"]["events"] == 1
    assert rs["opt"]["inf_found"] == 1 and rs["opt"]["events"] == 1
    assert rs["opt"]["nan_found"] == 0
    # folded exactly once: the state's block is zeroed after the fold
    assert int(np.asarray(state["rule_counts"]).sum()) == 0
