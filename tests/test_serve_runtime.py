"""Serving runtime plumbing: generate's scrub cadence, jit_serve_step
sharding construction on a 1-device mesh, batched-prefill parity, and the
memoized serving space."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.launch.mesh import make_local_mesh
from repro.launch.serve import generate, jit_serve_step, scrub_cache, serve_space
from repro.models import build_model
from repro.runtime import ApproxSpace, ScrubSchedule


@pytest.fixture(scope="module")
def model_params():
    return tiny_transformer()


def test_generate_scrub_cadence_fires_due(model_params):
    """The scrub_every cadence must actually consult ScrubSchedule.due and
    run the host-side periodic scrub exactly at the due ticks."""
    model, params = model_params
    interval = 3
    space = ApproxSpace(
        model.cfg.repair, mode="memory", max_magnitude=None,
        scrub=ScrubSchedule(boundary=False, interval=interval),
    )
    calls = []
    orig = space.scrub
    space.scrub = lambda tree, stats=None, **kw: (
        calls.append(1), orig(tree, stats, **kw)
    )[1]

    prompt = jnp.ones((1, 4), jnp.int32)
    S0, max_new = 4, 6
    generate(model, params, prompt, max_new=max_new, max_seq=16, space=space)

    # batched prefill checks due(0); the decode loop checks due(t) for
    # t in [S0, S0+max_new-1)
    expected = [t for t in [0] + list(range(S0, S0 + max_new - 1))
                if space.config.scrub.due(t)]
    assert len(calls) == len(expected) > 0


def test_jit_serve_step_builds_on_one_device_mesh(model_params):
    """Sharding construction (params/cache/token specs) must work on the
    degenerate 1-device mesh and produce a runnable step."""
    model, params = model_params
    mesh = make_local_mesh(data=1, model=1)
    assert mesh.devices.size == 1
    step, (params_sh, cache_sh, token_sh) = jit_serve_step(
        model, mesh, batch=2, max_seq=8, donate_cache=False
    )
    assert jax.tree.structure(params_sh) == jax.tree.structure(params)
    cache = model.init_cache(2, 8)
    nxt, logits, cache2 = step(
        params, cache, {"tokens": jnp.ones((2, 1), jnp.int32)},
        jnp.zeros((), jnp.int32),
    )
    assert nxt.shape == (2,)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(cache2) == jax.tree.structure(cache)


def test_generate_batched_prefill_matches_tokenwise(model_params):
    """One batched model.prefill pass must produce the same tokens as the
    old token-by-token cache warmup."""
    model, params = model_params
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 5), 1, 96)
    fast, _ = generate(model, params, prompt, max_new=4, max_seq=16)

    slow_model = build_model(model.cfg)
    slow_model.supports_batched_prefill = False     # force the legacy path
    slow, _ = generate(slow_model, params, prompt, max_new=4, max_seq=16)
    np.testing.assert_array_equal(np.asarray(fast), np.asarray(slow))


def test_serve_space_memoized_per_config(model_params):
    """serve_space must return one long-lived runtime per (config, cadence):
    repeated scrub_cache calls reuse its treedef-cached regions instead of
    rebuilding a fresh space (and re-annotating) every call."""
    model, _ = model_params
    s1 = serve_space(model)
    s2 = serve_space(model)
    assert s1 is s2
    assert serve_space(model, scrub_every=4) is not s1

    cache = model.init_cache(1, 8)
    scrub_cache(model, cache)
    n_cached = len(s1._region_cache)
    assert n_cached >= 1
    scrub_cache(model, cache)                       # same treedef: no growth
    assert len(s1._region_cache) == n_cached
