"""Fig. 6 analogue: jaxpr origin-traceability of protected operands."""
import jax
import jax.numpy as jnp

from repro.core import provenance


def specs(*shapes):
    return [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]


def test_direct_consumption_is_origin_traceable():
    def f(w, x):
        return x @ w                     # w consumed directly

    r = provenance.analyze(f, [0], *specs((8, 8), (4, 8)))
    assert r.total_arith == 1
    assert r.origin_traceable == 1
    assert r.fraction == 1.0


def test_transparent_chain_preserves_origin():
    def f(w, x):
        wt = jnp.transpose(w).reshape(8, 8)      # address-preserving ops
        return x @ wt

    r = provenance.analyze(f, [0], *specs((8, 8), (4, 8)))
    assert r.fraction == 1.0


def test_value_transform_breaks_origin():
    def f(w, x):
        w2 = jnp.tanh(w)                 # derived value: origin lost
        return x @ w2

    r = provenance.analyze(f, [0], *specs((8, 8), (4, 8)))
    # the matmul consumes a protected-DERIVED operand: counted, not traceable
    assert r.total_arith == 1
    assert r.origin_traceable == 0


def test_unprotected_args_not_counted():
    def f(w, x):
        return x @ w

    r = provenance.analyze(f, [], *specs((8, 8), (4, 8)))
    assert r.total_arith == 0 and r.fraction == 1.0


def test_mixed_graph_fraction():
    def f(w, x):
        a = x @ w                        # traceable
        b = x @ jnp.exp(w)               # derived
        c = x @ w[:, ::-1]               # rev: transparent -> traceable
        return a + b + c

    r = provenance.analyze(f, [0], *specs((8, 8), (4, 8)))
    dots = r.per_prim.get("dot_general")
    assert dots == [2, 3]                # 2 of 3 dots origin-traceable
    # the adds consume derived values (never origin-traceable); the paper's
    # register-mode fallback covers them
    assert 0 < r.fraction < 1.0


def test_scan_bodies_are_recursed():
    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    r = provenance.analyze(f, [0], *specs((3, 8, 8), (4, 8)))
    assert r.total_arith >= 1            # the dot inside the scan is seen
    assert r.origin_traceable >= 1       # w enters the body unmodified
