"""End-to-end behaviour of the paper's claim (§2.1 + §4): under approximate
memory, training survives WITH reactive NaN repair and is destroyed without
it; checkpoint/restart is bit-consistent; serving repairs poisoned caches."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint
from repro.configs import get_config
from repro.core import repair as repair_lib
from repro.data import SyntheticStream
from repro.launch.serve import generate, scrub_cache
from repro.launch.train import (
    build_train_step,
    init_train_state,
    inject_state,
    make_optimizer,
    train_loop,
)
from repro.models import build_model
import dataclasses


def tiny_cfg(mode="memory", policy="neighbor_mean", max_magnitude=1e3):
    cfg = get_config("qwen2-1.5b").reduced()
    return dataclasses.replace(
        cfg,
        n_layers=2,
        vocab=256,
        repair=repair_lib.RepairConfig(
            mode=mode, policy=policy, max_magnitude=max_magnitude
        ),
    )


BER = 2e-6      # aggressive approximate-memory regime (~1 NaN every few steps)
STEPS = 30


def run(mode, ber=BER, steps=STEPS, seed=0):
    cfg = tiny_cfg(mode)
    model = build_model(cfg)
    opt = make_optimizer(peak_lr=3e-3, warmup=5, total=steps)
    data = SyntheticStream(cfg, seed=seed, batch=8, seq=32)
    state, hist = train_loop(
        model, opt, data, steps=steps, key=jax.random.PRNGKey(seed),
        ber=ber, log_every=max(steps // 10, 1),
    )
    return state, hist


def test_training_without_repair_gets_poisoned():
    state, hist = run("off")
    # with repair off at this BER, NaNs reach the loss and stay
    assert any(not np.isfinite(h["loss"]) for h in hist) or not all(
        bool(jnp.isfinite(l.astype(jnp.float32)).all())
        for l in jax.tree.leaves(state["params"])
    )


def test_nan_only_repair_is_insufficient_for_training():
    """Beyond-paper finding (README §Config): the paper-faithful NaN/Inf-only
    repair does NOT survive sustained-BER training — a high-exponent drift
    value (~1e38, a legal float) explodes the loss before it ever becomes a
    NaN in memory.  The magnitude-clamp extension is what makes the
    technique deployable for training."""
    cfg = tiny_cfg("memory", max_magnitude=None)     # paper-faithful
    model = build_model(cfg)
    opt = make_optimizer(peak_lr=3e-3, warmup=5, total=STEPS)
    data = SyntheticStream(cfg, seed=0, batch=8, seq=32)
    state, hist = train_loop(
        model, opt, data, steps=STEPS, key=jax.random.PRNGKey(0),
        ber=BER, log_every=3,
    )
    exploded = any(
        (not np.isfinite(h["loss"])) or h["loss"] > 1e3 for h in hist
    ) or not all(
        bool(jnp.isfinite(l.astype(jnp.float32)).all())
        for l in jax.tree.leaves(state["params"])
    )
    assert exploded


def test_training_with_memory_repair_converges():
    state, hist = run("memory")
    losses = [h["loss"] for h in hist]
    assert all(np.isfinite(l) for l in losses)
    assert losses[-1] < losses[0]                 # actually learns
    assert hist[-1]["nan_found"] + hist[-1]["inf_found"] > 0   # repairs fired
    for l in jax.tree.leaves(state["params"]):
        if jnp.issubdtype(l.dtype, jnp.floating):
            assert bool(jnp.isfinite(l.astype(jnp.float32)).all())


def test_register_mode_also_survives():
    _, hist = run("register", steps=15)
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_repair_overhead_loss_parity_without_errors():
    """At BER=0 the repaired and unrepaired runs are numerically identical —
    the paper's 'no overhead when nothing happens' property, as exact
    equality of the training trajectory."""
    _, h_mem = run("memory", ber=0.0, steps=10)
    _, h_off = run("off", ber=0.0, steps=10)
    np.testing.assert_allclose(
        [h["loss"] for h in h_mem], [h["loss"] for h in h_off],
        rtol=0, atol=0,
    )


def test_checkpoint_restart_resumes_identically(tmp_path):
    """Fault-tolerance: kill at step 10, restore, continue to 20 — the
    trajectory must match an uninterrupted run (stateless data + exact
    optimizer state)."""
    cfg = tiny_cfg("memory")
    model = build_model(cfg)
    opt = make_optimizer(peak_lr=3e-3, warmup=5, total=20)
    data = SyntheticStream(cfg, seed=3, batch=8, seq=32)
    key = jax.random.PRNGKey(3)

    # uninterrupted
    ref_state, _ = train_loop(model, opt, data, steps=20, key=key, ber=0.0)

    # interrupted at 10 + restart
    mgr = CheckpointManager(str(tmp_path), keep=2, scrub=True)
    st, _ = train_loop(
        model, opt, data, steps=10, key=key, ber=0.0,
        checkpoint_manager=mgr, checkpoint_every=10,
    )
    del st
    like = {
        "params": model.abstract_params(),
        "opt": opt.abstract_state(model.abstract_params()),
        "stats": {k: jax.ShapeDtypeStruct((), jnp.int32)
                  for k in ("flips", "nan_found", "inf_found", "events")},
    }
    restored, step0 = load_checkpoint(str(tmp_path), like=like)
    assert step0 == 10
    resumed, _ = train_loop(
        model, opt, data, steps=20, key=key, ber=0.0,
        state=restored, start_step=10,
    )
    for a, b in zip(jax.tree.leaves(ref_state["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serving_with_poisoned_cache_recovers():
    """Inject NaNs into a live KV cache mid-generation; scrub_cache repairs
    it and generation continues finite."""
    cfg = tiny_cfg("register")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jnp.ones((2, 4), jnp.int32)
    tokens, stats = generate(model, params, prompt, max_new=8, max_seq=32,
                             scrub_every=0)
    assert tokens.shape == (2, 12)

    # now poison a cache and scrub it
    cache = model.init_cache(2, 32)
    cache = jax.tree.map(
        lambda x: x.at[(0,) * x.ndim].set(jnp.nan)
        if jnp.issubdtype(x.dtype, jnp.floating) else x,
        cache,
    )
    fixed, s = scrub_cache(model, cache)
    assert int(s["nan_found"]) > 0
    for l in jax.tree.leaves(fixed):
        if jnp.issubdtype(l.dtype, jnp.floating):
            assert bool(jnp.isfinite(l.astype(jnp.float32)).all())


def test_injection_hits_only_approx_region():
    cfg = tiny_cfg("memory")
    model = build_model(cfg)
    opt = make_optimizer()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    before_step = int(state["opt"].step)
    poisoned = inject_state(state, jax.random.PRNGKey(1), ber=1e-3)
    assert int(poisoned["opt"].step) == before_step      # exact region intact
