"""EDEN-style BER autopilot: campaign determinism + JSON round trips, the
frontier solver's budget/collapse logic, the online guard's hysteresis
ladder, and the train-loop / serving-engine wiring."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.autopilot import (
    CampaignConfig,
    FrontierAssignment,
    GroupAssignment,
    NOMINAL_REFRESH_S,
    OnlineGuard,
    ProfileCell,
    RegionGroup,
    ToleranceProfile,
    campaign_space,
    group_regions,
    run_campaign,
    solve_frontier,
)
from repro.core import regions as regions_lib
from repro.core.rules import Detector, RepairRule, RuleSet
from repro.runtime import ApproxConfig, ApproxSpace, AutopilotConfig


WEIGHT_RULE = RepairRule(
    detect=Detector(nan=True, inf=True, max_magnitude=1e3),
    fill="neighbor_mean", trigger="boundary",
)


# ----------------------------------------------------------------- configs
def test_autopilot_config_validates_and_normalizes():
    cfg = AutopilotConfig(expected={"b": 1.0, "a": 0.5})
    assert cfg.expected == (("a", 0.5), ("b", 1.0))     # sorted tuple
    assert cfg.expected_rate("a") == 0.5
    assert cfg.expected_rate("missing") == 0.0
    # threshold = tolerance * rate * window + floor
    assert cfg.threshold("b") == pytest.approx(
        cfg.tolerance * 1.0 * cfg.window + cfg.floor
    )
    with pytest.raises(ValueError):
        AutopilotConfig(window=0)
    with pytest.raises(ValueError):
        AutopilotConfig(patience=0)


def test_campaign_config_validation():
    g = RegionGroup(name="g", pattern="params/")
    with pytest.raises(ValueError):
        CampaignConfig(groups=(), refresh_points=(1.0,))
    with pytest.raises(ValueError):
        CampaignConfig(groups=(g,), refresh_points=())
    with pytest.raises(ValueError):
        CampaignConfig(groups=(g, g), refresh_points=(1.0,))
    with pytest.raises(ValueError):
        CampaignConfig(groups=(g,), refresh_points=(1.0,), episode="eval")
    with pytest.raises(ValueError):
        CampaignConfig(groups=(g,), refresh_points=(1.0,), steps=1)


# --------------------------------------------------- rule-swap primitives
def test_ruleset_with_rule_replaces_in_place_keeping_label_and_order():
    rs = RuleSet((
        ("params/", RepairRule(detect=Detector(nan=True), label="w")),
        ("cache/", RepairRule(detect=Detector(nan=True), label="kv")),
    ))
    swapped = rs.with_rule("kv", RepairRule.exact_rule())
    assert [r.label for _, r in swapped.entries] == ["w", "kv"]
    assert [p for p, _ in swapped.entries] == ["params/", "cache/"]
    assert swapped.entries[1][1].exact
    assert not rs.entries[1][1].exact             # original untouched
    assert swapped.digest() != rs.digest()
    with pytest.raises(KeyError):
        rs.with_rule("nope", RepairRule.exact_rule())


def test_space_set_rules_swaps_digest_and_preserves_counters():
    rs = RuleSet((
        ("w", RepairRule(detect=Detector(nan=True), label="w")),
    ))
    space = ApproxSpace(ApproxConfig(mode="memory", rules=rs))
    space.record_rule_counts(
        jnp.asarray([[3, 1, 4], [0, 0, 0]], jnp.int32)
    )
    before = space.rule_stats()["w"]
    d0 = space.ruleset.digest()
    space.set_rules(rs.with_rule("w", RepairRule(
        detect=Detector(nan=True, inf=True, max_magnitude=10.0),
        label="w",
    )))
    assert space.ruleset.digest() != d0
    # same labels -> the per-rule ledger survives the swap
    assert space.rule_stats()["w"] == before
    assert space.config.rules is space.ruleset


# ------------------------------------------------------------ region masks
def test_group_regions_masks_non_matching_leaves_exact():
    tree = {
        "params": {"w": jnp.ones((4, 4))},
        "cache": {"k": jnp.ones((2, 2))},
        "step": jnp.zeros((), jnp.int32),
    }
    space = campaign_space((RegionGroup(name="g", pattern=r"cache/"),))
    masked = group_regions(space, tree, r"cache/")
    flat = {
        regions_lib.path_str(p): r
        for (p, _), r in zip(
            jax.tree_util.tree_flatten_with_path(tree)[0],
            jax.tree.leaves(masked),
        )
    }
    assert flat["cache/k"] == regions_lib.Region.APPROX
    assert flat["params/w"] == regions_lib.Region.EXACT
    assert flat["step"] == regions_lib.Region.EXACT


def test_masked_injection_confines_flips_to_the_group():
    tree = {
        "params": {"w": jnp.ones((64, 64))},
        "cache": {"k": jnp.ones((64, 64))},
    }
    space = campaign_space((RegionGroup(name="g", pattern=r"cache/"),))
    masked = group_regions(space, tree, r"cache/")
    out, flips = space.inject(
        tree, jax.random.PRNGKey(0), 1e-3, record=False, regions=masked
    )
    assert int(flips) > 0
    np.testing.assert_array_equal(
        np.asarray(out["params"]["w"]), np.asarray(tree["params"]["w"])
    )
    assert not np.array_equal(
        np.asarray(out["cache"]["k"]), np.asarray(tree["cache"]["k"])
    )


# ------------------------------------------------------- campaign + JSON
def _mini_campaign():
    return CampaignConfig(
        groups=(
            RegionGroup(
                name="ffn", pattern=r"params/layers/mlp/", rule=WEIGHT_RULE
            ),
            RegionGroup(name="kv", pattern=r"cache/"),
        ),
        refresh_points=(1.0, 4.0),
        episode="serve",
        steps=4,
        batch=2,
        prompt_len=4,
        seed=0,
    )


def test_campaign_is_seed_deterministic_and_round_trips_json():
    model, params = tiny_transformer()
    cfg = _mini_campaign()
    p1 = run_campaign(model, cfg, params=params)
    p2 = run_campaign(model, cfg, params=params)
    assert p1.cells == p2.cells
    assert p1.metric == "token_divergence"
    assert len(p1.cells) == 4                     # 2 groups x 2 points
    assert {c.group for c in p1.cells} == {"ffn", "kv"}
    # injected cells actually flipped bits at the aggressive point
    assert p1.cell("ffn", 4.0).flips > 0
    rt = ToleranceProfile.from_json(p1.to_json())
    assert rt == p1
    json.loads(p1.to_json())                      # valid JSON text


def test_campaign_train_episode_measures_loss_delta():
    model, params = tiny_transformer()
    cfg = dataclasses.replace(
        _mini_campaign(),
        episode="train",
        groups=(
            RegionGroup(
                name="ffn", pattern=r"params/layers/mlp/", rule=WEIGHT_RULE
            ),
        ),
        refresh_points=(4.0,),
    )
    prof = run_campaign(model, cfg, params=params)
    assert prof.metric == "loss_delta"
    (cell,) = prof.cells
    assert cell.flips > 0
    assert np.isfinite(cell.quality)


# ------------------------------------------------------------- the solver
def _profile(cells):
    groups = tuple(
        RegionGroup(name=n, pattern=f"{n}/")
        for n in sorted({c.group for c in cells})
    )
    return ToleranceProfile(
        model="m", episode="serve", metric="token_divergence",
        steps=4, seed=0, groups=groups,
        refresh_points=tuple(sorted({c.refresh_s for c in cells})),
        cells=tuple(cells),
    )


def _cell(group, refresh, quality, faults=0.5, nbytes=1024):
    from repro.core.injection import ApproxMemoryModel

    mm = ApproxMemoryModel.from_refresh(refresh)
    return ProfileCell(
        group=group, refresh_s=refresh, ber=mm.ber,
        energy_saving=mm.energy_saving, quality=quality,
        flips=7, faults_per_step=faults, approx_bytes=nbytes,
    )


def test_solver_picks_longest_refresh_within_budget():
    prof = _profile([
        _cell("a", 0.256, 0.0),
        _cell("a", 1.0, 0.1),
        _cell("a", 4.0, 0.9),
    ])
    fr = solve_frontier(prof, budget=0.25)
    a = fr.assignment("a")
    assert a.refresh_s == 1.0 and not a.collapsed
    assert a.quality == 0.1
    assert fr.refresh_map() == {"a/": 1.0}


def test_solver_collapses_hopeless_group_to_exact_island():
    prof = _profile([
        _cell("a", 0.256, 0.0),
        _cell("a", 1.0, 0.05),
        _cell("s", 0.256, 0.6),
        _cell("s", 1.0, float("nan")),      # diverged episode: never passes
    ])
    fr = solve_frontier(prof, budget=0.25)
    s = fr.assignment("s")
    assert s.collapsed and s.refresh_s == NOMINAL_REFRESH_S
    assert s.ber == 0.0 and s.energy_saving == 0.0
    rules = dict(fr.ruleset().entries)
    assert rules["s/"].exact and rules["s/"].label == "s"
    assert not rules["a/"].exact
    # guard contract: collapsed group expects zero faults
    auto = fr.autopilot()
    assert auto.expected_rate("s") == 0.0
    assert auto.expected_rate("a") == 0.5
    # byte-weighted saving counts the collapsed group's bytes at 0 saving
    assert 0.0 < fr.energy_saving < fr.assignment("a").energy_saving


def test_frontier_round_trips_json():
    prof = _profile([
        _cell("a", 1.0, 0.1),
        _cell("s", 1.0, 0.9),
    ])
    fr = solve_frontier(prof, budget=0.3)
    rt = FrontierAssignment.from_json(fr.to_json())
    assert rt.assignments == fr.assignments
    assert rt.budget == fr.budget
    d = json.loads(fr.to_json())
    assert {e["rule"]["label"] for e in d["ruleset"]} == {"a", "s"}


# ------------------------------------------------------------- the guard
class _FakeSpace:
    """Scripted rule_stats stream for hysteresis tests."""

    def __init__(self, ruleset):
        self._ruleset = ruleset
        self.faults = {r.label: 0 for _, r in ruleset.entries}
        self.swaps = []

    @property
    def ruleset(self):
        return self._ruleset

    def rule_stats(self):
        return {
            label: {"nan_found": n, "inf_found": 0, "events": n}
            for label, n in self.faults.items()
        }

    def set_rules(self, ruleset):
        self._ruleset = ruleset
        self.swaps.append(ruleset)
        return self


def _guarded(window=2, patience=2, cooldown=1, expected=(("g", 0.0),),
             rule=None):
    rule = rule or RepairRule(
        detect=Detector(nan=True), fill="zero", trigger="boundary",
        label="g",
    )
    space = _FakeSpace(RuleSet((("g/", rule),)))
    cfg = AutopilotConfig(
        window=window, tolerance=1.0, floor=0.5, patience=patience,
        cooldown=cooldown, expected=expected,
    )
    return space, OnlineGuard(space, cfg)


def test_guard_needs_patience_consecutive_bad_windows():
    space, guard = _guarded(patience=2)
    space.faults["g"] += 5
    assert guard.observe() == []                  # strike 1: no trip
    space.faults["g"] += 5
    decisions = guard.observe()                   # strike 2: trip
    assert len(decisions) == 1
    assert decisions[0]["label"] == "g"
    assert decisions[0]["action"] == "stricter"
    assert len(space.swaps) == 1


def test_guard_clean_window_resets_strikes():
    space, guard = _guarded(patience=2)
    space.faults["g"] += 5
    assert guard.observe() == []
    assert guard.observe() == []                  # clean window: reset
    space.faults["g"] += 5
    assert guard.observe() == []                  # strike 1 again, no trip
    assert space.swaps == []


def test_guard_cooldown_ignores_windows_after_a_trip():
    space, guard = _guarded(patience=1, cooldown=2)
    space.faults["g"] += 5
    assert len(guard.observe()) == 1              # trip immediately
    space.faults["g"] += 50
    assert guard.observe() == []                  # cooldown window 1
    space.faults["g"] += 50
    assert guard.observe() == []                  # cooldown window 2
    space.faults["g"] += 50
    assert len(guard.observe()) == 1              # armed again


def test_guard_ladder_stricter_then_exact():
    rule = RepairRule(
        detect=Detector(nan=True), fill="zero", trigger="reactive",
        label="g",
    )
    space, guard = _guarded(patience=1, cooldown=0, rule=rule)
    space.faults["g"] += 5
    (d1,) = guard.observe()
    assert d1["action"] == "stricter" and d1["stage"] == 1
    tightened = space.ruleset.entries[0][1]
    assert tightened.detect.nan and tightened.detect.inf
    assert tightened.trigger == "boundary"
    space.faults["g"] += 5
    (d2,) = guard.observe()
    assert d2["action"] == "exact" and d2["stage"] == 2
    assert space.ruleset.entries[0][1].exact
    # fully demoted: further drift has nothing left to tighten
    space.faults["g"] += 5
    assert guard.observe() == []
    assert guard.summary()["trips"] == 2


def test_guard_tick_observes_every_window_steps():
    space, guard = _guarded(window=3, patience=1)
    space.faults["g"] += 5
    assert guard.tick() == []
    assert guard.tick() == []
    assert len(guard.tick()) == 1                 # 3rd tick closes a window


def test_guard_within_expectation_never_trips():
    space, guard = _guarded(patience=1, expected=(("g", 2.0),))
    # threshold = 1.0 * 2.0 * 2 + 0.5 = 4.5; 4 faults/window is in budget
    for _ in range(5):
        space.faults["g"] += 4
        assert guard.observe() == []
    assert space.swaps == []


# ------------------------------------------------------------- the wiring
def test_train_loop_guard_tightens_under_fault_pressure():
    from repro.launch.train import make_optimizer, train_loop

    model, _ = tiny_transformer()
    rules = RuleSet((
        (r"params/|opt/", RepairRule(
            detect=Detector(nan=True, inf=True), fill="zero",
            trigger="boundary", label="resident",
        )),
    ))
    space = ApproxSpace(ApproxConfig(
        mode="memory",
        rules=rules,
        autopilot=AutopilotConfig(
            window=2, tolerance=1.0, floor=0.0, patience=1, cooldown=0,
            expected=(("resident", 0.0),),
        ),
    ))
    vocab = model.cfg.vocab

    def data(i):
        return {"tokens": jax.random.randint(
            jax.random.fold_in(jax.random.PRNGKey(3), i), (2, 8), 1, vocab
        )}

    state, history = train_loop(
        model, make_optimizer(warmup=1, total=6), data,
        steps=6, key=jax.random.PRNGKey(0), ber=2e-3, space=space,
        log_every=0,
    )
    trips = [h for h in history if "autopilot" in h]
    assert trips, "guard never tripped despite ber=2e-3 vs expected 0"
    first = trips[0]["autopilot"][0]
    assert first["label"] == "resident"
    # the deployed rule is now stricter than the profiled one
    deployed = dict(space.ruleset.entries)[r"params/|opt/"]
    assert deployed.exact or deployed.detect.max_magnitude is not None
    # the loop kept training after the executable rebuild
    assert "rule_counts" in state


def test_engine_guard_trips_and_keeps_serving():
    from repro.serving import Engine, ServingConfig

    model, params = tiny_transformer()
    cfg = ServingConfig(
        page_size=4, n_pages=16, max_batch=2, max_pages_per_request=4,
        repair="page", paged_decode="off", ber=2e-3, seed=5,
        autopilot=AutopilotConfig(
            window=2, tolerance=1.0, floor=0.0, patience=1, cooldown=0,
            expected=(("default", 0.0),),
        ),
    )
    eng = Engine(model, params, cfg)
    assert eng.guard is not None
    eng.add_request([5, 6, 7], max_new=8)
    results = eng.run()
    # served to completion: the prompt plus all 8 new tokens
    assert len(results[0]["tokens"]) == 3 + 8
    assert eng.metrics()["autopilot_trips"] >= 1
    assert eng.guard.trips[0]["label"] == "default"


def test_engine_without_autopilot_has_no_guard():
    from repro.serving import Engine, ServingConfig

    model, params = tiny_transformer()
    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=16, max_batch=2, max_pages_per_request=4,
    ))
    assert eng.guard is None
    assert eng.metrics()["autopilot_trips"] == 0


# ------------------------------------------------------- preset acceptance
def test_presets_exist_for_transformer_and_recurrent():
    from repro.configs import get_preset, preset_names

    assert set(preset_names()) >= {"transformer", "recurrent"}
    for name in ("transformer", "recurrent"):
        p = get_preset(name)
        assert len(p.campaign.groups) >= 2
        assert len(p.campaign.refresh_points) >= 2
        assert p.budget > 0
    with pytest.raises(KeyError):
        get_preset("nope")


def test_recurrent_smoke_campaign_separates_state_from_weights():
    """The acceptance asymmetry at smoke scale: 2 groups x 2 refresh points
    on the xLSTM preset — the recurrent state must land on a strictly
    shorter (more conservative) refresh than the projection weights."""
    from repro.configs import get_preset

    p = get_preset("recurrent", steps=6)
    p = dataclasses.replace(
        p, campaign=dataclasses.replace(
            p.campaign, refresh_points=(1.0, 2.0)
        )
    )
    profile = run_campaign(p.build_model(), p.campaign)
    frontier = solve_frontier(profile, p.budget)
    weights = frontier.assignment("proj_weights")
    state = frontier.assignment("recurrent_state")
    assert not weights.collapsed
    assert state.refresh_s < weights.refresh_s
    # and the emitted artifacts carry the assignment
    assert frontier.refresh_map()[state.pattern] == state.refresh_s
    auto = frontier.autopilot()
    assert auto.expected_rate("proj_weights") >= 0.0
