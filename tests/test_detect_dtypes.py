"""Per-dtype detection edge cases through ``fatal_masks`` — the satellite
coverage for every dtype a ``RepairRule`` can bind (float16/float64 join
float32/bfloat16): signaling vs quiet NaN patterns, subnormals, negative
zero, max-finite, and the range guard's exponent-field compare.

``fatal_masks`` is the ONE definition of "fatal" shared by the jnp repair
path, the rule detectors, and (via the constants operand) the Pallas
kernels, so these patterns pin the contract at the bit level per dtype.
float64 runs under a local ``enable_x64`` scope (the suite is x32).
"""
import contextlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detect
from repro.core.repair import fatal_masks
from repro.core.rules import Detector

DTYPES = [jnp.float16, jnp.float32, jnp.bfloat16, jnp.float64]


def _scope(dtype):
    """float64 bit views need x64 enabled; everything else runs as-is."""
    if jnp.dtype(dtype) == jnp.float64:
        return jax.experimental.enable_x64()
    return contextlib.nullcontext()


def _cases(lay):
    """(bits, is_nan, is_inf) triples covering the per-dtype edge patterns."""
    quiet_bit = 1 << (lay.man_bits - 1)
    return [
        (0, False, False),                                   # +0
        (lay.sign_mask, False, False),                       # -0 (NOT fatal)
        (1, False, False),                                   # min subnormal
        (lay.man_mask, False, False),                        # max subnormal
        (lay.exp_mask - 1, False, False),                    # max finite
        (lay.exp_mask, False, True),                         # +inf
        (lay.exp_mask | lay.sign_mask, False, True),         # -inf
        (lay.exp_mask | 1, True, False),                     # signaling NaN
        (lay.exp_mask | quiet_bit, True, False),             # quiet NaN
        (lay.exp_mask | lay.man_mask, True, False),          # all-ones mantissa
        (lay.sign_mask | lay.exp_mask | quiet_bit, True, False),  # -qNaN
        (lay.sign_mask | lay.exp_mask | 1, True, False),     # -sNaN
    ]


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
def test_fatal_masks_edge_patterns(dtype):
    with _scope(dtype):
        lay = detect.layout_of(dtype)
        cases = _cases(lay)
        bits = np.array([b for b, _, _ in cases], np.dtype(lay.int_dtype))
        x = jax.lax.bitcast_convert_type(jnp.asarray(bits), dtype)

        nan_m, inf_m = fatal_masks(x)                        # NaN + Inf
        assert nan_m.tolist() == [n for _, n, _ in cases]
        assert inf_m.tolist() == [i for _, _, i in cases]

        nan_m, inf_m = fatal_masks(x, include_inf=False)     # NaN-only
        assert nan_m.tolist() == [n for _, n, _ in cases]
        assert not any(inf_m.tolist())


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
def test_signaling_vs_quiet_nan_both_fatal(dtype):
    """The paper's pattern is structural (exp all-ones + mantissa != 0):
    quiet (MSB of mantissa set) and signaling NaNs are the same flip class,
    and both must repair identically under a rule detector."""
    with _scope(dtype):
        lay = detect.layout_of(dtype)
        quiet = lay.exp_mask | (1 << (lay.man_bits - 1))
        signaling = lay.exp_mask | 1
        bits = np.array([quiet, signaling], np.dtype(lay.int_dtype))
        x = jax.lax.bitcast_convert_type(jnp.asarray(bits), dtype)
        nan_m, _ = Detector(inf=False).masks(x)
        assert nan_m.tolist() == [True, True]
        # IEEE agreement, via numpy's own view of the same bits
        np_dt = {16: np.uint16, 32: np.uint32, 64: np.uint64}[lay.width]
        if jnp.dtype(dtype) != jnp.bfloat16:     # numpy has no bf16
            host = bits.astype(np_dt).view(np.dtype(dtype).str)
            np.testing.assert_array_equal(np.isnan(host), [True, True])


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
def test_subnormals_and_negzero_never_fatal(dtype):
    """Subnormals (exp field == 0) and ±0 must never trip any detector
    bucket — a repair that zeroed denormals would silently quantize."""
    with _scope(dtype):
        lay = detect.layout_of(dtype)
        bits = np.array(
            [0, lay.sign_mask, 1, lay.man_mask, lay.sign_mask | 1],
            np.dtype(lay.int_dtype),
        )
        x = jax.lax.bitcast_convert_type(jnp.asarray(bits), dtype)
        for det in (Detector(), Detector(inf=False),
                    Detector(max_magnitude=1e3)):
            nan_m, inf_m = det.masks(x)
            assert not any(nan_m.tolist()), det
            assert not any(inf_m.tolist()), det


@pytest.mark.parametrize("dtype", DTYPES, ids=lambda d: jnp.dtype(d).name)
def test_range_guard_exponent_compare(dtype):
    """max_magnitude is an exponent-field compare: values at/above the
    threshold's binade are fatal (inf bucket), values below are not, NaN
    keeps its own bucket — per dtype layout."""
    with _scope(dtype):
        x = jnp.array([1.0, 900.0, 2048.0, jnp.inf, jnp.nan], dtype)
        nan_m, inf_m = fatal_masks(x, max_magnitude=1024.0)
        assert nan_m.tolist() == [False, False, False, False, True]
        # 900 sits in the binade below 1024 -> not fatal; 2048 and inf are
        assert inf_m.tolist() == [False, False, True, True, False]


def test_float16_vs_bfloat16_layouts_differ():
    """The same 16-bit pattern classifies differently under the two 16-bit
    layouts (5/10 vs 8/7 split) — per-dtype constants are load-bearing."""
    pattern = 0x7C01                       # f16: sNaN; bf16: a finite value
    bits = jnp.asarray(np.array([pattern], np.uint16))
    f16_nan = detect.is_nan_bits(bits, jnp.float16)
    bf16_nan = detect.is_nan_bits(bits, jnp.bfloat16)
    assert bool(f16_nan[0]) is True
    assert bool(bf16_nan[0]) is False


def test_custom_bitpattern_binds_per_dtype():
    """A bitpattern entry tagged with a dtype fires only there; an untagged
    entry fires for every dtype."""
    det = Detector(nan=False, inf=False,
                   bitpatterns=(("float16", 0x7FFF, 0x7C01),))
    f16 = jax.lax.bitcast_convert_type(
        jnp.asarray(np.array([0x7C01], np.uint16)), jnp.float16
    )
    f32 = jnp.array([1.0], jnp.float32)
    assert det.masks(f16)[0].tolist() == [True]
    assert det.masks(f32)[0].tolist() == [False]
