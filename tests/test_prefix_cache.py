"""Prefix cache: refcount discipline (double-free is an error, shared pages
survive preemption), dwell-charged scrub-on-reuse, copy-on-write forks,
LRU eviction under pressure, and zero-BER bit parity against the no-cache
engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.runtime import ApproxConfig, ApproxSpace
from repro.serving import Engine, PagedKVPool, PrefixCache, ServingConfig


@pytest.fixture(scope="module")
def model_params():
    return tiny_transformer()


def _cfg(**kw):
    base = dict(page_size=4, n_pages=16, max_batch=4,
                max_pages_per_request=5, seed=3)
    base.update(kw)
    return ServingConfig(**base)


def _pool(model, **kw):
    return PagedKVPool(model, ApproxSpace(mode="memory"), _cfg(**kw))


# ---------------------------------------------------------------- refcounts
def test_pool_double_free_is_an_error(model_params):
    model, _ = model_params
    pool = _pool(model)
    pages = pool.alloc(2)
    pool.free(pages)
    with pytest.raises(RuntimeError, match="double free"):
        pool.free(pages)


def test_pool_share_keeps_page_resident(model_params):
    model, _ = model_params
    pool = _pool(model)
    (page,) = pool.alloc(1)
    pool.share([page])                      # rc 2
    pool.free([page])                       # rc 1 — still resident
    assert not pool.is_free(page)
    pool.free([page])                       # rc 0 — back on the free list
    assert pool.is_free(page)
    with pytest.raises(RuntimeError, match="sharing free page"):
        pool.share([page])


def test_pool_dwell_clock_and_copy_page(model_params):
    model, _ = model_params
    pool = _pool(model)
    src, dst = pool.alloc(2)
    pool.now = 5
    assert pool.dwell(src) == 5
    pool.copy_page(src, dst)                # clone inherits the dwell stamp
    assert pool.dwell(dst) == 5
    pool.mark_clean([src])
    assert pool.dwell(src) == 0 and pool.dwell(dst) == 5
    for a in jax.tree.leaves(pool.tree):
        np.testing.assert_array_equal(np.asarray(a[src]), np.asarray(a[dst]))


def test_expected_faults_is_linear_in_dwell():
    cfg = ApproxConfig(mode="memory", ber=1e-6)
    one = cfg.expected_faults(1024, 1.0)
    assert one == pytest.approx(1024 * 8 * 1e-6)
    assert cfg.expected_faults(1024, 3.0) == pytest.approx(3 * one)
    assert cfg.expected_faults(1024, 0.0) == 0.0
    assert cfg.expected_faults(1024, 2.0, ber=0.0) == 0.0


def test_serving_config_validates_cache_cap():
    with pytest.raises(ValueError, match="max_cached_pages"):
        ServingConfig(n_pages=8, max_cached_pages=9)
    with pytest.raises(ValueError, match="max_cached_pages"):
        ServingConfig(max_cached_pages=-1)


# ------------------------------------------------------------- cache basics
def _run_engine(model, params, cfg, prompts, *, stagger=True, max_new=4):
    eng = Engine(model, params, cfg)
    rids = []
    for p in prompts:
        rids.append(eng.add_request(p, max_new=max_new))
        if stagger:
            eng.run()
    eng.run()
    return eng, [eng.results[r]["generated"] for r in rids]


def test_cache_hits_skip_prefix_prefill(model_params):
    model, params = model_params
    shared = [1, 2, 3, 4, 5, 6, 7, 8]
    prompts = [shared + [9], shared + [10], shared + [9, 11, 12]]
    eng, _ = _run_engine(model, params, _cfg(prefix_cache=True), prompts)
    s = eng.cache_stats()
    assert s["enabled"] and s["hits"] == 2 and s["misses"] == 1
    # prompt 2 rides the two full cached pages (8 tokens); prompt 3 also
    # matches the first prompt's 9-token partial entry (8 + 9 = 17)
    assert s["hit_tokens"] == 17 and eng.prefill_tokens_saved == 17
    assert eng.metrics()["prefill_tokens_saved"] == 17


def test_cache_disabled_reports_disabled(model_params):
    model, params = model_params
    eng, _ = _run_engine(model, params, _cfg(), [[1, 2, 3]])
    assert eng.cache_stats() == {
        "enabled": False, "prefill_tokens_saved": 0,
    }


def test_zero_ber_cache_tokens_bit_identical(model_params):
    model, params = model_params
    shared = [1, 2, 3, 4, 5, 6, 7, 8]
    prompts = [shared + [9], shared + [10], shared + [9, 11, 12],
               shared + [9]]
    base, out0 = _run_engine(model, params, _cfg(), prompts)
    cached, out1 = _run_engine(
        model, params, _cfg(prefix_cache=True), prompts
    )
    assert out0 == out1
    assert cached.cache_stats()["hits"] == 3
    # the dwell gate trusted every hit at zero BER — no reuse scrubs ran
    assert cached.cache_stats()["reuse_scrubs"] == 0
    assert cached.cache_stats()["reuse_ref_repairs"] == 0


def test_cow_fork_inside_partial_page(model_params):
    model, params = model_params
    cfg = _cfg(prefix_cache=True)
    eng = Engine(model, params, cfg)
    rid = eng.add_request([1, 2, 3, 4, 5, 6, 7, 8, 9], max_new=4)
    eng.run()
    cont = eng.results[rid]["tokens"]        # 13 tokens: 3 full pages + 1 row
    rB = eng.add_request(cont + [17], max_new=4)
    rC = eng.add_request(cont[:10] + [23], max_new=4)
    eng.run()
    s = eng.cache_stats()
    assert s["cow_forks"] == 2               # both diverge inside a page

    # no-cache arm must emit the same bits
    eng0 = Engine(model, params, _cfg())
    r0 = eng0.add_request([1, 2, 3, 4, 5, 6, 7, 8, 9], max_new=4)
    eng0.run()
    rB0 = eng0.add_request(cont + [17], max_new=4)
    rC0 = eng0.add_request(cont[:10] + [23], max_new=4)
    eng0.run()
    assert eng.results[rB]["generated"] == eng0.results[rB0]["generated"]
    assert eng.results[rC]["generated"] == eng0.results[rC0]["generated"]
    assert eng.results[rid]["generated"] == eng0.results[r0]["generated"]


def test_interior_fragment_forks_again_instead_of_reprefilling(model_params):
    """Divergence *inside* an already-forked partial page: the second
    request shares only an interior fraction of the cached tail, so the
    exact-key probe misses — the fragment index must still match the
    owner's valid rows and CoW-fork again instead of re-prefilling them."""
    model, params = model_params
    prompt = list(range(1, 12))              # 11 tokens: 2 full pages + 3 rows
    eng = Engine(model, params, _cfg(prefix_cache=True))
    eng.add_request(prompt, max_new=4)
    eng.run()
    # the cached partial tail holds 3 rows; diverge after its first row
    rB = eng.add_request(prompt[:9] + [99], max_new=4)
    eng.run()
    s = eng.cache_stats()
    assert s["fragment_hits"] == 1
    assert s["cow_forks"] == 1
    # 8 full-page tokens + 1 interior row of the partial were reused
    assert s["hit_tokens"] == 9
    assert eng.prefill_tokens_saved == 9

    # the fragment-served bits match the no-cache engine exactly
    eng0 = Engine(model, params, _cfg())
    eng0.add_request(prompt, max_new=4)
    eng0.run()
    rB0 = eng0.add_request(prompt[:9] + [99], max_new=4)
    eng0.run()
    assert eng.results[rB]["generated"] == eng0.results[rB0]["generated"]

    # evicting the owner drops its fragment keys with it
    eng.cache.evict(eng.cfg.n_pages)
    assert eng.cache._fragments == {}


# --------------------------------------------------- refcount balance / LRU
def test_refcounts_balance_to_zero_after_drain(model_params):
    model, params = model_params
    shared = [1, 2, 3, 4, 5, 6, 7, 8]
    prompts = [shared + [9 + i] for i in range(5)] + [shared + [9, 30, 31]]
    eng, _ = _run_engine(model, params, _cfg(prefix_cache=True), prompts)
    assert eng.pool.n_free == eng.cfg.n_pages - eng.cache.cached_pages
    # drain the cache: every page returns to the free list, refcounts zero
    freed = eng.cache.evict(eng.cfg.n_pages)
    assert freed == eng.cache.stats()["evictions"] > 0
    assert eng.cache.cached_pages == 0
    assert eng.pool.n_free == eng.cfg.n_pages
    rc = eng.pool._refcount[: eng.cfg.n_pages]
    assert int(np.sum(rc)) == 0 and int(np.min(rc)) == 0


def test_lru_eviction_under_allocation_pressure(model_params):
    model, params = model_params
    # 8-page pool: cached prefixes must be reclaimed to admit new requests
    cfg = _cfg(n_pages=8, prefix_cache=True)
    prompts = [[i, i + 1, i + 2, i + 3, i + 4] for i in range(1, 60, 10)]
    eng, outs = _run_engine(model, params, cfg, prompts)
    assert all(len(o) == 4 for o in outs)    # everyone finished
    assert eng.cache_stats()["evictions"] > 0
    assert eng.pool.n_free == eng.cfg.n_pages - eng.cache.cached_pages


def test_max_cached_pages_cap_is_enforced(model_params):
    model, params = model_params
    cfg = _cfg(prefix_cache=True, max_cached_pages=3)
    prompts = [[i, i + 1, i + 2, i + 3, i + 4, i + 5] for i in range(1, 80, 10)]
    eng, _ = _run_engine(model, params, cfg, prompts)
    assert eng.cache.cached_pages <= 3
    assert eng.cache_stats()["evictions"] > 0


def test_shared_pages_survive_preemption_storm(model_params):
    model, params = model_params
    # worst-case demand ~3x capacity over a shared prefix: preemptions fire,
    # shared pages must never be reclaimed out from under the cache
    cfg = _cfg(n_pages=10, prefix_cache=True)
    shared = [1, 2, 3, 4, 5, 6, 7, 8]
    eng = Engine(model, params, cfg)
    rids = [eng.add_request(shared + [9 + i], max_new=6) for i in range(8)]
    eng.run()
    assert all(len(eng.results[r]["generated"]) == 6 for r in rids)
    assert eng.pool.n_free == eng.cfg.n_pages - eng.cache.cached_pages
    # cached entries still hold exactly one (their own) pool reference
    for e in eng.cache._entries.values():
        assert eng.pool.refcount(e.page) == 1
    eng.cache.evict(eng.cfg.n_pages)
    assert eng.pool.n_free == eng.cfg.n_pages

    # same storm without the cache emits the same bits
    eng0 = Engine(model, params, _cfg(n_pages=10))
    rids0 = [eng0.add_request(shared + [9 + i], max_new=6) for i in range(8)]
    eng0.run()
    assert [eng0.results[r]["generated"] for r in rids0] == [
        eng.results[r]["generated"] for r in rids
    ]


# -------------------------------------------------------- scrub-on-reuse
def _reuse_engine(model, params, *, dwell_threshold, ber=2e-4, idle=5):
    cfg = _cfg(prefix_cache=True, ber=ber, dwell_threshold=dwell_threshold)
    eng = Engine(model, params, cfg)
    rid = eng.add_request([1, 2, 3, 4, 5, 6, 7, 8, 9], max_new=4)
    eng.run()
    for _ in range(idle):                    # cached pages dwell + take flips
        eng.step()
    cont = eng.results[rid]["tokens"]
    eng.add_request(cont + [17], max_new=4)
    eng.run()
    return eng


def test_reuse_scrub_fires_after_dwell(model_params):
    model, params = model_params
    eng = _reuse_engine(model, params, dwell_threshold=1.0)
    s = eng.cache_stats()
    assert s["hits"] == 1
    # full-page entries restore from their insert-time snapshot; the partial
    # tail (no stable snapshot) detector-scrubs
    assert s["reuse_ref_repairs"] > 0
    assert s["reuse_scrubs"] > 0


def test_reuse_skips_below_threshold(model_params):
    model, params = model_params
    eng = _reuse_engine(model, params, dwell_threshold=1e9)
    s = eng.cache_stats()
    assert s["hits"] == 1 and s["reuse_skips"] > 0
    assert s["reuse_ref_repairs"] == 0 and s["reuse_scrubs"] == 0


def test_always_scrub_arm_never_skips(model_params):
    model, params = model_params
    eng = _reuse_engine(model, params, dwell_threshold=0.0, ber=0.0)
    s = eng.cache_stats()
    assert s["hits"] == 1 and s["reuse_skips"] == 0
    assert s["reuse_ref_repairs"] + s["reuse_scrubs"] > 0


def test_reference_repair_restores_snapshot_bits(model_params):
    model, _ = model_params
    pool = _pool(model)
    (page,) = pool.alloc(1)
    leaves = jax.tree.leaves(pool.tree)
    stamped = jax.tree.map(
        lambda a: a.at[page].set(
            jax.random.normal(jax.random.PRNGKey(7), a.shape[1:], a.dtype)
        ),
        pool.tree,
    )
    pool.tree = stamped
    snap = pool.snapshot_page(page)
    # poison one lane, then reference-repair against the snapshot
    poisoned = jax.tree.map(
        lambda a: a.at[(page,) + (0,) * (a.ndim - 1)].set(jnp.nan), pool.tree
    )
    pool.tree = poisoned
    from repro.core import stats as stats_lib

    pool.now = 9
    stats = pool.reference_repair_page(page, snap, stats_lib.zeros())
    assert int(stats["nan_found"]) == len(leaves)
    assert pool.dwell(page) == 0             # repair stamps the page clean
    for a, b in zip(jax.tree.leaves(pool.tree), jax.tree.leaves(stamped)):
        np.testing.assert_array_equal(np.asarray(a[page]), np.asarray(b[page]))
