"""Repair policies + register/memory repair modes (paper §3.3/§3.4/§5.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import policies, repair, stats
from repro.core.regions import Region, annotate
from repro.core.checkpoint_repair import scrub_with_reference


def poisoned(key=0, shape=(32, 64), n_nan=3, n_inf=2):
    x = jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)
    flat = x.reshape(-1)
    flat = flat.at[jnp.arange(n_nan)].set(jnp.nan)
    flat = flat.at[jnp.arange(n_nan, n_nan + n_inf) * 7].set(jnp.inf)
    return flat.reshape(shape)


# ---------------------------------------------------------------- policies
@pytest.mark.parametrize("name", ["zero", "clamp_finite_max", "neighbor_mean"])
def test_policy_produces_finite(name):
    x = poisoned()
    fixed, n_nan, n_inf = repair.repair_tensor(x, policy=policies.get(name))
    assert int(n_nan) == 3 and int(n_inf) == 2
    assert bool(jnp.isfinite(fixed).all())


def test_zero_policy_value():
    x = poisoned()
    fixed, *_ = repair.repair_tensor(x, policy=policies.zero)
    mask = ~jnp.isfinite(x)
    assert bool((jnp.where(mask, fixed, 0.0) == 0.0).all())


def test_neighbor_mean_value():
    x = poisoned()
    fixed, *_ = repair.repair_tensor(x, policy=policies.neighbor_mean)
    finite_mean = float(jnp.nanmean(jnp.where(jnp.isinf(x), jnp.nan, x)))
    bad = ~jnp.isfinite(x)
    got = float(fixed[jnp.argwhere(bad)[0, 0], jnp.argwhere(bad)[0, 1]])
    assert abs(got - finite_mean) < 1e-5


def test_neighbor_mean_zero_size_and_tile_shapes():
    """Zero-size leaves (empty optimizer slots) must pass through the
    tile-local mean untouched, and awkward shapes must still tile."""
    empty = jnp.zeros((0, 8), jnp.float32)
    out, n, i = repair.repair_tensor(empty, policy=policies.neighbor_mean)
    assert out.shape == (0, 8) and int(n) == 0 and int(i) == 0
    for shape in [(1,), (7, 3), (300, 520)]:
        x = jnp.ones(shape).at[(0,) * len(shape)].set(jnp.nan)
        fixed, *_ = repair.repair_tensor(x, policy=policies.neighbor_mean)
        assert bool(jnp.isfinite(fixed).all())


def test_constant_policy_and_registry():
    x = poisoned()
    fixed, *_ = repair.repair_tensor(x, policy=policies.get(1.5))
    bad = ~jnp.isfinite(x)
    np.testing.assert_allclose(np.asarray(fixed)[np.asarray(bad)], 1.5)
    with pytest.raises(KeyError):
        policies.get("nope")


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000))
def test_property_repair_touches_only_fatal_lanes(seed):
    """Drift values (non-NaN flips) must be left as-is — the paper's core
    low-overhead argument."""
    x = poisoned(key=seed)
    fixed, *_ = repair.repair_tensor(x, policy=policies.zero)
    ok = jnp.isfinite(x)
    assert bool((jnp.where(ok, fixed == x, True)).all())
    assert bool(jnp.isfinite(fixed).all())


# -------------------------------------------------------------- modes
def test_register_mode_repairs_at_use():
    cfg = repair.RepairConfig(mode="register", policy="zero")
    x = poisoned()
    s = stats.zeros()
    fixed, s = repair.use(x, cfg, s)
    assert bool(jnp.isfinite(fixed).all())
    assert int(s["nan_found"]) == 3 and int(s["inf_found"]) == 2
    assert int(s["events"]) == 1


def test_memory_off_modes_are_identity_at_use():
    x = poisoned()
    for mode in ("memory", "off"):
        cfg = repair.RepairConfig(mode=mode)
        out = repair.use(x, cfg)
        assert out is x


def test_scrub_pytree_memory_mode():
    cfg = repair.RepairConfig(mode="memory", policy="zero")
    tree = {"w": poisoned(1), "step": jnp.zeros((), jnp.int32),
            "nested": {"v": poisoned(2)}}
    s = stats.zeros()
    out, s = repair.scrub_pytree(tree, cfg, s)
    assert bool(jnp.isfinite(out["w"]).all())
    assert bool(jnp.isfinite(out["nested"]["v"]).all())
    assert int(s["nan_found"]) == 6
    # exact-region & integer leaves untouched
    assert out["step"].dtype == jnp.int32


def test_register_vs_memory_event_counts_table3():
    """Table 3 analogue at the jnp level: consuming the same poisoned buffer
    N times fires N events in register mode, 1 in memory mode."""
    N = 5
    x = poisoned()

    reg = repair.RepairConfig(mode="register", policy="zero")
    s = stats.zeros()
    for _ in range(N):
        _, s = repair.use(x, reg, s)          # stored buffer keeps its NaN
    assert int(s["events"]) == N

    mem = repair.RepairConfig(mode="memory", policy="zero")
    s2 = stats.zeros()
    buf = {"x": x}
    for _ in range(N):
        buf, s2 = repair.scrub_pytree(buf, mem, s2)   # write-back
    assert int(s2["events"]) == 1


# -------------------------------------------------------------- regions
def test_region_annotation_rules():
    tree = {
        "params": {"w": jnp.zeros((2,)), "router": {"w": jnp.zeros((2,))}},
        "step": jnp.zeros(()),
        "rng_key": jnp.zeros((2,)),
    }
    regions = annotate(tree)
    assert regions["params"]["w"] is Region.APPROX
    assert regions["params"]["router"]["w"] is Region.EXACT
    assert regions["step"] is Region.EXACT
    assert regions["rng_key"] is Region.EXACT


# ----------------------------------------------------- checkpoint repair
def test_scrub_with_reference_restores_exact_values():
    ref = {"w": jax.random.normal(jax.random.PRNGKey(3), (16, 16))}
    bad = {"w": ref["w"].at[3, 4].set(jnp.nan).at[7, 7].set(jnp.inf)}
    out, s = scrub_with_reference(bad, ref, stats.zeros())
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(ref["w"]))
    assert int(s["nan_found"]) == 1 and int(s["inf_found"]) == 1
