import os

# Tests must see the single real CPU device — the 512-device flag belongs to
# launch/dryrun.py ONLY (per assignment).  Guard against accidental leakage.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), (
    "dry-run device-count flag leaked into the test environment"
)

import jax

jax.config.update("jax_enable_x64", False)
