import os

# Tier-1 tests must see the single real CPU device — the 512-device flag
# belongs to launch/dryrun.py ONLY (per assignment).  Guard against
# accidental leakage.  The ONE sanctioned exception is the multidev CI lane
# (`scripts/ci.sh multidev`): a separate subprocess that sets REPRO_MULTIDEV=1
# and runs tests/multidev/ under 8 fake host devices; everything else keeps
# the guard.
if not os.environ.get("REPRO_MULTIDEV"):
    assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), (
        "dry-run device-count flag leaked into the test environment"
    )

# Property tests degrade to fixed-example replay where hypothesis cannot be
# installed (tests/_hypothesis_compat.py); the real package wins when present.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_compat

    _hypothesis_compat.install()

import jax

jax.config.update("jax_enable_x64", False)


def tiny_transformer():
    """One shared CPU-scale TransformerLM for the serving test modules —
    shapes live here so the engine, runtime, and parity tests cannot drift
    apart.  Repair mode 'off': the serving space owns repair."""
    import dataclasses

    from repro.configs import get_config
    from repro.models import build_model
    from repro.runtime import ApproxConfig

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b").reduced(),
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=97,
        repair=ApproxConfig(mode="off"),
    )
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(0))
