import os

# Tests must see the single real CPU device — the 512-device flag belongs to
# launch/dryrun.py ONLY (per assignment).  Guard against accidental leakage.
assert "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""), (
    "dry-run device-count flag leaked into the test environment"
)

# Property tests degrade to fixed-example replay where hypothesis cannot be
# installed (tests/_hypothesis_compat.py); the real package wins when present.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import _hypothesis_compat

    _hypothesis_compat.install()

import jax

jax.config.update("jax_enable_x64", False)
