"""Serving engine: paged pool roundtrip, targeted scrub, engine-vs-generate
parity, mixed workload with eviction, and page-granular vs whole-cache
repair accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.core import stats as stats_lib
from repro.kernels import ops as kernel_ops
from repro.launch.serve import generate
from repro.runtime import ApproxConfig, ApproxSpace
from repro.serving import (
    Engine,
    PagedKVPool,
    PageRepairManager,
    ServingConfig,
)


@pytest.fixture(scope="module")
def model_params():
    return tiny_transformer()


def _mixed_engine(model, params, *, repair, ber, max_new=6):
    """8 requests of up to 5 pages over a 10-page pool: admission control
    and preemption are live (worst-case demand ~3x capacity)."""
    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=10, max_batch=4, max_pages_per_request=5,
        repair=repair, ber=ber, sweep_interval=8, sweep_pages=2, seed=3,
    ))
    for i in range(8):
        prompt = jax.random.randint(jax.random.PRNGKey(i), (5 + i % 3,), 1, 96)
        eng.add_request(prompt, max_new=max_new)
    return eng


# -------------------------------------------------------------------- pool
def test_pool_alloc_free_and_gather_scatter_roundtrip(model_params):
    model, _ = model_params
    cfg = ServingConfig(page_size=4, n_pages=6, max_batch=2,
                        max_pages_per_request=3)
    pool = PagedKVPool(model, ApproxSpace(mode="memory"), cfg)

    pages = pool.alloc(2)
    assert pages is not None and pool.n_free == 4
    assert pool.alloc(5) is None            # admission-control signal

    bt = pool.block_table(pages)[None, :]   # (1, 3), null-padded
    assert bt[0, 2] == pool.null_page
    view = pool.gather(bt)
    k = jax.tree.leaves(view)[0]            # (L, 1, 12, K, Dh)
    assert k.shape[2] == cfg.max_pages_per_request * cfg.page_size

    stamped = jax.tree.map(lambda v: v + 7.0, view)
    pool.scatter(stamped, bt)
    back = pool.gather(bt)
    for a, b in zip(jax.tree.leaves(stamped), jax.tree.leaves(back)):
        # allocated pages roundtrip exactly; null-page positions may differ
        # (duplicate scatter writes collide there by design)
        np.testing.assert_array_equal(
            np.asarray(a[:, :, :8]), np.asarray(b[:, :, :8])
        )

    pool.free(pages)
    assert pool.n_free == 6


def test_pool_alloc_zeroes_recycled_pages(model_params):
    model, _ = model_params
    cfg = ServingConfig(page_size=4, n_pages=4, max_batch=1,
                        max_pages_per_request=2)
    pool = PagedKVPool(model, ApproxSpace(mode="memory"), cfg)
    pages = pool.alloc(2)
    pool.tree = jax.tree.map(lambda l: l + jnp.nan, pool.tree)  # poison all
    pool.free(pages)
    again = pool.alloc(2)                  # recycled: must come back clean
    idx = jnp.asarray(again, jnp.int32)
    for leaf in jax.tree.leaves(pool.tree):
        assert bool(jnp.isfinite(leaf[idx]).all())


# ---------------------------------------------------------- targeted scrub
def test_space_scrub_pages_repairs_only_named_pages():
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    tree = {"k": jnp.zeros((4, 8)).at[1, 0].set(jnp.nan).at[3, 2].set(jnp.nan)}
    out, stats = space.scrub_pages(tree, jnp.asarray([1]), stats_lib.zeros())
    assert bool(jnp.isfinite(out["k"][1]).all())
    assert bool(jnp.isnan(out["k"][3, 2]))          # untouched page keeps NaN
    assert int(stats["nan_found"]) == 1
    assert int(stats["events"]) == 1
    # no-op outside memory mode
    off = ApproxSpace(ApproxConfig(mode="off"))
    same, _ = off.scrub_pages(tree, jnp.asarray([1, 3]), stats_lib.zeros())
    assert bool(jnp.isnan(same["k"][1, 0]))


def test_kernel_scrub_pages_page_view():
    x = jnp.ones((6, 64), jnp.float32).at[2, 5].set(jnp.nan).at[4, 9].set(jnp.nan)
    fixed, counts = kernel_ops.scrub_pages(x, jnp.asarray([2]), policy="zero")
    assert bool(jnp.isfinite(fixed[2]).all())
    assert bool(jnp.isnan(fixed[4, 9]))             # outside the page view
    assert int(counts[0]) == 1                      # nan lanes in the view


# ----------------------------------------------------------------- parity
def test_engine_matches_generate_at_zero_ber(model_params):
    model, params = model_params
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 1, 96)
    ref, _ = generate(model, params, prompt, max_new=5, max_seq=16)

    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=8, max_batch=2, max_pages_per_request=4,
    ))
    rids = [eng.add_request(prompt[b], max_new=5) for b in range(2)]
    results = eng.run()
    got = np.asarray([results[r]["tokens"] for r in rids])
    np.testing.assert_array_equal(np.asarray(ref), got)


# ---------------------------------------------------------- mixed workload
def test_mixed_workload_evicts_and_completes(model_params):
    model, params = model_params
    eng = _mixed_engine(model, params, repair="page", ber=0.0)
    results = eng.run()
    assert len(results) == 8
    assert all(len(r["generated"]) == 6 for r in results.values())
    assert eng.sched.n_preemptions > 0              # page pressure was real
    assert any(r["n_preempted"] > 0 for r in results.values())
    assert eng.pool.n_free == 10                    # no page leaks


def test_page_repair_scrubs_fewer_bytes_than_whole(model_params):
    model, params = model_params
    whole = _mixed_engine(model, params, repair="whole", ber=1e-3, max_new=5)
    whole.run()
    page = _mixed_engine(model, params, repair="page", ber=1e-3, max_new=5)
    page.run()

    # same seed + same schedule => identical fault exposure; both must have
    # actually repaired something for the comparison to mean anything
    assert whole.stats_dict()["events"] > 0
    assert page.stats_dict()["events"] > 0
    assert 0 < page.pool.scrubbed_bytes < whole.pool.scrubbed_bytes
    mw, mp = whole.metrics(), page.metrics()
    assert (
        mp["scrubbed_bytes_per_token"] < mw["scrubbed_bytes_per_token"]
    )


# ------------------------------------------------------- kernel routing
def test_kernel_counters_route_to_touched_pages(model_params):
    model, _ = model_params
    cfg = ServingConfig(page_size=4, n_pages=4, max_batch=1,
                        max_pages_per_request=2, repair="page")
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    pool = PagedKVPool(model, space, cfg)
    mgr = PageRepairManager(pool, space, cfg)

    # poison an allocated page that no step will touch (cold): reactive
    # detection over touched pages alone would never find it.  (It must be
    # allocated — routing skips freed pages, whose faults belong to no one.)
    pages = pool.alloc(3)
    cold = pages[-1]
    pool.tree = jax.tree.map(
        lambda l: l.at[cold, 0, 0, 0, 0].set(jnp.nan), pool.tree
    )
    counts = jnp.zeros((8,), jnp.int32).at[kernel_ops.MM_EV_TOTAL].set(3)
    mgr.note_kernel(counts, touched=[cold])

    assert space.stats_dict()["events"] == 3        # unified stream
    assert pool.page_events[cold] == 3              # per-page ledger
    stats = mgr.repair_step(touched=[], stats=stats_lib.zeros())
    assert int(stats["nan_found"]) == 2             # both pool leaves (k, v)
    for leaf in jax.tree.leaves(pool.tree):
        assert bool(jnp.isfinite(leaf[cold]).all())
    assert pool.scrubbed_bytes > 0

    # a freed page reported through the same route is never charged: its
    # faults belong to no live request
    free_probe = 3
    assert pool.is_free(free_probe)
    mgr.note_kernel(counts, touched=[free_probe])
    assert pool.page_events[free_probe] == 0


# ------------------------------------------------------------------ config
def test_serving_config_validation():
    with pytest.raises(ValueError):
        ServingConfig(repair="bogus")
    with pytest.raises(ValueError):
        ServingConfig(n_pages=2, max_pages_per_request=4)
    cfg = ServingConfig(page_size=4, max_pages_per_request=3)
    assert cfg.max_seq == 12
    assert cfg.pages_for(9) == 3
