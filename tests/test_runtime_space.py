"""The `ApproxSpace` redesign: parity with the legacy surface, region-tree
caching, kernel-counter unification, and the flips ground-truth counter."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import detect, injection, regions as regions_lib
from repro.core import repair as repair_lib
from repro.core import stats as stats_lib
from repro.kernels import ops
from repro.runtime import ApproxConfig, ApproxSpace, ScrubSchedule


def poisoned_state(seed=0):
    """A train-state-shaped pytree with NaN/Inf lanes injected into the
    approximate region."""
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    w = jax.random.normal(k1, (16, 32), jnp.float32)
    v = jax.random.normal(k2, (64,), jnp.float32)
    w = injection.inject_nan(k3, w, 2)
    v = v.at[3].set(jnp.inf)
    return {
        "params": {"w": w, "router": {"gate": jnp.ones((4,))}},
        "moments": {"mu": v},
        "step": jnp.zeros((), jnp.int32),
        "rng_key": jnp.zeros((2,), jnp.uint32),
    }


# ------------------------------------------------------------------ parity
@pytest.mark.parametrize("mode", ["memory", "off"])
@pytest.mark.parametrize("policy", ["zero", "neighbor_mean"])
def test_scrub_bitwise_parity_with_legacy(mode, policy):
    """ApproxSpace.scrub == legacy scrub_pytree, bit for bit, in both the
    active and the no-op mode."""
    tree = poisoned_state()
    legacy_cfg = repair_lib.RepairConfig(mode=mode, policy=policy)
    space = ApproxSpace(ApproxConfig(mode=mode, policy=policy))

    legacy_out, legacy_stats = repair_lib.scrub_pytree(
        tree, legacy_cfg, stats_lib.zeros()
    )
    new_out, new_stats = space.scrub(tree, stats_lib.zeros())

    for a, b in zip(jax.tree.leaves(legacy_out), jax.tree.leaves(new_out)):
        if jnp.issubdtype(a.dtype, jnp.floating):
            np.testing.assert_array_equal(
                np.asarray(detect.bits_of(a)), np.asarray(detect.bits_of(b))
            )
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats_lib.as_dict(legacy_stats) == stats_lib.as_dict(new_stats)


@pytest.mark.parametrize("mode", ["register", "memory", "off"])
def test_use_bitwise_parity_with_legacy(mode):
    """ApproxSpace.use == legacy use, bit for bit, in all three modes."""
    x = injection.inject_nan(
        jax.random.PRNGKey(1),
        jax.random.normal(jax.random.PRNGKey(0), (32, 32), jnp.float32),
        3,
    )
    legacy_cfg = repair_lib.RepairConfig(mode=mode, policy="neighbor_mean")
    space = ApproxSpace(legacy_cfg)       # legacy-config lift

    legacy_out, legacy_stats = repair_lib.use(x, legacy_cfg, stats_lib.zeros())
    new_out, new_stats = space.use(x, stats_lib.zeros())
    np.testing.assert_array_equal(
        np.asarray(detect.bits_of(legacy_out)),
        np.asarray(detect.bits_of(new_out)),
    )
    assert stats_lib.as_dict(legacy_stats) == stats_lib.as_dict(new_stats)


def test_inject_parity_and_flip_ground_truth():
    """Same key + BER => bitwise-identical flips through both entry points,
    and the returned count matches the actually-changed bit count."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(5), (128, 128))}
    key = jax.random.PRNGKey(6)
    space = ApproxSpace(ApproxConfig(ber=1e-5))

    legacy_out, legacy_flips = repair_lib.inject_pytree(tree, key, 1e-5)
    new_out, new_flips = space.inject(tree, key, 1e-5)
    np.testing.assert_array_equal(
        np.asarray(detect.bits_of(legacy_out["w"])),
        np.asarray(detect.bits_of(new_out["w"])),
    )
    assert int(legacy_flips) == int(new_flips)

    delta = np.asarray(detect.bits_of(tree["w"])) ^ np.asarray(
        detect.bits_of(new_out["w"])
    )
    true_flips = int(np.unpackbits(delta.view(np.uint8)).sum())
    assert int(new_flips) == true_flips > 0
    # ...and the space recorded them in the unified stream
    assert space.stats_dict()["flips"] == true_flips


def test_inject_state_records_flips_in_train_stats():
    """The previously-dead `flips` counter: the train-loop injection window
    must record ground truth into the state's stats."""
    from repro.launch.train import inject_state

    state = {
        "params": {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 256))},
        "opt": {"mu": jnp.zeros((8,)), "step": jnp.zeros((), jnp.int32)},
        "stats": stats_lib.zeros(),
    }
    out = inject_state(state, jax.random.PRNGKey(1), ber=1e-5)
    assert int(out["stats"]["flips"]) > 0
    assert int(out["opt"]["step"]) == 0         # exact region untouched


# ------------------------------------------------------------------ caching
def test_region_tree_cached_by_treedef():
    """Equal treedefs share one region-tree object; distinct treedefs don't."""
    space = ApproxSpace()
    t1 = {"w": jnp.zeros((4, 4)), "step": jnp.zeros((), jnp.int32)}
    t2 = {"w": jnp.ones((8, 2)), "step": jnp.ones((), jnp.int32)}  # same treedef
    t3 = {"w": jnp.zeros((4,)), "extra": jnp.zeros((2,))}          # different
    r1, r2, r3 = space.regions_for(t1), space.regions_for(t2), space.regions_for(t3)
    assert r1 is r2
    assert r1 is not r3
    assert r1["w"] is regions_lib.Region.APPROX
    assert r1["step"] is regions_lib.Region.EXACT


def test_custom_region_rules_flow_through_space():
    rules = ((r"(^|/)frozen($|/)", regions_lib.Region.EXACT),
             (r".*", regions_lib.Region.APPROX))
    space = ApproxSpace(ApproxConfig(region_rules=rules))
    regions = space.regions_for({"frozen": jnp.zeros((2,)), "w": jnp.zeros((2,))})
    assert regions["frozen"] is regions_lib.Region.EXACT
    assert regions["w"] is regions_lib.Region.APPROX


# --------------------------------------------------------- kernel counters
def test_kernel_counters_land_in_unified_stats():
    """Fused-kernel repair events (Pallas counter vectors) must appear in the
    core.stats Table-3 analogue through the space."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    a = injection.inject_nan(k3, jax.random.normal(k1, (128, 128)), 1)
    b = jax.random.normal(k2, (128, 128))
    space = ApproxSpace(mode="memory", policy="zero")

    res = ops.repair_matmul(a, b, mode="memory", policy="zero",
                            blocks=(64, 64, 64))
    space.record_kernel(res.counts)
    d = space.stats_dict()
    assert d["events"] == int(res.counts[ops.MM_EV_TOTAL]) > 0
    assert d["nan_found"] == int(res.counts[ops.MM_NAN_A] + res.counts[ops.MM_NAN_B]) > 0

    # attention counters use the same layout and the same unified mapping
    q = jax.random.normal(k1, (1, 2, 64, 32))
    kk = injection.inject_nan(k3, jax.random.normal(k2, (1, 2, 64, 32)), 1)
    v = jax.random.normal(k2, (1, 2, 64, 32))
    at = ops.flash_attention(q, kk, v, mode="register", blocks=(32, 32))
    before = d["events"]
    space.record_kernel(at.counts)
    assert space.stats_dict()["events"] == before + int(at.counts[ops.AT_EV_TOTAL])


# -------------------------------------------------------- step decorators
def test_wrap_train_step_installs_boundary_scrub():
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))

    def raw_step(state, batch):
        # the raw compute must see already-clean params in memory mode
        return state, {"finite": jnp.isfinite(state["params"]["w"]).all()}

    step = space.wrap_train_step(raw_step)
    state = {
        "params": {"w": jnp.array([1.0, jnp.nan, 3.0])},
        "opt": {"mu": jnp.array([jnp.inf, 0.0])},
        "stats": stats_lib.zeros(),
    }
    out, metrics = jax.jit(step)(state, {})
    assert bool(metrics["finite"])
    assert bool(jnp.isfinite(out["params"]["w"]).all())
    assert bool(jnp.isfinite(out["opt"]["mu"]).all())
    assert int(out["stats"]["nan_found"]) == 1
    assert int(out["stats"]["inf_found"]) == 1


def test_wrap_serve_step_threads_stats_and_scrubs_cache():
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero",
                                     scrub=ScrubSchedule(boundary=True)))

    def raw_step(params, cache, batch, pos):
        return jnp.zeros((1,), jnp.int32), cache

    step = space.wrap_serve_step(raw_step)
    cache = {"k": jnp.array([jnp.nan, 2.0])}
    nxt, cache_out, stats = jax.jit(step)(
        {}, cache, {}, jnp.zeros((), jnp.int32), stats_lib.zeros()
    )
    assert bool(jnp.isfinite(cache_out["k"]).all())
    assert int(stats["nan_found"]) == 1


def test_compiled_executables_cached_one_trace_per_layout():
    """Host-side mechanisms dispatch jit-compiled executables cached by
    (treedef, avals, shardings): repeated same-layout calls never retrace;
    a new layout (different avals) compiles exactly one more."""
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    tree = poisoned_state()
    out, _ = space.scrub(tree, stats_lib.zeros())
    assert space.n_traces == 1
    for _ in range(3):
        out, _ = space.scrub(out, stats_lib.zeros())
    assert space.n_traces == 1, "same layout must reuse the cached executable"
    space.scrub({"w": jnp.zeros((4, 4))}, stats_lib.zeros())
    assert space.n_traces == 2


def test_scrub_donate_consumes_input():
    """donate=True donates the resident buffers: the returned tree REPLACES
    the input (in-place under XLA), and the old buffers are invalidated."""
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    tree = {"w": jnp.ones((32, 32)).at[0, 0].set(jnp.nan)}
    out = space.scrub(tree, donate=True)
    assert bool(jnp.isfinite(out["w"]).all())
    with pytest.raises(RuntimeError):
        np.asarray(tree["w"])           # donated away


def test_inject_threads_caller_stats_stream():
    """The ONE injection/stat entry point (train + serve): with `stats` the
    flip count threads into that stream and self.stats stays untouched."""
    space = ApproxSpace(ApproxConfig(ber=1e-5))
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (256, 256))}
    out, stream = space.inject(
        tree, jax.random.PRNGKey(1), 1e-5, stats=stats_lib.zeros()
    )
    assert int(stream["flips"]) > 0
    assert space.stats_dict()["flips"] == 0
    # parity with the recording form
    out2, flips = space.inject(tree, jax.random.PRNGKey(1), 1e-5)
    assert int(flips) == int(stream["flips"])
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(out2["w"]))


def test_scrub_pages_bucketing_parity():
    """The compiled page scrub buckets id counts to powers of two (padding
    masked out of the counts): every count from 1..n matches the eager
    unbucketed reference bit-for-bit and stat-for-stat."""
    from repro.runtime.space import scrub_pages_tree

    pool = {"kv": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 4))}
    pool["kv"] = (
        pool["kv"].at[1, 0, 0].set(jnp.nan).at[3, 1, 1].set(jnp.inf)
        .at[6, 2, 2].set(jnp.nan)
    )
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    for ids in ([1], [1, 3], [1, 3, 6], [0, 1, 3, 5, 6]):
        ref, ref_stats = scrub_pages_tree(
            pool, jnp.asarray(ids, jnp.int32), space.config,
            stats_lib.zeros(), space.regions_for(pool),
        )
        out, out_stats = space.scrub_pages(pool, ids, stats_lib.zeros())
        np.testing.assert_array_equal(
            np.asarray(ref["kv"]), np.asarray(out["kv"])
        )
        assert stats_lib.as_dict(ref_stats) == stats_lib.as_dict(out_stats)
    # buckets of 1, 2, 4, 8 -> at most 4 distinct traces, not one per count
    assert space.n_traces <= 4


def test_repair_plan_scope_resolution():
    """RepairPlan picks scope from the mechanism + mode: memory-mode scrubs
    plan their scope, non-memory modes resolve to the no-op plan, reference
    repair always runs, and the serving mode map lives in runtime.plan."""
    from repro.runtime import serving_scope
    from repro.runtime.plan import plan_for

    tree = {"w": jnp.zeros((4, 4))}
    mem = ApproxSpace(ApproxConfig(mode="memory"))
    off = ApproxSpace(ApproxConfig(mode="off"))
    assert plan_for(mem, tree, scope="tree").scope == "tree"
    assert plan_for(mem, tree, scope="pages").scope == "pages"
    assert plan_for(off, tree, scope="tree").scope == "none"
    assert plan_for(off, tree, scope="reference").scope == "reference"
    assert plan_for(mem, tree).placement == "local"
    assert (serving_scope("off"), serving_scope("whole"), serving_scope("page")) == (
        "none", "tree", "pages"
    )
    with pytest.raises(ValueError):
        serving_scope("bogus")
    with pytest.raises(ValueError):
        plan_for(mem, tree, scope="bogus")


def test_compiled_paths_pass_non_array_leaves_through():
    """User trees may carry plain python scalars (the eager path passed
    them through untouched); the compiled path must not choke on them."""
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    tree = {"w": jnp.array([jnp.nan, 2.0]), "step": 3}
    out, st = space.scrub(tree, stats_lib.zeros())
    assert bool(jnp.isfinite(out["w"]).all())
    assert int(out["step"]) == 3
    assert stats_lib.as_dict(st)["nan_found"] == 1
    out2, _ = space.inject(
        {"w": jnp.ones((64, 64)), "epoch": 7}, jax.random.PRNGKey(0), 1e-4
    )
    assert int(out2["epoch"]) == 7


def test_plan_run_empty_page_ids_is_noop():
    """Direct plan users get the same empty-set no-op as scrub_pages."""
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    pool = {"kv": jnp.ones((4, 2))}
    plan = space.plan_for(pool, scope="pages")
    out, delta = plan.run(pool, page_ids=[])
    np.testing.assert_array_equal(np.asarray(out["kv"]), np.asarray(pool["kv"]))
    assert stats_lib.as_dict(delta)["events"] == 0


def test_scrub_off_mode_noop_through_plan():
    """mode != memory: scrub is the identity (scope "none"), zero stats
    delta, zero bytes — matching the eager tree functions' gate."""
    space = ApproxSpace(ApproxConfig(mode="register"))
    tree = {"w": jnp.array([jnp.nan, 1.0])}
    out, st = space.scrub(tree, stats_lib.zeros())
    assert not bool(jnp.isfinite(out["w"]).all())       # untouched
    assert stats_lib.as_dict(st)["events"] == 0
    assert space.scrubbed_bytes == 0


def test_scrubbed_bytes_ledger():
    """The space's host ledger counts approximate-region bytes per pass —
    full tree for scope "tree", faulted rows only for scope "pages"."""
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"))
    pool = {"kv": jnp.zeros((8, 4, 4), jnp.float32)}
    space.scrub(pool)
    whole = 8 * 4 * 4 * 4
    assert space.scrubbed_bytes == whole
    space.scrub_pages(pool, [0, 3])
    assert space.scrubbed_bytes == whole + 2 * (whole // 8)


# -------------------------------------------------------------- deprecation
def test_legacy_shims_warn():
    """The legacy pytree entry points are real deprecated shims now: every
    call emits a DeprecationWarning (satellite: no more docs-only note)."""
    from repro.core import checkpoint_repair

    tree = {"w": jnp.array([jnp.nan, 1.0])}
    cfg = repair_lib.RepairConfig(mode="memory", policy="zero")
    with pytest.warns(DeprecationWarning, match="scrub_pytree"):
        repair_lib.scrub_pytree(tree, cfg, stats_lib.zeros())
    with pytest.warns(DeprecationWarning, match="inject_pytree"):
        repair_lib.inject_pytree(tree, jax.random.PRNGKey(0), 1e-6)
    with pytest.warns(DeprecationWarning, match="scrub_with_reference"):
        checkpoint_repair.scrub_with_reference(
            tree, {"w": jnp.zeros((2,))}, stats_lib.zeros()
        )


def _bits(x):
    return np.asarray(detect.bits_of(x))


def test_inject_seed_deterministic_compiled_vs_eager():
    """Same (tree, key, ber) => bit-identical flip masks through the
    compiled plan and the eager `inject_tree` path, and across repeated
    compiled calls — the determinism the autopilot campaign's profiles
    depend on."""
    tree = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (32, 128)),
    }
    key = jax.random.PRNGKey(7)
    space = ApproxSpace(ApproxConfig(ber=1e-4))

    c1, f1 = space.inject(tree, key, 1e-4, record=False)   # compiled
    c2, f2 = space.inject(tree, key, 1e-4, record=False)   # cached exec
    eager, fe = inject_space_eager(space, tree, key, 1e-4)
    assert int(f1) == int(f2) == int(fe) > 0
    for name in ("a", "b"):
        np.testing.assert_array_equal(_bits(c1[name]), _bits(c2[name]))
        np.testing.assert_array_equal(_bits(c1[name]), _bits(eager[name]))


def inject_space_eager(space, tree, key, ber):
    """The eager reference: the same per-leaf-position key split the
    compiled plan funnels through."""
    from repro.runtime.space import inject_tree

    return inject_tree(tree, key, ber, space.regions_for(tree))


def test_inject_region_mask_never_shifts_other_leaves_keys():
    """Masking one leaf EXACT via `regions=` must leave every other leaf's
    flip mask bit-identical to the unmasked run — keys are split once per
    leaf *position*, so the campaign's per-group masks can't perturb the
    flips the other groups would have drawn."""
    tree = {
        "a": jax.random.normal(jax.random.PRNGKey(0), (64, 64)),
        "b": jax.random.normal(jax.random.PRNGKey(1), (64, 64)),
        "c": jax.random.normal(jax.random.PRNGKey(2), (64, 64)),
    }
    key = jax.random.PRNGKey(11)
    space = ApproxSpace(ApproxConfig(ber=1e-4))

    full, _ = space.inject(tree, key, 1e-4, record=False)
    masked_regions = dict(space.regions_for(tree))
    masked_regions["b"] = regions_lib.Region.EXACT
    part, _ = space.inject(
        tree, key, 1e-4, record=False, regions=masked_regions
    )
    # the masked leaf is untouched...
    np.testing.assert_array_equal(_bits(part["b"]), _bits(tree["b"]))
    # ...and the surviving leaves drew the exact same flips as before
    np.testing.assert_array_equal(_bits(part["a"]), _bits(full["a"]))
    np.testing.assert_array_equal(_bits(part["c"]), _bits(full["c"]))
    assert not np.array_equal(_bits(full["a"]), _bits(tree["a"]))


def test_schedule_due():
    sched = ScrubSchedule(boundary=False, interval=4)
    assert [t for t in range(9) if sched.due(t)] == [0, 4, 8]
    assert not ScrubSchedule(interval=0).due(0)


# ------------------------------------------------------------- config lift
def test_config_lift_and_memory_model():
    legacy = repair_lib.RepairConfig(mode="register", policy=1.5,
                                     include_inf=False, max_magnitude=9.0)
    cfg = ApproxConfig.from_legacy(legacy)
    assert (cfg.mode, cfg.policy, cfg.include_inf, cfg.max_magnitude) == (
        "register", 1.5, False, 9.0
    )
    back = cfg.legacy()
    assert back == legacy
    # refresh→BER resolution comes along for free
    flikker = dataclasses.replace(cfg, refresh_interval_s=1.0)
    assert abs(flikker.resolved_ber - 1e-6) < 1e-9
    assert abs(flikker.memory_model.energy_saving - 0.225) < 1e-6
    with pytest.raises(ValueError):
        ApproxConfig(mode="bogus")
