"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles
(interpret=True on CPU), counter semantics, and the Table 3 behaviour."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import injection
from repro.kernels import ops, ref


def poison(x, key, n):
    return injection.inject_nan(key, x, n) if n else x


# ---------------------------------------------------------------- scrub
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,block", [
    ((64, 128), (32, 128)),
    ((8, 16, 128), (16, 128)),
    ((256, 512), (64, 256)),
    ((128,), None),
])
@pytest.mark.parametrize("policy", ["zero", "neighbor_mean"])
def test_scrub_matches_ref(shape, block, dtype, policy):
    key = jax.random.PRNGKey(hash((shape, policy)) % 2**31)
    x = jax.random.normal(key, shape, jnp.float32).astype(dtype)
    x = poison(x, jax.random.PRNGKey(1), 3)
    got, counts = ops.scrub(x, policy=policy, block=block)
    want, want_counts = ref.scrub_ref(
        x.reshape(1, -1) if x.ndim == 1 else x.reshape(-1, x.shape[-1]),
        policy=policy, block=block,
    )
    np.testing.assert_allclose(
        np.asarray(got, np.float32).reshape(want.shape),
        np.asarray(want, np.float32),
        rtol=1e-2 if dtype == jnp.bfloat16 else 1e-6, atol=1e-6,
    )
    np.testing.assert_array_equal(np.asarray(counts), np.asarray(want_counts))
    assert bool(jnp.isfinite(got.astype(jnp.float32)).all())


def test_scrub_clean_input_is_identity_with_zero_counts():
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 256), jnp.float32)
    got, counts = ops.scrub(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(x))
    assert counts.tolist() == [0, 0, 0]


# ---------------------------------------------------------------- matmul
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("mnk,blocks", [
    ((128, 128, 256), (64, 64, 128)),
    ((256, 128, 128), (128, 128, 128)),
    ((64, 512, 256), (64, 128, 256)),
])
@pytest.mark.parametrize("n_bad", [0, 1, 4])
def test_repair_matmul_matches_ref(mnk, blocks, dtype, n_bad):
    M, N, K = mnk
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(M + N + K + n_bad), 3)
    a = jax.random.normal(k1, (M, K), jnp.float32).astype(dtype)
    b = jax.random.normal(k2, (K, N), jnp.float32).astype(dtype)
    a = poison(a, k3, n_bad)
    got = ops.repair_matmul(a, b, mode="register", policy="zero", blocks=blocks)
    want_c, want_counts = ref.repair_matmul_ref(a, b, policy="zero", blocks=blocks)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(
        np.asarray(got.c, np.float32), np.asarray(want_c, np.float32),
        rtol=tol, atol=tol,
    )
    # counter semantics: nan_a / ev_a replay the visit schedule exactly
    np.testing.assert_array_equal(
        np.asarray(got.counts[:6]), np.asarray(want_counts[:6])
    )


def test_matmul_memory_mode_scrubs_origin_and_register_does_not():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    a = jax.random.normal(k1, (128, 128), jnp.float32)
    b = jax.random.normal(k2, (128, 128), jnp.float32)
    a_bad = injection.inject_nan(k3, a, 2)

    reg = ops.repair_matmul(a_bad, b, mode="register", blocks=(64, 64, 64))
    assert bool(jnp.isnan(reg.a).any())           # origin untouched

    mem = ops.repair_matmul(a_bad, b, mode="memory", blocks=(64, 64, 64))
    assert not bool(jnp.isnan(mem.a).any())       # origin repaired
    np.testing.assert_allclose(np.asarray(mem.c), np.asarray(reg.c),
                               rtol=1e-6, atol=1e-6)


def test_matmul_table3_event_counts():
    """Paper Table 3: register mode re-fires on every consumption of the
    poisoned buffer; memory mode fires exactly once, ever."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(5), 3)
    a = injection.inject_nan(k3, jax.random.normal(k1, (128, 128)), 1)
    b = jax.random.normal(k2, (128, 128))
    blocks = (64, 64, 64)
    n_iter = 4

    reg_events = mem_events = 0
    a_reg, a_mem = a, a
    for _ in range(n_iter):
        r = ops.repair_matmul(a_reg, b, mode="register", blocks=blocks)
        a_reg = r.a
        reg_events += int(r.counts[ops.MM_EV_TOTAL] > 0)
        m = ops.repair_matmul(a_mem, b, mode="memory", blocks=blocks)
        a_mem = m.a                                # functional write-back
        mem_events += int(m.counts[ops.MM_EV_TOTAL] > 0)
    assert reg_events == n_iter                    # N traps
    assert mem_events == 1                         # exactly 1


def test_matmul_no_error_fast_path_zero_counts():
    a = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
    b = jax.random.normal(jax.random.PRNGKey(2), (128, 128))
    res = ops.repair_matmul(a, b, mode="memory", blocks=(64, 64, 64))
    assert res.counts.tolist()[:7] == [0] * 7
    np.testing.assert_allclose(
        np.asarray(res.c), np.asarray(a) @ np.asarray(b), rtol=1e-5, atol=1e-4
    )


# -------------------------------------------------------------- attention
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("dims,blocks", [
    # (B, H, Kh, S, T, D)
    ((2, 4, 2, 256, 256, 64), (64, 64)),
    ((1, 8, 8, 128, 128, 128), (64, 128)),
    ((2, 4, 1, 128, 256, 64), (128, 64)),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("n_bad", [0, 2])
def test_flash_attention_matches_ref(dims, blocks, dtype, causal, n_bad):
    B, H, Kh, S, T, D = dims
    if causal and S != T:
        pytest.skip("causal oracle assumes aligned ends only")
    ks = jax.random.split(jax.random.PRNGKey(sum(dims) + n_bad), 4)
    q = jax.random.normal(ks[0], (B, H, S, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Kh, T, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Kh, T, D), jnp.float32).astype(dtype)
    k = poison(k, ks[3], n_bad)
    got = ops.flash_attention(
        q, k, v, mode="register", causal=causal, policy="zero", blocks=blocks
    )
    want = ref.flash_attention_ref(
        q, k, v, causal=causal, policy="zero", kv_block=blocks[1]
    )
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(got.out, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )
    if n_bad:
        assert int(got.counts[ops.AT_EV_TOTAL]) > 0
    else:
        assert got.counts.tolist()[:7] == [0] * 7


def test_flash_attention_memory_mode_scrubs_cache():
    B, H, Kh, S, D = 1, 4, 2, 128, 64
    ks = jax.random.split(jax.random.PRNGKey(9), 4)
    q = jax.random.normal(ks[0], (B, H, S, D))
    k = injection.inject_nan(ks[3], jax.random.normal(ks[1], (B, Kh, S, D)), 2)
    v = jax.random.normal(ks[2], (B, Kh, S, D))
    res = ops.flash_attention(q, k, v, mode="memory", blocks=(64, 64))
    assert not bool(jnp.isnan(res.k).any())
    # second call on the repaired cache: no events (Table 3 for serving)
    res2 = ops.flash_attention(q, res.k, res.v, mode="memory", blocks=(64, 64))
    assert res2.counts.tolist()[:7] == [0] * 7


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_property_flash_rows_are_convex_combos(seed):
    """Attention output rows live in the convex hull of V rows ⇒ bounded by
    max|V| — even with NaNs repaired to 0 (a repaired lane only shrinks the
    hull).  Catches normalization bugs under repair."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    k = injection.inject_nan(ks[3], jax.random.normal(ks[1], (1, 2, 128, 64)), 1)
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    out = ops.flash_attention(q, k, v, mode="register", blocks=(64, 64)).out
    assert float(jnp.max(jnp.abs(out))) <= float(jnp.max(jnp.abs(v))) + 1e-4
