"""Multidev lane: the device-local sharded serving hot path.

Kernel contract (ISSUE 10 tentpole): `paged_attention_sharded` /
`paged_prefill_sharded` partition the block-table walk by page ownership
(the pool's "page"->"data" sharding rule), so decode, chunked prefill, and
split-K reads never cross device boundaries.  Parity targets under
injected/poisoned flips:

  * integer ledgers (slot_counts, counts) — bit-identical to the SERIAL
    kernel: every block slot is owned by exactly one device;
  * float output — bit-identical to `paged_*_shard_ref`, the single-device
    oracle running the identical ownership partition + device-major LSE
    merge (the serial kernel groups its accumulation differently, so its
    float output is only allclose);
  * engine end-to-end — same tokens as the single-device engine, zero
    full-view copies, with the shard_map path demonstrably engaged.

Collected (and skipped) in the tier-1 single-device run; executed by
``scripts/ci.sh multidev`` / the ``traffic`` lane with
``XLA_FLAGS=--xla_force_host_platform_device_count=8 REPRO_MULTIDEV=1``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.kernels import paged_attention as pk
from repro.runtime import ApproxConfig, ApproxSpace

pytestmark = [
    pytest.mark.multidev,
    pytest.mark.skipif(
        jax.device_count() < 8,
        reason="needs the 8-device lane (scripts/ci.sh multidev)",
    ),
]

N_SHARDS = 4          # the mesh's "data" axis


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((N_SHARDS, 2), ("data", "model"))


def _pool(seed=0, P_pages=8, L=1, pg=4, Kh=2, Dh=8):
    """A small page pool with fatal lanes parked in several pages (spread
    across every ownership shard) — the last row doubles as null padding."""
    kk, kv = jax.random.split(jax.random.PRNGKey(seed))
    kp = jax.random.normal(kk, (P_pages, L, pg, Kh, Dh), jnp.float32)
    vp = jax.random.normal(kv, (P_pages, L, pg, Kh, Dh), jnp.float32)
    kp = kp.at[1, 0, 2, 0, 3].set(jnp.nan).at[6, 0, 0, 1, 0].set(jnp.inf)
    vp = vp.at[3, 0, 1, 1, 5].set(jnp.nan).at[7, 0, 0, 0, 0].set(jnp.nan)
    return kp, vp


def _shard_pool(mesh, kp, vp):
    s = NamedSharding(mesh, P("data", None, None, None, None))
    return jax.device_put(kp, s), jax.device_put(vp, s)


def _bits(x):
    return np.asarray(x, np.float32).view(np.uint32)


BT = np.array([[0, 3, 5, 7], [2, 6, 7, 7]], np.int32)    # 7 = null padding
POS = np.array([13, 9], np.int32)


# ---------------------------------------------------------------- decode
def test_sharded_decode_kernel_parity(mesh):
    kp, vp = _pool()
    q = jax.random.normal(jax.random.PRNGKey(2), (2, 4, 8), jnp.float32)
    layer = jnp.int32(0)

    out_ser, slot_ser, cnt_ser = paged = pk.paged_attention_raw(
        q, kp, vp, BT, POS, layer
    )
    out_ref, slot_ref, cnt_ref = pk.paged_attention_shard_ref(
        q, kp, vp, BT, POS, layer, n_shards=N_SHARDS
    )
    ksh, vsh = _shard_pool(mesh, kp, vp)
    out_sh, slot_sh, cnt_sh = pk.paged_attention_sharded(
        q, ksh, vsh, BT, POS, layer, mesh=mesh, axis="data"
    )
    # the poison was detected at all (the test has teeth)
    assert int(cnt_ser[pk.EV_TOTAL]) > 0
    # integer ledgers: bit-identical to the SERIAL kernel
    np.testing.assert_array_equal(np.asarray(slot_sh), np.asarray(slot_ser))
    np.testing.assert_array_equal(np.asarray(cnt_sh), np.asarray(cnt_ser))
    np.testing.assert_array_equal(np.asarray(slot_ref), np.asarray(slot_ser))
    np.testing.assert_array_equal(np.asarray(cnt_ref), np.asarray(cnt_ser))
    # float output: bit-identical to the shard oracle, allclose to serial
    np.testing.assert_array_equal(_bits(out_sh), _bits(out_ref))
    np.testing.assert_allclose(
        np.asarray(out_sh), np.asarray(out_ser), rtol=2e-6, atol=2e-6
    )
    del paged


def test_sharded_decode_composes_with_splitk(mesh):
    """splits > 1 inside the sharded walk: nd x splits partials merge to
    the same bits as the shard oracle at the same splits, same ledgers as
    serial."""
    kp, vp = _pool(seed=3)
    q = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 8), jnp.float32)
    layer = jnp.int32(0)
    _, slot_ser, cnt_ser = pk.paged_attention_splitk_raw(
        q, kp, vp, BT, POS, layer, splits=2
    )
    out_ref, _, _ = pk.paged_attention_shard_ref(
        q, kp, vp, BT, POS, layer, n_shards=N_SHARDS, splits=2
    )
    ksh, vsh = _shard_pool(mesh, kp, vp)
    out_sh, slot_sh, cnt_sh = pk.paged_attention_sharded(
        q, ksh, vsh, BT, POS, layer, mesh=mesh, axis="data", splits=2
    )
    np.testing.assert_array_equal(np.asarray(slot_sh), np.asarray(slot_ser))
    np.testing.assert_array_equal(np.asarray(cnt_sh), np.asarray(cnt_ser))
    np.testing.assert_array_equal(_bits(out_sh), _bits(out_ref))


# --------------------------------------------------------------- prefill
def test_sharded_prefill_kernel_parity(mesh):
    kp, vp = _pool(seed=5)
    C = 4
    q = jax.random.normal(jax.random.PRNGKey(6), (2, C, 4, 8), jnp.float32)
    q_start = np.array([8, 4], np.int32)
    layer = jnp.int32(0)

    out_ser, slot_ser, cnt_ser = pk.paged_prefill_raw(
        q, kp, vp, BT, q_start, layer
    )
    out_ref, slot_ref, cnt_ref = pk.paged_prefill_shard_ref(
        q, kp, vp, BT, q_start, layer, n_shards=N_SHARDS
    )
    ksh, vsh = _shard_pool(mesh, kp, vp)
    out_sh, slot_sh, cnt_sh = pk.paged_prefill_sharded(
        q, ksh, vsh, BT, q_start, layer, mesh=mesh, axis="data"
    )
    assert int(cnt_ser[pk.EV_TOTAL]) > 0
    np.testing.assert_array_equal(np.asarray(slot_sh), np.asarray(slot_ser))
    np.testing.assert_array_equal(np.asarray(cnt_sh), np.asarray(cnt_ser))
    np.testing.assert_array_equal(np.asarray(slot_ref), np.asarray(slot_ser))
    np.testing.assert_array_equal(np.asarray(cnt_ref), np.asarray(cnt_ser))
    np.testing.assert_array_equal(_bits(out_sh), _bits(out_ref))
    np.testing.assert_allclose(
        np.asarray(out_sh), np.asarray(out_ser), rtol=2e-6, atol=2e-6
    )


# --------------------------------------------------------- engine, e2e
def _spaces(mesh):
    mk = lambda m: ApproxSpace(  # noqa: E731
        ApproxConfig(mode="memory", policy="zero", max_magnitude=None),
        mesh=m,
    )
    return mk(mesh), mk(None)


def test_engine_sharded_hot_path_token_parity(mesh):
    """n_pages+1 divides the data axis => the engine resolves the pool's
    page shard axis and runs decode AND chunked prefill under shard_map,
    emitting the same tokens as the single-device engine with zero
    full-view copies."""
    from conftest import tiny_transformer
    from repro.serving import Engine, ServingConfig

    model, params = tiny_transformer()
    cfg = ServingConfig(
        page_size=4, n_pages=7, max_batch=2, max_pages_per_request=4,
        ber=1e-3, seed=23, prefill_chunk=4,
    )
    sp_mesh, sp_plain = _spaces(mesh)
    sharded = Engine(model, params, cfg, space=sp_mesh)
    assert sharded._kernel_shard is not None, (
        "8 pool rows over data=4 must engage the sharded walk"
    )
    assert sharded._kernel_shard[1] == "data"
    plain = Engine(model, params, cfg, space=sp_plain)
    assert plain._kernel_shard is None
    prompts = [[5, 6, 7, 8, 9, 10], [11, 3]]
    rids_s = [sharded.add_request(p, max_new=5) for p in prompts]
    rids_p = [plain.add_request(p, max_new=5) for p in prompts]
    res_s, res_p = sharded.run(), plain.run()
    for rs, rp in zip(rids_s, rids_p):
        assert res_s[rs]["tokens"] == res_p[rp]["tokens"]
    assert sharded.pool.n_gathers == 0
    assert sharded.pool.n_scatters == 0


def test_engine_indivisible_pages_degrade_gracefully(mesh):
    """13 pool rows over data=4: spec_for_leaf degrades to replicated, the
    shard axis resolves to None, and the engine keeps the single-device
    kernel walk (no shard_map) — serving still works."""
    from conftest import tiny_transformer
    from repro.serving import Engine, ServingConfig

    model, params = tiny_transformer()
    cfg = ServingConfig(
        page_size=4, n_pages=12, max_batch=2, max_pages_per_request=4,
        seed=7,
    )
    sp_mesh, _ = _spaces(mesh)
    eng = Engine(model, params, cfg, space=sp_mesh)
    assert eng.pool.page_shard_axis() is None
    assert eng._kernel_shard is None
    rid = eng.add_request([5, 6, 7], max_new=3)
    assert len(eng.run()[rid]["generated"]) == 3


def test_traffic_sharded_token_parity(mesh):
    """CI `traffic` lane assertion: the load harness replayed against a
    sharded engine and a single-device engine yields identical per-request
    token streams, and regenerating the workload from the same seed yields
    identical arrivals."""
    from conftest import tiny_transformer
    from repro.serving import Engine, ServingConfig
    from repro.serving.workload import WorkloadConfig, generate_arrivals

    from benchmarks.traffic import drive

    wl = WorkloadConfig(
        n_requests=6, arrival_rate=0.7, prompt_len=(2, 6),
        long_prompt_len=(8, 10), long_frac=0.3, output_len=(2, 5),
        seed=13,
    )
    arrivals = generate_arrivals(wl)
    assert [
        (a.step, a.prompt, a.max_new) for a in generate_arrivals(wl)
    ] == [(a.step, a.prompt, a.max_new) for a in arrivals]

    model, params = tiny_transformer()
    cfg = ServingConfig(
        page_size=4, n_pages=7, max_batch=2, max_pages_per_request=4,
        ber=1e-3, seed=29, prefill_chunk=4,
    )
    sp_mesh, sp_plain = _spaces(mesh)
    sharded = Engine(model, params, cfg, space=sp_mesh)
    assert sharded._kernel_shard is not None
    plain = Engine(model, params, cfg, space=sp_plain)
    rep_s = drive(sharded, arrivals)
    rep_p = drive(plain, arrivals)
    assert rep_s["token_streams"] == rep_p["token_streams"]
    assert rep_s["tokens_emitted"] == rep_p["tokens_emitted"] > 0
