"""Multidev lane (scripts/ci.sh multidev): the mesh-native repair pipeline
under 8 fake host devices.

These tests verify the PR-3 acceptance contract on a real multi-device
topology: sharded compiled scrub/inject bit-identical to the eager
single-device path with identical GLOBAL counters (reduced once, never
per-replica), one executable trace per (treedef, avals, shardings), page
scrubs on a page-axis-sharded pool, the shard_map Pallas scrub, train_loop
on a mesh, and the elastic reshard + post-restore reference repair.

Collected (and skipped) in the tier-1 single-device run; executed by
``scripts/ci.sh multidev`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8 REPRO_MULTIDEV=1``.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import stats as stats_lib
from repro.runtime import ApproxConfig, ApproxSpace
from repro.runtime.space import inject_tree, scrub_tree

pytestmark = [
    pytest.mark.multidev,
    pytest.mark.skipif(
        jax.device_count() < 8,
        reason="needs the 8-device lane (scripts/ci.sh multidev)",
    ),
]


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((4, 2), ("data", "model"))


def poisoned_tree(seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    w = jax.random.normal(k1, (32, 16), jnp.float32)
    mu = jax.random.normal(k2, (16, 8), jnp.float32)
    w = w.at[3, 4].set(jnp.nan).at[17, 2].set(jnp.inf)
    mu = mu.at[0, 0].set(jnp.nan)
    return {"w": w, "mu": mu, "step": jnp.zeros((), jnp.int32)}


def shard(tree, mesh):
    return jax.device_put(tree, {
        "w": NamedSharding(mesh, P("data", "model")),
        "mu": NamedSharding(mesh, P("data", None)),
        "step": NamedSharding(mesh, P()),
    })


# ----------------------------------------------------------------- parity
def test_sharded_scrub_bitwise_parity_and_global_counts(mesh):
    """Compiled scrub over FSDP/TP-sharded state == eager single-device
    scrub, bit for bit, with identical global counters (zero policy: the
    repair is elementwise, so sharding cannot perturb it)."""
    tree = poisoned_tree()
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"), mesh=mesh)
    eager, eager_stats = scrub_tree(
        tree, space.config, stats_lib.zeros(), space.regions_for(tree)
    )
    out, out_stats = space.scrub(shard(tree, mesh), stats_lib.zeros())
    for a, b in zip(jax.tree.leaves(eager), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert stats_lib.as_dict(eager_stats) == stats_lib.as_dict(out_stats)
    assert stats_lib.as_dict(out_stats)["nan_found"] == 2
    assert stats_lib.as_dict(out_stats)["inf_found"] == 1
    # counted once globally: events is 1 scrub pass, not 8 replicas' worth
    assert stats_lib.as_dict(out_stats)["events"] == 1
    assert space.plan_for(shard(tree, mesh)).placement == "sharded"


def test_sharded_neighbor_mean_bitwise_parity(mesh):
    """neighbor_mean is now tile-local with an order-fixed pairwise
    reduction (ROADMAP leftover): the fill value no longer depends on the
    reduction order GSPMD picks, so the sharded compiled scrub is
    BIT-IDENTICAL to the eager single-device path — not merely allclose —
    and the integer counters stay exactly equal."""
    tree = poisoned_tree(1)
    space = ApproxSpace(
        ApproxConfig(mode="memory", policy="neighbor_mean"), mesh=mesh
    )
    eager, eager_stats = scrub_tree(
        tree, space.config, stats_lib.zeros(), space.regions_for(tree)
    )
    out, out_stats = space.scrub(shard(tree, mesh), stats_lib.zeros())
    for a, b in zip(jax.tree.leaves(eager), jax.tree.leaves(out)):
        a, b = np.asarray(a), np.asarray(b)
        if a.dtype == np.float32:
            np.testing.assert_array_equal(a.view(np.uint32), b.view(np.uint32))
        else:
            np.testing.assert_array_equal(a, b)
    assert stats_lib.as_dict(eager_stats) == stats_lib.as_dict(out_stats)


def test_sharded_inject_bitwise_parity_and_global_flips(mesh):
    """Same key + BER => bit-identical flips through the sharded compiled
    executable and the eager host path, with the ground-truth flip count
    reduced globally (not once per replica)."""
    tree = {"w": jax.random.normal(jax.random.PRNGKey(5), (128, 128))}
    key = jax.random.PRNGKey(6)
    space = ApproxSpace(ApproxConfig(ber=1e-5), mesh=mesh)
    stree = jax.device_put(
        tree, {"w": NamedSharding(mesh, P("data", "model"))}
    )

    eager, eager_flips = inject_tree(
        tree, key, 1e-5, space.regions_for(tree)
    )
    out, flips = space.inject(stree, key, 1e-5)
    np.testing.assert_array_equal(
        np.asarray(eager["w"]), np.asarray(out["w"])
    )
    assert int(eager_flips) == int(flips) > 0
    assert space.stats_dict()["flips"] == int(flips)


# ------------------------------------------------------------------ caching
def test_one_trace_per_layout(mesh):
    """One executable trace per (treedef, avals, shardings): repeated calls
    reuse the cache; a new sharding layout (same treedef/avals) compiles a
    second executable."""
    tree = poisoned_tree(2)
    space = ApproxSpace(ApproxConfig(mode="memory", policy="zero"), mesh=mesh)
    stree = shard(tree, mesh)
    out, _ = space.scrub(stree, stats_lib.zeros())
    assert space.n_traces == 1
    for _ in range(3):
        out, _ = space.scrub(out, stats_lib.zeros())
    assert space.n_traces == 1, "same layout must never retrace"

    replicated = jax.device_put(
        tree, jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    )
    space.scrub(replicated, stats_lib.zeros())
    assert space.n_traces == 2, "a new sharding layout is a new executable"


# ----------------------------------------------------------- serving pool
def test_pool_page_axis_sharding_and_page_scrub_parity(mesh):
    """The engine's pool registers page-axis shardings from the space's
    mesh; page scrubs over the sharded pool are bit-identical (zero policy)
    to the same scrub on an unsharded copy, with identical counters."""
    from repro.serving import Engine, ServingConfig

    from conftest import tiny_transformer

    model, params = tiny_transformer()
    cfg = ServingConfig(
        page_size=4, n_pages=7, max_batch=2, max_pages_per_request=4, seed=0
    )
    sp = ApproxSpace(
        ApproxConfig(mode="memory", policy="zero", max_magnitude=None),
        mesh=mesh,
    )
    eng = Engine(model, params, cfg, space=sp)
    assert eng.pool.shardings is not None
    specs = {str(s.spec) for s in jax.tree.leaves(eng.pool.shardings)}
    # n_pages+1 = 8 divides the data axis (4): the page axis IS sharded
    assert any("data" in s for s in specs), specs

    # poison two pages; scrub them on both the sharded pool and a host copy
    host = jax.device_get(eng.pool.tree)
    poison = jax.tree.map(
        lambda v: jnp.asarray(v).at[2, 0, 0, 0, 0].set(jnp.nan)
        .at[5, 0, 1, 0, 0].set(jnp.inf),
        host,
    )
    eng.pool.tree = jax.device_put(poison, eng.pool.shardings)
    unsharded = ApproxSpace(
        ApproxConfig(mode="memory", policy="zero", max_magnitude=None)
    )
    ref_fixed, ref_stats = unsharded.scrub_pages(
        poison, [2, 5], stats_lib.zeros()
    )
    stats = eng.pool.scrub_pages([2, 5], stats_lib.zeros())
    assert stats_lib.as_dict(ref_stats) == stats_lib.as_dict(stats)
    for a, b in zip(
        jax.tree.leaves(ref_fixed), jax.tree.leaves(eng.pool.tree)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # and the engine serves end-to-end on the sharded pool
    rid = eng.add_request([5, 6, 7], max_new=4)
    results = eng.run()
    assert len(results[rid]["generated"]) == 4


def test_engine_params_sharded_not_replicated(mesh):
    """serve_shardings threading (ROADMAP leftover): a mesh-carrying engine
    device_puts model params onto their logical-axis shardings — params are
    no longer replicated next to the sharded pool."""
    from repro.serving import Engine, ServingConfig

    from conftest import tiny_transformer

    model, params = tiny_transformer()
    cfg = ServingConfig(
        page_size=4, n_pages=7, max_batch=2, max_pages_per_request=4, seed=0
    )
    sp = ApproxSpace(
        ApproxConfig(mode="memory", policy="zero", max_magnitude=None),
        mesh=mesh,
    )
    eng = Engine(model, params, cfg, space=sp)
    assert eng.params_shardings is not None
    leaves = jax.tree.leaves(eng.params)
    assert any(
        getattr(leaf.sharding, "num_devices", 1) > 1
        and not leaf.sharding.is_fully_replicated
        for leaf in leaves
    ), "at least one param must be genuinely sharded"
    # tokens still come out right on the sharded params
    rid = eng.add_request([3, 4, 5], max_new=3)
    results = eng.run()
    assert len(results[rid]["generated"]) == 3

    # a mesh-free engine keeps the legacy behavior (no device_put)
    eng2 = Engine(model, params, cfg, space=ApproxSpace(
        ApproxConfig(mode="memory", policy="zero", max_magnitude=None)
    ))
    assert eng2.params_shardings is None


# ----------------------------------------------------------- kernel entry
def test_scrub_sharded_kernel_shard_local(mesh):
    """The shard_map Pallas scrub repairs each device's local rows with no
    gather; NaN/Inf lane counts are exact global totals (events follow the
    per-shard tiling, like the fused kernels' block shapes)."""
    from repro.kernels.scrub import scrub, scrub_sharded

    x = jax.random.normal(jax.random.PRNGKey(0), (32, 16))
    x = x.at[3, 4].set(jnp.nan).at[17, 2].set(jnp.inf)
    xs = jax.device_put(x, NamedSharding(mesh, P("data", "model")))
    ref, ref_counts = scrub(x, policy="zero")
    out, counts = scrub_sharded(xs, mesh, P("data", "model"), policy="zero")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out))
    assert int(counts[0]) == int(ref_counts[0]) == 1     # nan lanes
    assert int(counts[1]) == int(ref_counts[1]) == 1     # inf lanes

    # partial sharding: replicas along the unused ("model") axis must NOT
    # multiply the global counts (psum runs only over the spec's axes)
    xp = jax.device_put(x, NamedSharding(mesh, P("data", None)))
    out_p, counts_p = scrub_sharded(xp, mesh, P("data", None), policy="zero")
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(out_p))
    assert int(counts_p[0]) == 1 and int(counts_p[1]) == 1

    # fully replicated: each device already holds the global array — no
    # reduction at all, counts stay global
    xr = jax.device_put(x, NamedSharding(mesh, P()))
    _, counts_r = scrub_sharded(xr, mesh, P(), policy="zero")
    assert int(counts_r[0]) == 1 and int(counts_r[1]) == 1


# ------------------------------------------------------------- train loop
def test_train_loop_on_mesh_runs_sharded_repair(mesh):
    """train_loop(mesh=...) threads train_state_shardings into the space:
    the state is sharded, injection windows compile against the placements,
    and the flips counter accumulates ground truth."""
    from conftest import tiny_transformer
    from repro.launch.train import make_optimizer, train_loop

    model, _ = tiny_transformer()
    model = type(model)(dataclasses.replace(model.cfg))
    opt = make_optimizer(total=3)

    def data_fn(i):
        return {
            "tokens": jax.random.randint(jax.random.PRNGKey(i), (8, 16), 1, 96)
        }

    space = ApproxSpace(
        ApproxConfig(mode="memory", policy="zero", ber=1e-5)
    )
    state, history = train_loop(
        model, opt, data_fn, steps=2, key=jax.random.PRNGKey(0),
        ber=1e-5, mesh=mesh, space=space, log_every=1,
    )
    assert space.mesh is mesh
    assert history[-1]["flips"] > 0
    w = jax.tree.leaves(state["params"])[0]
    assert w.sharding.mesh.shape == mesh.shape
    assert np.isfinite(history[-1]["loss"])


# ------------------------------------------------------ elastic reshard
def test_elastic_reshard_restore_and_reference_repair(mesh, tmp_path):
    """Save from one mesh shape, restore onto another: tree equality, the
    new shardings, and a post-restore reference repair that runs on the NEW
    mesh's placements (the checkpoint/manager.py contract, now tested)."""
    from repro.checkpoint.manager import CheckpointManager

    mesh_a = mesh                                     # (data=4, model=2)
    mesh_b = jax.make_mesh((2, 4), ("data", "model"))  # restored topology

    tree = poisoned_tree(3)
    tree = {  # clean state for the save (scrub-on-save would fix it anyway)
        "w": jnp.nan_to_num(tree["w"], posinf=1.0),
        "mu": jnp.nan_to_num(tree["mu"]),
        "step": tree["step"],
    }
    state_a = shard(tree, mesh_a)
    mgr = CheckpointManager(str(tmp_path), scrub=True)
    mgr.save(7, state_a, blocking=True)

    shardings_b = {
        "w": NamedSharding(mesh_b, P("data", "model")),
        "mu": NamedSharding(mesh_b, P("data", None)),
        "step": NamedSharding(mesh_b, P()),
    }
    like = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree
    )
    restored, step = mgr.restore(like=like, shardings=shardings_b, repair=True)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert restored["w"].sharding.mesh.shape == mesh_b.shape

    # flips strike AFTER the restore; the reference repair heals them on
    # the new mesh's shardings and records the events
    poisoned = dict(restored, w=restored["w"].at[1, 2].set(jnp.nan))
    events0 = mgr.space.stats_dict()["events"]
    healed = mgr.reference_repair(poisoned)
    np.testing.assert_array_equal(
        np.asarray(healed["w"]), np.asarray(tree["w"])
    )
    assert healed["w"].sharding.mesh.shape == mesh_b.shape
    assert mgr.space.stats_dict()["events"] == events0 + 1
    assert mgr.space.stats_dict()["nan_found"] >= 1


# -------------------------------------------------------- paged attention
def test_paged_decode_over_sharded_pool_matches_unsharded(mesh):
    """The fused kernel family attends over a "page"->"data"-sharded pool:
    tokens identical to the unsharded engine, ZERO full-view copies across
    admission, prefill and decode — the page-axis sharding pays off end to
    end (no gather ever rebuilds a contiguous view)."""
    from repro.serving import Engine, ServingConfig

    from conftest import tiny_transformer

    model, params = tiny_transformer()
    cfg = ServingConfig(
        page_size=4, n_pages=7, max_batch=2, max_pages_per_request=4,
        ber=1e-3, seed=11,
    )
    sharded = Engine(model, params, cfg, space=ApproxSpace(
        ApproxConfig(mode="memory", policy="zero", max_magnitude=None),
        mesh=mesh,
    ))
    assert sharded.pool.shardings is not None
    assert sharded._paged_fn is not None, "fused path must engage on mesh"
    assert sharded._prefill_fn is not None
    plain = Engine(model, params, cfg, space=ApproxSpace(
        ApproxConfig(mode="memory", policy="zero", max_magnitude=None)
    ))
    prompts = [[5, 6, 7], [11, 3]]
    rids_s = [sharded.add_request(p, max_new=5) for p in prompts]
    rids_p = [plain.add_request(p, max_new=5) for p in prompts]
    res_s, res_p = sharded.run(), plain.run()
    for rs, rp in zip(rids_s, rids_p):
        assert res_s[rs]["tokens"] == res_p[rp]["tokens"]
    # prefill AND decode ran straight off the sharded pool
    assert sharded.pool.n_gathers == 0
    assert sharded.pool.n_scatters == 0


def test_splitk_decode_over_sharded_pool_matches_serial(mesh):
    """Split-K flash decoding over the sharded pool: the grid-parallel page
    walk (log-sum-exp merge) emits the same tokens and per-page fault
    ledger as the serial walk on the same mesh."""
    from repro.serving import Engine, ServingConfig

    from conftest import tiny_transformer

    model, params = tiny_transformer()

    def build(split_k):
        eng = Engine(model, params, ServingConfig(
            page_size=4, n_pages=12, max_batch=2, max_pages_per_request=8,
            ber=1e-3, seed=5, split_k=split_k,
        ), space=ApproxSpace(
            ApproxConfig(mode="memory", policy="zero", max_magnitude=None),
            mesh=mesh,
        ))
        prompt = jax.random.randint(jax.random.PRNGKey(9), (26,), 1, 96)
        eng.add_request(prompt, max_new=6)         # context spans 8 pages
        eng.add_request([4, 17, 2], max_new=6)
        return eng

    split = build(0)                               # auto: M=8 -> 4 splits
    assert split._split_k == 4 and split.pool.shardings is not None
    res_s = split.run()
    serial = build(1)
    res_1 = serial.run()
    for rid in res_s:
        assert res_s[rid]["tokens"] == res_1[rid]["tokens"]
    assert split.stats_dict() == serial.stats_dict()
    np.testing.assert_array_equal(
        split.pool.page_events, serial.pool.page_events
    )
    assert split.pool.n_gathers == 0 and split.pool.n_scatters == 0
