"""Chunked paged prefill + split-K flash decoding (the PR-8 kernel family).

Covers the acceptance contract: prefill-kernel-vs-oracle parity (values
allclose, per-page fatal counters bit-exact) with poisoned pages and ragged
chunk placement; ``Attention.paged_prefill`` parity with the gathered
``decode`` chunk math AND pool-write-set bit-equality (padded rows must not
perturb the pool); split-K vs serial bit-parity over >= 8-page walks
including the ragged null-tail regression (empty splits contribute -inf,
not fill-value mass); engine-level — fused prefill keeps tokens/stats/
bytes/ledger identical to the gathered-prefill arm under injected flips
with ZERO full-view copies, chunked prefill coexists with decode in one
step at token parity, prefix-cache suffix prefills land on the chunked
kernel, split-K decode is token/stats-identical to the serial walk; and the
retirement of the ``pool.fatal_pages`` probe behind a deprecation shim.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_transformer
from repro.core import rules as rules_lib
from repro.kernels import paged_attention as pa
from repro.kernels import ref
from repro.serving import Engine, ServingConfig
from repro.serving.config import ServingConfig as _SC


# ------------------------------------------------------------------ kernels
def _pool(key, P=9, L=2, pg=4, Kh=2, Dh=16):
    k1, k2 = jax.random.split(key)
    k_pages = jax.random.normal(k1, (P, L, pg, Kh, Dh), jnp.float32)
    v_pages = jax.random.normal(k2, (P, L, pg, Kh, Dh), jnp.float32)
    return k_pages, v_pages


@pytest.mark.parametrize("policy,constant", [("zero", 0.0), ("constant", 0.5)])
def test_prefill_kernel_matches_oracle_with_poisoned_pages(policy, constant):
    key = jax.random.PRNGKey(0)
    k_pages, v_pages = _pool(key)
    # chunk of 4 queries per request, ragged placement: request 0 resumes
    # at context position 5, request 1 starts at 0
    q = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 4, 16),
                          jnp.float32)
    k_pages = k_pages.at[2, 1, 1, 0, 3].set(jnp.nan)
    v_pages = v_pages.at[5, 1, 0, 1, 0].set(jnp.inf)
    k_pages = k_pages.at[7, 1, 0, 0, 0].set(jnp.nan)   # unreferenced page
    bt = jnp.asarray([[0, 2, 6], [5, 1, 8]], jnp.int32)
    q_start = jnp.asarray([5, 0], jnp.int32)

    out, page_counts, counts = pa.paged_prefill(
        q, k_pages, v_pages, bt, q_start, layer=1,
        policy=policy, constant=constant,
    )
    ref_out, slot = ref.paged_prefill_ref(
        q, k_pages, v_pages, bt, q_start, layer=1,
        policy=policy, constant=constant,
    )
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref_out), atol=1e-5
    )
    ref_pages = np.zeros(9, np.int64)
    np.add.at(ref_pages, np.asarray(bt), np.asarray(slot))
    np.testing.assert_array_equal(np.asarray(page_counts), ref_pages)
    assert int(page_counts[2]) == 1 and int(page_counts[5]) == 1
    assert int(page_counts[7]) == 0                    # never streamed
    assert int(counts[pa.NAN_K]) == 1 and int(counts[pa.INF_V]) == 1
    assert int(counts[pa.EV_TOTAL]) == 2


def test_prefill_kernel_causal_mask_matches_decode_walk():
    """Row c of a chunk must see exactly the prefix a decode at position
    ``q_start + c`` sees: run the decode kernel once per chunk row and
    compare against the one-shot prefill kernel."""
    key = jax.random.PRNGKey(2)
    k_pages, v_pages = _pool(key, P=6, L=1)
    C = 4
    q = jax.random.normal(jax.random.fold_in(key, 3), (1, C, 4, 16),
                          jnp.float32)
    bt = jnp.asarray([[1, 3, 4]], jnp.int32)
    q_start = jnp.asarray([3], jnp.int32)

    out, _, _ = pa.paged_prefill(
        q, k_pages, v_pages, bt, q_start, layer=0, policy="zero",
    )
    for c in range(C):
        step, _, _ = pa.paged_attention(
            q[:, c], k_pages, v_pages, bt,
            jnp.asarray([3 + c], jnp.int32), layer=0, policy="zero",
        )
        np.testing.assert_allclose(
            np.asarray(out[:, c]), np.asarray(step), atol=1e-5
        )


def test_attention_paged_prefill_matches_gathered_chunk():
    """`Attention.paged_prefill` == `Attention.decode` with an S>1 chunk
    over the gathered view, and the pool write set is bit-identical to the
    gathered path's (padded rows land as duplicates of the last valid row —
    unwritten lanes keep their exact prior bits)."""
    from repro.nn import module as nn_module
    from repro.nn.attention import Attention

    attn = Attention(
        d_model=32, n_heads=4, n_kv=2, head_dim=8, dtype=jnp.float32,
    )
    params = nn_module.init_params(attn.defs(), jax.random.PRNGKey(0))
    B, C, pg, M, P, L = 2, 4, 4, 3, 7, 1
    null = P - 1
    key = jax.random.PRNGKey(7)
    k_pages = jax.random.normal(key, (P, L, pg, 2, 8), jnp.float32)
    v_pages = jax.random.normal(
        jax.random.fold_in(key, 1), (P, L, pg, 2, 8), jnp.float32
    )
    x = jax.random.normal(jax.random.fold_in(key, 2), (B, C, 32), jnp.float32)
    bt = np.asarray([[0, 2, null], [4, 1, null]], np.int32)
    q_start = np.asarray([3, 0], np.int32)
    q_len = np.asarray([4, 2], np.int32)               # request 1 is ragged

    out_p, kp, vp, slot, counts = attn.paged_prefill(
        params, x, k_pages, v_pages, jnp.asarray(bt),
        jnp.asarray(q_start), jnp.asarray(q_len), jnp.zeros((), jnp.int32),
        policy="zero",
        detector_k=rules_lib.Detector(), detector_v=rules_lib.Detector(),
    )

    def gather(leaf):
        v = leaf[bt][:, :, 0]                          # (B, M, pg, K, Dh)
        return v.reshape(B, M * pg, 2, 8)

    cache = {"k": gather(k_pages), "v": gather(v_pages)}
    out_g, new_cache = attn.decode(
        params, x, cache, jnp.asarray(q_start)
    )
    for b in range(B):
        np.testing.assert_allclose(
            np.asarray(out_p[b, : q_len[b]]),
            np.asarray(out_g[b, : q_len[b]]),
            atol=1e-5,
        )
        # write-set bit-equality on every VALID chunk position...
        for c in range(int(q_len[b])):
            t = int(q_start[b]) + c
            page, off = bt[b][t // pg], t % pg
            np.testing.assert_array_equal(
                np.asarray(kp[page, 0, off]),
                np.asarray(new_cache["k"][b, t]),
            )
            np.testing.assert_array_equal(
                np.asarray(vp[page, 0, off]),
                np.asarray(new_cache["v"][b, t]),
            )
    # ...and bitwise NO change anywhere the chunks did not write
    written = set()
    for b in range(B):
        for c in range(int(q_len[b])):
            t = int(q_start[b]) + c
            written.add((int(bt[b][t // pg]), t % pg))
    mask = np.ones((P, pg), bool)
    for page, off in written:
        mask[page, off] = False
    np.testing.assert_array_equal(
        np.asarray(kp)[:, 0][mask], np.asarray(k_pages)[:, 0][mask]
    )
    np.testing.assert_array_equal(
        np.asarray(vp)[:, 0][mask], np.asarray(v_pages)[:, 0][mask]
    )


def test_splitk_matches_serial_over_wide_walk():
    """>= 8-page block tables through the split-K kernel: outputs allclose
    to the serial walk, per-slot fatal counts and AT_* totals bit-exact."""
    key = jax.random.PRNGKey(5)
    k_pages, v_pages = _pool(key, P=12, L=2, pg=4)
    q = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 16), jnp.float32)
    k_pages = k_pages.at[3, 0, 2, 0, 1].set(jnp.nan)
    v_pages = v_pages.at[9, 0, 1, 1, 5].set(jnp.inf)
    bt = jnp.asarray(
        [[0, 1, 2, 3, 4, 5, 6, 7], [8, 9, 10, 11, 11, 11, 11, 11]],
        jnp.int32,
    )
    pos = jnp.asarray([31, 14], jnp.int32)

    serial, slot_s, counts_s = pa.paged_attention(
        q, k_pages, v_pages, bt, pos, layer=0, policy="zero",
    )
    for splits in (2, 4, 8):
        split, slot_k, counts_k = pa.paged_attention_splitk(
            q, k_pages, v_pages, bt, pos, splits=splits, layer=0,
            policy="zero",
        )
        np.testing.assert_allclose(
            np.asarray(split), np.asarray(serial), atol=1e-5, rtol=1e-5
        )
        np.testing.assert_array_equal(np.asarray(slot_k), np.asarray(slot_s))
        np.testing.assert_array_equal(
            np.asarray(counts_k), np.asarray(counts_s)
        )


def test_splitk_ragged_null_tail_regression():
    """A request whose valid pages occupy only the FIRST split leaves the
    remaining splits entirely null — those must contribute -inf logits to
    the merge (weight exactly zero), not fill-value probability mass."""
    key = jax.random.PRNGKey(6)
    k_pages, v_pages = _pool(key, P=10, L=1, pg=4)
    null = 9
    # park huge finite garbage in the null page: any leakage of a null
    # split through the merge moves the output far off the serial walk
    k_pages = k_pages.at[null].set(1e4)
    v_pages = v_pages.at[null].set(-1e4)
    q = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 16), jnp.float32)
    bt = jnp.asarray(
        [[0, 1, 2, 3, 4, 5, 6, 7],
         [8, null, null, null, null, null, null, null]],
        jnp.int32,
    )
    pos = jnp.asarray([15, 1], jnp.int32)              # request 1: 2 tokens

    serial, slot_s, _ = pa.paged_attention(
        q, k_pages, v_pages, bt, pos, layer=0, policy="zero",
    )
    split, slot_k, _ = pa.paged_attention_splitk(
        q, k_pages, v_pages, bt, pos, splits=4, layer=0, policy="zero",
    )
    np.testing.assert_allclose(
        np.asarray(split), np.asarray(serial), atol=1e-5, rtol=1e-5
    )
    np.testing.assert_array_equal(np.asarray(slot_k), np.asarray(slot_s))
    assert bool(jnp.isfinite(split).all())
    # the independent oracle agrees
    ref_out, ref_slot = ref.paged_splitk_ref(
        q, k_pages, v_pages, bt, pos, splits=4, layer=0, policy="zero",
    )
    np.testing.assert_allclose(
        np.asarray(split), np.asarray(ref_out), atol=1e-5, rtol=1e-5
    )
    ref_pages = np.zeros(10, np.int64)
    np.add.at(ref_pages, np.asarray(bt), np.asarray(ref_slot))
    np.testing.assert_array_equal(np.asarray(slot_k), ref_pages)


# ------------------------------------------------------------------ engine
@pytest.fixture(scope="module")
def model_params():
    return tiny_transformer()


def _engine(model, params, *, ber=0.0, seed=3, max_new=6, n_req=6, **kw):
    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=10, max_batch=4, max_pages_per_request=5,
        ber=ber, sweep_interval=8, sweep_pages=2, seed=seed, **kw,
    ))
    for i in range(n_req):
        prompt = jax.random.randint(jax.random.PRNGKey(i), (5 + i % 3,), 1, 96)
        eng.add_request(prompt, max_new=max_new)
    return eng


def test_fused_prefill_bit_identical_to_gathered_under_flips(model_params):
    """The prefill acceptance bar: tokens, unified stats, scrubbed bytes,
    and the per-page fault ledger of the fused-prefill engine are identical
    to the gathered-prefill arm under the same injected bit-flips — and the
    fused engine issues ZERO full-view pool copies across the whole run."""
    model, params = model_params
    fused = _engine(model, params, ber=1e-3)
    assert fused._prefill_fn is not None
    res_f = fused.run()

    legacy = _engine(model, params, ber=1e-3, paged_prefill="off")
    assert legacy._prefill_fn is None and legacy._paged_fn is not None
    res_g = legacy.run()

    assert fused.stats_dict()["events"] > 0            # faults actually fired
    for rid in res_f:
        assert res_f[rid]["tokens"] == res_g[rid]["tokens"]
    assert fused.stats_dict() == legacy.stats_dict()
    assert fused.rule_stats() == legacy.rule_stats()
    assert fused.pool.scrubbed_bytes == legacy.pool.scrubbed_bytes
    np.testing.assert_array_equal(
        fused.pool.page_events, legacy.pool.page_events
    )
    assert fused.pool.n_gathers == 0
    assert fused.pool.n_scatters == 0
    assert legacy.pool.n_gathers > 0                   # the copies it retired


def test_chunked_prefill_coexists_with_decode(model_params):
    """vllm-style mixed batching: with ``prefill_chunk`` set, a step can
    stream one request's prompt chunk AND decode another request's token —
    and the chunked run emits exactly the tokens of the unchunked one."""
    model, params = model_params
    whole = Engine(model, params, ServingConfig(
        page_size=4, n_pages=12, max_batch=2, max_pages_per_request=6,
    ))
    chunked = Engine(model, params, ServingConfig(
        page_size=4, n_pages=12, max_batch=2, max_pages_per_request=6,
        prefill_chunk=3,
    ))
    prompts = [[5, 6, 7], [11, 3, 9, 2, 8, 4, 1, 7, 6, 2]]
    for eng in (whole, chunked):
        for p in prompts:
            eng.add_request(p, max_new=6)

    res_w = whole.run()
    mixed_steps = 0
    outs = []
    while chunked.has_work:
        out = chunked.step()
        outs.append(out)
        if chunked._prefilling and out["emitted"]:
            mixed_steps += 1                   # a chunk AND a token together
    res_c = chunked.results
    for rid in res_w:
        assert res_c[rid]["tokens"] == res_w[rid]["tokens"]
    # request 0 (3 tokens) prefills in one chunk and decodes while request
    # 1 (10 tokens) is still streaming chunks
    assert mixed_steps > 0
    assert chunked.pool.n_gathers == 0 and chunked.pool.n_scatters == 0


def test_prefix_cache_suffix_prefill_on_chunked_kernel(model_params):
    """A cache hit prefills only the suffix — and that suffix pass runs on
    the chunked paged kernel, not a gathered view."""
    model, params = model_params
    eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=16, max_batch=2, max_pages_per_request=4,
        prefix_cache=True,
    ))
    prefix = [7, 3, 9, 2, 11, 5, 8, 4]                 # two full pages
    r0 = eng.add_request(prefix + [21], max_new=3)
    eng.run()
    r1 = eng.add_request(prefix + [33, 14], max_new=3)
    res = eng.run()
    assert len(res[r1]["generated"]) == 3
    assert eng.cache_stats()["prefill_tokens_saved"] == 8
    assert eng.pool.n_gathers == 0 and eng.pool.n_scatters == 0
    # parity: same second request through a cache-less engine
    ref_eng = Engine(model, params, ServingConfig(
        page_size=4, n_pages=16, max_batch=2, max_pages_per_request=4,
    ))
    rr = ref_eng.add_request(prefix + [33, 14], max_new=3)
    assert ref_eng.run()[rr]["tokens"] == res[r1]["tokens"]


def test_splitk_engine_parity_under_flips(model_params):
    """Split-K decode (auto-engaged at an 8-page block table) is token- and
    stats-identical to the serial walk under injected flips."""
    model, params = model_params

    def build(split_k):
        eng = Engine(model, params, ServingConfig(
            page_size=4, n_pages=12, max_batch=2, max_pages_per_request=8,
            ber=1e-3, seed=5, sweep_interval=8, sweep_pages=2,
            split_k=split_k,
        ))
        prompt = jax.random.randint(jax.random.PRNGKey(9), (26,), 1, 96)
        eng.add_request(prompt, max_new=6)             # context spans 8 pages
        eng.add_request([4, 17, 2], max_new=6)
        return eng

    split = build(0)                                   # auto: M=8 -> 4 splits
    assert split._split_k == 4
    res_s = split.run()

    serial = build(1)
    assert serial._split_k == 1
    res_1 = serial.run()

    assert split.stats_dict()["events"] > 0
    for rid in res_s:
        assert res_s[rid]["tokens"] == res_1[rid]["tokens"]
    assert split.stats_dict() == serial.stats_dict()
    assert split.pool.scrubbed_bytes == serial.pool.scrubbed_bytes
    np.testing.assert_array_equal(
        split.pool.page_events, serial.pool.page_events
    )
    assert split.pool.n_gathers == 0 and split.pool.n_scatters == 0


def test_fatal_pages_probe_is_deprecated(model_params):
    """Satellite: the probe survives only as a compat shim — calling it
    warns, and a default fused engine run never triggers it."""
    model, params = model_params
    eng = _engine(model, params, ber=1e-3, n_req=2, max_new=3)
    with pytest.warns(DeprecationWarning, match="fatal_pages is deprecated"):
        eng.pool.fatal_pages([0, 1])
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng.run()                                      # fused paths: no probe


def test_serving_config_split_k_resolution():
    base = dict(page_size=4, n_pages=32)
    assert _SC(**base, max_pages_per_request=8).resolve_split_k() == 4
    assert _SC(**base, max_pages_per_request=5).resolve_split_k() == 1
    assert _SC(**base, max_pages_per_request=12).resolve_split_k() == 6
    assert _SC(**base, max_pages_per_request=8, split_k=1).resolve_split_k() == 1
    assert _SC(**base, max_pages_per_request=8, split_k=3).resolve_split_k() == 2
    assert _SC(**base, max_pages_per_request=8, split_k=16).resolve_split_k() == 8
    assert _SC(**base, max_pages_per_request=6, split_k=6).resolve_split_k() == 6
    with pytest.raises(ValueError):
        _SC(split_k=-1)
    with pytest.raises(ValueError):
        _SC(prefill_chunk=-2)
    with pytest.raises(ValueError):
        _SC(paged_prefill="sometimes")
