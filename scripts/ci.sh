#!/usr/bin/env bash
# CI entry point: tier-1 suite + multidev lane + example smoke test +
# benchmark smoke run.
#
#   bash scripts/ci.sh            # everything
#   bash scripts/ci.sh lint       # ruff check (skipped if ruff is absent)
#   bash scripts/ci.sh tests      # tier-1 suite only (single device)
#   bash scripts/ci.sh multidev   # distributed-repair suite (8 fake devices)
#   bash scripts/ci.sh smoke      # examples only
#   bash scripts/ci.sh autopilot  # autopilot smoke lane: tiny 2-group x
#                                 # 2-point campaign + online-guard trip
#   bash scripts/ci.sh bench      # benchmark sections (--smoke shapes),
#                                 # records + validates BENCH_repair.json
#   bash scripts/ci.sh traffic    # traffic smoke lane (8 fake devices):
#                                 # workload seed-determinism + sharded-vs-
#                                 # single-device token parity under load
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
what="${1:-all}"

if [[ "$what" == "all" || "$what" == "lint" ]]; then
    # lint lane (config in pyproject.toml [tool.ruff]).  ruff is not baked
    # into every container image; when absent the lane degrades to a loud
    # skip instead of failing environments that cannot install it.
    echo "== lint (ruff check) =="
    if command -v ruff >/dev/null 2>&1; then
        ruff check .
    elif python -m ruff --version >/dev/null 2>&1; then
        python -m ruff check .
    else
        echo "ruff not installed — skipping lint lane"
    fi
fi

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "== tier-1 suite =="
    python -m pytest -x -q
fi

if [[ "$what" == "all" || "$what" == "multidev" ]]; then
    # dedicated lane in a subprocess: 8 fake host devices, REPRO_MULTIDEV=1
    # opts out of the tier-1 conftest single-device guard for this run ONLY
    # (the guard itself stays enforced for every other invocation)
    echo "== multidev lane (8 fake host devices) =="
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        REPRO_MULTIDEV=1 \
        python -m pytest tests/multidev -x -q -m multidev
fi

if [[ "$what" == "all" || "$what" == "smoke" ]]; then
    echo "== smoke: examples/quickstart.py =="
    python examples/quickstart.py
fi

if [[ "$what" == "all" || "$what" == "autopilot" ]]; then
    # the EDEN-style autopilot at smoke scale, fixed seeds: the recurrent
    # preset's campaign (2 groups x 2 refresh points) must land the
    # recurrent state strictly more conservative than the weights, and the
    # online guard must demonstrably tighten under injected fault excess
    echo "== autopilot smoke (campaign separation + guard trip) =="
    python -m pytest -x -q \
        tests/test_autopilot.py::test_recurrent_smoke_campaign_separates_state_from_weights \
        tests/test_autopilot.py::test_engine_guard_trips_and_keeps_serving
fi

if [[ "$what" == "all" || "$what" == "bench" ]]; then
    # every section — incl. the serving-engine and repair-pipeline benches —
    # executes on every CI run at tiny shapes with fixed seeds, so broken
    # benches fail loudly; the repair bench also asserts compiled <= eager
    # and records the trajectory to BENCH_repair.json
    echo "== benchmarks (smoke shapes) =="
    # the CI layer stamps the history entry explicitly so the record's
    # trajectory carries a reproducible label per run
    python -m benchmarks.run --smoke --out BENCH_repair.json \
        --timestamp "$(date -u +%Y-%m-%dT%H:%M:%SZ)"
    # the record must keep every key the README quotes (fail loudly if a
    # refactor renames/drops one — the README's perf claims would go stale)
    python scripts/check_bench.py BENCH_repair.json
fi

if [[ "$what" == "all" || "$what" == "traffic" ]]; then
    # the load harness under the 8-fake-device topology: the workload must
    # regenerate bit-equal from its seed and the sharded engine must emit
    # the same token streams as the single-device engine under real traffic
    echo "== traffic lane (load harness, 8 fake host devices) =="
    XLA_FLAGS="--xla_force_host_platform_device_count=8${XLA_FLAGS:+ $XLA_FLAGS}" \
        REPRO_MULTIDEV=1 \
        python -m pytest -x -q \
        tests/test_traffic.py::test_harness_seed_deterministic \
        "tests/multidev/test_sharded_serving.py::test_traffic_sharded_token_parity"
fi

echo "CI OK"
