#!/usr/bin/env bash
# CI entry point: tier-1 suite + example smoke test + benchmark smoke run.
#
#   bash scripts/ci.sh          # everything
#   bash scripts/ci.sh tests    # suite only
#   bash scripts/ci.sh smoke    # examples only
#   bash scripts/ci.sh bench    # benchmark sections only (--smoke shapes)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
what="${1:-all}"

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "== tier-1 suite =="
    python -m pytest -x -q
fi

if [[ "$what" == "all" || "$what" == "smoke" ]]; then
    echo "== smoke: examples/quickstart.py =="
    python examples/quickstart.py
fi

if [[ "$what" == "all" || "$what" == "bench" ]]; then
    # every section — incl. the serving-engine bench — executes on every CI
    # run at tiny shapes with fixed seeds, so broken benches fail loudly
    echo "== benchmarks (smoke shapes) =="
    python -m benchmarks.run --smoke
fi

echo "CI OK"
