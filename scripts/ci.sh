#!/usr/bin/env bash
# CI entry point: tier-1 suite + example smoke test.
#
#   bash scripts/ci.sh          # everything
#   bash scripts/ci.sh tests    # suite only
#   bash scripts/ci.sh smoke    # examples only
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
what="${1:-all}"

if [[ "$what" == "all" || "$what" == "tests" ]]; then
    echo "== tier-1 suite =="
    python -m pytest -x -q
fi

if [[ "$what" == "all" || "$what" == "smoke" ]]; then
    echo "== smoke: examples/quickstart.py =="
    python examples/quickstart.py
fi

echo "CI OK"
