"""Validate BENCH_repair.json against the keys the README quotes.

README §Distributed repair cites the repair-pipeline bench record: eager vs
compiled scrub/inject wall-time and scrubbed-bytes/step on 1 and 8 fake
devices, plus the trace count.  If a refactor renames or drops any of those
keys the bench silently stops backing the README's claims — this check makes
the bench step fail loudly instead.

    python scripts/check_bench.py BENCH_repair.json
"""
from __future__ import annotations

import json
import sys

SECTIONS = ("devices_1", "devices_8")
SECTION_KEYS = (
    "devices",
    "placement",
    "eager_scrub_us",
    "compiled_scrub_us",
    "eager_inject_us",
    "compiled_inject_us",
    "scrubbed_bytes_per_step",
    "traces",
)


def check(path: str) -> int:
    with open(path) as f:
        record = json.load(f)
    missing = []
    sections = record.get("sections")
    if not isinstance(sections, dict):
        missing.append("sections")
        sections = {}
    for name in SECTIONS:
        sec = sections.get(name)
        if not isinstance(sec, dict):
            missing.append(f"sections.{name}")
            continue
        for key in SECTION_KEYS:
            if key not in sec:
                missing.append(f"sections.{name}.{key}")
    if missing:
        print(f"{path}: missing keys the README quotes:", file=sys.stderr)
        for m in missing:
            print(f"  - {m}", file=sys.stderr)
        return 1
    print(f"{path}: all README-quoted keys present "
          f"({len(SECTIONS) * len(SECTION_KEYS)} checked)")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    sys.exit(check(sys.argv[1]))
