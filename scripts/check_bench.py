"""Validate BENCH_repair.json against the keys the README quotes.

README §Distributed repair cites the repair-pipeline bench record (eager vs
compiled scrub/inject wall-time and scrubbed-bytes/step on 1 and 8 fake
devices, plus the trace count) and README §Serving engine cites the serving
section (tokens/s + scrubbed-bytes/token per arm, the paged-kernel arm's
zero-decode-copy counters), the tiered-KV section (swap-vs-recompute
re-prefilled tokens, boundary-scrub bytes/token), and the prefix-cache
section (prefill-tokens-saved per share ratio, gated vs always-scrub
reuse bytes).  README §Autopilot cites the autopilot section (the profiled
quality-vs-refresh frontier per region group and the solved per-group
assignments for the transformer and recurrent presets).  The record's
``history`` list — the bench trajectory ``benchmarks/run.py`` appends each
run to — must be non-empty, well-shaped, and end with the latest sections.
If a refactor renames or drops any of those keys the bench silently stops
backing the README's claims — this check makes the bench step fail loudly
instead.

    python scripts/check_bench.py BENCH_repair.json
"""
from __future__ import annotations

import json
import sys

SECTIONS = ("devices_1", "devices_8")
SECTION_KEYS = (
    "devices",
    "placement",
    "eager_scrub_us",
    "compiled_scrub_us",
    "eager_inject_us",
    "compiled_inject_us",
    "scrubbed_bytes_per_step",
    "traces",
)
SERVING_KEYS = ("rows", "paged_vs_gather_bytes_ok")
SERVING_ROW_KEYS = (
    "us_per_token",
    "warmup_us",
    "scrubbed_bytes_per_token",
    "tokens_emitted",
    "pool_gathers",
    "pool_scatters",
    "events",
)
TIERED_KEYS = ("rows", "swap_beats_recompute_ok")
TIERED_ROW_KEYS = (
    "us_per_token",
    "warmup_us",
    "tokens_emitted",
    "prefill_tokens_recomputed",
    "boundary_scrub_bytes_per_token",
    "swap_outs",
    "swap_ins",
    "recompute_fallbacks",
    "n_preemptions",
)
TRAFFIC_KEYS = (
    "rows",
    "seed_deterministic",
    "desync_token_parity_ok",
    "desync_fewer_syncs_ok",
)
TRAFFIC_ROW_KEYS = (
    "tokens_per_s",
    "p50_ms_per_token",
    "p99_ms_per_token",
    "ttft_p50_ms",
    "ttft_p99_ms",
    "scrubbed_bytes_per_token",
    "tokens_emitted",
    "n_preemptions",
    "n_host_syncs",
    "host_syncs_per_step",
)
# the README quotes the latency/throughput frontier at both BER points,
# the preemption storm, and the desynchronized-drain comparison arm
TRAFFIC_ROWS = (
    "traffic_ber0",
    "traffic_ber0.001",
    "traffic_storm_ber0.001",
    "traffic_desync_ber0.001",
)
PREFIX_KEYS = ("rows", "zero_ber_parity_ok", "gated_vs_always_bytes_ok")
PREFIX_ROW_KEYS = (
    "us_per_token",
    "tokens_emitted",
    "prefill_tokens_saved",
    "scrubbed_bytes_per_token",
    "hits",
    "reuse_scrubs",
    "reuse_ref_repairs",
    "reuse_skips",
)
AUTOPILOT_KEYS = ("models", "recurrent_state_more_conservative")
AUTOPILOT_MODELS = ("transformer", "recurrent")
AUTOPILOT_MODEL_KEYS = (
    "model",
    "metric",
    "budget",
    "frontier",
    "assignments",
    "energy_saving",
)
AUTOPILOT_CELL_KEYS = (
    "group",
    "refresh_s",
    "ber",
    "quality",
    "flips",
    "faults_per_step",
    "energy_saving",
)
AUTOPILOT_ASSIGN_KEYS = (
    "refresh_s",
    "ber",
    "collapsed",
    "quality",
    "energy_saving",
    "expected_faults_per_step",
)


def check(path: str) -> int:
    with open(path) as f:
        record = json.load(f)
    missing = []
    checked = 0
    sections = record.get("sections")
    if not isinstance(sections, dict):
        missing.append("sections")
        sections = {}
    for name in SECTIONS:
        sec = sections.get(name)
        if not isinstance(sec, dict):
            missing.append(f"sections.{name}")
            continue
        for key in SECTION_KEYS:
            checked += 1
            if key not in sec:
                missing.append(f"sections.{name}.{key}")
    serving = sections.get("serving")
    if not isinstance(serving, dict):
        missing.append("sections.serving")
    else:
        for key in SERVING_KEYS:
            checked += 1
            if key not in serving:
                missing.append(f"sections.serving.{key}")
        rows = serving.get("rows") or {}
        for prefix in (
            "serving_paged_", "serving_prefill_paged_", "serving_split_k_"
        ):
            checked += 1
            if not any(name.startswith(prefix) for name in rows):
                missing.append(f"sections.serving.rows.{prefix}*")
        for name, row in rows.items():
            for key in SERVING_ROW_KEYS:
                checked += 1
                if key not in row:
                    missing.append(f"sections.serving.rows.{name}.{key}")
    tiered = sections.get("tiered_kv")
    if not isinstance(tiered, dict):
        missing.append("sections.tiered_kv")
    else:
        for key in TIERED_KEYS:
            checked += 1
            if key not in tiered:
                missing.append(f"sections.tiered_kv.{key}")
        rows = tiered.get("rows") or {}
        checked += 1
        # both comparison arms must be on record for the README's claim
        if not ("tiered_recompute" in rows and "tiered_swap" in rows):
            missing.append("sections.tiered_kv.rows.tiered_{recompute,swap}")
        for name, row in rows.items():
            for key in TIERED_ROW_KEYS:
                checked += 1
                if key not in row:
                    missing.append(f"sections.tiered_kv.rows.{name}.{key}")
    traffic = sections.get("traffic")
    if not isinstance(traffic, dict):
        missing.append("sections.traffic")
    else:
        for key in TRAFFIC_KEYS:
            checked += 1
            if key not in traffic:
                missing.append(f"sections.traffic.{key}")
        rows = traffic.get("rows") or {}
        for name in TRAFFIC_ROWS:
            checked += 1
            if name not in rows:
                missing.append(f"sections.traffic.rows.{name}")
        for name, row in rows.items():
            for key in TRAFFIC_ROW_KEYS:
                checked += 1
                if key not in row:
                    missing.append(f"sections.traffic.rows.{name}.{key}")
    prefix = sections.get("prefix_cache")
    if not isinstance(prefix, dict):
        missing.append("sections.prefix_cache")
    else:
        for key in PREFIX_KEYS:
            checked += 1
            if key not in prefix:
                missing.append(f"sections.prefix_cache.{key}")
        rows = prefix.get("rows") or {}
        checked += 1
        # the gated-vs-always comparison arms must both be on record
        if not ("ber_gated_scrub" in rows and "ber_always_scrub" in rows):
            missing.append("sections.prefix_cache.rows.ber_{gated,always}_scrub")
        for name, row in rows.items():
            for key in PREFIX_ROW_KEYS:
                checked += 1
                if key not in row:
                    missing.append(f"sections.prefix_cache.rows.{name}.{key}")
    auto = sections.get("autopilot")
    if not isinstance(auto, dict):
        missing.append("sections.autopilot")
    else:
        for key in AUTOPILOT_KEYS:
            checked += 1
            if key not in auto:
                missing.append(f"sections.autopilot.{key}")
        models = auto.get("models") or {}
        for mname in AUTOPILOT_MODELS:
            mod = models.get(mname)
            if not isinstance(mod, dict):
                missing.append(f"sections.autopilot.models.{mname}")
                continue
            for key in AUTOPILOT_MODEL_KEYS:
                checked += 1
                if key not in mod:
                    missing.append(f"sections.autopilot.models.{mname}.{key}")
            cells = mod.get("frontier") or []
            checked += 1
            if len(cells) < 4:      # >= 2 groups x >= 2 refresh points
                missing.append(
                    f"sections.autopilot.models.{mname}.frontier"
                    "[>=2 groups x >=2 points]"
                )
            for i, cell in enumerate(cells):
                for key in AUTOPILOT_CELL_KEYS:
                    checked += 1
                    if key not in cell:
                        missing.append(
                            f"sections.autopilot.models.{mname}"
                            f".frontier[{i}].{key}"
                        )
            for gname, assign in (mod.get("assignments") or {}).items():
                for key in AUTOPILOT_ASSIGN_KEYS:
                    checked += 1
                    if key not in assign:
                        missing.append(
                            f"sections.autopilot.models.{mname}"
                            f".assignments.{gname}.{key}"
                        )
    # the bench trajectory: every run appended under a timestamp, the
    # top-level sections mirroring the newest entry
    history = record.get("history")
    checked += 1
    if not isinstance(history, list) or not history:
        missing.append("history[non-empty list]")
    else:
        for i, entry in enumerate(history):
            checked += 1
            if not (
                isinstance(entry, dict)
                and isinstance(entry.get("timestamp"), str)
                and isinstance(entry.get("sections"), dict)
            ):
                missing.append(f"history[{i}].{{timestamp,sections}}")
        if not missing and history[-1]["sections"] != sections:
            missing.append("history[-1].sections == sections (latest run)")
    if missing:
        print(f"{path}: missing keys the README quotes:", file=sys.stderr)
        for m in missing:
            print(f"  - {m}", file=sys.stderr)
        return 1
    print(f"{path}: all README-quoted keys present ({checked} checked)")
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 2:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    sys.exit(check(sys.argv[1]))
