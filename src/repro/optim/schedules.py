"""LR schedules (pure functions of the step scalar — exact-region state)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    final_fraction: float = 0.1,
):
    """Linear warmup then cosine decay to final_fraction·peak."""

    def schedule(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
        t = (step - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1)
        t = jnp.clip(t, 0.0, 1.0)
        cos = final_fraction + (1 - final_fraction) * 0.5 * (
            1 + jnp.cos(jnp.pi * t)
        )
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return schedule
