from .adamw import AdamW, OptState  # noqa: F401
from .schedules import cosine_with_warmup  # noqa: F401
