"""AdamW with decoupled weight decay, global-norm clipping, and
ZeRO-compatible state layout.

State design for the approximate-memory setting (README §Regions):

  * moments (mu, nu) mirror the parameter pytree — they inherit the params'
    logical sharding axes, which under the FSDP rules shards them over the
    data axis (ZeRO-1/2 for free via GSPMD);
  * mu/nu live in the APPROXIMATE region (regions.DEFAULT_RULES: anything not
    matching the exact patterns).  They are drift-tolerant: a flipped moment
    bit perturbs one update by epsilon — amortized.  NaN moments would be
    fatal and are covered by the step-boundary scrub;
  * ``step`` (and everything derived from it: schedule, bias correction) is
    an int32 scalar in the EXACT region — a flipped step would corrupt bias
    correction for every parameter at once, the "invalid pointer" class of
    failure repair cannot express.

Numerics: moments are f32 regardless of param dtype (bf16 moments diverge);
update math in f32, param write-back in the param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array          # int32 scalar — exact region ("step" path rule)
    mu: Any                  # f32 pytree like params — approx region
    nu: Any                  # f32 pytree like params — approx region


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]   # schedule(step) -> f32
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> OptState:
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return OptState(
            step=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(f32, params),
            nu=jax.tree.map(f32, params),
        )

    def abstract_state(self, abstract_params) -> OptState:
        sds = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
        return OptState(
            step=jax.ShapeDtypeStruct((), jnp.int32),
            mu=jax.tree.map(sds, abstract_params),
            nu=jax.tree.map(sds, abstract_params),
        )

    def state_logical_axes(self, params_axes) -> OptState:
        """Moments inherit the parameter sharding (ZeRO via GSPMD)."""
        return OptState(step=None, mu=params_axes, nu=params_axes)

    # ------------------------------------------------------------------ step
    def update(
        self, grads, state: OptState, params
    ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
        gnorm = _global_norm(grads)
        if self.clip_norm is not None:
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-12))
            grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = self.lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            # Invariant-aware repair (approximate-memory hardening): nu must
            # be ≥ 0, but a sign-bit flip is a *finite* drift error the NaN
            # scrub deliberately leaves alone — and sqrt(negative) NaN-poisons
            # the whole update.  Clamping at the consumer is the register-mode
            # philosophy applied to an algebraic invariant (README §Config).
            v = b2 * jnp.maximum(v, 0.0) + (1 - b2) * g * g
            mhat = m / c1
            vhat = v / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
            return p_new, m, v

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state.mu)
        flat_v = jax.tree.leaves(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
        new_m = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
        new_v = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_p, OptState(step, new_m, new_v), metrics


def _global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))
