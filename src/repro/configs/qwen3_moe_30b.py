"""qwen3-moe-30b-a3b — fine-grained MoE LM [hf:Qwen/Qwen3-30B-A3B; hf].

48L, d_model 2048, 32 heads (GQA kv=4), per-expert d_ff 768, vocab 151936,
128 experts top-8.  The fine-grained-expert stress case: the dispatch
all-to-all dominates the collective roofline term at train_4k (§Perf cell
candidate).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv=4,
    d_ff=768,
    vocab=151936,
    head_dim=128,
    rope_theta=1000000.0,
    norm="rms",
    mlp="swiglu",
    tie_embeddings=False,
    n_experts=128,
    top_k=8,
)
