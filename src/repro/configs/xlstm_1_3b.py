"""xlstm-1.3b — sLSTM + mLSTM stack [arXiv:2405.04517; unverified].

48 blocks, d_model 2048, 4 heads, vocab 50304, d_ff=0 (blocks carry their
own projections).  Every 8th block is sLSTM (sequential scalar memory), the
rest mLSTM (chunked-parallel matrix memory).  Sub-quadratic: runs the
long_500k cell; the mLSTM matrix memory C is the long-lived decode state
(KV-cache analogue) protected by the repair machinery.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv=4,
    d_ff=0,
    vocab=50304,
    norm="rms",
    tie_embeddings=True,
    slstm_every=8,
)
