"""Profiled autopilot presets (README §Autopilot).

One preset per model family the acceptance story needs: a dense
transformer (qwen2) and a recurrent state-space stack (xLSTM).  Each
bundles the CPU-scale architecture, the region grouping (weights vs the
long-lived decode state), and the campaign geometry — ``run_campaign``
over a preset is the whole profiling story in one call.

The grouping encodes the paper's central asymmetry:

  * **weight groups** carry the training-defaults rule — NaN/Inf plus a
    range guard (``max_magnitude=1e3``) repaired by ``neighbor_mean`` —
    because a flipped weight is read fresh from memory every step and a
    bounded excursion amortizes over the ensemble;
  * **state groups** (KV cache / recurrent mLSTM-sLSTM state) carry the
    NaN/Inf-only zero-fill rule: legal-float exponent flips pass the
    detector and *compound* through the recurrence, so the campaign is
    expected to measure collapse at aggressive refresh — exactly the
    signal the frontier solver turns into an exact-ECC island.
"""
from __future__ import annotations

import dataclasses
from typing import Any

from ..core.rules import Detector, RepairRule
from ..runtime import ApproxConfig
from . import get_config
from .base import ArchConfig

# NOTE: repro.autopilot.campaign reaches back through launch/ and models/
# into this package, so the campaign types are imported inside the preset
# builders (not at module scope) to keep `import repro.autopilot` acyclic.

__all__ = [
    "AutopilotPreset",
    "PRESETS",
    "get_preset",
    "preset_names",
    "recurrent_preset",
    "transformer_preset",
]

# the training-defaults rule for weight groups: range-guarded, ensemble
# fill — bounded drift instead of collapse under exponent flips
_WEIGHT_RULE = RepairRule(
    detect=Detector(nan=True, inf=True, max_magnitude=1e3),
    fill="neighbor_mean",
    trigger="boundary",
)

# four refresh points spanning the anchor table's interesting span:
# 0.256 s (BER 1e-9, 16.1 % saving), 1.0 s (1e-6, 22.5 %), the
# interpolated 2.0 s (1e-5, ~25 %), and 4.0 s (1e-4, 30 %).  2.0 s is
# where the curves separate: range-guarded weights hold their divergence
# under the budget while recurrent state — whose legal-float exponent
# flips pass the NaN/Inf detector and compound through the recurrence —
# collapses to full divergence
_REFRESH_POINTS = (0.256, 1.0, 2.0, 4.0)


@dataclasses.dataclass(frozen=True)
class AutopilotPreset:
    """One profilable model: tiny architecture + campaign recipe + budget."""

    name: str
    arch: ArchConfig
    campaign: Any                   # autopilot.campaign.CampaignConfig
    budget: float                   # quality budget handed to solve_frontier

    def build_model(self):
        from ..models import build_model

        return build_model(self.arch)


def _tiny(name: str, **overrides) -> ArchConfig:
    return dataclasses.replace(
        get_config(name).reduced(),
        repair=ApproxConfig(mode="off"),
        **overrides,
    )


def transformer_preset(steps: int = 8, seed: int = 0) -> AutopilotPreset:
    """Dense transformer: FFN weights vs the KV cache."""
    from ..autopilot.campaign import CampaignConfig, RegionGroup

    arch = _tiny(
        "qwen2-1.5b",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, head_dim=16,
        d_ff=128, vocab=97,
    )
    groups = (
        RegionGroup(
            name="ffn_weights", pattern=r"params/layers/mlp/",
            rule=_WEIGHT_RULE,
        ),
        # the alternation binds one rule to BOTH path renderings of the KV
        # cache: the serve-state tree (cache/layers/{k,v}) the campaign
        # profiles, and the engine's paged-pool tree (layers/{k,v}) the
        # frontier's RuleSet is deployed onto — so the online guard's
        # per-label counters stay keyed to the profiled group in serving
        RegionGroup(name="kv_cache", pattern=r"cache/|layers/(k|v)$"),
    )
    return AutopilotPreset(
        name="transformer",
        arch=arch,
        campaign=CampaignConfig(
            groups=groups, refresh_points=_REFRESH_POINTS,
            episode="serve", steps=steps, seed=seed,
        ),
        budget=0.3,
    )


def recurrent_preset(steps: int = 8, seed: int = 0) -> AutopilotPreset:
    """xLSTM: projection weights vs the recurrent mLSTM/sLSTM state."""
    from ..autopilot.campaign import CampaignConfig, RegionGroup

    arch = _tiny(
        "xlstm-1.3b",
        n_layers=2, slstm_every=2, vocab=97,
    )
    groups = (
        RegionGroup(
            name="proj_weights", pattern=r"params/.*/w_(up|down)",
            rule=_WEIGHT_RULE,
        ),
        RegionGroup(name="recurrent_state", pattern=r"cache/"),
    )
    return AutopilotPreset(
        name="recurrent",
        arch=arch,
        campaign=CampaignConfig(
            groups=groups, refresh_points=_REFRESH_POINTS,
            episode="serve", steps=steps, seed=seed,
        ),
        budget=0.3,
    )


PRESETS = {
    "transformer": transformer_preset,
    "recurrent": recurrent_preset,
}


def preset_names():
    return list(PRESETS)


def get_preset(name: str, **kwargs) -> AutopilotPreset:
    if name not in PRESETS:
        raise KeyError(f"unknown preset {name!r}; known: {sorted(PRESETS)}")
    return PRESETS[name](**kwargs)
