"""stablelm-1.6b — dense MHA LM [hf:stabilityai/stablelm-2-1_6b; unverified].

24L, d_model 2048, 32 heads (kv=32 — full MHA), d_ff 5632, vocab 100352.
LayerNorm, partial rotary (25 % of head dim), SwiGLU, untied embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv=32,
    d_ff=5632,
    vocab=100352,
    head_dim=64,
    rope_theta=10000.0,
    rotary_pct=0.25,
    norm="ln",
    mlp="swiglu",
    tie_embeddings=False,
)
