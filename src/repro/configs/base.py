"""Architecture + run configuration schema.

One ``ArchConfig`` per assigned architecture lives in ``configs/<id>.py``
(exact numbers from the assignment table); ``reduced()`` derives the
CPU-smoke-test variant of the same family.  Shape cells (train_4k, …) are
defined here as the assignment's global shape table.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from ..runtime import ApproxConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int

    # attention / block details
    head_dim: Optional[int] = None      # default d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    norm: str = "rms"                   # rms | ln
    mlp: str = "swiglu"                 # swiglu | gelu
    tie_embeddings: bool = True

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    mamba_per_attn: int = 2             # zamba: mamba layers per shared-attn
    n_shared_blocks: int = 2            # zamba: alternating shared blocks
    slstm_every: int = 8                # xlstm: every k-th block is sLSTM

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0

    # frontend stub ([vlm]/[audio]: assignment says modality frontend is a
    # stub feeding precomputed embeddings)
    frontend: str = "none"              # none | patches | frames
    frontend_fraction: float = 0.125    # fraction of seq that is frontend tokens

    # numerics
    dtype_name: str = "bfloat16"

    # the paper's technique, as one unified runtime config (README §Config;
    # a legacy core.repair.RepairConfig is accepted too — every consumer
    # reads only the shared mode/policy/include_inf/max_magnitude fields).
    # max_magnitude is the beyond-paper extension (README §Config): NaN-only
    # repair provably does not survive sustained BER in training — a flip on
    # a high exponent bit is a *legal float* (0.02 -> 5e3/8e7/1e38 for
    # successive bits) that poisons the loss one matmul later.  Healthy
    # weights/moments are O(1); single-bit exponent flips either stay within
    # ~8x (amortizable drift, deliberately kept) or jump >= ~5e3 — 1e3
    # separates the two regimes with huge margin.
    repair: ApproxConfig = ApproxConfig(
        mode="memory", policy="neighbor_mean", max_magnitude=1e3
    )

    # distribution knobs (per-arch defaults; launch may override)
    scan_layers: bool = True
    remat: bool = True
    attn_q_block: int = 512
    attn_kv_block: int = 1024
    ssm_chunk: int = 128

    @property
    def dtype(self):
        return jnp.dtype(self.dtype_name)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self) -> "ArchConfig":
        """Same family, laptop-scale — used by per-arch smoke tests.

        f32 storage: the CPU backend cannot *execute* some bf16 batched dots
        (DotThunk); full-size bf16 configs are only ever lowered (dry-run),
        never executed on CPU."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            dtype_name="float32",
            n_layers=min(self.n_layers, 4),
            d_model=128,
            n_heads=4,
            n_kv=min(self.n_kv, 2) if self.n_kv < self.n_heads else 4,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            mamba_per_attn=2,       # 4 reduced layers: 2 groups, no tail
            slstm_every=4,          # 4 reduced layers: 1 group of 3+1
            enc_layers=min(self.enc_layers, 2) if self.enc_layers else 0,
            dec_layers=min(self.dec_layers, 2) if self.dec_layers else 0,
            attn_q_block=64,
            attn_kv_block=64,
            ssm_chunk=16,
        )


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


# The assignment's shape table (shared by all 10 LM-family archs).
SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}

# long_500k requires sub-quadratic context handling: only SSM/hybrid archs
# run it (README §Workloads records the skips for the 8 full-attention archs).
LONG_CONTEXT_FAMILIES = ("hybrid", "ssm")


def cells_for(cfg: ArchConfig):
    """The executed (arch × shape) cells for one architecture."""
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and cfg.family not in LONG_CONTEXT_FAMILIES:
            continue
        out.append(s)
    return out
