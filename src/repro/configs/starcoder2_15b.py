"""starcoder2-15b — dense GQA code LM [arXiv:2402.19173; hf].

40L, d_model 6144, 48 heads (GQA kv=4), d_ff 24576, vocab 49152.
StarCoder2 uses RoPE, LayerNorm, GeLU MLP with biases, grouped-query
attention with 4 KV heads, untied embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv=4,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    qkv_bias=True,
    rope_theta=100000.0,
    norm="ln",
    mlp="gelu",
    tie_embeddings=False,
)
