"""qwen2-1.5b — dense GQA LM [arXiv:2407.10671; hf].

28L, d_model 1536, 12 heads (GQA kv=2), d_ff 8960, vocab 151936.
QKV bias (Qwen2 signature), RMSNorm, SwiGLU, tied embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv=2,
    d_ff=8960,
    vocab=151936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1000000.0,
    norm="rms",
    mlp="swiglu",
    tie_embeddings=True,
)
