"""zamba2-7b — hybrid Mamba2 + shared-attention [arXiv:2411.15242; unverified].

81 Mamba2 layers, d_model 3584, ssm_state 64; shared transformer block
(on concat(h, emb) = 7168 wide, 32 heads → head_dim 224, d_ff 14336)
applied every 6 Mamba layers, alternating between 2 shared parameter sets,
with a per-invocation down-projection.  vocab 32000.

Sub-quadratic: runs the long_500k cell (SSM state is O(1) in context; the
shared-attn KV is a thin slice of the stack).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv=32,
    d_ff=14336,
    vocab=32000,
    rope_theta=10000.0,
    norm="rms",
    mlp="swiglu",
    tie_embeddings=True,
    ssm_state=64,
    ssm_head_dim=64,
    mamba_per_attn=6,
    n_shared_blocks=2,
)
