"""Config registry: one module per assigned architecture (+ the paper's own
matmul workload).  ``get_config(name)`` resolves assignment ids."""
from __future__ import annotations

from typing import Dict, List

from .base import (  # noqa: F401
    SHAPES,
    ArchConfig,
    ShapeCell,
    cells_for,
    LONG_CONTEXT_FAMILIES,
)

from . import (  # noqa: F401
    llava_next_mistral_7b,
    mistral_large_123b,
    paper_mmm,
    phi35_moe_42b,
    qwen2_1_5b,
    qwen3_moe_30b,
    seamless_m4t_large_v2,
    stablelm_1_6b,
    starcoder2_15b,
    xlstm_1_3b,
    zamba2_7b,
)

_MODULES = (
    starcoder2_15b,
    qwen2_1_5b,
    mistral_large_123b,
    stablelm_1_6b,
    phi35_moe_42b,
    qwen3_moe_30b,
    llava_next_mistral_7b,
    seamless_m4t_large_v2,
    zamba2_7b,
    xlstm_1_3b,
)

REGISTRY: Dict[str, ArchConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def arch_names() -> List[str]:
    return list(REGISTRY)


def get_config(name: str) -> ArchConfig:
    if name not in REGISTRY:
        raise KeyError(
            f"unknown architecture {name!r}; known: {sorted(REGISTRY)}"
        )
    return REGISTRY[name]


# autopilot campaign presets (README §Autopilot) — imported after the
# registry exists because the preset recipes resolve through get_config
from .autopilot_presets import (  # noqa: E402,F401
    AutopilotPreset,
    get_preset,
    preset_names,
)
