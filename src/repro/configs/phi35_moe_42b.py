"""phi3.5-moe-42b-a6.6b — MoE LM [hf:microsoft/Phi-3.5-MoE-instruct; hf].

32L, d_model 4096, 32 heads (GQA kv=8), per-expert d_ff 6400, vocab 32064,
16 experts top-2.  Expert weights are the prime approximate-memory resident
(big, cold, read-mostly); the router is pinned to the exact region
(README §Regions, nn/moe.py).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=6400,
    vocab=32064,
    head_dim=128,
    rope_theta=10000.0,
    norm="ln",
    mlp="swiglu",
    tie_embeddings=False,
    n_experts=16,
    top_k=2,
)
