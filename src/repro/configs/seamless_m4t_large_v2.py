"""seamless-m4t-large-v2 — speech/text enc-dec [arXiv:2308.11596; hf].

24L encoder + 24L decoder, d_model 1024, 16 heads (MHA, kv=16), d_ff 8192,
vocab 256206.  The speech frontend (fbank + conformer conv modules) is a
STUB per the assignment — ``input_specs`` feeds precomputed frame embeddings
(B, S, d_model).  The giant vocab makes the embedding table the dominant
approximate-memory resident for this arch.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv=16,
    d_ff=8192,
    vocab=256206,
    head_dim=64,
    rope_theta=10000.0,
    norm="ln",
    mlp="gelu",
    tie_embeddings=True,
    enc_layers=24,
    dec_layers=24,
    frontend="frames",
)
