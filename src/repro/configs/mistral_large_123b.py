"""mistral-large-123b — dense GQA LM
[hf:mistralai/Mistral-Large-Instruct-2407; unverified].

88L, d_model 12288, 96 heads (GQA kv=8), d_ff 28672, vocab 32768.
The FSDP/TP stress case of the assignment: 123 B params — the dry-run must
shard parameters over both mesh axes to fit (README §Sharding).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv=8,
    d_ff=28672,
    vocab=32768,
    head_dim=128,
    rope_theta=1000000.0,
    norm="rms",
    mlp="swiglu",
    tie_embeddings=False,
)
