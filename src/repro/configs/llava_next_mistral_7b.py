"""llava-next-mistral-7b — VLM backbone
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Mistral-7B backbone: 32L, d_model 4096, 32 heads (GQA kv=8), d_ff 14336,
vocab 32000.  Per the assignment the vision frontend (anyres tiling + CLIP
tower) is a STUB — ``input_specs`` feeds precomputed patch embeddings
(B, P, d_model) that prefix the token sequence; loss is over text positions.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    rope_theta=1000000.0,
    norm="rms",
    mlp="swiglu",
    tie_embeddings=False,
    frontend="patches",
    frontend_fraction=0.125,
)
