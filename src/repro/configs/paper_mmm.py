"""The paper's own evaluation workload (§4): N×N matrix-matrix multiply with
an injected NaN, in three conditions (normal / register / memory).

Not an ArchConfig — a small workload descriptor consumed by
benchmarks/fig7_overhead.py, benchmarks/table3_counts.py and
examples/quickstart.py.  Matrix sizes follow the paper (1000…5000), scaled
to CPU-feasible N by default.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class PaperMMMConfig:
    sizes: Tuple[int, ...] = (256, 512, 1024, 2048)   # CPU-scaled N
    paper_sizes: Tuple[int, ...] = (1000, 2000, 3000, 4000, 5000)
    n_injected: int = 1            # paper injects exactly one NaN
    dtype_name: str = "float32"
    repeats: int = 10              # paper: "measured 10 times, average"
    blocks: Tuple[int, int, int] = (128, 128, 256)


CONFIG = PaperMMMConfig()
