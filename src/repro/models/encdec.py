"""Encoder-decoder transformer (seamless-m4t family).

The speech frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, D) directly; the transformer backbone
(self-attn encoder + causal decoder with cross-attention) is the real system
under test.  Conformer-specific encoder details (conv modules) are out of
backbone scope — recorded in README §Workloads.

Decode state = per-decoder-layer self-attention KV cache (grows with emitted
tokens) + per-layer cross-attention KV computed once from the encoder output
(read-only thereafter — the classic approximate-memory resident: large, cold,
reused every step).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCell
from ..distributed.sharding import constrain
from ..nn import module
from ..nn.attention import Attention
from ..nn.layers import Embedding, LayerNorm, RMSNorm
from ..nn.mlp import GeluMLP, SwiGLU
from .base import Model, next_token_loss


class EncDecLM(Model):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        rcfg = cfg.repair
        Norm = RMSNorm if cfg.norm == "rms" else LayerNorm
        mk_norm = lambda: Norm(cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)
        self.norm = mk_norm()          # template reused for every norm site
        mk_attn = lambda causal, rope: Attention(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            n_kv=cfg.n_kv,
            head_dim=cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias,
            rope_theta=cfg.rope_theta,
            use_rope=rope,
            causal=causal,
            dtype=cfg.dtype,
            rcfg=rcfg,
            q_block=cfg.attn_q_block,
            kv_block=cfg.attn_kv_block,
        )
        self.enc_attn = mk_attn(False, True)
        self.dec_attn = mk_attn(True, True)
        self.cross_attn = mk_attn(False, False)   # no RoPE across modalities
        if cfg.mlp == "gelu":
            self.mlp: Any = GeluMLP(cfg.d_model, cfg.d_ff, dtype=cfg.dtype, rcfg=rcfg)
        else:
            self.mlp = SwiGLU(cfg.d_model, cfg.d_ff, dtype=cfg.dtype, rcfg=rcfg)
        self.embed = Embedding(cfg.vocab, cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)

    # ------------------------------------------------------------------ defs
    def _enc_layer_defs(self):
        return {
            "norm1": self.norm.defs(),
            "attn": self.enc_attn.defs(),
            "norm2": self.norm.defs(),
            "mlp": self.mlp.defs(),
        }

    def _dec_layer_defs(self):
        return {
            "norm1": self.norm.defs(),
            "self_attn": self.dec_attn.defs(),
            "norm_x": self.norm.defs(),
            "cross_attn": self.cross_attn.defs(),
            "norm2": self.norm.defs(),
            "mlp": self.mlp.defs(),
        }

    def defs(self):
        cfg = self.cfg
        return {
            "embed": self.embed.defs(),
            "encoder": module.stack_defs(self._enc_layer_defs(), cfg.enc_layers),
            "enc_norm": self.norm.defs(),
            "decoder": module.stack_defs(self._dec_layer_defs(), cfg.dec_layers),
            "final_norm": self.norm.defs(),
        }

    def enc_len_for(self, cell: ShapeCell) -> int:
        """Encoder length for decode cells (frames already encoded)."""
        return max(cell.seq_len // 8, 128)

    def cache_defs(self, batch: int, max_seq: int, enc_len: int = None):
        enc_len = enc_len or max(max_seq // 8, 128)
        return {
            "self": module.stack_defs(
                self.dec_attn.cache_defs(batch, max_seq), self.cfg.dec_layers
            ),
            "cross": module.stack_defs(
                self.cross_attn.cache_defs(batch, enc_len), self.cfg.dec_layers
            ),
        }

    # --------------------------------------------------------------- forward
    def encode(self, params, frames: jax.Array) -> jax.Array:
        B, S, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = frames.astype(self.cfg.dtype)

        _ACT = ("act_batch", "act_seq", "act_embed")

        def body(carry, p_l):
            h, _ = carry
            h = h + self.enc_attn(
                p_l["attn"], self.norm(p_l["norm1"], h), positions
            )
            h = constrain(
                h + self.mlp(p_l["mlp"], self.norm(p_l["norm2"], h)), _ACT
            )
            return (h, None), None

        fn = jax.checkpoint(body) if self.cfg.remat else body
        (h, _), _ = jax.lax.scan(fn, (h, None), params["encoder"])
        return self.norm(params["enc_norm"], h)

    def decode_train(self, params, tokens: jax.Array, enc: jax.Array):
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        h = self.embed(params["embed"], tokens)

        _ACT = ("act_batch", "act_seq", "act_embed")

        def body(carry, p_l):
            h, _ = carry
            h = h + self.dec_attn(
                p_l["self_attn"], self.norm(p_l["norm1"], h), positions
            )
            h = h + self.cross_attn(
                p_l["cross_attn"], self.norm(p_l["norm_x"], h), kv_x=enc
            )
            h = constrain(
                h + self.mlp(p_l["mlp"], self.norm(p_l["norm2"], h)), _ACT
            )
            return (h, None), None

        fn = jax.checkpoint(body) if self.cfg.remat else body
        (h, _), _ = jax.lax.scan(fn, (h, None), params["decoder"])
        h = self.norm(params["final_norm"], h)
        return self.embed.attend(params["embed"], h)

    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        enc = self.encode(params, batch["frames"])
        return self.decode_train(params, batch["tokens"], enc)

    def loss(self, params, batch):
        logits = self.forward(params, batch)
        return next_token_loss(logits, batch["tokens"])

    # ---------------------------------------------------------------- decode
    def build_cross_cache(self, params, enc: jax.Array):
        """Project encoder output to per-decoder-layer cross K/V (prefill)."""
        def body(_, p_l):
            pa = p_l["cross_attn"]
            _, k, v = self.cross_attn._qkv(pa, enc[:, :1], kv_x=enc)
            return None, {"k": k, "v": v}

        _, cross = jax.lax.scan(body, None, params["decoder"])
        return cross

    def serve_step(self, params, cache, batch, pos):
        h = self.embed(params["embed"], batch["tokens"])

        def body(h, xs):
            p_l, self_c, cross_c = xs
            a, self_new = self.dec_attn.decode(
                p_l["self_attn"], self.norm(p_l["norm1"], h), self_c, pos
            )
            h = h + a
            h = h + self.cross_attn.decode_cross(
                p_l["cross_attn"], self.norm(p_l["norm_x"], h), cross_c
            )
            h = h + self.mlp(p_l["mlp"], self.norm(p_l["norm2"], h))
            return h, self_new

        h, self_new = jax.lax.scan(
            body, h, (params["decoder"], cache["self"], cache["cross"])
        )
        h = self.norm(params["final_norm"], h)
        logits = self.embed.attend(params["embed"], h)
        return logits, {"self": self_new, "cross": cache["cross"]}

    # ----------------------------------------------------------- input specs
    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        B, S = cell.global_batch, cell.seq_len
        cfg = self.cfg
        if cell.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        return {
            "frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), cfg.dtype),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
