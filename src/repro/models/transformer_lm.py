"""Decoder-only transformer LM — unified over dense, MoE, and VLM families.

One class covers starcoder2 / qwen2 / mistral-large / stablelm (dense),
phi3.5-moe / qwen3-moe (MoE FFN), and llava-next (dense backbone + patch-
embedding prefix from the stubbed vision frontend).  The family switches are
all config-driven: norm type, MLP type, biases, partial RoPE, expert count.

Layer stacking is a ``lax.scan`` over stacked parameters (HLO size flat in
depth — mandatory for the 88-layer mistral-large dry-run) with
``jax.checkpoint`` around the block body.

Approximate-memory integration: every parameter/cache read inside the layers
goes through ``core.repair.use`` (register mode repairs at each use; memory
mode is a step-boundary scrub of the state pytree — see launch/train.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCell
from ..distributed.sharding import constrain
from ..nn import module
from ..nn.attention import Attention
from ..nn.layers import Embedding, LayerNorm, Linear, RMSNorm
from ..nn.mlp import GeluMLP, SwiGLU
from ..nn.moe import MoE
from .base import Model, next_token_loss


class TransformerLM(Model):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        rcfg = cfg.repair
        Norm = RMSNorm if cfg.norm == "rms" else LayerNorm
        # each block carries its rendered state-tree path prefix, so a
        # use()-site read binds the same RuleSet rule the scheduled scrubs
        # assign to that parameter (README §RepairRule, per-path on-read)
        self.norm1 = Norm(
            cfg.d_model, dtype=cfg.dtype, rcfg=rcfg, path="layers/norm1"
        )
        self.norm2 = Norm(
            cfg.d_model, dtype=cfg.dtype, rcfg=rcfg, path="layers/norm2"
        )
        self.final_norm = Norm(
            cfg.d_model, dtype=cfg.dtype, rcfg=rcfg, path="final_norm"
        )
        self.attn = Attention(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            n_kv=cfg.n_kv,
            head_dim=cfg.resolved_head_dim,
            qkv_bias=cfg.qkv_bias,
            rope_theta=cfg.rope_theta,
            rotary_pct=cfg.rotary_pct,
            dtype=cfg.dtype,
            rcfg=rcfg,
            q_block=cfg.attn_q_block,
            kv_block=cfg.attn_kv_block,
            path="layers/attn",
        )
        if cfg.n_experts:
            self.mlp: Any = MoE(
                d_model=cfg.d_model,
                d_ff=cfg.d_ff,
                n_experts=cfg.n_experts,
                top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
                dtype=cfg.dtype,
                rcfg=rcfg,
            )
        elif cfg.mlp == "gelu":
            self.mlp = GeluMLP(
                cfg.d_model, cfg.d_ff, dtype=cfg.dtype, rcfg=rcfg,
                path="layers/mlp",
            )
        else:
            self.mlp = SwiGLU(
                cfg.d_model, cfg.d_ff, dtype=cfg.dtype, rcfg=rcfg,
                path="layers/mlp",
            )
        self.embed = Embedding(
            cfg.vocab, cfg.d_model, dtype=cfg.dtype, rcfg=rcfg, path="embed"
        )
        if not cfg.tie_embeddings:
            self.lm_head = Linear(
                cfg.d_model, cfg.vocab, ("embed", "vocab"), dtype=cfg.dtype,
                rcfg=rcfg, path="lm_head",
            )

    # ------------------------------------------------------------------ defs
    def layer_defs(self):
        return {
            "norm1": self.norm1.defs(),
            "attn": self.attn.defs(),
            "norm2": self.norm2.defs(),
            "mlp": self.mlp.defs(),
        }

    def defs(self):
        d = {
            "embed": self.embed.defs(),
            "layers": module.stack_defs(self.layer_defs(), self.cfg.n_layers),
            "final_norm": self.final_norm.defs(),
        }
        if not self.cfg.tie_embeddings:
            d["lm_head"] = self.lm_head.defs()
        return d

    def cache_defs(self, batch: int, max_seq: int):
        return {
            "layers": module.stack_defs(
                self.attn.cache_defs(batch, max_seq), self.cfg.n_layers
            )
        }

    # The decode path is length-generic (attention masks per query position),
    # so one serve_step call with the whole prompt is a valid batched prefill.
    supports_batched_prefill: bool = True

    def paged_cache_defs(self, n_pages: int, page_size: int):
        return {
            "layers": self.attn.paged_cache_defs(
                n_pages, page_size, self.cfg.n_layers
            )
        }

    # --------------------------------------------------------------- forward
    _ACT = ("act_batch", "act_seq", "act_embed")

    def _block(self, carry, p_l, positions):
        h, aux = carry
        h = h + self.attn(p_l["attn"], self.norm1(p_l["norm1"], h), positions)
        h = constrain(h, self._ACT)
        y = self.mlp(p_l["mlp"], self.norm2(p_l["norm2"], h))
        if isinstance(self.mlp, MoE):
            y, aux_l = y
            aux = aux + aux_l
        h = constrain(h + y, self._ACT)
        return (h, aux)

    def _trunk(self, params, h, positions):
        """Embeddings -> final norm, scanned over stacked layers."""
        def body(carry, p_l):
            return self._block(carry, p_l, positions), None

        fn = jax.checkpoint(body) if self.cfg.remat else body
        (h, aux), _ = jax.lax.scan(
            fn, (h, jnp.zeros((), jnp.float32)), params["layers"]
        )
        return self.final_norm(params["final_norm"], h), aux

    def _readout(self, params, h):
        if self.cfg.tie_embeddings:
            logits = self.embed.attend(params["embed"], h)
        else:
            logits = self.lm_head(params["lm_head"], h).astype(jnp.float32)
        return constrain(logits, ("act_batch", "act_seq", "act_vocab"))

    def _embed_inputs(self, params, batch):
        """Token embeddings, with the VLM patch-prefix prepended when given.

        Returns (h, positions, n_prefix)."""
        tokens = batch["tokens"]
        h = self.embed(params["embed"], tokens)
        n_prefix = 0
        if "patch_embeds" in batch:
            prefix = batch["patch_embeds"].astype(h.dtype)
            n_prefix = prefix.shape[1]
            h = jnp.concatenate([prefix, h], axis=1)
        B, S = h.shape[:2]
        h = constrain(h, self._ACT)
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        return h, positions, n_prefix

    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        h, positions, n_prefix = self._embed_inputs(params, batch)
        h, _ = self._trunk(params, h, positions)
        if n_prefix:
            h = h[:, n_prefix:]
        return self._readout(params, h)

    def loss(self, params, batch):
        h, positions, n_prefix = self._embed_inputs(params, batch)
        h, aux = self._trunk(params, h, positions)
        if n_prefix:
            h = h[:, n_prefix:]
        logits = self._readout(params, h)
        loss, metrics = next_token_loss(logits, batch["tokens"])
        if self.cfg.n_experts:
            loss = loss + 0.01 * aux
            metrics = dict(metrics, moe_aux=aux)
        return loss, metrics

    # ---------------------------------------------------------------- decode
    def serve_step(self, params, cache, batch, pos):
        """One decode step.  batch["tokens"]: (B, S) — S==1 for decode, S>1
        for batched (chunked) prefill; pos: i32 scalar or (B,) per-request
        write positions (continuous batching mixes request progress)."""
        h = self.embed(params["embed"], batch["tokens"])

        def body(h, xs):
            p_l, c_l = xs
            a, c_new = self.attn.decode(
                p_l["attn"], self.norm1(p_l["norm1"], h), c_l, pos
            )
            h = h + a
            y = self.mlp(p_l["mlp"], self.norm2(p_l["norm2"], h))
            if isinstance(self.mlp, MoE):
                y, _ = y
            return h + y, c_new

        h, new_cache = jax.lax.scan(body, h, (params["layers"], cache["layers"]))
        h = self.final_norm(params["final_norm"], h)
        return self._readout(params, h), {"layers": new_cache}

    # ---------------------------------------------------------- paged decode
    supports_paged_decode: bool = True

    def serve_step_paged(
        self,
        params,
        pool,                    # {"layers": {"k","v"}}: (P, L, pg, K, Dh)
        batch,                   # {"tokens": (B, 1)}
        block_tables,            # (B, M) int32
        positions,               # (B,) int32 write/last-context position
        *,
        detectors=None,          # {"k": Detector|None, "v": Detector|None}
        policy: str = "zero",
        constant: float = 0.0,
        fills=None,              # {"k": (policy, constant), "v": (...)}
        split_k: int = 1,
        shard=None,              # (mesh, axis) — device-local sharded walk
    ):
        """One decode step straight off the paged pool (no gathered view):
        each layer writes its new K/V into one page slot per request and
        attends via the Pallas paged-attention kernel over (pool leaves,
        block tables, positions) with fused on-read repair.  The layer
        index rides the scan carry and reaches the kernel as a
        scalar-prefetch operand, so one compiled kernel serves every layer
        and the HLO stays flat in depth.  ``fills`` overrides the shared
        ``policy``/``constant`` per pool leaf name — each operand's rule
        fill reaches its kernel tile, so mixed-fill RuleSets keep the
        fused path.  ``split_k > 1`` selects the split-K flash-decoding
        walk (``ServingConfig.split_k``)."""
        detectors = detectors or {}
        fills = fills or {}
        fill_k = fills.get("k", (policy, constant))
        fill_v = fills.get("v", (policy, constant))
        h = self.embed(params["embed"], batch["tokens"])
        B = h.shape[0]
        M = block_tables.shape[1]

        def body(carry, p_l):
            h, kp, vp, slot_acc, cnt_acc, layer = carry
            a, kp, vp, slot, cnt = self.attn.paged_decode(
                p_l["attn"], self.norm1(p_l["norm1"], h), kp, vp,
                block_tables, positions, layer,
                detector_k=detectors.get("k"), detector_v=detectors.get("v"),
                policy_k=fill_k[0], constant_k=fill_k[1],
                policy_v=fill_v[0], constant_v=fill_v[1],
                split_k=split_k,
                shard=shard,
            )
            h = h + a
            y = self.mlp(p_l["mlp"], self.norm2(p_l["norm2"], h))
            if isinstance(self.mlp, MoE):
                y, _ = y
            return (
                h + y, kp, vp, slot_acc + slot, cnt_acc + cnt, layer + 1
            ), None

        carry0 = (
            h,
            pool["layers"]["k"],
            pool["layers"]["v"],
            jnp.zeros((B, M), jnp.int32),
            jnp.zeros((8,), jnp.int32),
            jnp.zeros((), jnp.int32),
        )
        (h, kp, vp, slot_counts, counts, _), _ = jax.lax.scan(
            body, carry0, params["layers"]
        )
        h = self.final_norm(params["final_norm"], h)
        logits = self._readout(params, h)
        return logits, {"layers": {"k": kp, "v": vp}}, slot_counts, counts

    # ---------------------------------------------------------- paged prefill
    supports_paged_prefill: bool = True

    def prefill_paged(
        self,
        params,
        pool,                    # {"layers": {"k","v"}}: (P, L, pg, K, Dh)
        batch,                   # {"tokens": (B, C)} — one causal chunk
        block_tables,            # (B, M) int32
        q_start,                 # (B,) int32 — context position of row 0
        q_len,                   # (B,) int32 — valid rows in the chunk
        *,
        detectors=None,          # {"k": Detector|None, "v": Detector|None}
        policy: str = "zero",
        constant: float = 0.0,
        fills=None,              # {"k": (policy, constant), "v": (...)}
        shard=None,              # (mesh, axis) — device-local sharded walk
    ):
        """One prompt chunk straight off the paged pool — the admission-side
        twin of ``serve_step_paged``: each layer scatters the chunk's K/V
        into the requests' pages and attends via the chunked-q paged kernel
        with fused on-read repair, the layer index riding the scan carry as
        a scalar-prefetch operand.  Rows past ``q_len`` are padding (their
        writes deduplicate onto the last valid position; their logits are
        garbage the engine discards)."""
        detectors = detectors or {}
        fills = fills or {}
        fill_k = fills.get("k", (policy, constant))
        fill_v = fills.get("v", (policy, constant))
        h = self.embed(params["embed"], batch["tokens"])
        B = h.shape[0]
        M = block_tables.shape[1]

        def body(carry, p_l):
            h, kp, vp, slot_acc, cnt_acc, layer = carry
            a, kp, vp, slot, cnt = self.attn.paged_prefill(
                p_l["attn"], self.norm1(p_l["norm1"], h), kp, vp,
                block_tables, q_start, q_len, layer,
                detector_k=detectors.get("k"), detector_v=detectors.get("v"),
                policy_k=fill_k[0], constant_k=fill_k[1],
                policy_v=fill_v[0], constant_v=fill_v[1],
                shard=shard,
            )
            h = h + a
            y = self.mlp(p_l["mlp"], self.norm2(p_l["norm2"], h))
            if isinstance(self.mlp, MoE):
                y, _ = y
            return (
                h + y, kp, vp, slot_acc + slot, cnt_acc + cnt, layer + 1
            ), None

        carry0 = (
            h,
            pool["layers"]["k"],
            pool["layers"]["v"],
            jnp.zeros((B, M), jnp.int32),
            jnp.zeros((8,), jnp.int32),
            jnp.zeros((), jnp.int32),
        )
        (h, kp, vp, slot_counts, counts, _), _ = jax.lax.scan(
            body, carry0, params["layers"]
        )
        h = self.final_norm(params["final_norm"], h)
        logits = self._readout(params, h)
        return logits, {"layers": {"k": kp, "v": vp}}, slot_counts, counts

    # ----------------------------------------------------------- input specs
    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        B, S = cell.global_batch, cell.seq_len
        cfg = self.cfg
        if cell.kind == "decode":
            return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}
        if cfg.frontend == "patches":
            P = int(S * cfg.frontend_fraction)
            return {
                "tokens": jax.ShapeDtypeStruct((B, S - P), jnp.int32),
                "patch_embeds": jax.ShapeDtypeStruct((B, P, cfg.d_model), cfg.dtype),
            }
        return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
