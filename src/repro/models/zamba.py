"""Zamba2-style hybrid: Mamba2 backbone + shared attention blocks.

Structure (arXiv:2411.15242, adapted): ``n_layers`` Mamba2 layers; after
every ``mamba_per_attn``-th layer a **shared** transformer block is applied
to ``concat(h, emb0)`` (the original embedding is re-injected, Zamba's
signature trick), alternating between ``n_shared_blocks`` parameter sets;
each invocation has its own down-projection back to d_model (the paper's
per-invocation LoRA, simplified to a full per-invocation projection —
recorded in README §Workloads).

Grouped scan: G = n_layers // mamba_per_attn groups of (mamba_per_attn
Mamba layers + 1 shared-block application), then the remainder layers.
Keeps HLO flat in depth for the 81-layer config.

Approximate-memory note: the recurrent SSM state is the long-lived decode
resident; a NaN there poisons *all future tokens* (temporal Fig. 1), so the
state flows through ``core.repair.use`` like the KV caches (README §Regions).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCell
from ..distributed.sharding import constrain
from ..nn import module
from ..nn.attention import Attention
from ..nn.layers import Embedding, RMSNorm
from ..nn.mlp import SwiGLU
from ..nn.module import ParamDef
from ..nn.ssm import Mamba2
from ..nn import initializers as ini
from .base import Model, next_token_loss


class ZambaLM(Model):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        rcfg = cfg.repair
        self.d_shared = 2 * cfg.d_model
        self.mamba = Mamba2(
            d_model=cfg.d_model,
            d_state=cfg.ssm_state,
            head_dim=cfg.ssm_head_dim,
            chunk=cfg.ssm_chunk,
            dtype=cfg.dtype,
            rcfg=rcfg,
        )
        self.mamba_norm = RMSNorm(cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)
        self.shared_attn = Attention(
            d_model=self.d_shared,
            n_heads=cfg.n_heads,
            n_kv=cfg.n_kv,
            head_dim=self.d_shared // cfg.n_heads,
            rope_theta=cfg.rope_theta,
            dtype=cfg.dtype,
            rcfg=rcfg,
            q_block=cfg.attn_q_block,
            kv_block=cfg.attn_kv_block,
        )
        self.shared_norm = RMSNorm(self.d_shared, dtype=cfg.dtype, rcfg=rcfg)
        self.shared_mlp = SwiGLU(
            self.d_shared, cfg.d_ff, dtype=cfg.dtype, rcfg=rcfg
        )
        self.final_norm = RMSNorm(cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)
        self.embed = Embedding(cfg.vocab, cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)

    # ------------------------------------------------------------- structure
    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // self.cfg.mamba_per_attn

    @property
    def n_tail(self) -> int:
        return self.cfg.n_layers - self.n_groups * self.cfg.mamba_per_attn

    # ------------------------------------------------------------------ defs
    def _mamba_layer_defs(self):
        return {"norm": self.mamba_norm.defs(), "mamba": self.mamba.defs()}

    def _shared_block_defs(self):
        return {
            "norm1": self.shared_norm.defs(),
            "attn": self.shared_attn.defs(),
            "norm2": self.shared_norm.defs(),
            "mlp": self.shared_mlp.defs(),
        }

    def defs(self):
        cfg = self.cfg
        d = {
            "embed": self.embed.defs(),
            "mamba_groups": module.stack_defs(
                module.stack_defs(self._mamba_layer_defs(), cfg.mamba_per_attn),
                self.n_groups,
            ),
            "shared": module.stack_defs(
                self._shared_block_defs(), cfg.n_shared_blocks
            ),
            # per-invocation down-projection 2D -> D (Zamba's per-use LoRA,
            # here a full projection)
            "proj": ParamDef(
                (self.n_groups, self.d_shared, cfg.d_model),
                cfg.dtype, ini.fan_in(), ("layers", "mlp", "embed"),
            ),
            "final_norm": self.final_norm.defs(),
        }
        if self.n_tail:
            d["mamba_tail"] = module.stack_defs(
                self._mamba_layer_defs(), self.n_tail
            )
        return d

    def cache_defs(self, batch: int, max_seq: int):
        d = {
            "mamba_groups": module.stack_defs(
                module.stack_defs(
                    self.mamba.cache_defs(batch), self.cfg.mamba_per_attn
                ),
                self.n_groups,
            ),
            "shared_kv": module.stack_defs(
                self.shared_attn.cache_defs(batch, max_seq), self.n_groups
            ),
        }
        if self.n_tail:
            d["mamba_tail"] = module.stack_defs(
                self.mamba.cache_defs(batch), self.n_tail
            )
        return d

    # --------------------------------------------------------------- forward
    def _select_shared(self, params, g_idx):
        """Alternating shared-block parameter set (A/B/... by group index)."""
        sel = g_idx % self.cfg.n_shared_blocks
        return jax.tree.map(lambda a: jnp.take(a, sel, axis=0), params["shared"])

    def _shared_block(self, sp, proj_g, h, emb0, positions):
        x = jnp.concatenate([h, emb0], axis=-1)            # (B,S,2D)
        x = x + self.shared_attn(
            sp["attn"], self.shared_norm(sp["norm1"], x), positions
        )
        x = x + self.shared_mlp(sp["mlp"], self.shared_norm(sp["norm2"], x))
        return constrain(
            h + jnp.einsum(
                "bse,ed->bsd", x, proj_g, preferred_element_type=jnp.float32
            ).astype(h.dtype),
            ("act_batch", "act_seq", "act_embed"),
        )

    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        emb0 = self.embed(params["embed"], tokens)
        h = emb0

        _ACT = ("act_batch", "act_seq", "act_embed")

        def mamba_layer(carry, p_l):
            h, _ = carry
            h = constrain(
                h + self.mamba(p_l["mamba"], self.mamba_norm(p_l["norm"], h)),
                _ACT,
            )
            return (h, None), None

        mfn = jax.checkpoint(mamba_layer) if self.cfg.remat else mamba_layer

        def group(carry, xs):
            h, _ = carry
            p_group, proj_g, g_idx = xs
            (h, _), _ = jax.lax.scan(mfn, (h, None), p_group)
            sp = self._select_shared(params, g_idx)
            h = self._shared_block(sp, proj_g, h, emb0, positions)
            return (h, None), None

        gfn = jax.checkpoint(group) if self.cfg.remat else group
        (h, _), _ = jax.lax.scan(
            gfn,
            (h, None),
            (params["mamba_groups"], params["proj"], jnp.arange(self.n_groups)),
        )
        if self.n_tail:
            (h, _), _ = jax.lax.scan(mfn, (h, None), params["mamba_tail"])
        h = self.final_norm(params["final_norm"], h)
        return self.embed.attend(params["embed"], h)

    def loss(self, params, batch):
        logits = self.forward(params, batch)
        return next_token_loss(logits, batch["tokens"])

    # ---------------------------------------------------------------- decode
    def serve_step(self, params, cache, batch, pos):
        h = self.embed(params["embed"], batch["tokens"])   # (B,1,D)
        emb0 = h

        def mamba_step(h, xs):
            p_l, c_l = xs
            y, c_new = self.mamba.decode_step(
                p_l["mamba"], self.mamba_norm(p_l["norm"], h), c_l
            )
            return h + y, c_new

        def group(h, xs):
            p_group, c_group, kv_c, proj_g, g_idx = xs
            h, c_new = jax.lax.scan(mamba_step, h, (p_group, c_group))
            sp = self._select_shared(params, g_idx)
            x = jnp.concatenate([h, emb0], axis=-1)
            a, kv_new = self.shared_attn.decode(
                sp["attn"], self.shared_norm(sp["norm1"], x), kv_c, pos
            )
            x = x + a
            x = x + self.shared_mlp(sp["mlp"], self.shared_norm(sp["norm2"], x))
            h = h + jnp.einsum(
                "bse,ed->bsd", x, proj_g, preferred_element_type=jnp.float32
            ).astype(h.dtype)
            return h, (c_new, kv_new)

        h, (mamba_new, kv_new) = jax.lax.scan(
            group,
            h,
            (
                params["mamba_groups"],
                cache["mamba_groups"],
                cache["shared_kv"],
                params["proj"],
                jnp.arange(self.n_groups),
            ),
        )
        new_cache = {"mamba_groups": mamba_new, "shared_kv": kv_new}
        if self.n_tail:
            h, tail_new = jax.lax.scan(
                mamba_step, h, (params["mamba_tail"], cache["mamba_tail"])
            )
            new_cache["mamba_tail"] = tail_new
        h = self.final_norm(params["final_norm"], h)
        return self.embed.attend(params["embed"], h), new_cache
