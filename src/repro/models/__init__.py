"""Model zoo: builds the right architecture class from an ArchConfig."""
from __future__ import annotations

from ..configs.base import ArchConfig
from .base import Model, next_token_loss  # noqa: F401
from .encdec import EncDecLM
from .transformer_lm import TransformerLM
from .xlstm_lm import XLSTMLM
from .zamba import ZambaLM


def build_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg)
    if cfg.family == "audio":
        return EncDecLM(cfg)
    if cfg.family == "hybrid":
        return ZambaLM(cfg)
    if cfg.family == "ssm":
        return XLSTMLM(cfg)
    raise ValueError(f"unknown family {cfg.family!r}")
