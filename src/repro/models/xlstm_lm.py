"""xLSTM LM: mLSTM blocks with a periodic sLSTM block (arXiv:2405.04517).

``slstm_every``-th position in the stack is an sLSTM block; the rest are
mLSTM.  With 48 layers and slstm_every=8 the stack is 6 homogeneous groups
of (7 mLSTM + 1 sLSTM), scanned as an outer scan over groups with an inner
scan over the mLSTM run — flat HLO in depth.

Both block types are pre-norm residual; neither carries an external FFN
(d_ff=0 in the assignment — mLSTM has its own up/down projections, sLSTM its
own small FF; see nn/xlstm.py).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCell
from ..distributed.sharding import constrain
from ..nn import module
from ..nn.layers import Embedding, RMSNorm
from ..nn.xlstm import MLSTM, SLSTM
from .base import Model, next_token_loss


class XLSTMLM(Model):
    def __init__(self, cfg: ArchConfig):
        super().__init__(cfg)
        rcfg = cfg.repair
        assert cfg.n_layers % cfg.slstm_every == 0, (
            "xLSTM stack must be whole groups", cfg.n_layers, cfg.slstm_every
        )
        self.mlstm = MLSTM(
            d_model=cfg.d_model,
            n_heads=cfg.n_heads,
            chunk=cfg.ssm_chunk,
            dtype=cfg.dtype,
            rcfg=rcfg,
        )
        self.slstm = SLSTM(
            d_model=cfg.d_model, n_heads=cfg.n_heads, dtype=cfg.dtype, rcfg=rcfg
        )
        self.norm = RMSNorm(cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)
        self.final_norm = RMSNorm(cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)
        self.embed = Embedding(cfg.vocab, cfg.d_model, dtype=cfg.dtype, rcfg=rcfg)

    @property
    def n_groups(self) -> int:
        return self.cfg.n_layers // self.cfg.slstm_every

    @property
    def m_per_group(self) -> int:
        return self.cfg.slstm_every - 1

    # ------------------------------------------------------------------ defs
    def defs(self):
        m_layer = {"norm": self.norm.defs(), "mlstm": self.mlstm.defs()}
        s_layer = {"norm": self.norm.defs(), "slstm": self.slstm.defs()}
        return {
            "embed": self.embed.defs(),
            "mlstm_groups": module.stack_defs(
                module.stack_defs(m_layer, self.m_per_group), self.n_groups
            ),
            "slstm_layers": module.stack_defs(s_layer, self.n_groups),
            "final_norm": self.final_norm.defs(),
        }

    def cache_defs(self, batch: int, max_seq: int):
        return {
            "mlstm_groups": module.stack_defs(
                module.stack_defs(self.mlstm.cache_defs(batch), self.m_per_group),
                self.n_groups,
            ),
            "slstm_layers": module.stack_defs(
                self.slstm.cache_defs(batch), self.n_groups
            ),
        }

    # --------------------------------------------------------------- forward
    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        tokens = batch["tokens"]
        h = self.embed(params["embed"], tokens)

        _ACT = ("act_batch", "act_seq", "act_embed")

        def m_layer(carry, p_l):
            h, _ = carry
            h = constrain(h + self.mlstm(p_l["mlstm"], self.norm(p_l["norm"], h)), _ACT)
            return (h, None), None

        mfn = jax.checkpoint(m_layer) if self.cfg.remat else m_layer

        def group(carry, xs):
            h, _ = carry
            p_group, p_s = xs
            (h, _), _ = jax.lax.scan(mfn, (h, None), p_group)
            h = constrain(h + self.slstm(p_s["slstm"], self.norm(p_s["norm"], h)), _ACT)
            return (h, None), None

        gfn = jax.checkpoint(group) if self.cfg.remat else group
        (h, _), _ = jax.lax.scan(
            gfn, (h, None), (params["mlstm_groups"], params["slstm_layers"])
        )
        h = self.final_norm(params["final_norm"], h)
        return self.embed.attend(params["embed"], h)

    def loss(self, params, batch):
        logits = self.forward(params, batch)
        return next_token_loss(logits, batch["tokens"])

    # ---------------------------------------------------------------- decode
    def serve_step(self, params, cache, batch, pos):
        h = self.embed(params["embed"], batch["tokens"])

        def m_step(h, xs):
            p_l, c_l = xs
            y, c_new = self.mlstm.decode_step(
                p_l["mlstm"], self.norm(p_l["norm"], h), c_l
            )
            return h + y, c_new

        def group(h, xs):
            p_group, c_group, p_s, c_s = xs
            h, c_new = jax.lax.scan(m_step, h, (p_group, c_group))
            y, s_new = self.slstm.decode_step(
                p_s["slstm"], self.norm(p_s["norm"], h), c_s
            )
            return h + y, (c_new, s_new)

        h, (m_new, s_new) = jax.lax.scan(
            group,
            h,
            (
                params["mlstm_groups"],
                cache["mlstm_groups"],
                params["slstm_layers"],
                cache["slstm_layers"],
            ),
        )
        h = self.final_norm(params["final_norm"], h)
        logits = self.embed.attend(params["embed"], h)
        return logits, {"mlstm_groups": m_new, "slstm_layers": s_new}
