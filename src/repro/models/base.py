"""Model protocol + shared pieces (loss, readout, scan/remat helpers).

Every architecture exposes the same surface so launch/, tests/ and
benchmarks/ are arch-agnostic:

  defs()                       param-def pytree (module.ParamDef leaves)
  init(key)                    real params
  abstract_params()            ShapeDtypeStruct tree (dry-run)
  logical_axes()               logical-axis tree (sharding rules input)
  loss(params, batch)          -> (scalar, metrics dict)      [train cells]
  forward(params, batch)       -> logits                      [prefill cells]
  cache_defs(batch, max_seq)   decode-state param-defs
  serve_step(params, cache, batch, pos) -> (logits, cache)    [decode cells]
  input_specs(cell)            ShapeDtypeStruct stand-ins for every input

``batch`` is a dict; LM cells use {"tokens": (B,S) i32}; VLM adds
{"patch_embeds": (B,P,D)}; audio enc-dec uses {"frames": (B,Se,D),
"tokens": (B,St)} — the modality frontends are stubs per the assignment
(input_specs provides precomputed frame/patch embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ArchConfig, ShapeCell
from ..nn import module


class Model:
    """Base: wires the def-driven machinery; subclasses fill the math."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---- def-driven machinery (uniform across archs) ----
    def defs(self):
        raise NotImplementedError

    def init(self, key: jax.Array):
        return module.init_params(self.defs(), key)

    def abstract_params(self):
        return module.abstract_params(self.defs())

    def logical_axes(self):
        return module.logical_axes(self.defs())

    def param_count(self) -> int:
        return module.param_count(self.defs())

    def cache_defs(self, batch: int, max_seq: int):
        raise NotImplementedError

    def paged_cache_defs(self, n_pages: int, page_size: int):
        """Paged decode-state defs: same treedef as ``cache_defs`` but every
        leaf has a LEADING page axis — ``(n_pages, ..., page_size, ...)``
        physical pages indexed by a block table (README §Serving engine).
        Architectures with constant-size recurrent state (SSM/xLSTM) have no
        meaningful paging unit and leave this unimplemented."""
        raise NotImplementedError(
            f"{type(self).__name__} has no paged KV layout"
        )

    @property
    def supports_paged_kv(self) -> bool:
        """Whether this architecture can serve from a paged KV pool."""
        try:
            self.paged_cache_defs(1, 1)
            return True
        except NotImplementedError:
            return False

    # Whether serve_step accepts multi-token inputs (B, S>1) — the batched
    # prefill path.  Recurrent decode cells consume strictly one token.
    supports_batched_prefill: bool = False

    # Whether serve_step_paged exists: decode straight off the paged pool
    # (block tables + fused on-read repair, README §Serving engine).
    supports_paged_decode: bool = False

    def serve_step_paged(
        self, params, pool, batch, block_tables, positions, **repair_kw
    ):
        """One decode step over the page-major pool tree directly — no
        gathered view.  ``pool`` has the ``paged_cache_defs`` treedef;
        returns ``(logits, pool', slot_counts (B, M), counts int32[8])``
        where ``slot_counts`` are the fused kernel's per-block-slot fatal
        detections summed over layers (the reactive detector's input)."""
        raise NotImplementedError(
            f"{type(self).__name__} has no paged decode path"
        )

    # Whether prefill_paged exists: chunked prefill straight off the paged
    # pool (the admission-side twin of serve_step_paged).
    supports_paged_prefill: bool = False

    def prefill_paged(
        self, params, pool, batch, block_tables, q_start, q_len, **repair_kw
    ):
        """One causal prompt chunk over the page-major pool tree directly:
        writes the chunk's K/V into the requests' pages and attends via the
        chunked-q paged kernel.  ``batch["tokens"]``: (B, C); ``q_start`` /
        ``q_len``: (B,) int32 chunk placement (rows past ``q_len`` are
        padding — written as a harmless duplicate of the last valid row,
        their logits garbage).  Returns ``(logits (B, C, V), pool',
        slot_counts (B, M), counts int32[8])``."""
        raise NotImplementedError(
            f"{type(self).__name__} has no paged prefill path"
        )

    def prefill(self, params, cache, batch, pos):
        """Single batched prefill: consume all S prompt tokens in one call,
        populating cache positions ``pos .. pos+S-1`` and returning the
        full-sequence logits (one forward pass through the decode path —
        the production prefill, replacing token-by-token cache warmup)."""
        if not self.supports_batched_prefill:
            raise NotImplementedError(
                f"{type(self).__name__} decodes strictly token-by-token"
            )
        return self.serve_step(params, cache, batch, pos)

    def init_cache(self, batch: int, max_seq: int, key=None):
        key = key if key is not None else jax.random.PRNGKey(0)
        return module.init_params(self.cache_defs(batch, max_seq), key)

    def abstract_cache(self, batch: int, max_seq: int):
        return module.abstract_params(self.cache_defs(batch, max_seq))

    def cache_logical_axes(self, batch: int, max_seq: int):
        return module.logical_axes(self.cache_defs(batch, max_seq))

    # ---- arch math (subclass responsibility) ----
    def forward(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        raise NotImplementedError

    def loss(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        raise NotImplementedError

    def serve_step(self, params, cache, batch, pos):
        raise NotImplementedError

    # ---- input stand-ins per shape cell ----
    def input_specs(self, cell: ShapeCell) -> Dict[str, Any]:
        """ShapeDtypeStruct dict for the cell's entry point.

        train/prefill: the full-sequence batch.  decode: the one-token batch
        (the KV cache spec comes from abstract_cache, passed separately)."""
        B, S = cell.global_batch, cell.seq_len
        if cell.kind in ("train", "prefill"):
            return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


# ---------------------------------------------------------------------------
# Shared loss.
# ---------------------------------------------------------------------------


def next_token_loss(
    logits: jax.Array,        # (B, S, V) f32
    tokens: jax.Array,        # (B, S) i32
    mask: Optional[jax.Array] = None,   # (B, S) — which *targets* count
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Causal LM loss: predict tokens[:, t+1] from logits[:, t]."""
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    if mask is None:
        m = jnp.ones(targets.shape, jnp.float32)
    else:
        m = mask[:, 1:].astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * m
    denom = jnp.maximum(m.sum(), 1.0)
    loss = nll.sum() / denom
    acc = (jnp.argmax(logits, -1) == targets).astype(jnp.float32)
    return loss, {
        "loss": loss,
        "accuracy": (acc * m).sum() / denom,
        "tokens": m.sum(),
    }


def scan_blocks(block_fn, h, stacked_params, *, remat: bool = True,
                carry_extra=None):
    """Scan ``block_fn`` over a stacked-parameter pytree.

    block_fn((h, extra), layer_params) -> ((h, extra), y).  ``extra`` carries
    e.g. the MoE aux-loss accumulator.  remat wraps the body so backward
    recomputes activations (memory-term lever, §Perf)."""
    fn = jax.checkpoint(block_fn) if remat else block_fn
    carry = (h, carry_extra)
    (h, extra), ys = jax.lax.scan(fn, carry, stacked_params)
    return h, extra, ys
