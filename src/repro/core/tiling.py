"""One definition of the VMEM-friendly tile fit shared by the Pallas
kernels and the jnp policy layer.

The scrub kernel, the fused matmul/attention kernels, and the tile-local
``neighbor_mean`` policy all need the same answer to "the largest divisor
of this dimension that fits the VPU-friendly cap" — lane dim a multiple of
128 up to ``TILE_COLS``, sublane dim a multiple of 8 up to ``TILE_ROWS``,
degrading by halving for awkward shapes.  Keeping the fit here (rather
than one hand-copy per call site) makes the documented policy/kernel
tile agreement structural: change the caps or the rounding once, every
consumer follows.
"""
from __future__ import annotations

from typing import Tuple

# Default caps: sublane (row) dim ≤ 256, lane (col) dim ≤ 512.
TILE_ROWS, TILE_COLS = 256, 512


def fit(dim: int, cap: int) -> int:
    """Largest divisor of ``dim`` that is ≤ ``cap``, found by halving from
    ``min(dim, cap)``; never below 1 (zero-size dims fit the unit tile)."""
    if dim <= 0:
        return 1
    b = min(dim, cap)
    while dim % b:
        b //= 2
    return max(b, 1)


def fit_blocks(rows: int, cols: int) -> Tuple[int, int]:
    """(block_rows, block_cols) for a 2D view under the default caps."""
    return fit(rows, TILE_ROWS), fit(cols, TILE_COLS)
