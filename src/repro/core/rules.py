"""`RepairRule` — the repair surface as per-region Detector × Fill × Trigger
rules bound by path patterns.

The paper leaves two choices open: *which* stored patterns count as fatal
(§2.2 defines the NaN pattern; §5.2 notes ±Inf and huge-exponent flips are
one mantissa bit away) and *what value* a fatal lane is fixed to (§5.2's
"the value to which a NaN is fixed").  EDEN (PAPERS.md) adds the systems
lesson: approximate-DRAM deployments only work when error tolerance is tuned
*per data structure*.  One global knob cannot express "fp32 optimizer state
is range-guarded and conservatively filled, bf16 KV pages are NaN-only and
zero-filled, embedding tables sit in an ECC-protected exact island".

A rule is the triple the design space factors into:

  Detector   which stored bit patterns are fatal — NaN, ±Inf, exponent-range
             (the beyond-paper ``max_magnitude`` clamp), or a custom
             per-dtype bit pattern ((bits & mask) == value, the
             integrated-ECC analogue for formats the defaults do not cover)
  Fill       the repair-value policy (``core.policies``: zero, constant,
             neighbor_mean, clamp_finite_max, ...)
  Trigger    which scheduled passes repair the leaf —
               boundary   every memory-mode pass (step boundary, periodic,
                          reactive; the legacy default)
               interval   periodic + reactive passes only (skip the
                          per-step boundary scrub)
               reactive   reactive passes only (serving page repair /
                          kernel-event routing)
               on-read    use()-site repair only (register semantics per
                          leaf; scheduled scrubs skip it)
             Forced passes (checkpoint save, reference repair) repair every
             non-exact leaf regardless of trigger: a checkpoint must never
             persist a fatal lane.

``RepairRule.exact_rule()`` expresses "exact via stronger correction" as
just another rule: the matched leaves are pinned to the exact region (never
injected, never repaired — they are error-free by construction), instead of
hard-coding the split in the region rules.

A ``RuleSet`` binds rules to state-tree paths with ordered regex patterns
(first match wins, same matching as ``core.regions``), and is the single
definition train scrub, serving page repair, and checkpoint-restore repair
all resolve their behavior from (README §RepairRule).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import detect, policies, regions as regions_lib

__all__ = [
    "Detector", "RepairRule", "RuleSet", "TRIGGERS", "PASSES", "ruleset_of",
]

TRIGGERS = ("boundary", "interval", "reactive", "on-read")

# Scheduled-pass tags and which triggers fire on them.  "forced" is the
# explicit-request tag (checkpoint save scrub, reference repair, direct
# ``space.scrub`` calls): every trigger fires there.
PASSES = ("boundary", "interval", "reactive", "forced")

_FIRES = {
    "boundary": frozenset(("boundary", "interval", "reactive", "forced")),
    "interval": frozenset(("interval", "reactive", "forced")),
    "reactive": frozenset(("reactive", "forced")),
    "on-read": frozenset(("forced",)),
}


# ---------------------------------------------------------------------------
# Detector.
# ---------------------------------------------------------------------------

# Detector-constants layout for the Pallas kernels (int32[8], passed as a
# scalar-prefetch operand — see kernels/common.py):
#   0 exp_mask   1 man_mask   2 flags   3 range exp-field threshold (shifted)
#   4 bitpattern mask   5 bitpattern value   6-7 pad
FLAG_NAN, FLAG_INF, FLAG_RANGE, FLAG_BITPATTERN = 1, 2, 4, 8


@dataclasses.dataclass(frozen=True)
class Detector:
    """Which stored bit patterns are fatal (per-dtype, via ``core.detect``
    layout constants).

    nan             the paper's pattern: exp all-ones, mantissa != 0
    inf             ±Inf (exp all-ones, mantissa == 0) — ignored when
                    ``max_magnitude`` is set (the range guard subsumes it:
                    Inf's exponent field is maximal)
    max_magnitude   beyond-paper range guard: lanes with exponent field ≥
                    that of the threshold are fatal (README §Config)
    bitpatterns     custom per-dtype patterns: (dtype_name | None, mask,
                    value) entries — a lane is fatal when
                    ``(bits & mask) == value`` and the entry's dtype matches
                    (None matches any dtype).  Counted in the NaN bucket.
    """

    nan: bool = True
    inf: bool = True
    max_magnitude: Optional[float] = None
    bitpatterns: Tuple[Tuple[Optional[str], int, int], ...] = ()

    def masks(self, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """(nan_mask, inf_mask) of the fatal lanes of ``x``.

        Branch structure mirrors the legacy ``fatal_masks`` exactly so a
        one-rule legacy lift is bit-for-bit identical: with
        ``max_magnitude`` set, the range guard owns the non-NaN bucket
        (it includes ±Inf by construction); otherwise ``inf`` gates the
        ±Inf pattern.
        """
        bits = detect.bits_of(x)
        if self.nan:
            nan_m = detect.is_nan_bits(bits, x.dtype)
        else:
            nan_m = jnp.zeros(x.shape, jnp.bool_)
        for dt, mask, value in self.bitpatterns:
            if dt is not None and jnp.dtype(dt) != jnp.dtype(x.dtype):
                continue
            lay = detect.layout_of(x.dtype)
            m = jnp.asarray(mask, lay.int_dtype)
            v = jnp.asarray(value, lay.int_dtype)
            nan_m = nan_m | ((bits & m) == v)
        if self.max_magnitude is not None:
            ext = detect.is_extreme_bits(bits, x.dtype, self.max_magnitude)
            inf_m = ext & ~nan_m
        elif self.inf:
            inf_m = detect.is_inf_bits(bits, x.dtype)
        else:
            inf_m = jnp.zeros_like(nan_m)
        return nan_m, inf_m

    def constants(self, dtype) -> Tuple[int, ...]:
        """The int32[8] scalar-operand encoding of this detector for
        ``dtype`` (kernels read it from SMEM instead of baking the NaN
        pattern in — see kernels/common.py)."""
        lay = detect.layout_of(dtype)
        if lay.width > 32:
            raise TypeError(
                f"kernel detectors support dtypes up to 32 bits, got {dtype}"
            )
        flags = 0
        if self.nan:
            flags |= FLAG_NAN
        range_field = 0
        if self.max_magnitude is not None:
            flags |= FLAG_RANGE
            range_field = (
                detect.exp_field_of(self.max_magnitude, dtype) << lay.man_bits
            )
        elif self.inf:
            flags |= FLAG_INF
        bp_mask = bp_value = 0
        for dt, mask, value in self.bitpatterns:
            if dt is not None and jnp.dtype(dt) != jnp.dtype(dtype):
                continue
            if flags & FLAG_BITPATTERN:
                raise ValueError(
                    "kernels support at most one bitpattern per dtype"
                )
            flags |= FLAG_BITPATTERN
            bp_mask, bp_value = int(mask), int(value)
        return (
            lay.exp_mask, lay.man_mask, flags, range_field,
            bp_mask, bp_value, 0, 0,
        )

    def key(self) -> Tuple:
        """Hashable digest for plan-cache keys."""
        return ("det", self.nan, self.inf, self.max_magnitude, self.bitpatterns)


# ---------------------------------------------------------------------------
# RepairRule.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RepairRule:
    """Detector × Fill × Trigger for one protection class of leaves."""

    detect: Detector = Detector()
    fill: Any = "neighbor_mean"       # name | float | RepairPolicy
    trigger: str = "boundary"
    exact: bool = False               # ECC-like exact island: never repaired
    label: str = ""                   # stats key; defaults to the bound pattern

    def __post_init__(self):
        if self.trigger not in TRIGGERS:
            raise ValueError(
                f"bad trigger {self.trigger!r}; expected one of {TRIGGERS}"
            )

    @staticmethod
    def exact_rule(label: str = "exact") -> "RepairRule":
        """The matched leaves live in exact memory (nominal refresh /
        stronger correction): never injected, never repaired."""
        return RepairRule(exact=True, label=label)

    def resolved_fill(self) -> policies.RepairPolicy:
        return policies.get(self.fill)

    def fires(self, pass_tag: str) -> bool:
        """Does this rule repair on a scheduled pass tagged ``pass_tag``?"""
        if self.exact:
            return False
        return pass_tag in _FIRES[self.trigger]

    def apply(
        self, x: jax.Array
    ) -> Tuple[jax.Array, jax.Array, jax.Array]:
        """Repair fatal lanes of one tensor under this rule.  Returns
        (repaired, nan_count, inf_count) — same contract as the legacy
        ``repair_tensor``, with detection delegated to the rule's detector."""
        nan_m, inf_m = self.detect.masks(x)
        mask = nan_m | inf_m
        fixed = jnp.where(mask, self.resolved_fill()(x, mask), x)
        return (
            fixed,
            jnp.sum(nan_m.astype(jnp.int32)),
            jnp.sum(inf_m.astype(jnp.int32)),
        )

    def key(self) -> Tuple:
        fill = self.fill
        if isinstance(fill, policies.RepairPolicy):
            fill = fill.name
        return (self.detect.key(), fill, self.trigger, self.exact)


# ---------------------------------------------------------------------------
# RuleSet.
# ---------------------------------------------------------------------------

# the trailing catch-all applied when no pattern matches (legacy defaults)
DEFAULT_RULE = RepairRule(label="default")


@dataclasses.dataclass(frozen=True)
class RuleSet:
    """Ordered (pattern, RepairRule) bindings over state-tree paths.

    Patterns are regexes searched against the ``a/b/c`` path rendering
    (``core.regions.path_str``), first match wins — identical matching to
    the region rules.  Unmatched leaves fall back to ``DEFAULT_RULE``
    (the legacy single-knob defaults) unless the set ends with its own
    catch-all.
    """

    entries: Tuple[Tuple[str, RepairRule], ...]

    def __post_init__(self):
        # normalize lists and auto-label rules with their binding pattern
        entries = []
        for pattern, rule in tuple(self.entries):
            if not rule.label:
                rule = dataclasses.replace(rule, label=pattern)
            entries.append((pattern, rule))
        object.__setattr__(self, "entries", tuple(entries))

    # ---------------------------------------------------------- constructors
    @staticmethod
    def single(rule: RepairRule) -> "RuleSet":
        """The one-rule compatibility set (legacy scalar-knob lift)."""
        if not rule.label:
            rule = dataclasses.replace(rule, label="default")
        return RuleSet(entries=((r".*", rule),))

    @staticmethod
    def from_legacy(cfg: Any) -> "RuleSet":
        """Lift legacy scalar repair fields (``RepairConfig`` /
        ``ApproxConfig`` without explicit rules) into a one-rule set."""
        return RuleSet.single(
            RepairRule(
                detect=Detector(
                    nan=True,
                    inf=cfg.include_inf,
                    max_magnitude=getattr(cfg, "max_magnitude", None),
                ),
                fill=cfg.policy,
                trigger="boundary",
                label="default",
            )
        )

    # --------------------------------------------------------------- lookup
    @property
    def table(self) -> Tuple[RepairRule, ...]:
        """Rules by index: one per entry, plus the fallback at the end."""
        return tuple(r for _, r in self.entries) + (DEFAULT_RULE,)

    def labels(self) -> Tuple[str, ...]:
        """Stats keys, one per rule index.  Duplicate labels (two rules
        sharing a user label, or a user "default" colliding with the
        fallback) are suffixed ``#n`` so no rule's counters can shadow
        another's in the per-rule ledger."""
        out, seen = [], {}
        for rule in self.table:
            n = seen.get(rule.label, 0)
            seen[rule.label] = n + 1
            out.append(rule.label if n == 0 else f"{rule.label}#{n}")
        return tuple(out)

    def rule_for(self, path: str) -> Tuple[int, RepairRule]:
        """(index, rule) for one rendered tree path (first match wins)."""
        for i, (pattern, rule) in enumerate(self.entries):
            if re.search(pattern, path):
                return i, rule
        return len(self.entries), DEFAULT_RULE

    def read_rule(self) -> RepairRule:
        """The rule ``use()`` (register-mode / on-read repair) applies: the
        first on-read rule if any, else the first non-exact rule, else the
        fallback — use() sites see single tensors with no tree path."""
        for _, rule in self.entries:
            if rule.trigger == "on-read" and not rule.exact:
                return rule
        for _, rule in self.entries:
            if not rule.exact:
                return rule
        return DEFAULT_RULE

    def assign(self, tree: Any) -> Tuple[Any, Any]:
        """(rule_tree, index_tree) matching ``tree``'s structure — the
        per-leaf rule assignment the planner compiles against."""
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        indices, rules = [], []
        for path, _ in flat:
            i, r = self.rule_for(regions_lib.path_str(path))
            indices.append(i)
            rules.append(r)
        return (
            jax.tree_util.tree_unflatten(treedef, rules),
            jax.tree_util.tree_unflatten(treedef, indices),
        )

    def with_rule(self, label: str, rule: RepairRule) -> "RuleSet":
        """A copy with the entry labeled ``label`` replaced by ``rule`` —
        same pattern, same position, same label (the replacement is
        relabeled to match, so the per-rule counter ledger and the
        autopilot guard's expectations stay keyed identically across a
        tighten).  Raises ``KeyError`` when no entry carries the label."""
        entries = []
        found = False
        for pattern, existing in self.entries:
            if not found and existing.label == label:
                entries.append(
                    (pattern, dataclasses.replace(rule, label=label))
                )
                found = True
            else:
                entries.append((pattern, existing))
        if not found:
            raise KeyError(f"no rule labeled {label!r} in this RuleSet")
        return RuleSet(entries=tuple(entries))

    @property
    def n_rules(self) -> int:
        return len(self.entries) + 1

    def digest(self) -> Tuple:
        """Stable hashable token for the plan-cache key: two value-equal
        rule sets share compiled executables."""
        return tuple((p, r.key()) for p, r in self.entries)


def ruleset_of(cfg: Any) -> RuleSet:
    """The effective ``RuleSet`` of any repair config: an ``ApproxConfig``
    exposes ``ruleset`` (explicit rules or the one-rule lift); a legacy
    ``RepairConfig`` lifts its scalar fields."""
    rs = getattr(cfg, "ruleset", None)
    if rs is not None:
        return rs
    return RuleSet.from_legacy(cfg)
