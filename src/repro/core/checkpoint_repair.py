"""``last_checkpoint`` repair policy — repair NaNs from checkpoint shards.

The strongest answer to the paper's open question (§5.2, "values to which
NaNs are fixed"): at framework scale we *have* a recent good value for every
protected buffer — the latest checkpoint.  Repairing a flipped weight from
its checkpointed value restores it exactly, up to one checkpoint interval of
optimizer drift; for inference (frozen weights) it is exact.

This is only available at pytree granularity (the reference must be resident
or fetchable); the in-kernel fused path uses the cheap statistical policies
and this pass covers anything they mis-estimate, at checkpoint-load and
periodic-scrub boundaries.

Runtime entry point: ``repro.runtime.ApproxSpace.scrub_with_reference``
(README §Policies) — it supplies the cached region tree and folds the event
counts into the unified stats stream; the function below is the underlying
implementation.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import detect, regions as regions_lib, stats as stats_lib


def scrub_with_reference(
    tree: Any,
    ref_tree: Any,
    stats: stats_lib.Stats,
    region_tree: Optional[Any] = None,
    *,
    include_inf: bool = True,
) -> Tuple[Any, stats_lib.Stats]:
    """Replace fatal lanes of approximate-region leaves with the values from
    ``ref_tree`` (same treedef, e.g. the last checkpoint)."""
    if region_tree is None:
        region_tree = regions_lib.annotate(tree)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    refs = jax.tree.leaves(ref_tree)
    regs = jax.tree.leaves(region_tree)
    assert len(leaves) == len(refs) == len(regs), "treedef mismatch"

    nan_tot = jnp.zeros((), jnp.int32)
    inf_tot = jnp.zeros((), jnp.int32)
    out = []
    for leaf, ref, region in zip(leaves, refs, regs):
        if (
            region is regions_lib.Region.APPROX
            and hasattr(leaf, "dtype")
            and jnp.issubdtype(leaf.dtype, jnp.floating)
        ):
            bits = detect.bits_of(leaf)
            nan_m = detect.is_nan_bits(bits, leaf.dtype)
            inf_m = (
                detect.is_inf_bits(bits, leaf.dtype)
                if include_inf
                else jnp.zeros_like(nan_m)
            )
            mask = nan_m | inf_m
            out.append(jnp.where(mask, ref.astype(leaf.dtype), leaf))
            nan_tot = nan_tot + jnp.sum(nan_m.astype(jnp.int32))
            inf_tot = inf_tot + jnp.sum(inf_m.astype(jnp.int32))
        else:
            out.append(leaf)
    return (
        jax.tree_util.tree_unflatten(treedef, out),
        stats_lib.record_repair(stats, nan_tot, inf_tot),
    )
