"""``last_checkpoint`` repair policy — repair NaNs from checkpoint shards.

The strongest answer to the paper's open question (§5.2, "values to which
NaNs are fixed"): at framework scale we *have* a recent good value for every
protected buffer — the latest checkpoint.  Repairing a flipped weight from
its checkpointed value restores it exactly, up to one checkpoint interval of
optimizer drift; for inference (frozen weights) it is exact.

.. deprecated::
    The implementation moved to ``repro.runtime`` (README §Migration): the
    reference scrub is one scope of ``runtime.plan.RepairPlan`` — the same
    planner that drives the train boundary scrub and the serving page scrub
    — and its mesh-aware compiled entry point is
    ``ApproxSpace.scrub_with_reference`` (repairs run shard-local on
    whatever mesh the restored job uses; ``CheckpointManager.restore`` /
    ``reference_repair`` call it after the elastic device_put).  This module
    is a thin shim kept for source compatibility and emits a
    ``DeprecationWarning`` on every call.
"""
from __future__ import annotations

import warnings
from typing import Any, Optional, Tuple

from . import regions as regions_lib, stats as stats_lib


def scrub_with_reference(
    tree: Any,
    ref_tree: Any,
    stats: stats_lib.Stats,
    region_tree: Optional[Any] = None,
    *,
    include_inf: bool = True,
) -> Tuple[Any, stats_lib.Stats]:
    """Replace fatal lanes of approximate-region leaves with the values from
    ``ref_tree`` (same treedef, e.g. the last checkpoint).

    Deprecated shim: delegates to ``runtime.reference_scrub_tree`` (the
    implementation behind ``ApproxSpace.scrub_with_reference``).
    """
    from ..runtime import space as runtime_space  # deferred: runtime builds on us

    warnings.warn(
        "core.checkpoint_repair.scrub_with_reference is a deprecated shim; "
        "use runtime.ApproxSpace.scrub_with_reference (README §Migration)",
        DeprecationWarning,
        stacklevel=2,
    )
    if region_tree is None:
        region_tree = regions_lib.annotate(tree)
    return runtime_space.reference_scrub_tree(
        tree, ref_tree, stats, region_tree, include_inf=include_inf
    )
