"""NaN/Inf detection via explicit bit patterns.

The paper defines a NaN structurally: *"Changing a floating-point number to a
NaN requires to flip all bits of the exponent part to 1"* (§2.2) — plus a
non-zero mantissa; all-ones exponent with zero mantissa is ±Inf.  We detect
at the bit level rather than with ``jnp.isnan`` for two reasons:

1. It is exactly what approximate-memory bit flips produce — we classify the
   *stored pattern*, which also lets us distinguish NaN from Inf and apply
   different policies to each (Inf can be a legitimate computed value; a
   *stored* Inf in a weight buffer is almost certainly a flip).
2. The same mask logic runs inside Pallas kernels on integer views of the
   loaded tile, where it compiles to cheap VPU compare/ands; keeping one
   canonical implementation here makes kernel and reference agree bit-for-bit.

All functions are shape-polymorphic and jit-safe.

This module owns the per-dtype layout constants; *which* of these patterns
count as fatal for a given leaf is decided one level up by
``core.rules.Detector`` (README §RepairRule), which also encodes the masks
and enables into the int32[8] scalar-prefetch operand the Pallas kernels
consume (``kernels/common.py``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Per-dtype IEEE-754 layout constants.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FloatLayout:
    """Bit layout of an IEEE-754 binary float format."""

    width: int            # total bits
    exp_bits: int         # exponent field width
    man_bits: int         # mantissa (fraction) field width
    int_dtype: jnp.dtype  # same-width integer dtype for bitcasts

    @property
    def exp_mask(self) -> int:
        return ((1 << self.exp_bits) - 1) << self.man_bits

    @property
    def man_mask(self) -> int:
        return (1 << self.man_bits) - 1

    @property
    def sign_mask(self) -> int:
        return 1 << (self.width - 1)

    @property
    def abs_mask(self) -> int:
        return self.sign_mask - 1  # everything but the sign bit


_LAYOUTS = {
    jnp.dtype(jnp.float64): FloatLayout(64, 11, 52, jnp.dtype(jnp.uint64)),
    jnp.dtype(jnp.float32): FloatLayout(32, 8, 23, jnp.dtype(jnp.uint32)),
    jnp.dtype(jnp.bfloat16): FloatLayout(16, 8, 7, jnp.dtype(jnp.uint16)),
    jnp.dtype(jnp.float16): FloatLayout(16, 5, 10, jnp.dtype(jnp.uint16)),
}


def layout_of(dtype) -> FloatLayout:
    """Return the IEEE layout for a floating dtype (KeyError if unsupported)."""
    dt = jnp.dtype(dtype)
    if dt not in _LAYOUTS:
        raise TypeError(f"no IEEE layout registered for dtype {dt}")
    return _LAYOUTS[dt]


def supported_dtypes():
    return tuple(_LAYOUTS.keys())


# ---------------------------------------------------------------------------
# Detection (works on the float view; bit-level, no isnan).
# ---------------------------------------------------------------------------


def bits_of(x: jax.Array) -> jax.Array:
    """Bitcast a float array to its same-width unsigned-integer view."""
    return jax.lax.bitcast_convert_type(x, layout_of(x.dtype).int_dtype)


def from_bits(bits: jax.Array, dtype) -> jax.Array:
    """Inverse of :func:`bits_of`."""
    return jax.lax.bitcast_convert_type(bits, jnp.dtype(dtype))


def is_nan_bits(bits: jax.Array, dtype) -> jax.Array:
    """NaN mask from an integer bit view: exp all-ones AND mantissa != 0."""
    lay = layout_of(dtype)
    exp_all_ones = (bits & lay.exp_mask) == lay.exp_mask
    man_nonzero = (bits & lay.man_mask) != 0
    return exp_all_ones & man_nonzero


def is_inf_bits(bits: jax.Array, dtype) -> jax.Array:
    """±Inf mask from an integer bit view: exp all-ones AND mantissa == 0."""
    lay = layout_of(dtype)
    exp_all_ones = (bits & lay.exp_mask) == lay.exp_mask
    man_zero = (bits & lay.man_mask) == 0
    return exp_all_ones & man_zero


def nan_mask(x: jax.Array) -> jax.Array:
    """Boolean mask of NaN lanes, computed from the bit pattern."""
    return is_nan_bits(bits_of(x), x.dtype)


def inf_mask(x: jax.Array) -> jax.Array:
    """Boolean mask of ±Inf lanes, computed from the bit pattern."""
    return is_inf_bits(bits_of(x), x.dtype)


def exp_field_of(value: float, dtype) -> int:
    """Exponent-field value of |value| in the given dtype's layout."""
    import numpy as np

    lay = layout_of(dtype)
    np_dt = {16: np.uint16, 32: np.uint32, 64: np.uint64}[lay.width]
    if jnp.dtype(dtype) == jnp.bfloat16:
        bits = np.float32(abs(value)).view(np.uint32) >> 16
    else:
        bits = np.abs(np.array(value, jnp.dtype(dtype))).view(np_dt)
    return int((int(bits) & lay.exp_mask) >> lay.man_bits)


def is_extreme_bits(bits: jax.Array, dtype, threshold: float) -> jax.Array:
    """Lanes with |x| ≥ threshold — including ±Inf and NaN — via a single
    integer compare on the exponent field.

    Beyond-paper extension (README §Config): a bit flip on a high
    exponent bit produces ~1e38, which is NOT a NaN but destroys a training
    run within one step (measured in tests/test_e2e_training.py).  The
    repair machinery therefore optionally treats 'exponent field ≥ that of
    the threshold' as fatal; on the VPU this is the same compare/and cost as
    the NaN pattern itself.
    """
    lay = layout_of(dtype)
    field = exp_field_of(threshold, dtype)
    return (bits & lay.exp_mask) >= (field << lay.man_bits)


def extreme_mask(x: jax.Array, threshold: float) -> jax.Array:
    return is_extreme_bits(bits_of(x), x.dtype, threshold)


def nonfinite_mask(x: jax.Array, *, include_inf: bool = True) -> jax.Array:
    """Mask of lanes the repair machinery considers *fatal*.

    The paper repairs NaNs only; stored ±Inf is optionally included because in
    an approximate-memory setting an all-ones exponent with a zero mantissa is
    the same flip event one mantissa-bit away (and Inf·0 = NaN one op later).
    """
    bits = bits_of(x)
    m = is_nan_bits(bits, x.dtype)
    if include_inf:
        m = m | is_inf_bits(bits, x.dtype)
    return m


@partial(jax.jit, static_argnames=("include_inf",))
def count_nonfinite(x: jax.Array, *, include_inf: bool = True) -> jax.Array:
    """Total number of fatal lanes (int32 scalar) — feeds core.stats."""
    return jnp.sum(nonfinite_mask(x, include_inf=include_inf).astype(jnp.int32))
