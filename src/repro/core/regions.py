"""Approximate-memory region annotation for pytrees.

Deployment model (paper §2 + Flikker [14]): memory is partitioned into an
*exact* region (nominal refresh, error-free) and an *approximate* region
(relaxed refresh, elevated BER, cheaper).  The framework decides which state
lives where.  Defaults (overridable per config):

  approximate: model weights, KV caches, optimizer moments   (large, drift-
               tolerant once NaN repair is in place — this is where the
               energy lives)
  exact:       step counters, PRNG keys, router/gating tables, loss scalars,
               LR schedules, shapes/metadata                  (small, fatal
               if corrupted in ways repair cannot express)

A region spec is a pytree of ``Region`` values with the same treedef as the
state it annotates, built from ordered path-pattern rules.

These rules carry the *default* partition (control-plane scalars pinned
exact).  ``RepairRule.exact_rule()`` bindings in a config's ``RuleSet``
(README §RepairRule) add exact islands on top: ``ApproxSpace.regions_for``
overrides a leaf to EXACT when its repair rule is exact, so "exact via
stronger correction" is expressed per path pattern, not by editing this
table.
"""
from __future__ import annotations

import enum
import re
from typing import Any, Sequence, Tuple

import jax


class Region(enum.Enum):
    EXACT = "exact"
    APPROX = "approx"


def path_str(path) -> str:
    """Render a jax tree path as 'a/b/0/c' for pattern matching."""
    parts = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            parts.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            parts.append(str(p.idx))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            parts.append(str(p.name))
        else:  # FlattenedIndexKey and friends
            parts.append(str(getattr(p, "key", p)))
    return "/".join(parts)


# Ordered (pattern, region) rules; first match wins.  Patterns are regexes
# searched against the full 'a/b/c' path.  NB: plain "scale" is NOT exact —
# norm_scale weight vectors belong in approximate memory; only control-plane
# scalars (step/schedule/rng/keys/counters) are pinned exact.
DEFAULT_RULES: Tuple[Tuple[str, Region], ...] = (
    (r"(^|/)(step|count|counter|schedule|loss_scale)($|/)", Region.EXACT),
    (r"(^|/)[^/]*(rng|key)[^/]*($|/)", Region.EXACT),
    (r"(^|/)router($|/)|gate_table", Region.EXACT),
    (r".*", Region.APPROX),
)


def annotate(tree: Any, rules: Sequence[Tuple[str, Region]] = DEFAULT_RULES):
    """Return a pytree of Region matching ``tree``'s structure."""
    compiled = [(re.compile(p), r) for p, r in rules]

    def classify(path, leaf):
        s = path_str(path)
        for pat, region in compiled:
            if pat.search(s):
                return region
        return Region.APPROX

    return jax.tree_util.tree_map_with_path(classify, tree)


def approx_mask(tree: Any, regions: Any):
    """Pytree of bools: True where the leaf is in approximate memory."""
    return jax.tree.map(lambda r: r is Region.APPROX, regions)


def count_bytes(tree: Any, regions: Any) -> Tuple[int, int]:
    """(approx_bytes, exact_bytes) over the annotated tree — feeds the
    energy model (savings apply only to the approximate fraction)."""
    approx = exact = 0
    for leaf, region in zip(jax.tree.leaves(tree), jax.tree.leaves(regions)):
        nbytes = leaf.size * leaf.dtype.itemsize if hasattr(leaf, "size") else 0
        if region is Region.APPROX:
            approx += nbytes
        else:
            exact += nbytes
    return approx, exact
