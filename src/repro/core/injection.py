"""Approximate-memory simulation: bit-flip injection with a refresh→BER model.

Production approximate DRAM/HBM does not exist in this container (or in any
shipping TPU), so the error process is *simulated*: a PRNG-driven pass that
flips bits in designated buffers at a configurable bit-error rate (BER).
This file is the only place where errors are *created*; everything else in
``core/`` is the production repair path.

Refresh→BER→energy model (anchor points from the literature the paper builds
on; linear-log interpolation between anchors):

  refresh interval   BER (per bit per refresh window)   memory-energy saving
  64 ms (nominal)    ~1e-17  (JEDEC-compliant)           0 %
  256 ms             ~1e-9                               ~16 %   (RAIDR [13])
  1 s                ~1e-6                               ~20-25 % (Flikker [14])
  4 s                ~1e-4                               ~30 %   (extrapolated)

The paper's premise is the 1e-9…1e-4 regime: dense enough that NaNs appear
with "non-negligible probability" (§2.2) yet sparse enough that drift errors
are amortized.  For a 1.5 B-parameter bf16 model resident for one window at
BER 1e-6, E[flips] ≈ 24 000, of which ≈ 8/256 hit the exponent's all-ones
distance... empirically ~0.4 % of flips on bf16 weights produce NaN/Inf
patterns (measured in tests/test_injection.py).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import detect

# ---------------------------------------------------------------------------
# Refresh-interval → (BER, energy saving) model.
# ---------------------------------------------------------------------------

# (refresh_interval_seconds, log10_ber, memory_energy_saving_fraction)
_ANCHORS = (
    (0.064, -17.0, 0.00),
    (0.256, -9.0, 0.161),   # RAIDR
    (1.0, -6.0, 0.225),     # Flikker (midpoint of 20-25 %)
    (4.0, -4.0, 0.30),
)


@dataclasses.dataclass(frozen=True)
class ApproxMemoryModel:
    """A point in the refresh/BER/energy trade-off space."""

    refresh_interval_s: float
    ber: float
    energy_saving: float

    @staticmethod
    def from_refresh(refresh_interval_s: float) -> "ApproxMemoryModel":
        t = float(refresh_interval_s)
        xs = [a[0] for a in _ANCHORS]
        if t <= xs[0]:
            _, lb, es = _ANCHORS[0]
            return ApproxMemoryModel(t, 10.0 ** lb, es)
        if t >= xs[-1]:
            _, lb, es = _ANCHORS[-1]
            return ApproxMemoryModel(t, 10.0 ** lb, es)
        for (t0, lb0, e0), (t1, lb1, e1) in zip(_ANCHORS, _ANCHORS[1:]):
            if t0 <= t <= t1:
                w = (math.log(t) - math.log(t0)) / (math.log(t1) - math.log(t0))
                return ApproxMemoryModel(
                    t, 10.0 ** (lb0 + w * (lb1 - lb0)), e0 + w * (e1 - e0)
                )
        raise AssertionError("unreachable")


# ---------------------------------------------------------------------------
# Bit-flip injection.
# ---------------------------------------------------------------------------


def _flip_budget(numel: int, width: int, ber: float) -> int:
    """Static cap on flips-per-call: λ + 6σ, so the truncation probability is
    negligible while keeping shapes static for jit."""
    lam = numel * width * ber
    return max(8, int(math.ceil(lam + 6.0 * math.sqrt(lam) + 1)))


@partial(jax.jit, static_argnames=("ber",))
def flip_bits(key: jax.Array, x: jax.Array, ber: float) -> jax.Array:
    """Flip each bit of ``x`` independently with probability ``ber``.

    Sparse implementation: draw k ~ Binomial(n_bits, ber) (normal approx via
    Poisson for the tiny-rate regime), place k uniform flips.  Collisions
    (two flips on the same bit) are allowed — XOR of two flips restores the
    bit, exactly as two physical flips would.
    """
    if not jnp.issubdtype(x.dtype, jnp.floating):
        raise TypeError("flip_bits expects a floating-point array")
    lay = detect.layout_of(x.dtype)
    flat = x.reshape(-1)
    numel = flat.shape[0]
    n_bits = numel * lay.width
    budget = _flip_budget(numel, lay.width, ber)

    k_key, pos_key, bit_key = jax.random.split(key, 3)
    lam = jnp.asarray(n_bits * ber, jnp.float32)
    # Poisson sample of the flip count (valid for ber*width << 1, our regime).
    k = jnp.minimum(jax.random.poisson(k_key, lam), budget)

    positions = jax.random.randint(pos_key, (budget,), 0, numel)
    bit_idx = jax.random.randint(bit_key, (budget,), 0, lay.width)
    live = jnp.arange(budget) < k

    bits = detect.bits_of(flat)
    one = jnp.asarray(1, lay.int_dtype)
    masks = jnp.where(live, one << bit_idx.astype(lay.int_dtype),
                      jnp.zeros((), lay.int_dtype))
    # Scatter-XOR the flip masks into the bit view (duplicate positions fold
    # by XOR, matching two physical flips restoring the bit).
    bits = _scatter_xor(bits, positions, masks)
    return detect.from_bits(bits, x.dtype).reshape(x.shape)


def _scatter_xor(bits: jax.Array, positions: jax.Array, masks: jax.Array):
    """XOR ``masks`` into ``bits`` at ``positions`` (duplicates fold by XOR).

    Implemented as a short fori_loop over the static flip budget — budget is
    tiny (≈λ+6σ), so this is negligible next to the O(numel) bitcasts.
    """
    def body(i, b):
        return b.at[positions[i]].set(b[positions[i]] ^ masks[i])
    return jax.lax.fori_loop(0, positions.shape[0], body, bits)


@partial(jax.jit, static_argnames=("n",))
def inject_nan(key: jax.Array, x: jax.Array, n: int = 1) -> jax.Array:
    """Force exactly ``n`` distinct-position NaNs into ``x`` (paper §4 setup:
    "A NaN is injected into one of the two matrices after their
    initialization to mimic an occurrence of a NaN by bit-flips").

    The injected pattern mirrors the paper's observed 0x7ff0_4645_4443_4241:
    exponent all-ones + non-zero mantissa (we use a fixed mantissa tag so
    injected NaNs are recognizable in dumps).
    """
    lay = detect.layout_of(x.dtype)
    flat = detect.bits_of(x.reshape(-1))
    positions = jax.random.choice(key, flat.shape[0], (n,), replace=False)
    tag = jnp.asarray(lay.exp_mask | (lay.man_mask & 0x4241424142414241),
                      lay.int_dtype)
    flat = flat.at[positions].set(tag)
    return detect.from_bits(flat, x.dtype).reshape(x.shape)


def expected_nan_fraction(dtype, ber: float) -> float:
    """Analytic P[a value becomes NaN/Inf after one window] ≈ P[its exponent
    reaches all-ones].  For a random trained-weight exponent, the dominant
    path is flipping the few zero bits of an already-high exponent; we use the
    conservative bound: P ≈ ber (single flip completes the pattern) ×
    fraction-of-values-one-flip-away.  Exposed for test assertions only."""
    lay = detect.layout_of(dtype)
    # one-flip-away fraction for typical N(0, small) weights: exponent fields
    # cluster around the bias; measured offline ≈ 2^-(exp_bits-1) scale.
    return ber * lay.exp_bits * (2.0 ** -(lay.exp_bits - 1))
