"""Repair-value policies.

Paper §5.2 leaves "the value to which a NaN is fixed" as future work and
sketches the design space: 0 is the LetGo choice but breaks divisions; deep
nets tolerate sign flips because values are symmetric around 0; the right
value is workload-dependent.  We make the policy a first-class, composable
object so each protected region can choose independently.

Every policy is a pure function ``(x, mask) -> repaired_values`` where
``mask`` marks fatal lanes; the caller does the final ``where``.  Policies
must be jit-safe, shape-polymorphic, and must *not* read the masked lanes'
values in a way that propagates NaN (hence the masked-mean trick below).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from . import tiling


@dataclasses.dataclass(frozen=True)
class RepairPolicy:
    """A named repair-value policy."""

    name: str
    fn: Callable[[jax.Array, jax.Array], jax.Array]

    def __call__(self, x: jax.Array, mask: jax.Array) -> jax.Array:
        return self.fn(x, mask)


def _zero(x, mask):
    return jnp.zeros_like(x)


def _constant(c):
    def fn(x, mask):
        return jnp.full_like(x, c)
    return fn


def _clamp_finite_max(x, mask):
    """Largest finite magnitude of the dtype, sign-preserving where the sign
    bit survived (per Li et al. [12] the sign bit rarely matters, but keeping
    it is free)."""
    big = jnp.array(jnp.finfo(x.dtype).max, x.dtype)
    sign = jnp.where(jax.lax.sign(x) < 0, -1.0, 1.0).astype(x.dtype)
    # sign() of NaN is NaN -> force +1 on fatal lanes via where on the mask.
    sign = jnp.where(mask & ~(sign == sign), jnp.ones_like(sign), sign)
    return sign * big


def _pairwise_sum(v: jax.Array) -> jax.Array:
    """Order-fixed pairwise (halving) sum along the last axis.

    A plain ``jnp.sum`` lets XLA pick the reduction order, which differs
    between shardings (a cross-shard sum reassociates) — the one thing that
    kept sharded neighbor_mean scrubs off bit parity with single-device
    (README §Distributed repair).  The halving fold is a fixed association
    tree built from elementwise adds of identical values, so the result is
    bit-identical under any GSPMD placement."""
    n = v.shape[-1]
    p = 1 << max(0, (n - 1).bit_length())
    if p != n:
        pad = jnp.zeros(v.shape[:-1] + (p - n,), v.dtype)
        v = jnp.concatenate([v, pad], axis=-1)
    while v.shape[-1] > 1:
        half = v.shape[-1] // 2
        v = v[..., :half] + v[..., half:]
    return v[..., 0]


def _neighbor_mean(x, mask):
    """TILE-LOCAL mean of the finite lanes: the repaired lane takes the mean
    of its own tile, matching the fused kernels' tile-mean semantics (the
    statistics come from the data already resident in VMEM).  This is the
    cheapest statistically-plausible value: weights and activations in
    trained nets are near-symmetric around a small mean, so the tile mean is
    a far better guess than 0 for denominator-bearing tensors (addresses the
    paper's §5.2 division concern).

    The per-tile reduction is an order-fixed pairwise sum in f32 (same
    accumulation dtype as the kernels), so the fill value is bit-identical
    between single-device and sharded executions — sharding can reassociate
    a free-form ``jnp.sum``, never this fold.

    Tile geometry comes from ``core.tiling`` — the ONE fit shared with the
    scrub/matmul/attention kernels."""
    if x.size == 0:
        return x                      # nothing to fill; zero-size leaf
    orig_shape = x.shape
    x2 = x.reshape(1, -1) if x.ndim < 2 else x.reshape(-1, x.shape[-1])
    ok2 = (~mask).reshape(x2.shape)
    rows, cols = x2.shape
    br, bc = tiling.fit_blocks(rows, cols)
    # (R/br, br, C/bc, bc) -> (R/br, C/bc, br*bc): one row per tile
    tiles = x2.reshape(rows // br, br, cols // bc, bc).transpose(0, 2, 1, 3)
    tiles = tiles.reshape(rows // br, cols // bc, br * bc)
    okt = ok2.reshape(rows // br, br, cols // bc, bc).transpose(0, 2, 1, 3)
    okt = okt.reshape(rows // br, cols // bc, br * bc)
    total = _pairwise_sum(jnp.where(okt, tiles.astype(jnp.float32), 0.0))
    cnt = jnp.maximum(_pairwise_sum(okt.astype(jnp.float32)), 1.0)
    mean = (total / cnt).astype(x.dtype)          # (R/br, C/bc)
    fill = jnp.broadcast_to(
        mean[:, None, :, None], (rows // br, br, cols // bc, bc)
    )
    return fill.reshape(rows, cols).reshape(orig_shape)


zero = RepairPolicy("zero", _zero)
clamp_finite_max = RepairPolicy("clamp_finite_max", _clamp_finite_max)
neighbor_mean = RepairPolicy("neighbor_mean", _neighbor_mean)


def constant(c: float) -> RepairPolicy:
    return RepairPolicy(f"constant({c})", _constant(c))


def from_reference(ref: jax.Array) -> RepairPolicy:
    """Repair from a reference tensor of the same shape — used by the
    ``last_checkpoint`` policy where ``ref`` is the checkpointed shard
    (see core/checkpoint_repair.py).  The strongest policy: restores the
    exact pre-flip value up to one checkpoint interval of staleness."""
    def fn(x, mask):
        return ref.astype(x.dtype)
    return RepairPolicy("from_reference", fn)


_REGISTRY = {
    "zero": zero,
    "clamp_finite_max": clamp_finite_max,
    "neighbor_mean": neighbor_mean,
}


def get(name_or_policy) -> RepairPolicy:
    """Resolve a policy by name (config-friendly) or pass one through."""
    if isinstance(name_or_policy, RepairPolicy):
        return name_or_policy
    if isinstance(name_or_policy, (int, float)):
        return constant(float(name_or_policy))
    try:
        return _REGISTRY[name_or_policy]
    except KeyError:
        raise KeyError(
            f"unknown repair policy {name_or_policy!r}; "
            f"known: {sorted(_REGISTRY)} or a float constant"
        ) from None
