"""Repair-value policies.

Paper §5.2 leaves "the value to which a NaN is fixed" as future work and
sketches the design space: 0 is the LetGo choice but breaks divisions; deep
nets tolerate sign flips because values are symmetric around 0; the right
value is workload-dependent.  We make the policy a first-class, composable
object so each protected region can choose independently.

Every policy is a pure function ``(x, mask) -> repaired_values`` where
``mask`` marks fatal lanes; the caller does the final ``where``.  Policies
must be jit-safe, shape-polymorphic, and must *not* read the masked lanes'
values in a way that propagates NaN (hence the masked-mean trick below).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class RepairPolicy:
    """A named repair-value policy."""

    name: str
    fn: Callable[[jax.Array, jax.Array], jax.Array]

    def __call__(self, x: jax.Array, mask: jax.Array) -> jax.Array:
        return self.fn(x, mask)


def _zero(x, mask):
    return jnp.zeros_like(x)


def _constant(c):
    def fn(x, mask):
        return jnp.full_like(x, c)
    return fn


def _clamp_finite_max(x, mask):
    """Largest finite magnitude of the dtype, sign-preserving where the sign
    bit survived (per Li et al. [12] the sign bit rarely matters, but keeping
    it is free)."""
    big = jnp.array(jnp.finfo(x.dtype).max, x.dtype)
    sign = jnp.where(jax.lax.sign(x) < 0, -1.0, 1.0).astype(x.dtype)
    # sign() of NaN is NaN -> force +1 on fatal lanes via where on the mask.
    sign = jnp.where(mask & ~(sign == sign), jnp.ones_like(sign), sign)
    return sign * big


def _neighbor_mean(x, mask):
    """Mean of the *finite* lanes of the same tensor (or tile, inside a
    kernel).  This is the cheapest statistically-plausible value: weights and
    activations in trained nets are near-symmetric around a small mean, so the
    tile mean is a far better guess than 0 for denominator-bearing tensors
    (addresses the paper's §5.2 division concern)."""
    ok = ~mask
    cnt = jnp.maximum(jnp.sum(ok.astype(x.dtype)), jnp.array(1, x.dtype))
    total = jnp.sum(jnp.where(ok, x, jnp.zeros_like(x)))
    return jnp.broadcast_to(total / cnt, x.shape).astype(x.dtype)


zero = RepairPolicy("zero", _zero)
clamp_finite_max = RepairPolicy("clamp_finite_max", _clamp_finite_max)
neighbor_mean = RepairPolicy("neighbor_mean", _neighbor_mean)


def constant(c: float) -> RepairPolicy:
    return RepairPolicy(f"constant({c})", _constant(c))


def from_reference(ref: jax.Array) -> RepairPolicy:
    """Repair from a reference tensor of the same shape — used by the
    ``last_checkpoint`` policy where ``ref`` is the checkpointed shard
    (see core/checkpoint_repair.py).  The strongest policy: restores the
    exact pre-flip value up to one checkpoint interval of staleness."""
    def fn(x, mask):
        return ref.astype(x.dtype)
    return RepairPolicy("from_reference", fn)


_REGISTRY = {
    "zero": zero,
    "clamp_finite_max": clamp_finite_max,
    "neighbor_mean": neighbor_mean,
}


def get(name_or_policy) -> RepairPolicy:
    """Resolve a policy by name (config-friendly) or pass one through."""
    if isinstance(name_or_policy, RepairPolicy):
        return name_or_policy
    if isinstance(name_or_policy, (int, float)):
        return constant(float(name_or_policy))
    try:
        return _REGISTRY[name_or_policy]
    except KeyError:
        raise KeyError(
            f"unknown repair policy {name_or_policy!r}; "
            f"known: {sorted(_REGISTRY)} or a float constant"
        ) from None
