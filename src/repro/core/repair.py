"""Reactive NaN repair — the paper's contribution as composable JAX transforms.

Two repair modes mirror the paper's two mechanisms (§3.3 / §3.4):

* **register mode** (`use`) — repair *at the point of use*, every use.  The
  stored buffer keeps its NaN; each consuming op pays a detect+select.  This
  is the paper's register-repairing mechanism: the trap fires on every reuse
  (Table 3: N events for an N×N matmul).

* **memory mode** (`scrub` + buffer replacement) — repair once and write the
  repaired value back to (approximate) memory, so subsequent uses are clean.
  In JAX the "write back" is functional: the scrubbed pytree *replaces* the
  old one as the carried training/serving state, and under jit with donated
  buffers XLA performs it in place.  This is the paper's memory-repairing
  mechanism: one event per NaN (Table 3: exactly 1).

The production-grade fused path (detection folded into the HBM→VMEM tile load
of matmul/attention) lives in ``repro.kernels``; these jnp-level transforms
are the mode-faithful reference used by the full-model training/serving steps
and by the oracles.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import detect, policies, regions as regions_lib, stats as stats_lib


@dataclasses.dataclass(frozen=True)
class RepairConfig:
    """Config-level switch for the whole repair subsystem.

    ``max_magnitude`` (beyond-paper, DESIGN.md §2): also treat |x| ≥ this
    value as fatal.  The paper repairs NaN patterns only; a flip on a high
    exponent bit yields ~1e38 — not a NaN, but it NaN-poisons the loss one
    matmul later and destroys training (measured).  None = paper-faithful.
    """

    mode: str = "memory"          # "off" | "register" | "memory"
    policy: Any = "neighbor_mean"  # name | float | RepairPolicy
    include_inf: bool = True
    max_magnitude: Optional[float] = None

    def resolved_policy(self) -> policies.RepairPolicy:
        return policies.get(self.policy)

    def __post_init__(self):
        if self.mode not in ("off", "register", "memory"):
            raise ValueError(f"bad repair mode {self.mode!r}")


# ---------------------------------------------------------------------------
# Tensor-level repair.
# ---------------------------------------------------------------------------


def repair_tensor(
    x: jax.Array,
    *,
    policy: policies.RepairPolicy,
    include_inf: bool = True,
    max_magnitude: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Repair fatal lanes of one tensor.

    Returns (repaired, nan_count, inf_count).  The repaired tensor is bitwise
    identical to ``x`` on non-fatal lanes — drift errors are deliberately
    left as-is (the paper's core low-overhead argument: only NaNs are fatal).
    With ``max_magnitude``, |x| ≥ threshold lanes are fatal too (counted with
    the inf bucket — they are the same flip event one mantissa bit away).
    """
    bits = detect.bits_of(x)
    nan_m = detect.is_nan_bits(bits, x.dtype)
    if max_magnitude is not None:
        ext = detect.is_extreme_bits(bits, x.dtype, max_magnitude)
        inf_m = ext & ~nan_m
    elif include_inf:
        inf_m = detect.is_inf_bits(bits, x.dtype)
    else:
        inf_m = jnp.zeros_like(nan_m)
    mask = nan_m | inf_m
    fixed = jnp.where(mask, policy(x, mask), x)
    return (
        fixed,
        jnp.sum(nan_m.astype(jnp.int32)),
        jnp.sum(inf_m.astype(jnp.int32)),
    )


def use(
    x: jax.Array,
    cfg: RepairConfig,
    stats: Optional[stats_lib.Stats] = None,
):
    """Register-mode read: repair at the consumption site.

    In ``register`` mode this is the trap-analogue executed at *every* use.
    In ``memory``/``off`` modes it is the identity (memory mode relies on the
    scrubbed buffer, so per-use work would be pure overhead — exactly the
    paper's argument for the memory-repairing mechanism).

    Returns ``repaired`` (stats is None) or ``(repaired, stats')``.
    """
    if cfg.mode != "register":
        return x if stats is None else (x, stats)
    fixed, n, i = repair_tensor(
        x, policy=cfg.resolved_policy(), include_inf=cfg.include_inf,
        max_magnitude=cfg.max_magnitude,
    )
    if stats is None:
        return fixed
    return fixed, stats_lib.record_repair(stats, n, i)


# ---------------------------------------------------------------------------
# Pytree-level repair (memory mode) .
# ---------------------------------------------------------------------------


def scrub_pytree(
    tree: Any,
    cfg: RepairConfig,
    stats: stats_lib.Stats,
    region_tree: Optional[Any] = None,
) -> Tuple[Any, stats_lib.Stats]:
    """Memory-mode repair of every approximate-region leaf of ``tree``.

    One pass at the start of each step; the returned tree *replaces* the
    stored state (functional write-back).  Leaves in the exact region are
    untouched (they are error-free by construction).  Non-float leaves pass
    through.
    """
    if cfg.mode != "memory":
        return tree, stats
    if region_tree is None:
        region_tree = regions_lib.annotate(tree)
    policy = cfg.resolved_policy()

    nan_tot = jnp.zeros((), jnp.int32)
    inf_tot = jnp.zeros((), jnp.int32)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    assert len(leaves) == len(region_leaves), "region tree structure mismatch"

    fixed_leaves = []
    for leaf, region in zip(leaves, region_leaves):
        if (
            region is regions_lib.Region.APPROX
            and hasattr(leaf, "dtype")
            and jnp.issubdtype(leaf.dtype, jnp.floating)
        ):
            fixed, n, i = repair_tensor(
                leaf, policy=policy, include_inf=cfg.include_inf,
                max_magnitude=cfg.max_magnitude,
            )
            nan_tot = nan_tot + n
            inf_tot = inf_tot + i
            fixed_leaves.append(fixed)
        else:
            fixed_leaves.append(leaf)

    out = jax.tree_util.tree_unflatten(treedef, fixed_leaves)
    return out, stats_lib.record_repair(stats, nan_tot, inf_tot)


def inject_pytree(
    tree: Any,
    key: jax.Array,
    ber: float,
    region_tree: Optional[Any] = None,
) -> Any:
    """Simulation-only: one approximate-memory window of bit flips over the
    approximate-region leaves.  Not part of the production path."""
    from . import injection  # local import: simulation dependency only

    if ber <= 0.0:
        return tree
    if region_tree is None:
        region_tree = regions_lib.annotate(tree)

    leaves, treedef = jax.tree_util.tree_flatten(tree)
    region_leaves = jax.tree.leaves(region_tree)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for leaf, region, k in zip(leaves, region_leaves, keys):
        if (
            region is regions_lib.Region.APPROX
            and hasattr(leaf, "dtype")
            and jnp.issubdtype(leaf.dtype, jnp.floating)
        ):
            out.append(injection.flip_bits(k, leaf, ber))
        else:
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out)
