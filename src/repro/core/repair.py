"""Reactive NaN repair — the paper's contribution as composable JAX transforms.

Two repair modes mirror the paper's two mechanisms (§3.3 / §3.4):

* **register mode** (`use`) — repair *at the point of use*, every use.  The
  stored buffer keeps its NaN; each consuming op pays a detect+select.  This
  is the paper's register-repairing mechanism: the trap fires on every reuse
  (Table 3: N events for an N×N matmul).

* **memory mode** (`scrub` + buffer replacement) — repair once and write the
  repaired value back to (approximate) memory, so subsequent uses are clean.
  In JAX the "write back" is functional: the scrubbed pytree *replaces* the
  old one as the carried training/serving state, and under jit with donated
  buffers XLA performs it in place.  This is the paper's memory-repairing
  mechanism: one event per NaN (Table 3: exactly 1).

The production-grade fused path (detection folded into the HBM→VMEM tile load
of matmul/attention) lives in ``repro.kernels``; these jnp-level transforms
are the mode-faithful reference used by the full-model training/serving steps
and by the oracles.

.. deprecated::
    The pytree-level entry points here (``scrub_pytree``, ``inject_pytree``)
    are thin shims over ``repro.runtime.ApproxSpace`` and emit a
    ``DeprecationWarning`` on every call — the space is the single object
    that owns regions, repair, injection, and the unified stats stream
    (README §Runtime / §Migration).  ``repair_tensor`` / ``fatal_masks``
    remain the tensor-level primitives shared by both layers, and ``use``
    remains the per-read entry the nn layers call (warning-free: it is the
    production register-mode path, not a migration shim).
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from . import policies, regions as regions_lib, stats as stats_lib
from . import rules as rules_lib


def _deprecated(name: str, replacement: str) -> None:
    warnings.warn(
        f"core.repair.{name} is a deprecated shim; use {replacement} "
        "(README §Migration)",
        DeprecationWarning,
        stacklevel=3,
    )


@dataclasses.dataclass(frozen=True)
class RepairConfig:
    """Config-level switch for the whole repair subsystem.

    ``max_magnitude`` (beyond-paper, README §Config): also treat |x| ≥ this
    value as fatal.  The paper repairs NaN patterns only; a flip on a high
    exponent bit yields ~1e38 — not a NaN, but it NaN-poisons the loss one
    matmul later and destroys training (measured).  None = paper-faithful.
    """

    mode: str = "memory"          # "off" | "register" | "memory"
    policy: Any = "neighbor_mean"  # name | float | RepairPolicy
    include_inf: bool = True
    max_magnitude: Optional[float] = None

    def resolved_policy(self) -> policies.RepairPolicy:
        return policies.get(self.policy)

    def __post_init__(self):
        if self.mode not in ("off", "register", "memory"):
            raise ValueError(f"bad repair mode {self.mode!r}")


# ---------------------------------------------------------------------------
# Tensor-level repair.
# ---------------------------------------------------------------------------


def fatal_masks(
    x: jax.Array,
    *,
    include_inf: bool = True,
    max_magnitude: Optional[float] = None,
    detector: Optional[rules_lib.Detector] = None,
) -> Tuple[jax.Array, jax.Array]:
    """(nan_mask, inf_mask) of the fatal lanes of ``x`` — the detection half
    of ``repair_tensor``, exposed so callers that need per-lane masks (the
    page-bucketed compiled scrub masks padding rows out of its counts) share
    one definition of "fatal" with the repair path.

    Detection is a ``rules.Detector`` (README §RepairRule); the scalar
    ``include_inf``/``max_magnitude`` form lifts into the equivalent
    detector, bit for bit."""
    if detector is None:
        detector = rules_lib.Detector(
            nan=True, inf=include_inf, max_magnitude=max_magnitude
        )
    return detector.masks(x)


def repair_tensor(
    x: jax.Array,
    *,
    policy: policies.RepairPolicy,
    include_inf: bool = True,
    max_magnitude: Optional[float] = None,
    detector: Optional[rules_lib.Detector] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Repair fatal lanes of one tensor.

    Returns (repaired, nan_count, inf_count).  The repaired tensor is bitwise
    identical to ``x`` on non-fatal lanes — drift errors are deliberately
    left as-is (the paper's core low-overhead argument: only NaNs are fatal).
    With ``max_magnitude``, |x| ≥ threshold lanes are fatal too (counted with
    the inf bucket — they are the same flip event one mantissa bit away).
    ``detector`` overrides the scalar detection knobs with an explicit
    ``rules.Detector``.
    """
    nan_m, inf_m = fatal_masks(
        x, include_inf=include_inf, max_magnitude=max_magnitude,
        detector=detector,
    )
    mask = nan_m | inf_m
    fixed = jnp.where(mask, policy(x, mask), x)
    return (
        fixed,
        jnp.sum(nan_m.astype(jnp.int32)),
        jnp.sum(inf_m.astype(jnp.int32)),
    )


def use(
    x: jax.Array,
    cfg: RepairConfig,
    stats: Optional[stats_lib.Stats] = None,
    path: str = "",
):
    """Register-mode read: repair at the consumption site.

    In ``register`` mode this is the trap-analogue executed at *every* use.
    In ``memory``/``off`` modes it is the identity (memory mode relies on the
    scrubbed buffer, so per-use work would be pure overhead — exactly the
    paper's argument for the memory-repairing mechanism) — except for a
    bound *on-read* rule, whose leaves repair here and only here
    (README §RepairRule).  ``path`` names the parameter being read: the
    ruleset binds its exact per-path rule instead of the pathless read
    rule, so an on-read rule scoped to one parameter fires only there.

    Returns ``repaired`` (stats is None) or ``(repaired, stats')``.

    Deprecated shim: delegates to ``runtime.ApproxSpace.use`` (pure form).
    """
    from ..runtime import ApproxSpace  # deferred: runtime builds on us

    if stats is None:
        fixed, _ = ApproxSpace(cfg).use(x, stats_lib.zeros(), path=path)
        return fixed
    return ApproxSpace(cfg).use(x, stats, path=path)


# ---------------------------------------------------------------------------
# Pytree-level repair (memory mode) .
# ---------------------------------------------------------------------------


def scrub_pytree(
    tree: Any,
    cfg: RepairConfig,
    stats: stats_lib.Stats,
    region_tree: Optional[Any] = None,
) -> Tuple[Any, stats_lib.Stats]:
    """Memory-mode repair of every approximate-region leaf of ``tree``.

    One pass at the start of each step; the returned tree *replaces* the
    stored state (functional write-back).  Leaves in the exact region are
    untouched (they are error-free by construction).  Non-float leaves pass
    through.

    Deprecated shim: delegates to ``runtime.scrub_tree`` (the implementation
    behind ``ApproxSpace.scrub``).
    """
    from ..runtime import space as runtime_space  # deferred: runtime builds on us

    _deprecated("scrub_pytree", "runtime.ApproxSpace.scrub")
    if region_tree is None:
        region_tree = regions_lib.annotate(tree)
    return runtime_space.scrub_tree(tree, cfg, stats, region_tree)


def inject_pytree(
    tree: Any,
    key: jax.Array,
    ber: float,
    region_tree: Optional[Any] = None,
) -> Tuple[Any, jax.Array]:
    """Simulation-only: one approximate-memory window of bit flips over the
    approximate-region leaves.  Not part of the production path.

    Deprecated shim: delegates to ``runtime.inject_tree``.  Returns
    ``(flipped_tree, n_flips)`` — the ground-truth flip count feeds the
    previously-dead ``flips`` stats counter.
    """
    from ..runtime import space as runtime_space  # deferred: runtime builds on us

    _deprecated("inject_pytree", "runtime.ApproxSpace.inject")
    if region_tree is None:
        region_tree = regions_lib.annotate(tree)
    return runtime_space.inject_tree(tree, key, ber, region_tree)
