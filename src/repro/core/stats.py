"""Repair/flip event counters — the Table 3 analogue.

The paper's Table 3 counts SIGFPEs: N for register-only repair of an N×N
matmul, exactly 1 with memory repair.  Our counters are carried as a small
pytree of int32 scalars so they jit, shard (fully replicated), and cross
``lax.scan`` boundaries inside train/serve steps.

  flips      — bits flipped by the injection simulator (ground truth)
  nan_found  — NaN lanes detected at repair sites
  inf_found  — ±Inf lanes detected at repair sites
  events     — repair *invocations* that found ≥1 fatal lane (the SIGFPE
               analogue: one event ≈ one trap in the paper's prototype)
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

Stats = Dict[str, jax.Array]

_FIELDS = ("flips", "nan_found", "inf_found", "events")


def zeros() -> Stats:
    return {f: jnp.zeros((), jnp.int32) for f in _FIELDS}


def merge(a: Stats, b: Stats) -> Stats:
    return {f: a[f] + b[f] for f in _FIELDS}


def record_repair(s: Stats, nan_count, inf_count) -> Stats:
    nan_count = jnp.asarray(nan_count, jnp.int32)
    inf_count = jnp.asarray(inf_count, jnp.int32)
    return {
        "flips": s["flips"],
        "nan_found": s["nan_found"] + nan_count,
        "inf_found": s["inf_found"] + inf_count,
        "events": s["events"]
        + ((nan_count + inf_count) > 0).astype(jnp.int32),
    }


def record_flips(s: Stats, n) -> Stats:
    out = dict(s)
    out["flips"] = s["flips"] + jnp.asarray(n, jnp.int32)
    return out


def record_kernel_counts(s: Stats, counts) -> Stats:
    """Fold a Pallas kernel counter vector into the unified stream.

    ``counts`` is the int32[8] layout shared by ``kernels.repair_matmul`` and
    ``kernels.repair_attention`` (see ``kernels.ops`` re-exports): indices
    (0, 3) are per-operand NaN lane counts, (1, 4) Inf lane counts, and 6 is
    the tile-visit event total — the kernel's trap analogue, so it adds to
    ``events`` directly (one poisoned-tile visit ≈ one SIGFPE in the paper's
    prototype).
    """
    counts = jnp.asarray(counts, jnp.int32)
    return {
        "flips": s["flips"],
        "nan_found": s["nan_found"] + counts[0] + counts[3],
        "inf_found": s["inf_found"] + counts[1] + counts[4],
        "events": s["events"] + counts[6],
    }


def as_dict(s: Stats) -> Dict[str, int]:
    return {f: int(s[f]) for f in _FIELDS}
