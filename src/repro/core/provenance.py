"""Origin-traceability analysis over jaxprs — the Fig. 6 analogue.

Paper §3.4 / Fig. 6: the memory-repairing mechanism works only when the
faulting arithmetic instruction can be *back-traced* to the ``mov`` that
loaded the NaN, recovering its memory address; this succeeds for >95 % of FP
arithmetic instructions in SPEC binaries.  Failures: non-back-traceable
control flow, or clobbered address registers.

On TPU/JAX the compiled program is a dataflow graph, so the same question
becomes structural: *for each FLOP-carrying op, is some operand connected to
a protected (approximate-memory) buffer through a chain of address-preserving
ops only?*  If yes, a NaN observed at that op is repairable **at its memory
origin** (memory mode); if the chain passes through a value-transforming op,
the NaN is derived and only use-site (register-mode) repair applies — the
exact fallback the paper describes for its missing 5 %.

Address-preserving ops are those where output lane (i) is input lane σ(i)
for a static σ: reshape/transpose/slice/gather/concat/broadcast/convert.
Value-transforming ops (any arithmetic, reductions, select) break the chain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, FrozenSet, List, Sequence, Set

import jax
from jax.extend import core as jcore

# FLOP-carrying primitives we classify (superset of the paper's Table 1
# add/sub/mul/div families; dot_general/conv are their fused form).
ARITH_PRIMS: FrozenSet[str] = frozenset(
    {
        "add", "sub", "mul", "div",
        "dot_general", "conv_general_dilated",
    }
)

# Lane-identity-preserving primitives: a NaN at output lane i came from a
# recoverable input lane, so the origin address is recoverable.
TRANSPARENT_PRIMS: FrozenSet[str] = frozenset(
    {
        "reshape", "transpose", "broadcast_in_dim", "slice", "dynamic_slice",
        "squeeze", "rev", "gather", "concatenate", "pad",
        "convert_element_type", "copy", "device_put", "bitcast_convert_type",
        "expand_dims", "dynamic_update_slice",
    }
)

# Call-like primitives to recurse through.
_CALL_PARAM_KEYS = ("jaxpr", "call_jaxpr")


@dataclasses.dataclass
class ProvenanceReport:
    """Counts per arithmetic primitive."""

    total_arith: int = 0                 # arith ops consuming ≥1 protected-derived operand
    origin_traceable: int = 0            # ... where that operand chain is address-preserving
    per_prim: Dict[str, List[int]] = dataclasses.field(default_factory=dict)

    def record(self, prim: str, traceable: bool):
        self.total_arith += 1
        self.origin_traceable += int(traceable)
        t, n = self.per_prim.get(prim, [0, 0])
        self.per_prim[prim] = [t + int(traceable), n + 1]

    @property
    def fraction(self) -> float:
        return self.origin_traceable / self.total_arith if self.total_arith else 1.0


# Taint states per variable: NONE (not protected-derived), ORIGIN (protected
# and address-recoverable), DERIVED (protected-derived but transformed).
NONE, ORIGIN, DERIVED = 0, 1, 2


def _walk(jaxpr, taint: Dict[Any, int], report: ProvenanceReport):
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name

        def in_taints():
            out = []
            for v in eqn.invars:
                if isinstance(v, jcore.Literal):
                    out.append(NONE)
                else:
                    out.append(taint.get(v, NONE))
            return out

        # Recurse through call-like primitives (pjit, remat, custom_*).
        sub = None
        for k in _CALL_PARAM_KEYS:
            if k in eqn.params:
                sub = eqn.params[k]
                break
        if sub is not None:
            closed = sub if hasattr(sub, "jaxpr") else None
            inner = closed.jaxpr if closed is not None else sub
            inner_taint: Dict[Any, int] = {}
            ts = in_taints()
            # map outer invars -> inner invars (constvars first for closed)
            invars = list(inner.invars)
            # align from the right (some call prims prepend const/token args)
            for iv, t in zip(invars[-len(ts):], ts):
                inner_taint[iv] = t
            _walk(inner, inner_taint, report)
            for ov, iv in zip(eqn.outvars, inner.outvars):
                t = NONE
                if not isinstance(iv, jcore.Literal):
                    t = inner_taint.get(iv, NONE)
                taint[ov] = t
            continue

        if name == "scan":
            closed = eqn.params["jaxpr"]
            # handled above via 'jaxpr' key; unreachable, kept for clarity
        if name in ("while", "cond"):
            # conservative: outputs derived if any input tainted (control flow
            # is the paper's non-back-traceable case — never origin-traceable)
            ts = in_taints()
            t = DERIVED if any(x != NONE for x in ts) else NONE
            for ov in eqn.outvars:
                taint[ov] = t
            # still recurse to count arith inside branches, with DERIVED taint
            branches = eqn.params.get("branches") or (
                [eqn.params[k] for k in ("cond_jaxpr", "body_jaxpr") if k in eqn.params]
            )
            for br in branches or []:
                inner = br.jaxpr if hasattr(br, "jaxpr") else br
                inner_taint = {}
                for iv, tt in zip(inner.invars[-len(ts):], ts):
                    inner_taint[iv] = DERIVED if tt != NONE else NONE
                _walk(inner, inner_taint, report)
            continue

        ts = in_taints()
        tainted = [t for t in ts if t != NONE]

        if name in ARITH_PRIMS and tainted:
            # The op consumes a protected-derived value: is the *protected*
            # operand origin-traceable?  (Paper: can we find the mov?)
            report.record(name, any(t == ORIGIN for t in ts))
            out_t = DERIVED
        elif name in TRANSPARENT_PRIMS:
            # address-preserving: strongest input taint propagates unchanged
            out_t = max(ts) if ts else NONE
        else:
            # any other op transforms values: origin is lost
            out_t = DERIVED if tainted else NONE

        for ov in eqn.outvars:
            taint[ov] = out_t


def analyze(fn, protected_argnums: Sequence[int], *example_args, **kw) -> ProvenanceReport:
    """Trace ``fn`` and report origin-traceability of protected operands.

    ``protected_argnums`` marks which positional args live in approximate
    memory (whole-pytree granularity).  Example args may be ShapeDtypeStructs
    — the analysis never executes the function.
    """
    closed = jax.make_jaxpr(fn)(*example_args, **kw)
    jaxpr = closed.jaxpr

    # Flatten: figure out which flat invars belong to protected args.
    flat_sizes = []
    for a in example_args:
        flat_sizes.append(len(jax.tree.leaves(a)))
    taint: Dict[Any, int] = {}
    offset = 0
    protected = set(protected_argnums)
    for i, size in enumerate(flat_sizes):
        for j in range(size):
            v = jaxpr.invars[offset + j]
            taint[v] = ORIGIN if i in protected else NONE
        offset += size

    report = ProvenanceReport()
    _walk(jaxpr, taint, report)
    return report
