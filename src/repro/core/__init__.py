"""Reactive NaN repair for approximate memory — the paper's contribution.

Public surface:

  detect       bit-pattern NaN/Inf detection (shared with Pallas kernels)
  policies     repair-value policy lattice (paper §5.2 design space)
  injection    approximate-memory simulator (BER model + bit flips)
  regions      exact/approximate memory partitioning of state pytrees
  repair       register/memory repair modes (paper §3.3/§3.4)
  stats        repair-event counters (Table 3 analogue)
  provenance   origin-traceability analysis (Fig. 6 analogue)
  checkpoint_repair  repair-from-checkpoint policy (answers §5.2)
"""
from . import (  # noqa: F401
    checkpoint_repair,
    detect,
    injection,
    policies,
    provenance,
    regions,
    repair,
    stats,
)
from .repair import RepairConfig, repair_tensor, scrub_pytree, use  # noqa: F401
