"""Reactive NaN repair for approximate memory — the paper's contribution.

Public surface:

  detect       bit-pattern NaN/Inf detection (shared with Pallas kernels)
  rules        RepairRule API: Detector × Fill × Trigger bound to tree
               paths by a RuleSet (README §RepairRule)
  policies     repair-value policy lattice (paper §5.2 design space)
  injection    approximate-memory simulator (BER model + bit flips)
  regions      exact/approximate memory partitioning of state pytrees
  repair       register/memory repair modes (paper §3.3/§3.4); the pytree
               entry points are deprecated shims over ``repro.runtime``
  stats        repair-event counters (Table 3 analogue), incl. the mapping
               of Pallas kernel counter vectors into the unified stream
  provenance   origin-traceability analysis (Fig. 6 analogue)
  checkpoint_repair  repair-from-checkpoint policy (answers §5.2)
"""
from . import (  # noqa: F401
    checkpoint_repair,
    detect,
    injection,
    policies,
    provenance,
    regions,
    repair,
    rules,
    stats,
)
from .repair import RepairConfig, repair_tensor, scrub_pytree, use  # noqa: F401
from .rules import Detector, RepairRule, RuleSet  # noqa: F401
