"""`Engine` — continuous batching over the paged approximate-memory KV pool.

The facade every later scaling PR (sharded pools, async decode, multi-tenant
QoS) builds on:

    engine = Engine(model, params, ServingConfig(...))
    rid = engine.add_request(prompt_ids, max_new=32)
    while engine.has_work:
        out = engine.step()          # {"emitted": {rid: [tok]}, "finished"}
    engine.results[rid]["tokens"]    # prompt + generated

One engine step is: (1) one approximate-memory window strikes the resident
pool (simulation boundary, ``ber > 0`` only); (2) admission (a swapped-out
request skips prefill entirely and has its parked KV written back from the
host tier instead); (3) the prefill lane — fused (one prompt chunk per
mid-prefill request through the chunked-q paged kernel, straight off the
pool) or the gathered fallback (one whole-prompt ``Model.prefill`` call per
admission); (4) one jitted decode step over the static slot batch
(per-request positions — requests at different depths share the executable)
plus the reactive repair pass; (5) the background sweep tick.  With
``prefill_chunk > 0`` stages (3) and (4) coexist: prompt chunks and decode
tokens share the batch step, vllm-style.  All repair/flip/kernel events
land in the engine's unified stats stream.

Both lifecycle halves run *straight off the pool* whenever the model and
the pool rules allow it (``_paged_decode_plan``): the Pallas paged kernel
family consumes the page-major pool leaves + block tables directly,
repairing fatal KV lanes in VMEM as it streams them and emitting per-page
fatal counts — the fused kernels ARE the reactive detector, so admission,
prefill and decode together issue zero full-view ``gather``/``scatter``
copies (the surviving writes are the per-chunk/per-token K/V page slots)
and the reactive scrub runs *after* each lane from the kernels' counts.
Wide block tables additionally split the decode page walk across grid
cells (``ServingConfig.split_k`` — flash-decoding with a log-sum-exp merge).
Ineligible configurations (register-mode model reads, non-constant fills,
``repair="off"``) keep the PR-2 gathered-view path with its probe-based
pre-compute repair — token outputs are identical where both paths apply
(bit-exact for f32 pools; bf16 pools quantize softmax weights before the
online-softmax rescale, so parity there is value-approximate, token-level
in practice).

Static shapes: the decode batch is always ``(max_batch, 1)`` tokens over
``(max_batch, max_pages_per_request)`` block tables (empty slots run the
null page at position 0 and are ignored), so the whole serving run compiles
exactly one decode executable; prefill compiles one executable per distinct
chunk width (a fixed ``prefill_chunk`` means one compiled prefill step for
the whole run; 0 retraces per distinct remaining-prompt length, like the
gathered path).

Two serving-scale mechanisms ride the same fused path (README §Serving
engine — "Sharded decode & load testing"):

* **Device-local sharded walk** — when the engine's space carries a mesh
  and the pool's page axis is genuinely sharded over one mesh axis
  (``pool.page_shard_axis()``), the fused decode/prefill executables run
  the kernels under ``shard_map``: each device walks only the block-table
  slots whose pages it owns, repairs them in its own VMEM, and the partial
  softmax states merge with one ``all_gather`` + log-sum-exp combine.  No
  KV page ever crosses a device boundary.  Indivisible pool geometries
  degrade to the single-device walk transparently.

* **Desynchronized stats drain** — ``ServingConfig.drain_interval > 0``
  keeps the kernels' per-page fatal counts resident on device,
  accumulating across steps; every N steps one readback drains them and
  the reactive scrub covers the union of flagged pages.  The fused kernels
  repair on read with a value-independent fill, so deferring the HBM
  scrub never changes the tokens.  ``Engine.metrics()`` reports
  ``n_host_syncs`` — the blocking device→host readback count the drain
  exists to shrink — plus per-stage wall-clock totals.

``launch.serve.generate(..., paged=True)`` is the single-request degenerate
case of this engine.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import stats as stats_lib
from ..core.regions import Region
from ..kernels import common as kernels_common
from ..launch.serve import build_serve_step
from ..runtime import ApproxSpace, ScrubSchedule
from ..runtime.plan import serving_scope
from .config import ServingConfig
from .pool import PagedKVPool
from .prefix_cache import PrefixCache
from .repair import PageRepairManager
from .scheduler import Request, RequestState, Scheduler
from .tiers import TierManager


def engine_space(model: Any) -> ApproxSpace:
    """The engine's default runtime: memory-forced, NaN/Inf-only, no
    boundary scrub (the page repair manager owns every scrub), private to
    this engine so stats streams stay isolated.

    The default fill is ZERO (not the training default ``neighbor_mean``):
    KV lanes have no cheap neighborhood statistic on the decode hot path,
    zero is the paper's fix-to-a-predetermined-value choice, and a
    value-independent fill is what lets the fused paged-attention kernel
    apply the exact same repair in VMEM that the pool scrub applies in HBM
    — the fused decode path stays bit-compatible with the gathered one.  A
    model config carrying an explicit ``RuleSet`` keeps it (per-path rules
    already say how cache leaves are protected; eligibility then decides
    fused vs fallback)."""
    return ApproxSpace(
        model.cfg.repair,
        mode="memory",
        policy="zero",
        max_magnitude=None,
        scrub=ScrubSchedule(boundary=False, interval=0),
    )


# ---------------------------------------------------------------------------
# Paged-decode eligibility.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class _PagedDecodePlan:
    """Static repair spec the fused decode step is compiled against: one
    detector per pool-leaf name (``None`` = detection off for that leaf)
    plus one ``(policy, constant)`` kernel fill per leaf name — each
    operand's tile repairs with its own rule's fill, so a mixed-fill
    RuleSet no longer forces the gathered-decode fallback.  ``prefill``
    extends the same spec to admission: the chunked-q paged prefill kernel
    runs with identical per-operand detectors/fills, so the whole request
    lifecycle shares one repair contract."""

    detectors: Mapping[str, Any]
    fills: Mapping[str, Tuple[str, float]]
    prefill: bool = False


def _paged_decode_plan(
    model: Any, space: ApproxSpace, pool: PagedKVPool, cfg: ServingConfig
) -> Optional[_PagedDecodePlan]:
    """The fused-decode spec, or ``None`` when the configuration must keep
    the gathered-view fallback: no paged decode path on the model,
    ``repair="off"`` (the fused kernel always repairs what it reads — "no
    repair" semantics need the plain path), register-mode model reads (the
    in-kernel repair replaces ``use()``-site repair, not both), a fill the
    kernel cannot reproduce bit-for-bit, or a detector that does not encode
    into the scalar-prefetch constants (>32-bit dtypes)."""
    if not getattr(model, "supports_paged_decode", False):
        return None
    if serving_scope(cfg.repair) == "none" or space.config.mode != "memory":
        return None
    if getattr(model.cfg.repair, "mode", "off") == "register":
        return None
    regions = space.regions_for(pool.tree)
    rule_tree, _ = space.rules_for(pool.tree)
    flat = jax.tree_util.tree_flatten_with_path(pool.tree)[0]
    detectors: Dict[str, Any] = {}
    fills: Dict[str, Tuple[str, float]] = {}
    for (path, leaf), region, rule in zip(
        flat, jax.tree.leaves(regions), jax.tree.leaves(rule_tree)
    ):
        name = str(getattr(path[-1], "key", path[-1]))
        is_float = hasattr(leaf, "dtype") and jnp.issubdtype(
            leaf.dtype, jnp.floating
        )
        if (
            not is_float
            or region is not Region.APPROX
            or not rule.fires("reactive")
        ):
            det = None          # probe-gate parity: this leaf is never probed
            fill = ("zero", 0.0)     # irrelevant: nothing is ever detected
        else:
            fill = kernels_common.kernel_fill(rule.fill)
            if fill is None:
                return None
            try:
                rule.detect.constants(leaf.dtype)
            except (TypeError, ValueError):
                return None
            det = rule.detect
        if name in detectors and detectors[name] != det:
            return None         # one detector per leaf name (kernel operand)
        if det is not None and fills.get(name, fill) != fill:
            return None         # one fill per leaf name (kernel operand)
        detectors[name] = det
        if det is not None or name not in fills:
            fills[name] = fill
    return _PagedDecodePlan(
        detectors=detectors,
        fills=fills,
        # the prefill arm rides on decode eligibility: same pool rules, same
        # kernel repair contract — only the model surface and the config
        # switch are extra
        prefill=(
            bool(getattr(model, "supports_paged_prefill", False))
            and cfg.paged_prefill == "auto"
        ),
    )


class Engine:
    """Continuous-batching serving engine (add_request / step / run)."""

    def __init__(
        self,
        model: Any,
        params: Any,
        cfg: Optional[ServingConfig] = None,
        space: Optional[ApproxSpace] = None,
    ):
        if not model.supports_paged_kv:
            raise NotImplementedError(
                f"{type(model).__name__} has no paged KV layout — the engine "
                "serves attention-cache architectures"
            )
        if not model.supports_batched_prefill:
            raise NotImplementedError(
                f"{type(model).__name__} cannot batched-prefill — the engine "
                "consumes whole prompts in one pass"
            )
        self.model = model
        self.cfg = cfg or ServingConfig()
        self.space = space or engine_space(model)
        # mesh-native serving (ROADMAP leftover): when the engine's space
        # carries a mesh, model params are device_put onto their logical-axis
        # shardings — the same `serve_shardings` placement jit_serve_step
        # uses — instead of staying replicated alongside the sharded pool.
        self.params_shardings = None
        if self.space.mesh is not None:
            from ..distributed import sharding as sh  # deferred: keep layering thin

            rules = self.space.rules or sh.rules_for_mesh(self.space.mesh)
            self.params_shardings = sh.tree_shardings(
                model.abstract_params(), model.logical_axes(),
                self.space.mesh, rules,
            )
            params = jax.device_put(params, self.params_shardings)
        self.params = params
        self.pool = PagedKVPool(model, self.space, self.cfg)
        # observation counters the hot path reports through (must exist
        # before any helper that syncs is first called)
        self.n_host_syncs = 0
        self.stage_wall_s: Dict[str, float] = {
            "admit": 0.0, "prefill": 0.0, "decode": 0.0,
            "repair": 0.0, "guard": 0.0,
        }
        # device-local sharded hot path: engaged only when the pool's page
        # axis is genuinely sharded over exactly one mesh axis (divisible
        # row count) — otherwise the single-device kernel walk stays
        axis = self.pool.page_shard_axis()
        self._kernel_shard = (
            (self.space.mesh, axis) if axis is not None else None
        )
        # tiered KV (README §Serving engine — "Tiered KV"): a host-memory
        # exact tier preemption swaps to (boundary scrub on the way out)
        # and prefix-cache eviction demotes into
        self.tiers = (
            TierManager(self.pool, self.space, self.cfg)
            if self.cfg.host_pages > 0 else None
        )
        self.cache = (
            PrefixCache(self.pool, self.space, self.cfg, tiers=self.tiers)
            if self.cfg.prefix_cache else None
        )
        self.sched = Scheduler(
            self.pool, self.cfg, cache=self.cache, tiers=self.tiers
        )
        self.repair = PageRepairManager(
            self.pool, self.space, self.cfg,
            on_host_sync=self._note_host_sync,
        )
        # the one greedy step builder (shared with launch.serve.generate, so
        # the engine-vs-generate token-parity contract cannot drift)
        self._step_fn = jax.jit(
            self.space.wrap_serve_step(build_serve_step(model))
        )
        # fused paged decode: compiled once against the pool rules' static
        # repair spec; None keeps the gathered-view fallback
        self.paged_plan = (
            _paged_decode_plan(model, self.space, self.pool, self.cfg)
            if self.cfg.paged_decode == "auto" else None
        )
        # split-K flash decoding: resolved once against the static block-
        # table width (a divisor of it — see ServingConfig.resolve_split_k)
        self._split_k = self.cfg.resolve_split_k()
        self._paged_fn = (
            self._build_paged_step(self.paged_plan)
            if self.paged_plan is not None else None
        )
        # fused chunked prefill: the admission-side twin of the decode step
        self._prefill_fn = (
            self._build_paged_prefill_step(self.paged_plan)
            if self.paged_plan is not None and self.paged_plan.prefill
            else None
        )
        self._prefilling: List[Request] = []   # mid-prefill (chunk) lane
        self.kernel_counts = np.zeros(8, np.int64)   # fused AT_* totals
        # desynchronized stats drain (drain_interval > 0): fused-lane
        # counters accumulate on device; one concatenated readback per
        # drain window feeds the reactive scrub
        self._desync = (
            self.cfg.drain_interval > 0 and self._paged_fn is not None
        )
        self._pending = None            # device (n_pages+1+8,) accumulator
        self._pending_covered: set = set()
        self._pending_attr: List[Tuple[List[int], Any]] = []
        self._steps_since_drain = 0
        self._stream = stats_lib.zeros()
        self._requests: Dict[int, Request] = {}
        self.results: Dict[int, Dict[str, Any]] = {}
        self._next_rid = 0
        self._t = 0
        self._inject_key = jax.random.PRNGKey(self.cfg.seed + 1)
        self._last_touched: List[int] = []
        self.tokens_emitted = 0
        self.prefill_tokens_saved = 0
        # tokens a re-prefill had to re-process after a recompute-style
        # preemption (the cost swap-out exists to avoid)
        self.prefill_tokens_recomputed = 0
        # online autopilot guard (README §Autopilot): per-window fault
        # monitor over the pool rules; a trip tightens the drifting group's
        # rule and rebuilds the fused executables that closed over it
        self.guard = None
        self.autopilot_trips = 0
        if self.cfg.autopilot is not None:
            from ..autopilot.guard import OnlineGuard  # deferred import
            self.guard = OnlineGuard(self.space, self.cfg.autopilot)

    # ------------------------------------------------------------------ admit
    def add_request(self, prompt: Sequence[int], max_new: int) -> int:
        """Queue one generation request; returns its id."""
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=int(max_new))
        self._requests[rid] = req
        self.sched.add(req)
        return rid

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    # ------------------------------------------------------------------- step
    def step(self) -> Dict[str, Any]:
        """One engine step; returns the tokens emitted and requests finished."""
        t = self._t
        self.pool.now = t        # dwell clock: one step = one fault window
        emitted: Dict[int, List[int]] = {}
        finished: List[int] = []
        # kernel-counter routing targets the pages THIS step touches; stale
        # entries could point at pages since freed and reallocated
        self._last_touched = []

        # (0) deferred stats drain: runs BEFORE this step's flips land, so
        # a drain_interval=1 engine scrubs exactly the pages the lockstep
        # engine scrubbed inside the previous step — the pool bits entering
        # stage (1) are identical and the token trajectory replays
        if self._desync and self._steps_since_drain >= self.cfg.drain_interval:
            self._drain_pending()

        # (1) simulation boundary: one window of flips strikes the pool —
        # the same stats-threading injection entry point the train loop's
        # inject_state uses (flips land in the engine's functional stream,
        # donated pool buffers, compiled per pool layout)
        if self.cfg.ber > 0.0:
            self._inject_key, k = jax.random.split(self._inject_key)
            self.pool.tree, self._stream = self.space.inject(
                self.pool.tree, k, self.cfg.ber,
                stats=self._stream, donate=True,
            )

        # (2) admission.  A preempted lane member leaves the lane here: a
        # recompute victim restarts from scratch when re-admitted, a swap
        # victim rejoins the lane at its saved chunk position on swap-in.
        # (On the gathered fallback the whole-prompt prefill rides inside
        # admission, so its wall time lands in the "admit" bucket.)
        t_admit = time.perf_counter()
        self._prefilling = [
            r for r in self._prefilling if r.state is RequestState.RUNNING
        ]
        plan = self.sched.step_plan(self._prefilling)
        admitted = plan.admitted
        if admitted:
            pages = sorted({p for r in admitted for p in r.pages})
            shared = {
                e.page
                for r in admitted if r.cache_hit is not None
                for e in r.cache_hit.full
            } | {
                r.cache_hit.partial.page
                for r in admitted
                if r.cache_hit is not None and r.cache_hit.partial is not None
            }
            # swapped-in pages are excluded too: they are about to be
            # overwritten by exact host-tier bits (probing the just-zeroed
            # allocation would be charging for nothing)
            swapped = {p for r in admitted if r.swap is not None for p in r.pages}
            fresh = sorted(set(pages) - shared - swapped)
            if fresh and self._prefill_fn is None:
                # gathered fallback only: admitted pages are freshly zeroed,
                # but the null padding page rides along — one probe pass
                # covers every admission before prefill consumes its pages.
                # Cache-hit shared pages are excluded: their admission
                # policy IS scrub-on-reuse.  On the fused path the prefill
                # kernel is the detector — no probe at all.
                self._stream = self.repair.repair_step(fresh, self._stream)
            self._last_touched = pages
        for req in admitted:
            if req.swap is not None:
                # tier swap-in instead of re-prefill: the parked context is
                # written back whole and the request decodes this very step
                # — unless it was swapped out mid-prefill, in which case it
                # rejoins the chunk lane where it left off
                handle, req.swap = req.swap, None
                self.tiers.swap_in(handle, req.pages)
                if req.prefill_pos is not None and self._prefill_fn is not None:
                    self._prefilling.append(req)
                continue
            if self.cache is not None:
                self._stream = self.cache.prepare_hit(req, self._stream)
            if self._prefill_fn is not None:
                # fused lane: the request streams prompt chunks over the
                # next step(s); cache insert + finish happen when the last
                # chunk lands
                if req.prefill_pos is None:
                    req.prefill_pos = 0
                self._prefilling.append(req)
                continue
            self._prefill(req, emitted)
            if self.cache is not None:
                # insert BEFORE finish: the cache's own references keep the
                # prefix resident even when the request finishes right away
                self.cache.insert(req)
            if req.state is RequestState.RUNNING and self._maybe_finish(req):
                finished.append(req.rid)
        self.stage_wall_s["admit"] += time.perf_counter() - t_admit

        # (3) the fused prefill lane: one prompt chunk per mid-prefill
        # request, straight off the pool, then ONE reactive pass from the
        # summed per-page fatal counts (per-request passes would scrub a
        # faulty shared/null page once per request — the gathered path
        # charges it once per step).  The counter vectors stay on device
        # through the lane; `_flush_lane` reads them back (lockstep) or
        # parks them in the pending accumulator (desync).
        if self._prefilling:
            t_pre = time.perf_counter()
            page_counts = counts = None
            covered = {self.pool.null_page}
            still: List[Request] = []
            for req in self._prefilling:
                pc_r, cnt_r, done = self._prefill_paged(req, emitted)
                page_counts = pc_r if page_counts is None else page_counts + pc_r
                counts = cnt_r if counts is None else counts + cnt_r
                covered.update(req.pages)
                if not done:
                    still.append(req)
                    continue
                if self.cache is not None:
                    self.cache.insert(req)
                if req.state is RequestState.RUNNING and self._maybe_finish(req):
                    finished.append(req.rid)
            self._prefilling = still
            self._last_touched = sorted(
                set(self._last_touched) | (covered - {self.pool.null_page})
            )
            self.stage_wall_s["prefill"] += time.perf_counter() - t_pre
            self._flush_lane(page_counts, counts, covered)

        # (4) one decode step + the reactive repair pass.  Reserving a page
        # for one request may preempt another — both one that hasn't
        # reserved yet (inner state check) and one that already did (final
        # filter): victims never reach the decode batch.
        decodable = []
        for r in plan.decode:
            if r.state is not RequestState.RUNNING:
                continue
            if self._reserve_next_page(r):
                decodable.append(r)
        decodable = [r for r in decodable if r.state is RequestState.RUNNING]
        if decodable:
            touched = sorted(
                set(self._last_touched)
                | {p for r in decodable for p in r.pages}
            )
            self._last_touched = touched
            if self._paged_fn is not None:
                # fused path: the kernel repairs fatal lanes on read and IS
                # the detector — decode first, then scrub the resident pool
                # pages its per-page counts flagged (reactive write-back)
                t_dec = time.perf_counter()
                page_counts, counts = self._decode_paged(decodable, emitted)
                self.stage_wall_s["decode"] += time.perf_counter() - t_dec
                self._flush_lane(
                    page_counts, counts, set(touched) | {self.pool.null_page}
                )
            else:
                t_rep = time.perf_counter()
                self._stream = self.repair.repair_step(touched, self._stream)
                self.stage_wall_s["repair"] += time.perf_counter() - t_rep
                t_dec = time.perf_counter()
                self._decode(decodable, emitted)
                self.stage_wall_s["decode"] += time.perf_counter() - t_dec
            for req in decodable:
                if self._maybe_finish(req):
                    finished.append(req.rid)

        # (5) background sweep tick
        t_rep = time.perf_counter()
        self._stream = self.repair.sweep_step(t, self._stream)
        self.stage_wall_s["repair"] += time.perf_counter() - t_rep

        # (6) autopilot guard: close the observation window; a trip swapped
        # the pool RuleSet, so the fused executables that closed over the
        # old rules' detectors/fills must be rebuilt (the gathered _step_fn
        # is rules-independent — the engine space never scrubs in-step)
        if self.guard is not None:
            t_grd = time.perf_counter()
            decisions = self.guard.tick()
            if decisions:
                self.autopilot_trips += len(decisions)
                self.paged_plan = (
                    _paged_decode_plan(
                        self.model, self.space, self.pool, self.cfg
                    )
                    if self.cfg.paged_decode == "auto" else None
                )
                self._paged_fn = (
                    self._build_paged_step(self.paged_plan)
                    if self.paged_plan is not None else None
                )
                self._prefill_fn = (
                    self._build_paged_prefill_step(self.paged_plan)
                    if self.paged_plan is not None and self.paged_plan.prefill
                    else None
                )
                # a trip may have forced the gathered fallback — flush any
                # deferred counters before the fused path goes away
                self._desync = (
                    self.cfg.drain_interval > 0 and self._paged_fn is not None
                )
                if not self._desync:
                    self.drain()
            self.stage_wall_s["guard"] += time.perf_counter() - t_grd

        if self._desync:
            self._steps_since_drain += 1
        self._t += 1
        for rid, toks in emitted.items():
            self.tokens_emitted += len(toks)
        return {"t": t, "emitted": emitted, "finished": finished}

    def run(self, max_idle_steps: int = 100) -> Dict[int, Dict[str, Any]]:
        """Drive the engine until every queued request finishes.  Long
        workloads run as many steps as they need; the guard fires only on
        genuine stalls (``max_idle_steps`` consecutive steps emitting and
        finishing nothing)."""
        idle = 0
        while self.has_work:
            out = self.step()
            idle = 0 if (out["emitted"] or out["finished"]) else idle + 1
            if idle > max_idle_steps:
                raise RuntimeError(
                    f"engine made no progress in {max_idle_steps} steps"
                )
        self.drain()        # park nothing: scrub what the last window flagged
        return self.results

    # ----------------------------------------------------- stats drain
    def _note_host_sync(self) -> None:
        self.n_host_syncs += 1

    def _host(self, x) -> np.ndarray:
        """Blocking device→host readback — every hot-path sync funnels
        through here so ``metrics()["n_host_syncs"]`` audits them all."""
        self.n_host_syncs += 1
        return np.asarray(x)

    def _flush_lane(self, page_counts, counts, covered) -> None:
        """One fused lane's kernel counters.  Lockstep: read both vectors
        back now and run the reactive pass.  Desync: fold them into the
        resident pending accumulator — ONE concatenated device array, so a
        later drain costs a single readback no matter how many lanes and
        steps it covers."""
        if page_counts is None:
            return
        if self._desync:
            pending = jnp.concatenate(
                [jnp.asarray(page_counts, jnp.int32),
                 jnp.asarray(counts, jnp.int32)]
            )
            self._pending = (
                pending if self._pending is None else self._pending + pending
            )
            self._pending_covered |= set(covered)
            return
        pc = self._host(page_counts)
        self.kernel_counts += self._host(counts).astype(np.int64)
        t0 = time.perf_counter()
        self._stream = self.repair.repair_counts(pc, covered, self._stream)
        self.stage_wall_s["repair"] += time.perf_counter() - t0

    def _resolve_attr(self) -> None:
        """Charge the per-page ledger with the event deltas a drain-time
        scrub deferred (device scalars by now long computed)."""
        attrs, self._pending_attr = self._pending_attr, []
        for pages, delta in attrs:
            d = int(self._host(delta))
            if d > 0:
                self.pool.attribute(pages, d)

    def _drain_pending(self) -> None:
        """One deferred drain: resolve the previous drain's attribution,
        read the whole pending accumulator back in ONE sync, and scrub the
        union of flagged pages (its own attribution deferred in turn)."""
        self._resolve_attr()
        self._steps_since_drain = 0
        if self._pending is None:
            return
        pend = self._host(self._pending)
        n_rows = self.cfg.n_pages + 1
        page_counts, counts = pend[:n_rows], pend[n_rows:]
        self.kernel_counts += counts.astype(np.int64)
        covered = self._pending_covered
        self._pending = None
        self._pending_covered = set()
        t0 = time.perf_counter()
        self._stream = self.repair.repair_counts(
            page_counts, covered, self._stream, defer=self._pending_attr
        )
        self.stage_wall_s["repair"] += time.perf_counter() - t0

    def drain(self) -> None:
        """Flush every deferred readback: the pending kernel counters, the
        reactive scrub they drive, and that scrub's ledger attribution.
        ``metrics()`` and the end of ``run()`` call this; a lockstep
        (``drain_interval == 0``) engine no-ops."""
        self._drain_pending()
        self._resolve_attr()

    # -------------------------------------------------------------- internals
    def _build_paged_step(self, spec: _PagedDecodePlan):
        """The fused decode executable: model paged step + greedy readout +
        per-page fatal counts scatter-added over the block tables.  The pool
        tree is donated — the in-place write-back of the one resident."""
        model, n_rows = self.model, self.cfg.n_pages + 1
        split_k = self._split_k
        shard = self._kernel_shard

        def paged_step(params, pool_tree, batch, bt, pos, stats):
            logits, pool_tree, slot_counts, counts = model.serve_step_paged(
                params, pool_tree, batch, bt, pos,
                detectors=spec.detectors, fills=spec.fills, split_k=split_k,
                shard=shard,
            )
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
            page_counts = jnp.zeros((n_rows,), jnp.int32).at[bt].add(
                slot_counts
            )
            return nxt, pool_tree, page_counts, counts, stats

        return jax.jit(paged_step, donate_argnums=(1,))

    def _build_paged_prefill_step(self, spec: _PagedDecodePlan):
        """The fused prefill executable: chunked-q paged prefill + greedy
        readout at the chunk's last valid row + per-page fatal counts
        scatter-added over the block table.  One compiled executable per
        distinct chunk width (``q_len`` is a traced operand — ragged tails
        share the executable with full chunks)."""
        model, n_rows = self.model, self.cfg.n_pages + 1
        shard = self._kernel_shard

        def prefill_step(params, pool_tree, batch, bt, q_start, q_len, stats):
            logits, pool_tree, slot_counts, counts = model.prefill_paged(
                params, pool_tree, batch, bt, q_start, q_len,
                detectors=spec.detectors, fills=spec.fills,
                shard=shard,
            )
            last = jnp.maximum(q_len - 1, 0)
            nxt = jnp.argmax(
                jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0],
                axis=-1,
            ).astype(jnp.int32)
            page_counts = jnp.zeros((n_rows,), jnp.int32).at[bt].add(
                slot_counts
            )
            return nxt, pool_tree, page_counts, counts, stats

        return jax.jit(prefill_step, donate_argnums=(1,))

    def _reserve_next_page(self, req: Request) -> bool:
        """Point ``req.pos`` at this step's write position and make sure its
        block table covers it (growing/preempting under page pressure)."""
        req.pos = req.n_context - 1
        return self.sched.ensure_capacity(req)

    def _prefill(self, req: Request, emitted: Dict[int, List[int]]) -> None:
        """One batched prefill: the (re-)prefill context in one
        ``Model.prefill`` call over the request's gathered pages.  A cache
        hit prefills only the *suffix* — the matched prefix's KV is already
        resident in the shared (and CoW-forked) pages, so the pass starts
        at cache position ``req.cached_tokens``."""
        toks = req.prefill_tokens()
        n_cached = req.cached_tokens
        bt = self.pool.block_table(req.pages)[None, :]
        view = self.pool.gather(bt)
        tokens = jnp.asarray([toks[n_cached:]], jnp.int32)
        nxt, _, view, self._stream = self._step_fn(
            self.params, view, {"tokens": tokens},
            jnp.asarray(n_cached, jnp.int32), self._stream,
        )
        self.pool.scatter(view, bt)
        req.pos = len(toks)
        self.prefill_tokens_saved += n_cached
        if req.n_preempted:
            # every non-cached token of a post-preemption re-prefill is
            # work the engine already did once — the recompute bill the
            # tier swap exists to avoid
            self.prefill_tokens_recomputed += len(toks) - n_cached
        tok = int(self._host(nxt)[0])
        req.tokens.append(tok)
        emitted.setdefault(req.rid, []).append(tok)

    def _prefill_paged(
        self, req: Request, emitted: Dict[int, List[int]]
    ) -> Tuple[jax.Array, jax.Array, bool]:
        """One fused prompt chunk straight off the pool: write the chunk's
        K/V into the request's pages and attend via the chunked-q paged
        kernel — zero full-view copies.  ``prefill_chunk == 0`` consumes
        the whole remaining context in one chunk.  Returns the kernel's
        per-page fatal counts and AT_* counter vector as DEVICE arrays
        (the caller's lane flush decides when to read them back), plus
        whether the prefill completed (the first generated token is
        emitted only then — greedy readout at the last prompt position,
        same as the gathered path)."""
        toks = req.prefill_tokens()
        start = req.cached_tokens + req.prefill_pos
        rest = toks[start:]
        # static chunk width: a short tail pads up rather than retracing
        width = len(rest) if self.cfg.prefill_chunk == 0 else self.cfg.prefill_chunk
        chunk = rest[:width]
        q_len = len(chunk)
        padded = chunk + [0] * (width - q_len)
        bt = self.pool.block_table(req.pages)[None, :]
        nxt, self.pool.tree, page_counts, counts, self._stream = (
            self._prefill_fn(
                self.params, self.pool.tree,
                {"tokens": jnp.asarray([padded], jnp.int32)},
                jnp.asarray(bt), jnp.asarray([start], jnp.int32),
                jnp.asarray([q_len], jnp.int32), self._stream,
            )
        )
        req.prefill_pos += q_len
        done = start + q_len >= len(toks)
        if done:
            req.pos = len(toks)
            req.prefill_pos = None
            self.prefill_tokens_saved += req.cached_tokens
            if req.n_preempted:
                self.prefill_tokens_recomputed += len(toks) - req.cached_tokens
            tok = int(self._host(nxt)[0])
            req.tokens.append(tok)
            emitted.setdefault(req.rid, []).append(tok)
        return page_counts, counts, done

    def _decode_batch(
        self, reqs: List[Request]
    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """The static-shape decode batch: block tables, tokens, positions."""
        B, M = self.cfg.max_batch, self.cfg.max_pages_per_request
        bt = np.full((B, M), self.pool.null_page, np.int32)
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        for req in reqs:
            bt[req.slot] = self.pool.block_table(req.pages)
            tokens[req.slot, 0] = req.last_token
            pos[req.slot] = req.pos
        return bt, tokens, pos

    def _emit(self, reqs, nxt, emitted) -> None:
        nxt = self._host(nxt)
        for req in reqs:
            tok = int(nxt[req.slot])
            req.tokens.append(tok)
            req.pos += 1
            emitted.setdefault(req.rid, []).append(tok)

    def _decode(
        self, reqs: List[Request], emitted: Dict[int, List[int]]
    ) -> None:
        """Gathered-view decode (the PR-2 fallback path)."""
        bt, tokens, pos = self._decode_batch(reqs)
        view = self.pool.gather(bt)
        nxt, _, view, self._stream = self._step_fn(
            self.params, view, {"tokens": jnp.asarray(tokens)},
            jnp.asarray(pos), self._stream,
        )
        self.pool.scatter(view, bt)
        self._emit(reqs, nxt, emitted)

    def _decode_paged(
        self, reqs: List[Request], emitted: Dict[int, List[int]]
    ) -> Tuple[jax.Array, jax.Array]:
        """Fused decode straight off the pool: zero full-view copies.  The
        donated pool tree is replaced in place; returns the kernel's
        per-page fatal counts and AT_* counter vector as DEVICE arrays
        (the reactive detector's input — read back by the lane flush or a
        later drain, never here)."""
        bt, tokens, pos = self._decode_batch(reqs)
        nxt, self.pool.tree, page_counts, counts, self._stream = (
            self._paged_fn(
                self.params, self.pool.tree, {"tokens": jnp.asarray(tokens)},
                jnp.asarray(bt), jnp.asarray(pos), self._stream,
            )
        )
        self._emit(reqs, nxt, emitted)
        return page_counts, counts

    def _maybe_finish(self, req: Request) -> bool:
        if req.done or req.n_context >= self.cfg.max_seq:
            req.truncated = not req.done
            self.sched.finish(req)
            self.results[req.rid] = {
                "tokens": req.prompt + req.tokens,
                "generated": list(req.tokens),
                "n_preempted": req.n_preempted,
                "truncated": req.truncated,
            }
            return True
        return False

    # ----------------------------------------------------------- observation
    def record_kernel(self, counts) -> None:
        """Report a fused-kernel counter vector (``kernels.ops`` int32[8]
        layout): folded into the unified stats and routed back to the pages
        the last decode step touched (they are scrubbed next repair pass)."""
        self.repair.note_kernel(counts, self._last_touched)

    def unified_stats(self) -> stats_lib.Stats:
        """The space's host-side stream (injection flips, kernel counters)
        merged with the engine's functional step stream."""
        return stats_lib.merge(self.space.stats, self._stream)

    def stats_dict(self) -> Dict[str, int]:
        return stats_lib.as_dict(self.unified_stats())

    def rule_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-rule repair counters (README §RepairRule) over every pool
        repair pass this engine ran."""
        return self.space.rule_stats()

    def cache_stats(self) -> Dict[str, Any]:
        """Prefix-cache observation counters (``{"enabled": False}`` when
        the cache is off)."""
        out: Dict[str, Any] = {
            "enabled": self.cache is not None,
            "prefill_tokens_saved": self.prefill_tokens_saved,
        }
        if self.cache is not None:
            out.update(self.cache.stats())
        return out

    def tier_stats(self) -> Dict[str, Any]:
        """Tiered-KV observation counters (``{"enabled": False}`` when
        ``host_pages == 0``): swap traffic, the per-tier boundary-scrub
        byte ledger, and how often a full host store forced the recompute
        fallback."""
        out: Dict[str, Any] = {
            "enabled": self.tiers is not None,
            "swap_policy": self.cfg.swap_policy,
            "n_swap_preemptions": self.sched.n_swap_preemptions,
            "prefill_tokens_recomputed": self.prefill_tokens_recomputed,
        }
        if self.tiers is not None:
            out.update(self.tiers.stats())
        return out

    def metrics(self) -> Dict[str, Any]:
        self.drain()        # metrics reflect a fully flushed engine
        toks = max(self.tokens_emitted, 1)
        steps = max(self._t, 1)
        return {
            "tokens_emitted": self.tokens_emitted,
            "n_host_syncs": self.n_host_syncs,
            "host_syncs_per_step": self.n_host_syncs / steps,
            "drain_interval": self.cfg.drain_interval,
            "sharded_kernels": self._kernel_shard is not None,
            "stage_wall_s": dict(self.stage_wall_s),
            "prefill_tokens_saved": self.prefill_tokens_saved,
            "prefill_tokens_recomputed": self.prefill_tokens_recomputed,
            "n_preemptions": self.sched.n_preemptions,
            "n_swap_preemptions": self.sched.n_swap_preemptions,
            "scrubbed_bytes": self.pool.scrubbed_bytes,
            "scrub_calls": self.pool.scrub_calls,
            "scrubbed_bytes_per_token": self.pool.scrubbed_bytes / toks,
            "paged_decode": self._paged_fn is not None,
            "paged_prefill": self._prefill_fn is not None,
            "split_k": self._split_k,
            "pool_gathers": self.pool.n_gathers,
            "pool_scatters": self.pool.n_scatters,
            "paged_kernel_events": int(self.kernel_counts[6]),  # AT_EV_TOTAL
            "autopilot_trips": self.autopilot_trips,
            **self.repair.summary(),
        }
