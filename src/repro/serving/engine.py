"""`Engine` — continuous batching over the paged approximate-memory KV pool.

The facade every later scaling PR (sharded pools, async decode, multi-tenant
QoS) builds on:

    engine = Engine(model, params, ServingConfig(...))
    rid = engine.add_request(prompt_ids, max_new=32)
    while engine.has_work:
        out = engine.step()          # {"emitted": {rid: [tok]}, "finished"}
    engine.results[rid]["tokens"]    # prompt + generated

One engine step is: (1) one approximate-memory window strikes the resident
pool (simulation boundary, ``ber > 0`` only); (2) admission + batched
prefill of newly admitted requests (one ``Model.prefill`` call each — the
whole prompt in one pass); (3) the reactive repair pass over exactly the
pages this step will touch, then one jitted decode step over the static
slot batch (per-request positions — requests at different depths share the
executable); (4) the background sweep tick.  All repair/flip/kernel events
land in the engine's unified stats stream.

Static shapes: the decode batch is always ``(max_batch, 1)`` tokens over
``(max_batch, max_pages_per_request)`` block tables (empty slots run the
null page at position 0 and are ignored), so the whole serving run compiles
exactly one decode executable; prefill compiles one executable per distinct
prompt length.

``launch.serve.generate(..., paged=True)`` is the single-request degenerate
case of this engine.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import stats as stats_lib
from ..launch.serve import build_serve_step, serve_space
from ..runtime import ApproxSpace
from .config import ServingConfig
from .pool import PagedKVPool
from .repair import PageRepairManager
from .scheduler import Request, RequestState, Scheduler


def engine_space(model: Any) -> ApproxSpace:
    """The engine's default runtime: the serving space (memory-forced,
    NaN/Inf-only, no boundary scrub — the page repair manager owns every
    scrub), but private to this engine so stats streams stay isolated."""
    return serve_space(model, scrub_every=0, memoize=False)


class Engine:
    """Continuous-batching serving engine (add_request / step / run)."""

    def __init__(
        self,
        model: Any,
        params: Any,
        cfg: Optional[ServingConfig] = None,
        space: Optional[ApproxSpace] = None,
    ):
        if not model.supports_paged_kv:
            raise NotImplementedError(
                f"{type(model).__name__} has no paged KV layout — the engine "
                "serves attention-cache architectures"
            )
        if not model.supports_batched_prefill:
            raise NotImplementedError(
                f"{type(model).__name__} cannot batched-prefill — the engine "
                "consumes whole prompts in one pass"
            )
        self.model = model
        self.cfg = cfg or ServingConfig()
        self.space = space or engine_space(model)
        # mesh-native serving (ROADMAP leftover): when the engine's space
        # carries a mesh, model params are device_put onto their logical-axis
        # shardings — the same `serve_shardings` placement jit_serve_step
        # uses — instead of staying replicated alongside the sharded pool.
        self.params_shardings = None
        if self.space.mesh is not None:
            from ..distributed import sharding as sh  # deferred: keep layering thin

            rules = self.space.rules or sh.rules_for_mesh(self.space.mesh)
            self.params_shardings = sh.tree_shardings(
                model.abstract_params(), model.logical_axes(),
                self.space.mesh, rules,
            )
            params = jax.device_put(params, self.params_shardings)
        self.params = params
        self.pool = PagedKVPool(model, self.space, self.cfg)
        self.sched = Scheduler(self.pool, self.cfg)
        self.repair = PageRepairManager(self.pool, self.space, self.cfg)
        # the one greedy step builder (shared with launch.serve.generate, so
        # the engine-vs-generate token-parity contract cannot drift)
        self._step_fn = jax.jit(
            self.space.wrap_serve_step(build_serve_step(model))
        )
        self._stream = stats_lib.zeros()
        self._requests: Dict[int, Request] = {}
        self.results: Dict[int, Dict[str, Any]] = {}
        self._next_rid = 0
        self._t = 0
        self._inject_key = jax.random.PRNGKey(self.cfg.seed + 1)
        self._last_touched: List[int] = []
        self.tokens_emitted = 0

    # ------------------------------------------------------------------ admit
    def add_request(self, prompt: Sequence[int], max_new: int) -> int:
        """Queue one generation request; returns its id."""
        prompt = [int(t) for t in np.asarray(prompt).reshape(-1)]
        if not prompt:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1")
        rid = self._next_rid
        self._next_rid += 1
        req = Request(rid=rid, prompt=prompt, max_new=int(max_new))
        self._requests[rid] = req
        self.sched.add(req)
        return rid

    @property
    def has_work(self) -> bool:
        return self.sched.has_work

    # ------------------------------------------------------------------- step
    def step(self) -> Dict[str, Any]:
        """One engine step; returns the tokens emitted and requests finished."""
        t = self._t
        emitted: Dict[int, List[int]] = {}
        finished: List[int] = []
        # kernel-counter routing targets the pages THIS step touches; stale
        # entries could point at pages since freed and reallocated
        self._last_touched = []

        # (1) simulation boundary: one window of flips strikes the pool —
        # the same stats-threading injection entry point the train loop's
        # inject_state uses (flips land in the engine's functional stream,
        # donated pool buffers, compiled per pool layout)
        if self.cfg.ber > 0.0:
            self._inject_key, k = jax.random.split(self._inject_key)
            self.pool.tree, self._stream = self.space.inject(
                self.pool.tree, k, self.cfg.ber,
                stats=self._stream, donate=True,
            )

        # (2) admission + batched prefill (admitted pages are freshly zeroed,
        # but the null padding page rides along — one repair pass covers
        # every admission before any prefill consumes its pages)
        prefilled = set()
        admitted = self.sched.admit()
        if admitted:
            pages = sorted({p for r in admitted for p in r.pages})
            self._stream = self.repair.repair_step(pages, self._stream)
            self._last_touched = pages
        for req in admitted:
            self._prefill(req, emitted)
            prefilled.add(req.rid)
            if req.state is RequestState.RUNNING and self._maybe_finish(req):
                finished.append(req.rid)

        # (3) reactive repair over the touched pages, then one decode step.
        # Reserving a page for one request may preempt another — both one
        # that hasn't reserved yet (inner state check) and one that already
        # did (final filter): victims never reach the decode batch.
        decodable = []
        for r in list(self.sched.running):
            if r.rid in prefilled or r.state is not RequestState.RUNNING:
                continue
            if self._reserve_next_page(r):
                decodable.append(r)
        decodable = [r for r in decodable if r.state is RequestState.RUNNING]
        if decodable:
            touched = sorted(
                set(self._last_touched)
                | {p for r in decodable for p in r.pages}
            )
            self._last_touched = touched
            self._stream = self.repair.repair_step(touched, self._stream)
            self._decode(decodable, emitted)
            for req in decodable:
                if self._maybe_finish(req):
                    finished.append(req.rid)

        # (4) background sweep tick
        self._stream = self.repair.sweep_step(t, self._stream)

        self._t += 1
        for rid, toks in emitted.items():
            self.tokens_emitted += len(toks)
        return {"t": t, "emitted": emitted, "finished": finished}

    def run(self, max_idle_steps: int = 100) -> Dict[int, Dict[str, Any]]:
        """Drive the engine until every queued request finishes.  Long
        workloads run as many steps as they need; the guard fires only on
        genuine stalls (``max_idle_steps`` consecutive steps emitting and
        finishing nothing)."""
        idle = 0
        while self.has_work:
            out = self.step()
            idle = 0 if (out["emitted"] or out["finished"]) else idle + 1
            if idle > max_idle_steps:
                raise RuntimeError(
                    f"engine made no progress in {max_idle_steps} steps"
                )
        return self.results

    # -------------------------------------------------------------- internals
    def _reserve_next_page(self, req: Request) -> bool:
        """Point ``req.pos`` at this step's write position and make sure its
        block table covers it (growing/preempting under page pressure)."""
        req.pos = req.n_context - 1
        return self.sched.ensure_capacity(req)

    def _prefill(self, req: Request, emitted: Dict[int, List[int]]) -> None:
        """One batched prefill: the whole (re-)prefill context in one
        ``Model.prefill`` call over the request's gathered pages."""
        toks = req.prefill_tokens()
        bt = self.pool.block_table(req.pages)[None, :]
        view = self.pool.gather(bt)
        tokens = jnp.asarray([toks], jnp.int32)
        nxt, _, view, self._stream = self._step_fn(
            self.params, view, {"tokens": tokens},
            jnp.zeros((), jnp.int32), self._stream,
        )
        self.pool.scatter(view, bt)
        req.pos = len(toks)
        tok = int(np.asarray(nxt)[0])
        req.tokens.append(tok)
        emitted.setdefault(req.rid, []).append(tok)

    def _decode(
        self, reqs: List[Request], emitted: Dict[int, List[int]]
    ) -> None:
        B, M = self.cfg.max_batch, self.cfg.max_pages_per_request
        bt = np.full((B, M), self.pool.null_page, np.int32)
        tokens = np.zeros((B, 1), np.int32)
        pos = np.zeros((B,), np.int32)
        for req in reqs:
            bt[req.slot] = self.pool.block_table(req.pages)
            tokens[req.slot, 0] = req.last_token
            pos[req.slot] = req.pos
        view = self.pool.gather(bt)
        nxt, _, view, self._stream = self._step_fn(
            self.params, view, {"tokens": jnp.asarray(tokens)},
            jnp.asarray(pos), self._stream,
        )
        self.pool.scatter(view, bt)
        nxt = np.asarray(nxt)
        for req in reqs:
            tok = int(nxt[req.slot])
            req.tokens.append(tok)
            req.pos += 1
            emitted.setdefault(req.rid, []).append(tok)

    def _maybe_finish(self, req: Request) -> bool:
        if req.done or req.n_context >= self.cfg.max_seq:
            req.truncated = not req.done
            self.sched.finish(req)
            self.results[req.rid] = {
                "tokens": req.prompt + req.tokens,
                "generated": list(req.tokens),
                "n_preempted": req.n_preempted,
                "truncated": req.truncated,
            }
            return True
        return False

    # ----------------------------------------------------------- observation
    def record_kernel(self, counts) -> None:
        """Report a fused-kernel counter vector (``kernels.ops`` int32[8]
        layout): folded into the unified stats and routed back to the pages
        the last decode step touched (they are scrubbed next repair pass)."""
        self.repair.note_kernel(counts, self._last_touched)

    def unified_stats(self) -> stats_lib.Stats:
        """The space's host-side stream (injection flips, kernel counters)
        merged with the engine's functional step stream."""
        return stats_lib.merge(self.space.stats, self._stream)

    def stats_dict(self) -> Dict[str, int]:
        return stats_lib.as_dict(self.unified_stats())

    def rule_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-rule repair counters (README §RepairRule) over every pool
        repair pass this engine ran."""
        return self.space.rule_stats()

    def metrics(self) -> Dict[str, Any]:
        toks = max(self.tokens_emitted, 1)
        return {
            "tokens_emitted": self.tokens_emitted,
            "n_preemptions": self.sched.n_preemptions,
            "scrubbed_bytes": self.pool.scrubbed_bytes,
            "scrub_calls": self.pool.scrub_calls,
            "scrubbed_bytes_per_token": self.pool.scrubbed_bytes / toks,
            **self.repair.summary(),
        }
