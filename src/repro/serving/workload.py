"""Production-shaped serving workloads: seed-deterministic arrival traces.

The traffic harness (``benchmarks/traffic.py``) replays an *arrival trace*
against a live engine — Poisson arrivals at a configurable rate, a mixed
short/long prompt-length population, per-request output budgets, and an
optional burst (every burst request lands on the same step, the
preemption-storm shape the scheduler's fairness tests lean on).

Everything is derived from ONE ``numpy`` generator seeded by
``WorkloadConfig.seed``: regenerating from the same config yields the
identical trace, bit for bit, so two engines (sharded vs single-device,
desynchronized vs lockstep) can replay the same traffic and be compared
token-for-token.  No clock anywhere — "time" is the engine step index.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request of the trace: lands at engine step ``step``."""

    step: int
    prompt: Tuple[int, ...]
    max_new: int


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Shape of the synthetic traffic.

    n_requests       trace length (burst arrivals come on top)
    arrival_rate     mean arrivals per engine step (Poisson process:
                     exponential inter-arrival gaps, floored to steps)
    prompt_len       inclusive (lo, hi) token-count range of short prompts
    long_prompt_len  inclusive range of the long-prompt population
    long_frac        fraction of prompts drawn from the long range — the
                     bimodal prompt mix that makes chunked prefill and
                     admission control actually work for a living
    output_len       inclusive (lo, hi) range of per-request ``max_new``
    vocab            token ids are drawn uniformly from [1, vocab)
    burst_at         step at which ``burst_n`` extra arrivals land at once
                     (-1 disables) — the preemption-storm knob
    burst_n          size of the burst
    seed             the one generator seed everything derives from
    """

    n_requests: int = 32
    arrival_rate: float = 1.0
    prompt_len: Tuple[int, int] = (2, 16)
    long_prompt_len: Tuple[int, int] = (24, 48)
    long_frac: float = 0.0
    output_len: Tuple[int, int] = (4, 24)
    vocab: int = 97
    burst_at: int = -1
    burst_n: int = 0
    seed: int = 0

    def __post_init__(self):
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1 ({self.n_requests})")
        if self.arrival_rate <= 0.0:
            raise ValueError(
                f"arrival_rate must be > 0 ({self.arrival_rate})"
            )
        for name in ("prompt_len", "long_prompt_len", "output_len"):
            lo, hi = getattr(self, name)
            if not 1 <= lo <= hi:
                raise ValueError(f"bad {name} range ({lo}, {hi})")
        if not 0.0 <= self.long_frac <= 1.0:
            raise ValueError(f"long_frac must lie in [0, 1] ({self.long_frac})")
        if self.vocab < 2:
            raise ValueError(f"vocab must be >= 2 ({self.vocab})")
        if self.burst_n < 0:
            raise ValueError(f"burst_n must be >= 0 ({self.burst_n})")


def _draw_request(rng: np.random.Generator, cfg: WorkloadConfig, step: int
                  ) -> Arrival:
    lo, hi = (
        cfg.long_prompt_len
        if cfg.long_frac > 0.0 and rng.random() < cfg.long_frac
        else cfg.prompt_len
    )
    n = int(rng.integers(lo, hi + 1))
    prompt = tuple(int(t) for t in rng.integers(1, cfg.vocab, size=n))
    max_new = int(rng.integers(cfg.output_len[0], cfg.output_len[1] + 1))
    return Arrival(step=step, prompt=prompt, max_new=max_new)


def generate_arrivals(cfg: WorkloadConfig) -> List[Arrival]:
    """The trace, sorted by step.  Deterministic in ``cfg`` alone: one
    ``default_rng(cfg.seed)`` drives inter-arrival gaps and request shapes
    in a fixed draw order, so equal configs give bit-equal traces."""
    rng = np.random.default_rng(cfg.seed)
    arrivals: List[Arrival] = []
    t = 0.0
    for _ in range(cfg.n_requests):
        t += rng.exponential(1.0 / cfg.arrival_rate)
        arrivals.append(_draw_request(rng, cfg, int(t)))
    if cfg.burst_at >= 0 and cfg.burst_n > 0:
        for _ in range(cfg.burst_n):
            arrivals.append(_draw_request(rng, cfg, cfg.burst_at))
    arrivals.sort(key=lambda a: a.step)     # stable: burst order preserved
    return arrivals
