"""`ServingConfig` — the knob surface of the continuous-batching engine.

One frozen dataclass owns the pool geometry (pages × page size), the batch
shape (decode slots × block-table width — both static so every decode step
hits one compiled executable), the repair granularity, the background-sweep
cadence, and the simulation BER.  README §Serving engine documents each
field; the invariants below keep the scheduler deadlock-free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

_REPAIR_MODES = ("page", "whole", "off")
_PAGED_DECODE = ("auto", "off")
_PAGED_PREFILL = ("auto", "off")
_SWAP_POLICIES = ("swap", "recompute")

# split-K auto heuristic: engage flash decoding once the block-table walk
# is at least this many pages wide (below it the serial walk wins — the
# merge stage costs more than it saves)
_SPLIT_K_MIN_PAGES = 8


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Pool / scheduler / repair configuration for the serving engine.

    Pool geometry:
      page_size              tokens per KV page (the repair + accounting unit)
      n_pages                pool capacity (one extra null page is allocated
                             internally for block-table padding)

    Batch shape (static — one compiled decode step for the whole run):
      max_batch              concurrent decode slots
      max_pages_per_request  block-table width; caps a request's context at
                             ``max_seq = page_size * max_pages_per_request``

    Repair:
      repair                 "page"  — scrub only the faulted pages among
                                       those the step touched (the paper's
                                       reactive design at page granularity)
                             "whole" — scrub the entire pool whenever any
                                       touched page faulted (the pre-engine
                                       scrub_cache baseline)
                             "off"   — no repair (zero-BER / oracle runs)
      sweep_interval         background low-rate sweep cadence in engine
                             steps (0 disables); catches flips in cold pages
                             no step touches.  This is the demoted role of
                             the old whole-cache ``ScrubSchedule``.
      sweep_pages            pages repaired per background sweep tick
      paged_decode           "auto" — decode straight off the pool through
                                      the fused paged-attention kernel when
                                      the model + pool rules allow it (zero
                                      full-view copies; README §Serving
                                      engine)
                             "off"  — always use the gathered-view decode
                                      (the PR-2 baseline; bench comparison
                                      arm)
      paged_prefill          "auto" — admission prefills straight off the
                                      pool through the chunked-q paged
                                      kernel whenever the fused decode plan
                                      engages (zero full-view copies at
                                      admission too)
                             "off"  — gathered-view prefill (comparison arm)
      prefill_chunk          vllm-style chunked prefill: at most this many
                             prompt tokens per request per engine step, so
                             long admissions interleave with decode instead
                             of stalling it (0 = whole remaining prompt in
                             one chunk).  Only the fused paged prefill
                             chunks; the gathered fallback always prefills
                             whole.
      split_k                split-K flash decoding (``SNIPPETS.md`` 3):
                             0 — auto: split the page walk once the block
                                 table is >= 8 pages wide, into the largest
                                 divisor of ``max_pages_per_request`` that
                                 keeps >= 2 pages per split
                             1 — always serial (comparison arm)
                             N — split into (the largest divisor of the
                                 block-table width <=) N grid cells
      drain_interval         desynchronized stats drain (README §Serving
                             engine — "Sharded decode & load testing"):
                             0 — legacy lockstep: every fused lane reads its
                                 per-page fatal counts back to the host and
                                 scrubs within the same engine step
                             N — the fused kernels' counter vectors stay
                                 resident on device and accumulate across
                                 steps; every N steps ONE readback drains
                                 them and the reactive scrub covers the
                                 union of flagged pages.  Token streams are
                                 unchanged (the fused kernels repair on
                                 read with a value-independent fill, so
                                 deferring the HBM scrub never changes what
                                 attention consumes); ``N == 1`` replays
                                 the legacy scrub trajectory exactly while
                                 still batching each step's readbacks into
                                 one.  Requires the fused paged path;
                                 ignored on the gathered fallback.

    Prefix cache (README §Serving engine):
      prefix_cache           share KV pages between requests with a common
                             token prefix: admit matches the longest cached
                             prefix, prefills only the suffix, and finished
                             prefixes stay resident (refcounted, copy-on-
                             write forks at page-interior divergence)
      max_cached_pages       cap on pages the cache may keep referenced
                             (0 = no cap beyond the pool itself); LRU
                             eviction reclaims cache-only pages when the
                             cap — or an allocation — demands it
      dwell_threshold        expected-fault gate for scrub-on-reuse: a hit
                             page is scrubbed before re-sharing only when
                             ``ApproxConfig.expected_faults(page_bytes,
                             dwell_steps, ber)`` reaches this value.  ≤ 0
                             means scrub on EVERY hit (the always-scrub
                             comparison arm in benchmarks/prefix_cache.py)

    Tiered KV (README §Serving engine — "Tiered KV"):
      host_pages             capacity of the host-memory exact tier in pages
                             (0 disables tiering entirely).  May exceed
                             ``n_pages`` — host DRAM is the cheap tier.
      swap_policy            "swap"      — preemption parks the victim's
                                           pages in the host tier (boundary
                                           scrub on the way out) and swap-in
                                           restores them on re-admission;
                                           recompute survives only as the
                                           host-store-full fallback
                             "recompute" — preemption always drops pages and
                                           re-prefills (the pre-tier
                                           behavior; comparison arm).  The
                                           prefix cache still demotes cold
                                           entries when ``host_pages > 0``.

    Simulation:
      ber                    bit-error rate of one approximate-memory window
                             (applied to the pool between engine steps;
                             0 disables injection)
      seed                   PRNG seed for injection + pool init
    """

    page_size: int = 16
    n_pages: int = 64
    max_batch: int = 8
    max_pages_per_request: int = 8

    repair: str = "page"
    sweep_interval: int = 0
    sweep_pages: int = 4
    paged_decode: str = "auto"
    paged_prefill: str = "auto"
    prefill_chunk: int = 0
    split_k: int = 0
    drain_interval: int = 0

    prefix_cache: bool = False
    max_cached_pages: int = 0
    dwell_threshold: float = 1.0

    host_pages: int = 0
    swap_policy: str = "swap"

    ber: float = 0.0
    seed: int = 0

    # Online autopilot guard (README §Autopilot): an ``AutopilotConfig``
    # (runtime.config) arms the engine's per-window fault monitor — drifting
    # pool rule groups are tightened (stricter detector, then exact
    # demotion) against the profiled expectations.  ``None`` disables it.
    autopilot: Optional[Any] = None

    def __post_init__(self):
        if self.repair not in _REPAIR_MODES:
            raise ValueError(f"bad repair granularity {self.repair!r}")
        if self.paged_decode not in _PAGED_DECODE:
            raise ValueError(f"bad paged_decode mode {self.paged_decode!r}")
        if self.paged_prefill not in _PAGED_PREFILL:
            raise ValueError(f"bad paged_prefill mode {self.paged_prefill!r}")
        if self.prefill_chunk < 0:
            raise ValueError(f"prefill_chunk must be >= 0 ({self.prefill_chunk})")
        if self.split_k < 0:
            raise ValueError(f"split_k must be >= 0 ({self.split_k})")
        if self.drain_interval < 0:
            raise ValueError(
                f"drain_interval must be >= 0 ({self.drain_interval})"
            )
        if self.page_size < 1 or self.n_pages < 1:
            raise ValueError("page_size and n_pages must be >= 1")
        if self.max_pages_per_request > self.n_pages:
            # a lone request must always be able to make progress — otherwise
            # preemption has no victim and the scheduler deadlocks
            raise ValueError(
                "max_pages_per_request must not exceed n_pages "
                f"({self.max_pages_per_request} > {self.n_pages})"
            )
        if self.swap_policy not in _SWAP_POLICIES:
            raise ValueError(f"bad swap_policy {self.swap_policy!r}")
        if self.host_pages < 0:
            raise ValueError(f"host_pages must be >= 0 ({self.host_pages})")
        if self.max_cached_pages < 0 or self.max_cached_pages > self.n_pages:
            raise ValueError(
                "max_cached_pages must lie in [0, n_pages] "
                f"({self.max_cached_pages} vs {self.n_pages})"
            )

    @property
    def max_seq(self) -> int:
        """Per-request context cap implied by the block-table width."""
        return self.page_size * self.max_pages_per_request

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` cache positions."""
        return -(-n_tokens // self.page_size)

    def resolve_split_k(self) -> int:
        """Grid splits for the decode page walk, resolved against the
        block-table width M.  The kernel requires a divisor of M (each slot
        walked exactly once, or per-page counts would double-charge), so
        both the explicit setting and the auto heuristic round down to the
        largest divisor within their budget."""
        M = self.max_pages_per_request
        if self.split_k == 1:
            return 1
        if self.split_k > 1:
            want = min(self.split_k, M)
        elif M < _SPLIT_K_MIN_PAGES:
            return 1
        else:
            want = M // 2                 # auto: >= 2 pages per split
        return max(d for d in range(1, want + 1) if M % d == 0)
