"""Repair-aware prefix cache: refcounted copy-on-write KV pages with
dwell-time-charged scrub-on-reuse.

Serving workloads share long prompt prefixes (system prompts, few-shot
preambles), and the pool is already page-granular — so finished prefixes
stay *resident*: a hash-of-token-prefix → page index lets a new request
admit onto the longest cached prefix and prefill only its suffix
(vLLM/SGLang-style sharing, flattened: one dict entry per page instead of a
radix tree — exact token tuples are the hash keys, so there are no
collisions to resolve).

The approximate-memory twist is the cache's admission policy.  A cached
page *dwells* under relaxed refresh: every engine step is one injection
window, so its accumulated fault expectation grows linearly with age
(EDEN's refresh→BER relationship, ``ApproxConfig.expected_faults``).  The
pool timestamps each page's last scrub (``PagedKVPool.dwell``); on a cache
hit, scrub-on-reuse runs **only** for pages whose dwell-charged estimate
crosses ``ServingConfig.dwell_threshold`` — the paper's reactive thesis
(repair what is about to be read, when the risk warrants it) turned into a
reuse gate.  The repair itself is the strongest available:

  * full-page entries carry a host **snapshot** of the prefix KV taken at
    insert time (the checkpointed prefix) — scrub-on-reuse restores fatal
    lanes to their exact original bits (``reference_repair_page``);
  * partial tail pages keep changing after insert (their owner still
    appends rows), so they have no stable snapshot — detector-scrub
    (``scrub_pages``) repairs them with the rule's fill instead.

Sharing discipline (all host-side bookkeeping; device work is the engine's):

  refcounts   every cached page holds one pool reference from the cache
              itself, plus one per running request sharing it.  Preemption
              and finish release the request's reference only — a shared
              page can never be reclaimed out from under the cache
              (``PagedKVPool.free`` returns pages to the free list at
              refcount zero, and double-release is a hard error).
  CoW forks   a request diverging *inside* a cached partial page never
              writes the shared copy: ``prepare_hit`` clones the source
              page into the request's first private page and the suffix
              prefill overwrites the clone from the divergence point on.
              Full-page entries need no clone — a sharer's writes always
              land at positions past its cached prefix, i.e. in its own
              private pages.
  LRU         eviction (allocation pressure or ``max_cached_pages``)
              reclaims only *leaf* entries no request references
              (``n_children == 0`` and pool refcount 1): interior chain
              pages stay until their extensions go first, so a cached
              prefix is always a contiguous page run.
  demotion    with a ``TierManager`` (``ServingConfig.host_pages > 0``),
              eviction parks the victim in the host-memory *exact* tier
              before dropping it: full entries stash their insert-time
              snapshot (already exact — no scrub needed), partial tails
              cross the boundary scrub like any device→host move.  A later
              lookup *promotes* parked entries back through the normal
              allocation path (chain order, parents first) — the hit still
              skips the suffix prefill, it just pays one page write instead
              of keeping the page resident.  A full host store degrades to
              the plain drop, a full pool leaves the entry parked.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from ..core import stats as stats_lib
from ..runtime import ApproxSpace
from .config import ServingConfig
from .pool import PagedKVPool

__all__ = ["PrefixCache", "CacheHit"]


@dataclasses.dataclass
class _Entry:
    """One cached page: the KV of one page-worth (or tail-fraction) of a
    token prefix.  ``key`` is the exact token tuple whose KV the page's
    valid rows hold; ``parent`` is the one-page-shorter chain predecessor."""

    key: Tuple[int, ...]
    page: int
    n_tokens: int
    partial: bool
    snapshot: Any                      # host page copy (full entries only)
    parent: Optional[Tuple[int, ...]]
    n_children: int = 0
    last_used: int = 0
    hits: int = 0


@dataclasses.dataclass
class _HostEntry:
    """One cache entry parked in the host tier: the slot holding its page
    row, plus enough metadata to rebuild the resident ``_Entry`` on
    promotion (the chain walk supplies the parent)."""

    key: Tuple[int, ...]
    slot: int
    n_tokens: int
    partial: bool


@dataclasses.dataclass
class CacheHit:
    """A lookup match: ``full`` is the chain of whole-page entries, then
    optionally one ``partial`` tail entry extending it inside a page.
    ``n_tokens`` counts every matched token (full pages + partial rows)."""

    n_tokens: int
    full: Tuple[_Entry, ...]
    partial: Optional[_Entry]


class PrefixCache:
    """Hash-of-token-prefix → page-run index over one ``PagedKVPool``."""

    def __init__(
        self,
        pool: PagedKVPool,
        space: ApproxSpace,
        cfg: ServingConfig,
        tiers: Optional[Any] = None,
    ):
        self.pool = pool
        self.space = space
        self.cfg = cfg
        self.tiers = tiers                        # optional TierManager
        self._entries: Dict[Tuple[int, ...], _Entry] = {}
        self._host_entries: Dict[Tuple[int, ...], _HostEntry] = {}
        # interior fragments of partial tails: token-prefix → owner entry
        # key.  A request diverging *inside* an already-forked page matches
        # the owner's shared rows through one of these and CoW-forks again
        # instead of re-prefilling the whole tail.  Real entries shadow
        # fragments (the resident index is always probed first).
        self._fragments: Dict[Tuple[int, ...], Tuple[int, ...]] = {}
        self._clock = 0
        # observation counters (Engine.cache_stats)
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.inserts = 0
        self.evictions = 0
        self.cow_forks = 0
        self.reuse_scrubs = 0          # detector scrub-on-reuse passes
        self.reuse_ref_repairs = 0     # snapshot reference repairs
        self.reuse_skips = 0           # hits below the dwell threshold
        self.fragment_hits = 0         # partial matched via an interior key
        self.demotions = 0             # evictions parked in the host tier
        self.promotions = 0            # host entries re-materialized on hit

    # ------------------------------------------------------------------ state
    @property
    def cached_pages(self) -> int:
        return len(self._entries)

    def _touch(self, e: _Entry) -> None:
        self._clock += 1
        e.last_used = self._clock

    # ----------------------------------------------------------------- lookup
    def lookup(self, tokens: List[int]) -> Optional[CacheHit]:
        """The longest cached prefix of ``tokens``, capped at
        ``len(tokens) - 1`` — at least one token must remain for the suffix
        prefill to consume (its logits produce the next token).  With a
        tier manager, a miss in the resident index falls through to the
        host tier: parked entries are *promoted* back (chain order, so a
        parent is always resident before its child) and count as hits."""
        toks = tuple(int(t) for t in tokens)
        cap = len(toks) - 1
        pg = self.cfg.page_size
        full: List[_Entry] = []
        k = 1
        while k * pg <= cap:
            key = toks[: k * pg]
            e = self._entries.get(key)
            if e is None:
                e = self._promote(key, k * pg, False, full)
            if e is None or e.partial:
                break
            full.append(e)
            k += 1
        # bounded tail probe: the longest partial entry extending the chain
        # inside the next page (≤ page_size - 1 dict probes).  A miss on
        # the exact key falls through to the fragment index: the owner's
        # page holds valid KV for its first n rows (KV at a row depends
        # only on the tokens up to it, which match), so the hit reuses the
        # owner's page and the suffix prefill overwrites from row n on.
        partial = None
        matched = 0
        lo = len(full) * pg
        for n in range(min(cap, lo + pg - 1), lo, -1):
            key = toks[:n]
            e = self._entries.get(key)
            if e is None:
                e = self._promote(key, n, True, full)
            if e is None:
                owner = self._fragments.get(key)
                if owner is not None:
                    e = self._entries.get(owner)
                    if e is not None and e.partial:
                        self.fragment_hits += 1
            if e is not None and e.partial:
                partial = e
                matched = n
                break
        if not full and partial is None:
            return None
        for e in full:
            self._touch(e)
            e.hits += 1
        if partial is not None:
            self._touch(partial)
            partial.hits += 1
        n_tokens = matched if partial is not None else lo
        return CacheHit(n_tokens=n_tokens, full=tuple(full), partial=partial)

    def _promote(
        self,
        key: Tuple[int, ...],
        n_tokens: int,
        want_partial: bool,
        chain: List[_Entry],
    ) -> Optional[_Entry]:
        """Re-materialize one parked host entry as a resident entry linked
        onto ``chain`` (the already-matched full-page run).  Returns None on
        a genuine miss, a full pool, or cache-capacity pressure — the host
        entry stays parked in the latter two cases."""
        if self.tiers is None:
            return None
        he = self._host_entries.get(key)
        if he is None or he.partial != want_partial:
            return None
        assert he.n_tokens == n_tokens, (he, n_tokens)
        if not self._make_room({e.key for e in chain} | {key}):
            return None
        # a full entry's parked bits ARE its insert-time snapshot — promote
        # them back as the reference for future scrub-on-reuse
        snapshot = None if he.partial else self.tiers.slot_views(he.slot)
        page = self.tiers.promote_page(he.slot)
        if page is None:
            return None
        del self._host_entries[key]
        parent = chain[-1] if chain else None
        e = _Entry(
            key=key,
            page=page,
            n_tokens=he.n_tokens,
            partial=he.partial,
            snapshot=snapshot,
            parent=parent.key if parent is not None else None,
        )
        if parent is not None:
            parent.n_children += 1
        self._entries[key] = e
        if e.partial:
            self._register_fragments(e)
        self._touch(e)
        self.promotions += 1
        return e

    # -------------------------------------------------- interior fragments
    def _fragment_keys(self, e: _Entry):
        lo = (e.n_tokens // self.cfg.page_size) * self.cfg.page_size
        return (e.key[:n] for n in range(lo + 1, e.n_tokens))

    def _register_fragments(self, e: _Entry) -> None:
        """Index every interior prefix of a partial tail.  Two partials
        sharing a fragment race; last insert wins (the loser's rows are a
        miss again — one extra prefill, never a correctness issue)."""
        for key in self._fragment_keys(e):
            self._fragments[key] = e.key

    def _drop_fragments(self, e: _Entry) -> None:
        for key in self._fragment_keys(e):
            if self._fragments.get(key) == e.key:
                del self._fragments[key]

    def note_admit(self, hit: Optional[CacheHit]) -> None:
        """Count one successful admission against the hit/miss ledger (the
        scheduler calls this only when the request actually got its pages,
        so a full pool cannot inflate the miss rate)."""
        if hit is None:
            self.misses += 1
        else:
            self.hits += 1
            self.hit_tokens += hit.n_tokens

    # ------------------------------------------------------- scrub-on-reuse
    def _reuse_scrub(
        self, e: _Entry, stats: stats_lib.Stats
    ) -> stats_lib.Stats:
        """Dwell-gated scrub-on-reuse of one hit page: charge the page's
        dwell (steps since last scrub) to an expected-fault estimate; only
        a crossing estimate pays for repair before the page is re-read.
        ``dwell_threshold <= 0`` scrubs every hit (the always-scrub
        comparison arm)."""
        dwell = self.pool.dwell(e.page)
        est = self.space.config.expected_faults(
            self.pool.page_bytes, dwell, ber=self.cfg.ber
        )
        if self.cfg.dwell_threshold > 0 and est < self.cfg.dwell_threshold:
            self.reuse_skips += 1
            return stats
        if e.snapshot is not None:
            self.reuse_ref_repairs += 1
            return self.pool.reference_repair_page(e.page, e.snapshot, stats)
        self.reuse_scrubs += 1
        return self.pool.scrub_pages([e.page], stats, trigger="reactive")

    def prepare_hit(self, req: Any, stats: stats_lib.Stats) -> stats_lib.Stats:
        """Device work for one admitted cache hit, before its suffix
        prefill: scrub-on-reuse over the matched pages, then the
        copy-on-write fork of a partial tail (scrub the *source* first so
        the clone inherits clean bits and a fresh dwell stamp; the clone's
        rows past the match are overwritten by the suffix prefill).  Must
        run in the same engine phase as admission — the admit-time
        reference on the partial source is released here."""
        hit = req.cache_hit
        req.cache_hit = None
        if hit is None:
            return stats
        for e in hit.full:
            stats = self._reuse_scrub(e, stats)
        if hit.partial is not None:
            stats = self._reuse_scrub(hit.partial, stats)
            dst = req.pages[len(hit.full)]
            self.pool.copy_page(hit.partial.page, dst)
            self.cow_forks += 1
            self.pool.free([hit.partial.page])   # admit-time clone guard
        return stats

    # ----------------------------------------------------------------- insert
    def insert(self, req: Any) -> None:
        """Cache the request's just-prefilled prefix: one entry per fully
        written page (with a host snapshot — the checkpointed prefix for
        reference repair) plus one partial entry for a tail fraction.
        Existing entries are touched, not replaced (two same-prefix
        requests admitted in one batch race to insert; first wins).  The
        cache takes one pool reference per new entry.

        Only RESIDENT positions are cacheable: the prefill emitted one new
        token whose KV is written at the next decode step, so the key base
        stops at ``req.pos`` (the prefill context) — an entry must never
        promise a row the pool does not hold yet."""
        toks = tuple(int(t) for t in req.prefill_tokens())[: req.pos]
        if not toks:
            return
        pg = self.cfg.page_size
        n_full = len(toks) // pg
        protect = {toks[: k * pg] for k in range(1, n_full + 1)} | {toks}
        parent: Optional[_Entry] = None
        for k in range(1, n_full + 1):
            key = toks[: k * pg]
            e = self._entries.get(key)
            if e is None:
                e = self._insert_one(
                    key, req.pages[k - 1], k * pg, False, parent, protect
                )
                if e is None:
                    return
            else:
                self._touch(e)
            parent = e
        rem = len(toks) - n_full * pg
        if rem:
            e = self._entries.get(toks)
            if e is not None:
                self._touch(e)
            else:
                self._insert_one(
                    toks, req.pages[n_full], len(toks), True, parent, protect
                )

    def _insert_one(
        self,
        key: Tuple[int, ...],
        page: int,
        n_tokens: int,
        partial: bool,
        parent: Optional[_Entry],
        protect: set,
    ) -> Optional[_Entry]:
        if not self._make_room(protect):
            return None
        # a fresh resident insert supersedes any parked copy of the same
        # prefix — release its host slot instead of leaking it
        stale = self._host_entries.pop(key, None)
        if stale is not None:
            self.tiers.drop_slot(stale.slot)
        self.pool.share([page])
        e = _Entry(
            key=key,
            page=page,
            n_tokens=n_tokens,
            partial=partial,
            # a partial page's owner keeps appending rows, so it has no
            # stable reference — detector scrub handles it on reuse
            snapshot=None if partial else self.pool.snapshot_page(page),
            parent=parent.key if parent is not None else None,
        )
        if parent is not None:
            parent.n_children += 1
        self._entries[key] = e
        if partial:
            self._register_fragments(e)
        self._touch(e)
        self.inserts += 1
        return e

    def _make_room(self, protect: set) -> bool:
        """Enforce ``max_cached_pages`` (0 = uncapped) before an insert."""
        cap = self.cfg.max_cached_pages
        if cap <= 0:
            return True
        while len(self._entries) >= cap:
            if self._evict_one(protect) is None:
                return False
        return True

    # --------------------------------------------------------------- eviction
    def _evict_one(self, protect: set = frozenset()) -> Optional[int]:
        """Drop the least-recently-used evictable entry — a chain *leaf*
        (no cached extension) whose page only the cache still references —
        and release its pool reference.  Returns the page id (now on the
        free list) or None when nothing is evictable."""
        victim = None
        for e in self._entries.values():
            if e.key in protect or e.n_children > 0:
                continue
            if self.pool.refcount(e.page) != 1:
                continue            # a running request still shares it
            if victim is None or e.last_used < victim.last_used:
                victim = e
        if victim is None:
            return None
        del self._entries[victim.key]
        if victim.partial:
            self._drop_fragments(victim)
        if victim.parent is not None:
            self._entries[victim.parent].n_children -= 1
        self._demote(victim)
        self.pool.free([victim.page])
        self.evictions += 1
        return victim.page

    def _demote(self, victim: _Entry) -> None:
        """Park the evicted entry in the host tier before its page goes
        back to the free list.  Full entries stash their insert-time
        snapshot — those bits are already exact, so no boundary scrub is
        owed; partial tails snapshot the live page through the boundary
        scrub.  A full host store just drops the entry (pre-tier
        behavior)."""
        if self.tiers is None:
            return
        stale = self._host_entries.pop(victim.key, None)
        if stale is not None:
            self.tiers.drop_slot(stale.slot)
        slot = (
            self.tiers.stash_views(victim.snapshot)
            if victim.snapshot is not None
            else self.tiers.demote_page(victim.page)
        )
        if slot is None:
            return
        self._host_entries[victim.key] = _HostEntry(
            key=victim.key,
            slot=slot,
            n_tokens=victim.n_tokens,
            partial=victim.partial,
        )
        self.demotions += 1

    def evict(self, n_pages: int) -> int:
        """Reclaim up to ``n_pages`` pages for the allocator (admission /
        capacity pressure runs the cache dry before preempting a running
        request).  Returns how many pages actually reached the free list."""
        freed = 0
        while freed < max(n_pages, 1):
            if self._evict_one() is None:
                break
            freed += 1
        return freed

    # ------------------------------------------------------------ observation
    def stats(self) -> Dict[str, int]:
        return {
            "entries": len(self._entries),
            "cached_pages": self.cached_pages,
            "hits": self.hits,
            "misses": self.misses,
            "hit_tokens": self.hit_tokens,
            "inserts": self.inserts,
            "evictions": self.evictions,
            "cow_forks": self.cow_forks,
            "reuse_scrubs": self.reuse_scrubs,
            "reuse_ref_repairs": self.reuse_ref_repairs,
            "reuse_skips": self.reuse_skips,
            "fragment_hits": self.fragment_hits,
            "host_entries": len(self._host_entries),
            "demotions": self.demotions,
            "promotions": self.promotions,
        }
