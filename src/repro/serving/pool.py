"""Paged KV pool: block-table-indexed physical cache pages + the free list.

The pool owns the serving engine's approximate-memory resident.  Physical
layout (``Model.paged_cache_defs``): every leaf is ``(n_pages+1, L,
page_size, K, Dh)`` with the page axis LEADING, so one page is one
contiguous row — the unit of

  * region accounting (the pool tree is pre-registered with the owning
    ``ApproxSpace``, so classification/BER injection/stats are page-exact),
  * fault attribution (per-page repair-event counters, routed back from the
    step that touched the page), and
  * targeted repair (``ApproxSpace.scrub_pages`` / the Pallas page-view
    scrub — scrubbed bytes scale with the *faulted* pages, not the pool).

Row ``n_pages`` is the null page: block tables are padded with it, so
gather/scatter shapes stay static (one compiled executable per run).  It is
included in every repair candidate set — padding lanes are masked out of
attention scores, but a NaN there would still poison the context through
``0 * NaN`` in the value contraction.

Requests never see physical indices: the scheduler hands out block tables
(request-order lists of page ids).  On the decode hot path the engine feeds
the pool leaves + block tables straight into the Pallas paged-attention
kernel (``kernels/paged_attention.py`` — fused on-read repair, no copy);
gather/scatter survive only for prefill and for non-paged-decode fallbacks,
and are call-counted (``n_gathers`` / ``n_scatters``) so tests can assert
the decode path issues zero full-view copies.
"""
from __future__ import annotations

import collections
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from ..core import stats as stats_lib
from ..core.regions import Region
from ..distributed import sharding as sh
from ..nn import module
from ..runtime import ApproxSpace
from .config import ServingConfig


def _is_float(leaf) -> bool:
    return hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating)


@jax.jit
def _reset_pages(tree: Any, ids: jax.Array) -> Any:
    """Zero the named pages in one fused update (functional on CPU; on TPU
    buffer donation would make this an in-place page clear)."""
    return jax.tree.map(
        lambda leaf: leaf.at[ids].set(0) if _is_float(leaf) else leaf, tree
    )


@jax.jit
def _copy_page(tree: Any, src: jax.Array, dst: jax.Array) -> Any:
    """Clone one physical page row into another (the copy-on-write fork of
    the prefix cache — src stays shared, dst becomes the writer's private
    copy).  src/dst are traced scalars: one executable per pool layout."""
    return jax.tree.map(
        lambda leaf: (
            leaf.at[dst].set(leaf[src]) if _is_float(leaf) else leaf
        ),
        tree,
    )


@jax.jit
def _page_view(tree: Any, page: jax.Array) -> Any:
    """One page's rows as a leading-axis-1 tree (same key paths as the pool
    tree, so region/rule classification carries over)."""
    return jax.tree.map(
        lambda leaf: leaf[page][None] if _is_float(leaf) else leaf, tree
    )


@jax.jit
def _write_page(tree: Any, view: Any, page: jax.Array) -> Any:
    """Write a leading-axis-1 page view back into its pool row."""
    return jax.tree.map(
        lambda leaf, v: (
            leaf.at[page].set(v[0].astype(leaf.dtype))
            if _is_float(leaf) else leaf
        ),
        tree, view,
    )


@jax.jit
def _pages_view(tree: Any, ids: jax.Array) -> Any:
    """Several pages' rows as a leading-axis-n tree — the batched
    ``_page_view`` (tier swap-out snapshots whole block tables at once)."""
    return jax.tree.map(
        lambda leaf: leaf[ids] if _is_float(leaf) else leaf, tree
    )


@jax.jit
def _write_pages(tree: Any, views: Any, ids: jax.Array) -> Any:
    """Write leading-axis-n page views back into their pool rows — the
    batched ``_write_page`` (tier swap-in restores whole block tables)."""
    return jax.tree.map(
        lambda leaf, v: (
            leaf.at[ids].set(v.astype(leaf.dtype))
            if _is_float(leaf) else leaf
        ),
        tree, views,
    )


@jax.jit
def _gather(tree: Any, block_tables: jax.Array) -> Any:
    """Pool pages -> contiguous per-request cache views.

    leaf (P, L, pg, K, Dh) x block_tables (R, M) -> (L, R, M*pg, K, Dh) —
    exactly the treedef/axis order of ``Model.cache_defs``, so the gathered
    view feeds ``serve_step`` unchanged.
    """

    def g(leaf):
        v = leaf[block_tables]                    # (R, M, L, pg, ...)
        v = jnp.moveaxis(v, 2, 0)                 # (L, R, M, pg, ...)
        L, R, M, pg = v.shape[:4]
        return v.reshape(L, R, M * pg, *v.shape[4:])

    return jax.tree.map(g, tree)


@jax.jit
def _scatter(tree: Any, view: Any, block_tables: jax.Array) -> Any:
    """Write a per-request cache view back into the pool pages.

    Duplicate block-table entries (null-page padding) collide harmlessly —
    every colliding write targets the null row, whose contents are never
    consumed unmasked.
    """

    def s(leaf, v):
        pg = leaf.shape[2]
        L, R, V = v.shape[:3]
        v = v.reshape(L, R, V // pg, pg, *v.shape[3:])
        v = jnp.moveaxis(v, 0, 2)                 # (R, M, L, pg, ...)
        return leaf.at[block_tables].set(v.astype(leaf.dtype))

    return jax.tree.map(s, tree, view)


class PagedKVPool:
    """Fixed-size KV pages + free list + per-page fault accounting."""

    def __init__(
        self,
        model: Any,
        space: ApproxSpace,
        cfg: ServingConfig,
    ):
        defs = model.paged_cache_defs(cfg.n_pages + 1, cfg.page_size)
        self.tree = module.init_params(defs, jax.random.PRNGKey(cfg.seed))
        self.space = space
        self.cfg = cfg
        self.null_page = cfg.n_pages
        space.regions_for(self.tree)        # pre-register page regions
        # mesh-native pool: register page-axis shardings with the runtime —
        # pages spread over the DP axis (sharding rule "page", degrading to
        # replicated when n_pages+1 does not divide it), so page scrubs
        # repair device-local rows and the space's compiled executables
        # specialize to this placement once.
        self.shardings = None
        if space.mesh is not None:
            rules = space.rules or sh.rules_for_mesh(space.mesh)

            def page_sharding(leaf):
                axes = ("page",) + (None,) * (leaf.ndim - 1)
                spec = sh.spec_for_leaf(axes, leaf.shape, space.mesh, rules)
                return NamedSharding(space.mesh, spec)

            self.shardings = jax.tree.map(page_sharding, self.tree)
            self.tree = jax.device_put(self.tree, self.shardings)

        self._free: collections.deque = collections.deque(range(cfg.n_pages))
        # per-page reference counts: a page leaves the free list with one
        # reference (its allocating request); ``share`` adds holders (other
        # requests, the prefix cache); ``free`` releases one reference and
        # the page returns to the free list only at zero — so preemption can
        # never reclaim a page the cache (or another request) still shares.
        # The null padding page is permanently resident (count pinned to 1).
        self._refcount = np.zeros(cfg.n_pages + 1, np.int64)
        self._refcount[self.null_page] = 1
        # dwell clock (README §Serving engine): ``now`` is the engine's step
        # counter (one step == one injection window); ``page_clean_step``
        # timestamps each page's last scrub/zeroing.  now - clean_step is the
        # dwell the prefix cache charges through ApproxConfig.expected_faults.
        self.now = 0
        self.page_clean_step = np.zeros(cfg.n_pages + 1, np.int64)
        # per-page attribution: repair events routed back from steps that
        # touched the page, and how often each page has been scrubbed
        self.page_events = np.zeros(cfg.n_pages + 1, np.int64)
        self.page_scrubs = np.zeros(cfg.n_pages + 1, np.int64)
        self.scrubbed_bytes = 0
        self.scrub_calls = 0
        # full-view copy ledger: the paged-decode acceptance criterion is
        # that the decode hot path issues ZERO of these (prefill keeps them)
        self.n_gathers = 0
        self.n_scatters = 0

    # -------------------------------------------------------------- geometry
    def page_shard_axis(self) -> Optional[str]:
        """The mesh axis the pool's page axis is genuinely sharded over —
        or None.  Non-None iff EVERY leaf's leading (page) dimension is
        partitioned over the same single mesh axis AND the page count
        divides that axis's size (shard_map needs equal shards; the
        ``spec_for_leaf`` rule degrades to replicated otherwise).  The
        engine uses this to decide whether the fused kernels can run the
        device-local sharded walk (README §Serving engine, "Sharded decode
        & load testing")."""
        if self.shardings is None or self.space.mesh is None:
            return None
        axes = set()
        for s in jax.tree.leaves(self.shardings):
            part = s.spec[0] if len(s.spec) > 0 else None
            if isinstance(part, (tuple, list)):
                if len(part) != 1:
                    return None
                part = part[0]
            axes.add(part)
        if len(axes) != 1:
            return None
        axis = axes.pop()
        if axis is None:
            return None
        if (self.cfg.n_pages + 1) % self.space.mesh.shape[axis] != 0:
            return None
        return axis

    @property
    def total_bytes(self) -> int:
        """Bytes of the whole pool (what a whole-cache scrub processes)."""
        return sum(
            leaf.size * leaf.dtype.itemsize
            for leaf in jax.tree.leaves(self.tree)
            if _is_float(leaf)
        )

    @property
    def page_bytes(self) -> int:
        return self.total_bytes // (self.cfg.n_pages + 1)

    @property
    def n_free(self) -> int:
        return len(self._free)

    # ------------------------------------------------------------ allocation
    def alloc(self, n: int) -> Optional[List[int]]:
        """Allocate ``n`` pages (zeroed) or None if the pool cannot satisfy
        the request — admission control / preemption trigger upstream."""
        if n > len(self._free):
            return None
        pages = [self._free.popleft() for _ in range(n)]
        if pages:
            # physical pages are recycled memory: reset so a new request
            # never reads a previous tenant's (possibly flipped) lanes
            self.tree = _reset_pages(self.tree, jnp.asarray(pages, jnp.int32))
            assert all(self._refcount[p] == 0 for p in pages), pages
            self._refcount[pages] = 1
            self.page_clean_step[pages] = self.now    # zeroed == scrubbed
        return pages

    def share(self, pages: Sequence[int]) -> None:
        """Add one reference to each page (a new holder: another request
        admitted onto a cached prefix, or the prefix cache itself)."""
        for p in pages:
            if not 0 <= p < self.null_page:
                raise ValueError(f"bad page id {p}")
            if self._refcount[p] <= 0:
                raise RuntimeError(f"sharing free page {p}")
            self._refcount[p] += 1

    def free(self, pages: Sequence[int]) -> None:
        """Release one reference per page; a page returns to the free list
        only when its last holder lets go.  Releasing a page with no live
        reference is a hard error — before refcounts a double free silently
        duplicated the free-list entry, handing the same physical page to
        two requests."""
        for p in pages:
            if not 0 <= p < self.null_page:
                raise ValueError(f"bad page id {p}")
            if self._refcount[p] <= 0:
                raise RuntimeError(
                    f"double free of page {p} (no live reference)"
                )
            self._refcount[p] -= 1
            if self._refcount[p] == 0:
                self._free.append(p)

    def refcount(self, page: int) -> int:
        return int(self._refcount[page])

    def is_free(self, page: int) -> bool:
        return self._refcount[page] == 0

    # ------------------------------------------------------------ dwell clock
    def dwell(self, page: int) -> int:
        """Injection windows (engine steps) since ``page`` was last known
        clean — what the prefix cache charges to an expected-fault estimate
        before re-sharing the page."""
        return int(self.now - self.page_clean_step[page])

    def mark_clean(self, pages: Sequence[int]) -> None:
        self.page_clean_step[sorted(set(pages))] = self.now

    def copy_page(self, src: int, dst: int) -> None:
        """Device-copy page ``src``'s rows into ``dst`` (the prefix cache's
        copy-on-write fork).  The clone inherits the source's dwell stamp —
        its bits are exactly as old as the source's last scrub."""
        self.tree = _copy_page(
            self.tree,
            jnp.asarray(src, jnp.int32),
            jnp.asarray(dst, jnp.int32),
        )
        self.page_clean_step[dst] = self.page_clean_step[src]

    # --------------------------------------------------------- gather/scatter
    def block_table(self, pages: Sequence[int]) -> np.ndarray:
        """Fixed-width block table row, null-padded (static shapes)."""
        M = self.cfg.max_pages_per_request
        assert len(pages) <= M, "request outgrew its block table"
        row = np.full((M,), self.null_page, np.int32)
        row[: len(pages)] = pages
        return row

    def gather(self, block_tables: jax.Array) -> Any:
        self.n_gathers += 1
        return _gather(self.tree, jnp.asarray(block_tables, jnp.int32))

    def scatter(self, view: Any, block_tables: jax.Array) -> None:
        self.n_scatters += 1
        self.tree = _scatter(
            self.tree, view, jnp.asarray(block_tables, jnp.int32)
        )

    # ----------------------------------------------------------------- repair
    def fatal_pages(self, page_ids: Sequence[int]) -> List[int]:
        """DEPRECATED public probe — the paged kernel family emits per-page
        fatal counts as a side effect of the read (prefill AND decode), so
        reactive detection no longer needs a separate scan over resident
        pages.  The probe survives for gathered-view fallbacks (non-paged
        models, ineligible rule sets) via ``PageRepairManager.repair_step``,
        which calls the private ``_probe_fatal_pages`` directly."""
        import warnings

        warnings.warn(
            "PagedKVPool.fatal_pages is deprecated: the paged kernels emit "
            "per-page fatal counts on read (PageRepairManager.repair_counts);"
            " the probe remains only for gathered-view fallback paths",
            DeprecationWarning,
            stacklevel=2,
        )
        return self._probe_fatal_pages(page_ids)

    def _probe_fatal_pages(self, page_ids: Sequence[int]) -> List[int]:
        """The subset of ``page_ids`` holding >=1 fatal lane — the trap
        analogue at page granularity (detection only; no repair).

        "Fatal" is per-leaf: each pool leaf's assigned ``RepairRule``
        supplies the detector (README §RepairRule), so a NaN-only KV rule
        and a range-guarded rule disagree about the same bit pattern by
        design.  The probe gate mirrors the repair gate exactly
        (approximate-region float leaves whose rule fires reactively):
        exact-region/exact-island leaves are never probed, and leaves a
        reactive pass would not repair must not keep re-flagging their
        pages as faulty — that would dispatch a no-op scrub every step
        forever."""
        ids = sorted(set(page_ids))
        if not ids:
            return []
        idx = jnp.asarray(ids, jnp.int32)
        regions = self.space.regions_for(self.tree)
        rule_tree, _ = self.space.rules_for(self.tree)
        flags = None
        for leaf, region, rule in zip(
            jax.tree.leaves(self.tree),
            jax.tree.leaves(regions),
            jax.tree.leaves(rule_tree),
        ):
            if not _is_float(leaf) or region is not Region.APPROX:
                continue
            if not rule.fires("reactive"):
                continue
            rows = leaf[idx]
            nan_m, inf_m = rule.detect.masks(rows)
            bad = (nan_m | inf_m).reshape(rows.shape[0], -1).any(axis=1)
            flags = bad if flags is None else flags | bad
        if flags is None:
            return []
        mask = np.asarray(flags)
        return [p for p, b in zip(ids, mask) if b]

    def scrub_pages(
        self,
        page_ids: Sequence[int],
        stats: stats_lib.Stats,
        *,
        trigger: str = "reactive",
    ) -> stats_lib.Stats:
        """Targeted scrub of exactly ``page_ids`` (unique'd), with byte
        accounting — the page-granular reactive repair.  The pool tree is
        the resident state, so the compiled executable donates it (in-place
        page repair on device)."""
        ids = sorted(set(page_ids))
        if not ids:
            return stats
        # the plan knows what THIS pass actually repairs (rule gating by
        # trigger): a pass no rule fires on is a no-op — don't dispatch it
        # and don't charge the ledger for work that never happened
        plan = self.space.plan_for(self.tree, scope="pages", trigger=trigger)
        if plan.scope == "none" or plan.page_row_bytes == 0:
            return stats
        self.tree, stats = self.space.scrub_pages(
            self.tree, jnp.asarray(ids, jnp.int32), stats, donate=True,
            trigger=trigger,
        )
        self.page_scrubs[ids] += 1
        self.scrubbed_bytes += len(ids) * plan.page_row_bytes
        self.scrub_calls += 1
        self.mark_clean(ids)
        return stats

    def scrub_all(
        self, stats: stats_lib.Stats, *, trigger: str = "reactive"
    ) -> stats_lib.Stats:
        """Whole-pool scrub (the pre-engine ``scrub_cache`` baseline), with
        byte accounting — gated and charged like ``scrub_pages``: only the
        bytes the pass's firing rules cover."""
        plan = self.space.plan_for(self.tree, scope="tree", trigger=trigger)
        if plan.scope == "none" or plan.bytes_per_run == 0:
            return stats
        self.tree, stats = self.space.scrub(
            self.tree, stats, donate=True, trigger=trigger
        )
        self.page_scrubs += 1
        self.scrubbed_bytes += plan.bytes_per_run
        self.scrub_calls += 1
        self.mark_clean(range(self.cfg.n_pages + 1))
        return stats

    def scrub_scope(
        self,
        scope: str,
        page_ids: Sequence[int],
        stats: stats_lib.Stats,
        *,
        trigger: str = "reactive",
    ) -> stats_lib.Stats:
        """Execute one planned repair pass by ``RepairPlan`` scope — the
        pool's ledger-keeping dispatch for the page repair manager (the
        scope itself comes from ``runtime.plan.serving_scope``; no repair
        decisions are made here).  ``trigger`` tags the pass for rule
        gating (reactive repair vs the background interval sweep)."""
        if scope == "pages":
            return self.scrub_pages(page_ids, stats, trigger=trigger)
        if scope == "tree":
            return self.scrub_all(stats, trigger=trigger)
        assert scope == "none", f"bad plan scope {scope!r}"
        return stats

    def pages_view(self, pages: Sequence[int]) -> Any:
        """Host (numpy) copies of several pages' rows, leading axis in
        ``pages`` order — what the host tier stores on swap-out.  A copy,
        not a view: freeing or recycling the device pages afterwards
        cannot invalidate it."""
        return jax.device_get(
            _pages_view(self.tree, jnp.asarray(list(pages), jnp.int32))
        )

    def write_pages(self, pages: Sequence[int], views: Any) -> None:
        """Write page-row views (leading axis in ``pages`` order) into live
        pool pages — the tier swap-in.  Writing into a free page is a hard
        error: swapped-in contents must land in pages the normal
        allocation path just handed out, never in recycled rows another
        holder could claim."""
        pages = list(pages)
        for p in pages:
            if not 0 <= p < self.null_page:
                raise ValueError(f"bad page id {p}")
            if self._refcount[p] <= 0:
                raise RuntimeError(f"writing into free page {p}")
        self.tree = _write_pages(
            self.tree,
            jax.tree.map(jnp.asarray, views),
            jnp.asarray(pages, jnp.int32),
        )

    def snapshot_page(self, page: int) -> Any:
        """Host (numpy) copy of one page's rows — the prefix cache's
        checkpointed-prefix reference for scrub-on-reuse."""
        return jax.device_get(
            _page_view(self.tree, jnp.asarray(page, jnp.int32))
        )

    def reference_repair_page(
        self, page: int, snapshot: Any, stats: stats_lib.Stats
    ) -> stats_lib.Stats:
        """Repair one page against its host snapshot (``last_checkpoint``
        at page granularity): fatal lanes are restored to the exact bits the
        prefix held when it was cached, not a fill value — the strongest
        repair available, and only a cached prefix has the reference to pay
        for it.  Byte accounting matches ``scrub_pages`` (the reference
        plan's per-run bytes are exactly one page row's rule-gated bytes)."""
        idx = jnp.asarray(page, jnp.int32)
        view = _page_view(self.tree, idx)
        plan = self.space.plan_for(view, scope="reference")
        if plan.bytes_per_run == 0:
            return stats
        ref = jax.tree.map(jnp.asarray, snapshot)
        view, stats = self.space.scrub_with_reference(view, ref, stats)
        self.tree = _write_page(self.tree, view, idx)
        self.page_scrubs[page] += 1
        self.scrubbed_bytes += plan.bytes_per_run
        self.scrub_calls += 1
        self.mark_clean([page])
        return stats

    def attribute(self, page_ids: Sequence[int], n_events: int) -> None:
        """Route ``n_events`` repair events back to the pages a step touched
        (per-page fault ledger for eviction/QoS policies in later PRs)."""
        if n_events and len(page_ids):
            ids = sorted(set(page_ids))
            self.page_events[ids] += n_events
