"""Page-granular reactive repair + the demoted background sweep.

The paper's thesis — repair only what faulted — applied at the pool's page
granularity:

  reactive   every engine step knows exactly which pages it touched (the
             scheduled requests' block tables + the null padding page).
             On the paged paths — prefill AND decode — the *fused kernel*
             is the trap: it emits per-page fatal counts as it streams the
             KV lanes, so ``repair_counts`` scrubs exactly the pages that
             faulted with no separate detection pass at all.
             ``repair_step`` keeps probe-based detection (the deprecated
             ``pool.fatal_pages``, now ``_probe_fatal_pages`` internally)
             solely for the gathered-view fallback.  The pre-engine
             baseline — scrub the whole cache whenever anything faulted —
             is kept as ``repair="whole"`` for the bench comparison.

  routed     fused-kernel counter vectors (``kernels.ops`` ``MM_*``/``AT_*``
             layout) reported through ``note_kernel`` are folded into the
             unified stats via ``ApproxSpace.record_kernel`` AND routed back
             to the step's touched pages: they are marked dirty and scrubbed
             on the next repair pass, and the pool's per-page event ledger
             is charged.

  sweep      the old whole-cache ``ScrubSchedule`` interval is demoted to a
             background low-rate sweep: every ``sweep_interval`` steps a
             rotating window of ``sweep_pages`` pages is scrubbed, catching
             flips in cold pages no step touches (their NaNs would otherwise
             sit resident forever — invisible to reactive repair until read).
"""
from __future__ import annotations

from typing import Callable, Iterable, List, Optional, Sequence, Set

import numpy as np

from ..core import stats as stats_lib
from ..kernels import ops as kernel_ops
from ..runtime import ApproxSpace, ScrubSchedule, serving_scope
from .config import ServingConfig
from .pool import PagedKVPool


class PageRepairManager:
    """Owns the dirty set, the sweep cursor, and the repair-mode dispatch."""

    def __init__(
        self,
        pool: PagedKVPool,
        space: ApproxSpace,
        cfg: ServingConfig,
        on_host_sync: Optional[Callable[[], None]] = None,
    ):
        self.pool = pool
        self.space = space
        self.cfg = cfg
        self.sweep = ScrubSchedule(boundary=False, interval=cfg.sweep_interval)
        self._dirty: Set[int] = set()
        self._sweep_cursor = 0
        self.n_reactive_scrubs = 0
        self.n_sweep_scrubs = 0
        # the engine's device->host readback counter: every point where this
        # manager forces a blocking device read reports through it, so the
        # desynchronized drain's "strictly fewer syncs" claim is auditable
        self._on_host_sync = on_host_sync or (lambda: None)

    # ----------------------------------------------------------- kernel route
    def note_kernel(self, counts, touched: Iterable[int]) -> None:
        """Fold a Pallas kernel counter vector into the unified stats and
        route its events back to the pages the reporting step touched."""
        self.space.record_kernel(counts)
        events = int(counts[kernel_ops.MM_EV_TOTAL])
        if events > 0:
            # freed pages are skipped: they may already belong to (or be
            # zeroed for) a different request than the one that reported
            pages = [
                p for p in touched
                if p <= self.pool.null_page and not self.pool.is_free(p)
            ]
            self._dirty.update(pages)
            self.pool.attribute(pages, events)

    def mark_dirty(self, pages: Iterable[int]) -> None:
        self._dirty.update(pages)

    # ---------------------------------------------------------------- repair
    def repair_step(
        self, touched: Sequence[int], stats: stats_lib.Stats
    ) -> stats_lib.Stats:
        """One reactive repair pass before the step's compute consumes the
        touched pages.  Detection (the trap analogue) runs over touched ∪
        dirty ∪ {null}; repair granularity is planned by ``RepairPlan``
        (``serving_scope`` maps ``cfg.repair`` to the plan scope — the
        whole-vs-page decision lives in runtime/, not here)."""
        scope = serving_scope(self.cfg.repair)
        if scope == "none":
            return stats
        candidates = set(touched) | self._dirty | {self.pool.null_page}
        self._on_host_sync()          # the probe blocks on a device read
        faulty = self.pool._probe_fatal_pages(candidates)
        return self._scrub_faulty(scope, faulty, stats)

    def repair_counts(
        self,
        page_counts,
        covered: Sequence[int],
        stats: stats_lib.Stats,
        defer: Optional[List] = None,
    ) -> stats_lib.Stats:
        """Reactive repair driven by the fused paged kernels' per-page
        fatal counts — the replacement for the ``fatal_pages`` probe on
        every paged path (prefill and decode).  ``page_counts`` is the
        ``(n_pages+1,)`` vector the compiled step emitted (or several
        steps' vectors summed); ``covered`` is the page set
        the kernel actually streamed (the step's block tables, null page
        included).  Dirty pages *outside* the kernel's coverage keep the
        probe — their faults are invisible to this step's reads but were
        reported by an earlier kernel, and the old path scrubbed them too.

        One deliberate divergence from the probe: a fault landing exactly
        in the slot this step's new K/V write overwrites is healed by the
        write itself before the kernel reads — never consumed, never
        resident afterwards, never counted.  The probe (which ran before
        the write) counted it.  Repairing only what a read would consume
        is the paper's thesis; the probe was strictly more conservative.

        ``defer`` is the desynchronized engine's attribution queue: instead
        of blocking twice on ``stats["events"]`` to charge the per-page
        ledger, the scrub's event delta stays a device scalar and is
        appended as ``(faulty_pages, delta)`` for the *next* drain to
        resolve — the drain-time scrub itself then costs zero extra host
        syncs.
        """
        scope = serving_scope(self.cfg.repair)
        if scope == "none":
            return stats
        counts = np.asarray(page_counts)
        faulty = [int(p) for p in np.nonzero(counts > 0)[0]]
        stale = self._dirty - set(covered)
        if stale:
            self._on_host_sync()
            faulty = sorted(
                set(faulty) | set(self.pool._probe_fatal_pages(stale))
            )
        return self._scrub_faulty(scope, faulty, stats, defer=defer)

    def _scrub_faulty(
        self,
        scope: str,
        faulty: Sequence[int],
        stats: stats_lib.Stats,
        defer: Optional[List] = None,
    ) -> stats_lib.Stats:
        """Shared tail of the probe- and kernel-driven reactive passes:
        scrub faulty ∪ dirty, clear the dirty set, attribute events."""
        scrub_set = sorted(set(faulty) | self._dirty)
        self._dirty.clear()
        if not scrub_set:
            return stats
        events0 = stats["events"]
        if defer is None:
            self._on_host_sync()
            events0 = int(events0)
        stats = self.pool.scrub_scope(
            scope, scrub_set, stats, trigger="reactive"
        )
        self.n_reactive_scrubs += 1
        # the ledger charges only pages that actually held a fatal lane —
        # dirty-but-clean pages (kernel routing false positives) stay clean
        if defer is not None:
            defer.append((list(faulty), stats["events"] - events0))
            return stats
        self._on_host_sync()
        delta = int(stats["events"]) - events0
        if delta > 0:
            self.pool.attribute(faulty, delta)
        return stats

    # ----------------------------------------------------------------- sweep
    def sweep_step(self, t: int, stats: stats_lib.Stats) -> stats_lib.Stats:
        """Background low-rate sweep tick.  Scope comes from the planner
        (page mode sweeps a rotating window; whole mode's interval scrub IS
        a whole-cache pass, matching the legacy schedule)."""
        scope = serving_scope(self.cfg.repair)
        if scope == "none" or not self.sweep.due(t):
            return stats
        if scope == "tree":
            self.n_sweep_scrubs += 1
            return self.pool.scrub_scope(scope, (), stats, trigger="interval")
        n = self.pool.cfg.n_pages
        window: List[int] = [
            (self._sweep_cursor + i) % n
            for i in range(min(self.cfg.sweep_pages, n))
        ]
        self._sweep_cursor = (self._sweep_cursor + len(window)) % n
        self.n_sweep_scrubs += 1
        return self.pool.scrub_scope(scope, window, stats, trigger="interval")

    # ------------------------------------------------------------------ intro
    def summary(self) -> dict:
        return {
            "reactive_scrubs": self.n_reactive_scrubs,
            "sweep_scrubs": self.n_sweep_scrubs,
            "scrub_calls": self.pool.scrub_calls,
            "scrubbed_bytes": self.pool.scrubbed_bytes,
            "hot_pages": int(np.count_nonzero(self.pool.page_events)),
        }
