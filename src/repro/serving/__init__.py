"""Serving engine: continuous batching over a paged approximate-memory KV
pool with page-granular reactive repair (README §Serving engine).

  ServingConfig     pool geometry, batch shape, repair granularity, sweep
  PagedKVPool       block-table-indexed physical pages, pre-registered with
                    the owning ApproxSpace; gather/scatter + byte accounting
  Scheduler         admit -> prefill -> decode -> finish/evict lifecycle,
                    admission control against free pages, recompute-style
                    preemption under page pressure
  PageRepairManager reactive page-granular scrub + kernel-counter routing +
                    the demoted background sweep
  PrefixCache       refcounted copy-on-write prefix sharing with dwell-time-
                    charged scrub-on-reuse (README §Serving engine)
  HostPageStore     host-memory exact page tier (no dwell clock; free-list +
                    double-free guards mirroring the pool's)
  TierManager       swap orchestration across the device/host tiers with a
                    detector scrub at every device→host boundary crossing
  Engine            the facade: add_request / step / run, unified stats
  WorkloadConfig    seed-deterministic synthetic traffic (Poisson arrivals,
                    bimodal prompt mix, bursts) for benchmarks/traffic.py

The engine is the subsystem later scaling PRs (sharded pools, async decode,
multi-tenant QoS) build on; ``launch.serve.generate(..., paged=True)`` is
its single-request degenerate case.
"""
from .config import ServingConfig  # noqa: F401
from .engine import Engine, engine_space  # noqa: F401
from .pool import PagedKVPool  # noqa: F401
from .prefix_cache import CacheHit, PrefixCache  # noqa: F401
from .repair import PageRepairManager  # noqa: F401
from .scheduler import Request, RequestState, Scheduler  # noqa: F401
from .tiers import HostPageStore, SwapHandle, TierManager  # noqa: F401
from .workload import Arrival, WorkloadConfig, generate_arrivals  # noqa: F401

__all__ = [
    "Arrival",
    "CacheHit",
    "Engine",
    "HostPageStore",
    "PagedKVPool",
    "PageRepairManager",
    "PrefixCache",
    "Request",
    "RequestState",
    "Scheduler",
    "ServingConfig",
    "SwapHandle",
    "TierManager",
    "WorkloadConfig",
    "engine_space",
    "generate_arrivals",
]
