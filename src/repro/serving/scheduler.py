"""Continuous-batching scheduler: admit -> prefill -> decode -> finish/evict.

The request lifecycle mirrors the production serving cores in the related
file sets (vLLM/Bullet): a FIFO waiting queue, admission control against
free pages, per-step page growth for running requests, and recompute-style
preemption under page pressure — the evicted request frees its pages and
rejoins the waiting queue with its generated-so-far tokens folded into the
prefill prompt, so no output is lost.

The scheduler is pure host-side bookkeeping; all device work (gather, step,
scatter, repair) lives in the engine.  Deadlock freedom: a preemption victim
is always the *newest* running request, and only when it is newer than the
one that needs the page (FIFO priority — a starved newest request skips
steps instead of evicting its elders); ``ServingConfig`` guarantees a lone
request can always hold its maximum block table.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import List, Optional

from .config import ServingConfig
from .pool import PagedKVPool


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request and its page-mapped cache footprint."""

    rid: int
    prompt: List[int]
    max_new: int
    state: RequestState = RequestState.WAITING
    tokens: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0                 # next cache write position
    slot: Optional[int] = None   # decode batch slot while RUNNING
    n_preempted: int = 0
    truncated: bool = False      # hit the block-table context cap

    @property
    def n_context(self) -> int:
        """Tokens whose KV must be resident (prompt + generated)."""
        return len(self.prompt) + len(self.tokens)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new

    @property
    def last_token(self) -> int:
        return self.tokens[-1] if self.tokens else self.prompt[-1]

    def prefill_tokens(self) -> List[int]:
        """What a (re-)prefill must consume: the prompt plus anything already
        generated before a preemption (recompute-style resume)."""
        return self.prompt + self.tokens


class Scheduler:
    """Admission control + preemption over one ``PagedKVPool``."""

    def __init__(self, pool: PagedKVPool, cfg: ServingConfig):
        self.pool = pool
        self.cfg = cfg
        self.waiting: collections.deque = collections.deque()
        self.running: List[Request] = []          # admission order
        self._free_slots = list(range(cfg.max_batch - 1, -1, -1))
        self.n_preemptions = 0

    # -------------------------------------------------------------- lifecycle
    def add(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{len(req.prompt) + req.max_new} exceeds max_seq "
                f"{self.cfg.max_seq}"
            )
        self.waiting.append(req)

    def admit(self) -> List[Request]:
        """Admit waiting requests while a decode slot AND the pages for their
        full (re-)prefill context are free.  FIFO — no head-of-line bypass,
        so a preempted request cannot starve behind newer arrivals."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            need = self.cfg.pages_for(max(req.n_context, 1))
            pages = self.pool.alloc(need)
            if pages is None:
                break
            self.waiting.popleft()
            req.pages = pages
            req.pos = 0
            req.slot = self._free_slots.pop()
            req.state = RequestState.RUNNING
            self.running.append(req)
            admitted.append(req)
        return admitted

    def finish(self, req: Request) -> None:
        self.pool.free(req.pages)
        req.pages = []
        self._free_slots.append(req.slot)
        req.slot = None
        req.state = RequestState.FINISHED
        self.running.remove(req)

    # -------------------------------------------------------------- page flow
    def ensure_capacity(self, req: Request) -> bool:
        """Grow ``req``'s block table to cover its next write position,
        preempting newer requests under page pressure.  Must only be called
        while ``req`` is RUNNING.  Returns False when the pool stayed full
        (no preemptable victim — the request skips this step and retries),
        True when its pages cover position ``req.pos``."""
        assert req.state is RequestState.RUNNING, req
        while self.cfg.pages_for(req.pos + 1) > len(req.pages):
            got = self.pool.alloc(1)
            if got is not None:
                req.pages.extend(got)
                continue
            victim = self._pick_victim(req)
            if victim is None:
                return False
            self.preempt(victim)
        return True

    def _pick_victim(self, needy: Request) -> Optional[Request]:
        """The newest running request — and only if it is newer than
        ``needy`` (FIFO priority: a request never evicts one admitted before
        it; when the needy request is itself the newest it simply skips the
        step until older requests finish and free pages).  Never ``needy``
        itself: a lone request can always hold its maximum block table
        (ServingConfig guarantees ``max_pages_per_request <= n_pages``)."""
        if self.running and self.running[-1] is not needy:
            return self.running[-1]
        return None

    def preempt(self, req: Request) -> None:
        """Recompute-style eviction: drop the pages, keep the tokens, rejoin
        the head of the waiting queue."""
        self.pool.free(req.pages)
        req.pages = []
        req.pos = 0
        self._free_slots.append(req.slot)
        req.slot = None
        req.state = RequestState.WAITING
        req.n_preempted += 1
        self.running.remove(req)
        self.waiting.appendleft(req)
        self.n_preemptions += 1

    # ------------------------------------------------------------------ state
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
