"""Continuous-batching scheduler: admit -> prefill -> decode -> finish/evict.

The request lifecycle mirrors the production serving cores in the related
file sets (vLLM/Bullet): a FIFO waiting queue, admission control against
free pages, per-step page growth for running requests, and preemption under
page pressure.  With a ``TierManager`` (``host_pages > 0`` and
``swap_policy="swap"``) the victim's pages are *swapped out* to the
host-memory exact tier — boundary-scrubbed on the way, re-materialized
through the normal allocation path on re-admission, no re-prefill needed.
Without one (or when the host store is full) preemption stays
recompute-style: the evicted request frees its pages and rejoins the
waiting queue with its generated-so-far tokens folded into the prefill
prompt, so no output is lost either way.

The scheduler is pure host-side bookkeeping; all device work (gather, step,
scatter, repair) lives in the engine.  Deadlock freedom: a preemption victim
is always the *newest* running request, and only when it is newer than the
one that needs the page (FIFO priority — a starved newest request skips
steps instead of evicting its elders); ``ServingConfig`` guarantees a lone
request can always hold its maximum block table.
"""
from __future__ import annotations

import collections
import dataclasses
import enum
from typing import Any, List, Optional

from .config import ServingConfig
from .pool import PagedKVPool


class RequestState(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    """One generation request and its page-mapped cache footprint."""

    rid: int
    prompt: List[int]
    max_new: int
    state: RequestState = RequestState.WAITING
    tokens: List[int] = dataclasses.field(default_factory=list)
    pages: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0                 # next cache write position
    slot: Optional[int] = None   # decode batch slot while RUNNING
    prefill_pos: Optional[int] = None  # chunked-prefill progress (None = not mid-prefill)
    n_preempted: int = 0
    truncated: bool = False      # hit the block-table context cap
    cached_tokens: int = 0       # prefix tokens served from the cache
    cache_hit: Optional[Any] = None  # pending CacheHit (consumed by prepare)
    swap: Optional[Any] = None   # pending SwapHandle (consumed by swap-in)

    @property
    def n_context(self) -> int:
        """Tokens whose KV must be resident (prompt + generated)."""
        return len(self.prompt) + len(self.tokens)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.max_new

    @property
    def last_token(self) -> int:
        return self.tokens[-1] if self.tokens else self.prompt[-1]

    def prefill_tokens(self) -> List[int]:
        """What a (re-)prefill must consume: the prompt plus anything already
        generated before a preemption (recompute-style resume)."""
        return self.prompt + self.tokens


@dataclasses.dataclass
class StepPlan:
    """One engine step's worth of work, split by lifecycle stage.

    ``admitted`` are fresh (or re-admitted) requests this step pulled off
    the waiting queue; ``decode`` are running requests eligible for a
    decode token — i.e. not newly admitted and not mid-prefill.  With
    chunked prefill both lists are non-empty in the same step: prompt
    chunks and decode tokens share the batch (vllm-style mixed batching),
    each through its own fused kernel over the same pool."""

    admitted: List[Request]
    decode: List[Request]


class Scheduler:
    """Admission control + preemption over one ``PagedKVPool``."""

    def __init__(
        self,
        pool: PagedKVPool,
        cfg: ServingConfig,
        cache: Optional[Any] = None,
        tiers: Optional[Any] = None,
    ):
        self.pool = pool
        self.cfg = cfg
        self.cache = cache                        # optional PrefixCache
        self.tiers = tiers                        # optional TierManager
        self.waiting: collections.deque = collections.deque()
        self.running: List[Request] = []          # admission order
        self._free_slots = list(range(cfg.max_batch - 1, -1, -1))
        self.n_preemptions = 0
        self.n_swap_preemptions = 0

    # -------------------------------------------------------------- lifecycle
    def add(self, req: Request) -> None:
        if len(req.prompt) + req.max_new > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid}: prompt+max_new "
                f"{len(req.prompt) + req.max_new} exceeds max_seq "
                f"{self.cfg.max_seq}"
            )
        self.waiting.append(req)

    def admit(self) -> List[Request]:
        """Admit waiting requests while a decode slot AND the pages for their
        full (re-)prefill context are free.  FIFO — no head-of-line bypass,
        so a preempted request cannot starve behind newer arrivals.

        With a prefix cache, admission matches the longest cached prefix
        first: the matched full pages are *shared* (one pool reference per
        page — host bookkeeping only), and allocation covers just the
        suffix (plus the copy-on-write target when the match ends inside a
        page).  Cache eviction runs before admission gives up — cached-only
        pages are the cheapest capacity there is."""
        admitted = []
        while self.waiting and self._free_slots:
            req = self.waiting[0]
            if req.swap is not None:
                # a swapped-out request re-admits onto fresh pages through
                # the normal allocation path; the engine writes the parked
                # KV back before any decode reads it.  No cache lookup —
                # its context is bit-complete in the host tier already.
                pages = self._alloc(req.swap.n_pages)
                if pages is None:
                    break
                self.waiting.popleft()
                req.pages = pages
                req.slot = self._free_slots.pop()
                req.state = RequestState.RUNNING
                self.running.append(req)
                admitted.append(req)
                continue
            hit = (
                self.cache.lookup(req.prefill_tokens())
                if self.cache is not None else None
            )
            shared = [e.page for e in hit.full] if hit is not None else []
            # take the references BEFORE allocating: the allocation may run
            # cache eviction, which must not reclaim the pages just matched
            self.pool.share(shared)
            if hit is not None and hit.partial is not None:
                # guard the clone source too — released by prepare_hit
                self.pool.share([hit.partial.page])
            need = self.cfg.pages_for(max(req.n_context, 1)) - len(shared)
            pages = self._alloc(need)
            if pages is None:
                self.pool.free(shared)
                if hit is not None and hit.partial is not None:
                    self.pool.free([hit.partial.page])
                break
            self.waiting.popleft()
            req.pages = shared + pages
            req.cached_tokens = hit.n_tokens if hit is not None else 0
            req.cache_hit = hit
            req.pos = 0
            req.slot = self._free_slots.pop()
            req.state = RequestState.RUNNING
            self.running.append(req)
            admitted.append(req)
            if self.cache is not None:
                self.cache.note_admit(hit)
        return admitted

    def step_plan(self, prefilling: List[Request]) -> StepPlan:
        """Admit, then partition this step's work: requests still streaming
        prompt chunks (``prefilling`` — the engine's fused-prefill lane —
        plus anything just admitted that needs a prefill) hold their decode
        slot but are not decodable until their last chunk lands.  A
        swapped-out request re-admitting with a *complete* context
        (``prefill_pos is None``) decodes this very step — its parked KV is
        written back whole, no prefill owed."""
        admitted = self.admit()
        busy = {id(r) for r in prefilling}
        busy |= {
            id(r) for r in admitted
            if r.swap is None or r.prefill_pos is not None
        }
        decode = [
            r for r in self.running
            if id(r) not in busy and r.state is RequestState.RUNNING
        ]
        return StepPlan(admitted=admitted, decode=decode)

    def _alloc(self, n: int) -> Optional[List[int]]:
        """Pool allocation with cache-eviction backpressure: a full pool
        first reclaims LRU cache-only pages, then fails (admission waits /
        capacity growth preempts)."""
        pages = self.pool.alloc(n)
        if pages is None and self.cache is not None:
            if self.cache.evict(n - self.pool.n_free) > 0:
                pages = self.pool.alloc(n)
        return pages

    def finish(self, req: Request) -> None:
        self.pool.free(req.pages)
        req.pages = []
        self._free_slots.append(req.slot)
        req.slot = None
        req.state = RequestState.FINISHED
        self.running.remove(req)

    # -------------------------------------------------------------- page flow
    def ensure_capacity(self, req: Request) -> bool:
        """Grow ``req``'s block table to cover its next write position,
        preempting newer requests under page pressure.  Must only be called
        while ``req`` is RUNNING.  Returns False when the pool stayed full
        (no preemptable victim — the request skips this step and retries),
        True when its pages cover position ``req.pos``."""
        assert req.state is RequestState.RUNNING, req
        while self.cfg.pages_for(req.pos + 1) > len(req.pages):
            got = self._alloc(1)
            if got is not None:
                req.pages.extend(got)
                continue
            victim = self._pick_victim(req)
            if victim is None:
                return False
            self.preempt(victim)
        return True

    def _pick_victim(self, needy: Request) -> Optional[Request]:
        """The newest running request — and only if it is newer than
        ``needy`` (FIFO priority: a request never evicts one admitted before
        it; when the needy request is itself the newest it simply skips the
        step until older requests finish and free pages).  Never ``needy``
        itself: a lone request can always hold its maximum block table
        (ServingConfig guarantees ``max_pages_per_request <= n_pages``)."""
        if self.running and self.running[-1] is not needy:
            return self.running[-1]
        return None

    def preempt(self, req: Request) -> None:
        """Eviction under page pressure.  With a tier manager and
        ``swap_policy="swap"`` the victim's pages are parked in the
        host-memory exact tier (boundary-scrubbed copies — the device
        references are then released as usual) and the request re-admits
        without re-prefilling.  Otherwise — no tiers, ``"recompute"``
        policy, or a full host store — the classic recompute path: drop
        the pages, keep the tokens, rejoin the head of the waiting queue.
        Either way "drop" releases this request's references only — pages
        the prefix cache (or another request) still shares survive with
        their KV intact."""
        assert req.cache_hit is None, "preempting an unprepared cache hit"
        assert req.swap is None, "preempting a request not yet swapped in"
        handle = None
        if self.tiers is not None and self.cfg.swap_policy == "swap":
            handle = self.tiers.swap_out(req.pages)
        self.pool.free(req.pages)
        req.pages = []
        if handle is not None:
            # swap keeps prefill_pos: a mid-prefill victim re-enters the
            # fused prefill lane right where it left off after swap-in
            req.swap = handle
            self.n_swap_preemptions += 1
        else:
            req.pos = 0
            req.cached_tokens = 0
            req.prefill_pos = None   # recompute restarts the prefill
        self._free_slots.append(req.slot)
        req.slot = None
        req.state = RequestState.WAITING
        req.n_preempted += 1
        self.running.remove(req)
        self.waiting.appendleft(req)
        self.n_preemptions += 1

    # ------------------------------------------------------------------ state
    @property
    def has_work(self) -> bool:
        return bool(self.waiting or self.running)
