"""Tiered KV: a host-memory *exact* page tier with repair at the boundary.

The paper repairs values exactly when they cross from approximate memory
into computation.  A second KV tier generalizes that idea to a memory
hierarchy: the device pool dwells under relaxed refresh (approximate), the
host store does not (exact, normally-refreshed DRAM) — so every device→host
crossing is a legitimate repair boundary.  Concretely:

  swap-out   one detector-scrub pass over the leaving pages (a page-scoped
             ``RepairPlan`` tagged ``trigger="boundary"`` — the same "exact
             island" pass the RuleSet API already models), THEN the host
             copy.  The host tier therefore never holds a fatal lane: it is
             clean by construction, like the paper's checkpoint islands.
  swap-in    a trusted write back into freshly allocated device pages and a
             ``page_clean_step`` re-stamp — the dwell model restarts from a
             known-clean state, exactly as if the page had just been
             scrubbed.  No detector runs: exact→approximate needs no repair.

Two producers use the tier:

  * ``Scheduler.preempt`` swaps the victim's pages out instead of dropping
    them — preemption stops costing a full re-prefill (recompute survives
    only as the fallback when the host store is full);
  * ``PrefixCache`` eviction demotes cold entries to the host tier before
    dropping them — a later hit promotes the page back and still skips the
    suffix prefill.

``HostPageStore`` mirrors the pool's discipline on the host side: slots
leave a free list, double-free/read-after-free are hard errors (the PR-6
refcount lesson), and buffers are plain pinned numpy — one page row per
slot, same leaf layout as the pool, no dwell clock because the tier is
exact.  The store copies pages (``PagedKVPool.pages_view`` is a device_get
of the page rows), so freeing or recycling the device page afterwards can
never invalidate the host copy.

Byte accounting: every boundary scrub is charged to the owning
``ApproxSpace.scrubbed_bytes`` (inside ``PagedKVPool.scrub_pages``) AND to
the per-tier ``TierManager.boundary_scrub_bytes`` ledger, so tier-crossing
repair cost is visible both globally and per mechanism.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import numpy as np

from ..core import stats as stats_lib
from ..runtime import ApproxSpace
from ..runtime.plan import serving_scope
from .config import ServingConfig
from .pool import PagedKVPool, _is_float

__all__ = ["HostPageStore", "SwapHandle", "TierManager"]


class HostPageStore:
    """Fixed-capacity host-side page buffer: the exact tier.

    One numpy buffer per float pool leaf, shaped ``(host_pages, *row)`` —
    a slot holds exactly one pool page row per leaf.  Non-float leaves
    (none in the stock pool layouts) ride along as static copies, matching
    the pool's ``_page_view`` convention so put/get trees are
    tree-compatible with ``PagedKVPool.pages_view``/``write_pages``.
    """

    def __init__(self, pool_tree: Any, n_pages: int):
        self.n_pages = int(n_pages)
        leaves, self._treedef = jax.tree.flatten(pool_tree)
        self._paged = [_is_float(leaf) for leaf in leaves]
        self._buffers = [
            np.zeros((self.n_pages,) + leaf.shape[1:], leaf.dtype)
            if paged else np.asarray(leaf)
            for leaf, paged in zip(leaves, self._paged)
        ]
        self._free: collections.deque = collections.deque(range(self.n_pages))
        self._live = np.zeros(self.n_pages, bool)
        # observation counters
        self.puts = 0
        self.gets = 0
        self.peak_used = 0

    # -------------------------------------------------------------- capacity
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    # ------------------------------------------------------------------- i/o
    def put(self, views: Any, n: int) -> List[int]:
        """Store ``n`` page rows (leading axis of each float leaf in
        ``views``) into ``n`` free slots; returns the slot ids in row
        order.  Raises when the store cannot hold them — callers decide
        the fallback (recompute / plain eviction), the store never
        silently drops a page."""
        if n > len(self._free):
            raise RuntimeError(
                f"host store full ({self.n_used}/{self.n_pages} used, "
                f"need {n})"
            )
        slots = [self._free.popleft() for _ in range(n)]
        idx = np.asarray(slots)
        for buf, paged, v in zip(
            self._buffers, self._paged, jax.tree.leaves(views)
        ):
            if paged:
                buf[idx] = np.asarray(v)
        self._live[idx] = True
        self.puts += n
        self.peak_used = max(self.peak_used, self.n_used)
        return slots

    def get(self, slots: Sequence[int]) -> Any:
        """The stored rows for ``slots`` as a pool-shaped tree (leading
        axis = len(slots)).  Fancy indexing copies, so the returned views
        stay valid after the slots are freed and recycled."""
        idx = np.asarray(list(slots))
        if idx.size and not self._live[idx].all():
            raise RuntimeError(f"reading freed host slot(s) in {slots}")
        leaves = [
            buf[idx] if paged else buf
            for buf, paged in zip(self._buffers, self._paged)
        ]
        self.gets += idx.size
        return jax.tree.unflatten(self._treedef, leaves)

    def free(self, slots: Sequence[int]) -> None:
        """Release slots back to the free list.  Double-free is a hard
        error — the same silent-corruption class the pool's refcount
        guards close (PR 6), on the host side."""
        for s in slots:
            if not 0 <= s < self.n_pages:
                raise ValueError(f"bad host slot {s}")
            if not self._live[s]:
                raise RuntimeError(f"double free of host slot {s}")
            self._live[s] = False
            self._free.append(s)


@dataclasses.dataclass
class SwapHandle:
    """A preempted request's context parked in the exact tier: host slots
    in block-table page order.  Consumed exactly once by ``swap_in``."""

    slots: List[int]

    @property
    def n_pages(self) -> int:
        return len(self.slots)


class TierManager:
    """Swap orchestration between the approximate device pool and the exact
    host store — every crossing runs through here so the boundary-scrub
    invariant (device→host implies one detector pass) and the byte ledger
    cannot be bypassed."""

    def __init__(
        self, pool: PagedKVPool, space: ApproxSpace, cfg: ServingConfig
    ):
        self.pool = pool
        self.space = space
        self.cfg = cfg
        self.host = HostPageStore(pool.tree, cfg.host_pages)
        # per-tier ledger + swap counters (Engine.tier_stats)
        self.boundary_scrub_bytes = 0
        self.swap_outs = 0
        self.swap_ins = 0
        self.swapped_pages_out = 0
        self.swapped_pages_in = 0
        self.recompute_fallbacks = 0
        self.demotions = 0
        self.promotions = 0

    # ------------------------------------------------------- boundary scrub
    def _boundary_scrub(self, pages: Sequence[int]) -> None:
        """One page-scoped repair pass over ``pages`` before they cross to
        the host tier, tagged ``"boundary"`` so exact-island rule gating
        applies.  Skipped when serving repair is off (``repair="off"`` is
        the oracle arm: tier crossings must not repair either).  Bytes are
        charged to ``ApproxSpace.scrubbed_bytes`` (inside the pool scrub)
        and mirrored into the per-tier ledger."""
        if serving_scope(self.cfg.repair) == "none":
            return
        before = self.pool.scrubbed_bytes
        delta = self.pool.scrub_pages(
            pages, stats_lib.zeros(), trigger="boundary"
        )
        self.space.record(delta)
        self.boundary_scrub_bytes += self.pool.scrubbed_bytes - before

    # -------------------------------------------------------- request swaps
    def swap_out(self, pages: Sequence[int]) -> Optional[SwapHandle]:
        """Scrub-then-copy ``pages`` into the host tier.  Returns ``None``
        (and counts a recompute fallback) when the store cannot hold them
        — the caller keeps the recompute-style preemption path.  The
        device pages are NOT freed here; ownership stays with the caller
        (the scheduler frees its references right after)."""
        pages = list(pages)
        if not pages or len(pages) > self.host.n_free:
            self.recompute_fallbacks += 1
            return None
        self._boundary_scrub(pages)
        views = self.pool.pages_view(pages)
        slots = self.host.put(views, len(pages))
        self.swap_outs += 1
        self.swapped_pages_out += len(pages)
        return SwapHandle(slots=slots)

    def swap_in(self, handle: SwapHandle, pages: Sequence[int]) -> None:
        """Write a parked context back into freshly allocated device pages
        (the normal ``PagedKVPool.alloc`` path supplies ``pages``) and
        release the host slots.  The exact tier is trusted: no detector
        runs, and ``mark_clean`` re-stamps the dwell clock — the pages are
        as clean as a just-scrubbed page."""
        pages = list(pages)
        assert len(pages) == handle.n_pages, (pages, handle)
        self.pool.write_pages(pages, self.host.get(handle.slots))
        self.pool.mark_clean(pages)
        self.host.free(handle.slots)
        self.swap_ins += 1
        self.swapped_pages_in += len(pages)

    # --------------------------------------------------- prefix-cache moves
    def demote_page(self, page: int) -> Optional[int]:
        """Park one cold cache page in the host tier (boundary scrub +
        copy).  Returns the host slot, or ``None`` when the store is full
        — the cache then just drops the entry, as before tiers."""
        if self.host.n_free < 1:
            return None
        self._boundary_scrub([page])
        slot = self.host.put(self.pool.pages_view([page]), 1)[0]
        self.demotions += 1
        return slot

    def stash_views(self, views: Any) -> Optional[int]:
        """Park one page-row view that is ALREADY exact (a prefix-cache
        insert-time snapshot — bits from before any dwell) without a
        boundary scrub: the data never lived un-scrubbed in the
        approximate tier, so there is nothing to detect."""
        if self.host.n_free < 1:
            return None
        slot = self.host.put(views, 1)[0]
        self.demotions += 1
        return slot

    def promote_page(self, slot: int) -> Optional[int]:
        """Re-materialize one parked page through the normal allocation
        path.  Returns the new device page id (refcount 1, dwell
        re-stamped) or ``None`` when the pool is full — the host entry
        stays parked for a later attempt."""
        pages = self.pool.alloc(1)
        if pages is None:
            return None
        self.pool.write_pages(pages, self.host.get([slot]))
        self.pool.mark_clean(pages)
        self.host.free([slot])
        self.promotions += 1
        return pages[0]

    def slot_views(self, slot: int) -> Any:
        """The stored rows for one slot (leading-axis-1 tree) — the exact
        bits a promoted full entry can reuse as its reference snapshot."""
        return self.host.get([slot])

    def drop_slot(self, slot: int) -> None:
        """Discard a parked page (its cache entry was superseded)."""
        self.host.free([slot])

    # ------------------------------------------------------------ observation
    def stats(self) -> Dict[str, int]:
        return {
            "host_pages": self.host.n_pages,
            "host_used": self.host.n_used,
            "host_peak_used": self.host.peak_used,
            "swap_outs": self.swap_outs,
            "swap_ins": self.swap_ins,
            "swapped_pages_out": self.swapped_pages_out,
            "swapped_pages_in": self.swapped_pages_in,
            "boundary_scrub_bytes": self.boundary_scrub_bytes,
            "recompute_fallbacks": self.recompute_fallbacks,
            "demotions": self.demotions,
            "promotions": self.promotions,
        }
