"""Rotary position embeddings (RoPE), with partial-rotary support.

``rotary_pct < 1.0`` rotates only the leading fraction of each head dim
(stablelm-2 style); the remainder passes through unrotated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _angles(positions: jax.Array, rot_dim: int, theta: float) -> jax.Array:
    """(..., rot_dim/2) angle table for integer positions."""
    inv_freq = 1.0 / (
        theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim)
    )
    return positions.astype(jnp.float32)[..., None] * inv_freq  # (..., rot/2)


def apply_rope(
    x: jax.Array,           # (..., seq, heads, head_dim)
    positions: jax.Array,   # (..., seq)
    *,
    theta: float = 10000.0,
    rotary_pct: float = 1.0,
) -> jax.Array:
    head_dim = x.shape[-1]
    rot_dim = int(head_dim * rotary_pct) // 2 * 2
    if rot_dim == 0:
        return x
    ang = _angles(positions, rot_dim, theta)           # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., None, :]                    # (..., seq, 1, rot/2)
    sin = jnp.sin(ang)[..., None, :]

    xr = x[..., :rot_dim].astype(jnp.float32)
    x1, x2 = xr[..., : rot_dim // 2], xr[..., rot_dim // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = out.astype(x.dtype)
    if rot_dim == head_dim:
        return out
    return jnp.concatenate([out, x[..., rot_dim:]], axis=-1)
