"""Minimal functional module system (no flax dependency).

Every layer declares its parameters once, as a nested dict of ``ParamDef``;
generic machinery materializes from the same defs:

  * real parameters           (``init_params`` — deterministic per-path RNG)
  * ShapeDtypeStructs         (``abstract_params`` — dry-run lowering with
                               zero allocation, required for the 123 B arch)
  * logical sharding specs    (``logical_axes`` — consumed by
                               repro.distributed.sharding to build
                               PartitionSpecs from the rules table)

Layers are stateless objects: ``defs()`` describes params, ``__call__``
consumes the materialized dict.  Repeated layers are stacked with
``stack_defs`` and executed with ``jax.lax.scan`` so HLO size stays flat in
depth.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Initializer = Callable[[jax.Array, Tuple[int, ...], Any], jax.Array]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    dtype: Any
    init: Initializer
    axes: Tuple[Optional[str], ...]  # logical axis names, len == ndim

    def __post_init__(self):
        if len(self.axes) != len(self.shape):
            raise ValueError(
                f"axes {self.axes} rank != shape {self.shape} rank"
            )


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _path_key(root_key: jax.Array, path: str) -> jax.Array:
    """Deterministic per-parameter key: fold a stable path hash into the root
    key.  Keeps init independent of traversal order and of sibling params."""
    digest = hashlib.sha256(path.encode()).digest()
    salt = int.from_bytes(digest[:4], "little")
    return jax.random.fold_in(root_key, salt)


def _traverse(defs: Any, fn: Callable[[str, ParamDef], Any], prefix: str = ""):
    if _is_def(defs):
        return fn(prefix, defs)
    if isinstance(defs, dict):
        return {
            k: _traverse(v, fn, f"{prefix}/{k}" if prefix else str(k))
            for k, v in defs.items()
        }
    if defs is None:
        return None
    raise TypeError(f"param defs must be nested dicts of ParamDef, got {type(defs)}")


def init_params(defs: Any, key: jax.Array) -> Any:
    """Materialize real parameters from defs."""
    return _traverse(
        defs, lambda path, d: d.init(_path_key(key, path), d.shape, d.dtype)
    )


def abstract_params(defs: Any) -> Any:
    """ShapeDtypeStruct tree — lowering-only stand-in (no allocation)."""
    return _traverse(
        defs, lambda path, d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype))
    )


def logical_axes(defs: Any) -> Any:
    """Tree of logical-axis tuples, same structure as the params."""
    return _traverse(defs, lambda path, d: d.axes)


def stack_defs(defs: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacking dimension (for lax.scan over layers).

    Init of a stacked def vmaps the underlying init over ``n`` folded keys,
    so a stacked layer initializes identically to ``n`` independent layers.
    """

    def stack_one(path: str, d: ParamDef) -> ParamDef:
        def stacked_init(key, shape, dtype):
            keys = jax.random.split(key, n)
            return jax.vmap(lambda k: d.init(k, d.shape, d.dtype))(keys)

        return ParamDef(
            shape=(n, *d.shape),
            dtype=d.dtype,
            init=stacked_init,
            axes=(axis_name, *d.axes),
        )

    return _traverse(defs, stack_one)


def param_count(defs: Any) -> int:
    total = 0
    for leaf in jax.tree.leaves(
        _traverse(defs, lambda p, d: int(jnp.prod(jnp.array(d.shape))))
    ):
        total += leaf
    return total


def param_bytes(defs: Any) -> int:
    total = 0

    def acc(path, d):
        return int(jnp.prod(jnp.array(d.shape))) * jnp.dtype(d.dtype).itemsize

    for leaf in jax.tree.leaves(_traverse(defs, acc)):
        total += leaf
    return total
