"""Mixture-of-Experts with top-k token-choice routing.

Dispatch is **scatter-based** (no (T,E,C) one-hot materialization): tokens are
grouped along the batch dim (G groups, sharded over the data axis), each
group scatter-adds its tokens into a per-expert capacity buffer
(G, E, C, D) whose expert dim is sharded over the model axis; expert FFNs run
as stacked einsums; results gather back with combine weights.  Capacity
overflow drops tokens (their combine weight is masked), standard GShard
semantics with capacity_factor slack.

Approximate-memory integration (README §Regions): expert weights are the big,
cold, read-mostly table — a prime approximate-memory resident, protected via
``use``.  The **router is pinned to the exact region** (regions.DEFAULT_RULES
matches the "router" path) and router logits are additionally sanitized
before top-k: a NaN entering top-k would corrupt the *routing table* — an
integer-side failure repair cannot express, the paper's "invalid pointer"
analogue (§3.1 limitation) — so we keep it structurally impossible.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.repair import RepairConfig, use
from ..distributed.sharding import constrain
from . import initializers as ini
from .module import ParamDef

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class MoE:
    d_model: int
    d_ff: int                 # per-expert hidden dim
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")

    def defs(self):
        D, F, E = self.d_model, self.d_ff, self.n_experts
        lin = ini.fan_in()
        return {
            "router": {
                # exact-region by path rule; f32 for routing stability
                "w": ParamDef((D, E), jnp.float32, ini.normal(0.02), ("embed", "expert")),
            },
            "w_gate": ParamDef((E, D, F), self.dtype, lin, ("expert", "embed", "mlp")),
            "w_up": ParamDef((E, D, F), self.dtype, lin, ("expert", "embed", "mlp")),
            "w_down": ParamDef((E, F, D), self.dtype, lin, ("expert", "mlp", "embed")),
        }

    def capacity(self, tokens_per_group: int) -> int:
        return max(
            self.top_k,
            int(
                math.ceil(
                    self.top_k * tokens_per_group / self.n_experts
                    * self.capacity_factor
                )
            ),
        )

    def __call__(self, p, x: jax.Array) -> Tuple[jax.Array, jax.Array]:
        """x: (B, S, D) -> (out (B,S,D), aux_loss scalar).

        Groups = batch dim (sharded over data); S tokens per group.
        """
        B, S, D = x.shape
        E, k = self.n_experts, self.top_k
        C = self.capacity(S)

        # ---- routing (exact region, f32, sanitized) ----
        logits = jnp.einsum(
            "gsd,de->gse", x.astype(jnp.float32), p["router"]["w"]
        )
        # NaN in logits would poison top_k ordering: neutralize to -inf.
        logits = jnp.where(jnp.isnan(logits), NEG_INF, logits)
        gate_vals, expert_idx = jax.lax.top_k(logits, k)     # (G,S,k)
        gates = jax.nn.softmax(gate_vals, axis=-1)            # (G,S,k) f32

        # ---- load-balance aux loss (Switch-style) ----
        probs = jax.nn.softmax(logits, axis=-1)               # (G,S,E)
        me = jnp.mean(probs, axis=(0, 1))                     # (E,)
        onehot_top1 = jax.nn.one_hot(expert_idx[..., 0], E, dtype=jnp.float32)
        ce = jnp.mean(onehot_top1, axis=(0, 1))
        aux = jnp.sum(me * ce) * E

        # ---- capacity positions: exclusive cumsum over (S*k) slots ----
        flat_idx = expert_idx.reshape(B, S * k)               # (G, S*k)
        slot_onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)
        pos = (
            jnp.cumsum(slot_onehot, axis=1) - slot_onehot
        )  # (G, S*k, E) exclusive count of same-expert slots before this one
        pos = jnp.take_along_axis(
            pos, flat_idx[..., None], axis=-1
        )[..., 0]                                             # (G, S*k)
        keep = pos < C                                        # (G, S*k)

        # ---- dispatch: scatter tokens into (G, E*C, D) ----
        dest = jnp.where(keep, flat_idx * C + pos, E * C)     # E*C = drop slot
        x_rep = jnp.repeat(
            x, k, axis=1
        ).astype(self.dtype)                                  # (G, S*k, D) bf16

        def dispatch_one(dest_g, xg):
            buf = jnp.zeros((E * C, D), self.dtype)
            return buf.at[dest_g].add(xg, mode="drop")

        buf = jax.vmap(dispatch_one)(dest, x_rep)             # (G, E*C, D)
        # expert-sharded dispatch buffer: without this the scatter output is
        # replicated and every expert shard all-gathers the full (G,E·C,D)
        # buffer (§Perf iteration: 3×2.4e11 wire bytes on qwen3-moe train)
        buf = constrain(
            buf.reshape(B, E, C, D), ("act_batch", "act_expert", None, None)
        )

        # ---- expert FFN (SwiGLU), stacked einsum over E ----
        wg = use(p["w_gate"], self.rcfg)
        wu = use(p["w_up"], self.rcfg)
        wd = use(p["w_down"], self.rcfg)
        g = jnp.einsum("gecd,edf->gecf", buf, wg, preferred_element_type=jnp.float32)
        u = jnp.einsum("gecd,edf->gecf", buf, wu, preferred_element_type=jnp.float32)
        h = (jax.nn.silu(g) * u).astype(self.dtype)
        y = jnp.einsum("gecf,efd->gecd", h, wd, preferred_element_type=jnp.float32)
        y = constrain(
            y.astype(self.dtype), ("act_batch", "act_expert", None, None)
        ).reshape(B, E * C, D)

        # ---- combine: gather expert outputs back, weighted (bf16 wire) ----
        safe_dest = jnp.minimum(dest, E * C - 1)

        def combine_one(y_g, dest_g):
            return jnp.take(y_g, dest_g, axis=0)              # (S*k, D)

        gathered = jax.vmap(combine_one)(y, safe_dest)        # (G, S*k, D)
        w = (gates.reshape(B, S * k) * keep.astype(jnp.float32))
        out = gathered * w[..., None].astype(self.dtype)
        out = out.reshape(B, S, k, D).sum(axis=2).astype(self.dtype)
        return out, aux
