"""Feed-forward blocks: SwiGLU (llama-family) and GeLU (gpt-family)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.repair import RepairConfig, use
from ..distributed.sharding import constrain
from . import initializers as ini
from .module import ParamDef

# Hidden-activation constraint site, currently unconstrained beyond batch:
# forcing (B,S,F) feature-sharded was measured to cost 3.7× collective time
# on mistral-large train_4k (SP↔TP all-gathers every layer, fwd+bwd+remat)
# against a 6 GiB temp saving — XLA's propagated choice wins.  Kept as a
# named site for the §Perf iteration log.
_HID = ("act_batch", None, None)


@dataclasses.dataclass(frozen=True)
class SwiGLU:
    d_model: int
    d_ff: int
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")
    # parameter-path prefix for per-path on-read rules (README §RepairRule);
    # "" keeps the pathless read-rule binding
    path: str = ""

    def _path(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else ""

    def defs(self):
        lin = ini.fan_in()
        D, F = self.d_model, self.d_ff
        return {
            "w_gate": ParamDef((D, F), self.dtype, lin, ("embed", "mlp")),
            "w_up": ParamDef((D, F), self.dtype, lin, ("embed", "mlp")),
            "w_down": ParamDef((F, D), self.dtype, lin, ("mlp", "embed")),
        }

    def __call__(self, p, x):
        g = jnp.einsum(
            "bsd,df->bsf", x, use(p["w_gate"], self.rcfg, path=self._path("w_gate")),
            preferred_element_type=jnp.float32,
        )
        u = jnp.einsum(
            "bsd,df->bsf", x, use(p["w_up"], self.rcfg, path=self._path("w_up")),
            preferred_element_type=jnp.float32,
        )
        h = constrain((jax.nn.silu(g) * u).astype(self.dtype), _HID)
        return jnp.einsum(
            "bsf,fd->bsd", h, use(p["w_down"], self.rcfg, path=self._path("w_down")),
            preferred_element_type=jnp.float32,
        ).astype(self.dtype)


@dataclasses.dataclass(frozen=True)
class GeluMLP:
    d_model: int
    d_ff: int
    bias: bool = True
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")
    path: str = ""

    def _path(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else ""

    def defs(self):
        lin = ini.fan_in()
        D, F = self.d_model, self.d_ff
        d = {
            "w_up": ParamDef((D, F), self.dtype, lin, ("embed", "mlp")),
            "w_down": ParamDef((F, D), self.dtype, lin, ("mlp", "embed")),
        }
        if self.bias:
            d["b_up"] = ParamDef((F,), self.dtype, ini.zeros, ("mlp",))
            d["b_down"] = ParamDef((D,), self.dtype, ini.zeros, ("embed",))
        return d

    def __call__(self, p, x):
        h = jnp.einsum(
            "bsd,df->bsf", x, use(p["w_up"], self.rcfg, path=self._path("w_up")),
            preferred_element_type=jnp.float32,
        )
        if self.bias:
            h = h + use(p["b_up"], self.rcfg, path=self._path("b_up")).astype(h.dtype)
        h = constrain(jax.nn.gelu(h).astype(self.dtype), _HID)
        y = jnp.einsum(
            "bsf,fd->bsd", h, use(p["w_down"], self.rcfg, path=self._path("w_down")),
            preferred_element_type=jnp.float32,
        )
        if self.bias:
            y = y + use(p["b_down"], self.rcfg, path=self._path("b_down")).astype(y.dtype)
        return y.astype(self.dtype)
