"""Minimal functional layer zoo (no flax): def-driven params with logical
sharding axes, repair-aware reads, scan-friendly stacking."""
from . import (  # noqa: F401
    attention,
    initializers,
    layers,
    mlp,
    module,
    moe,
    rotary,
    ssm,
    xlstm,
)
