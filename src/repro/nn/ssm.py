"""Mamba2 block — chunked SSD (state-space dual) formulation.

TPU adaptation note (README §Workloads): the selective-scan CUDA kernel of the
original Mamba is replaced by the **chunked matmul form** of Mamba2/SSD —
within-chunk terms are plain einsums (MXU-friendly), cross-chunk state is a
short ``lax.scan`` over chunk summaries.  This is the TPU-native way to run
SSMs near the compute roofline instead of emulating a warp-level scan.

Recurrence (per head h, scalar decay):
    h_t = a_t · h_{t-1} + Δ_t · B_t ⊗ x_t          a_t = exp(Δ_t · A_h) ∈ (0,1)
    y_t = C_t · h_t + D_h · x_t

Approximate-memory note: the carried SSM state is long-lived in decode — a
NaN reaching it poisons *all future tokens* (the temporal analogue of the
paper's Fig. 1 row-poisoning), so ``decode_step`` scrubs the carried state
through ``core.repair.use`` every step in register mode.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from ..core.repair import RepairConfig, use
from . import initializers as ini
from .module import ParamDef

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class Mamba2:
    d_model: int
    d_state: int = 64            # N
    head_dim: int = 64           # P
    expand: int = 2
    conv_width: int = 4
    chunk: int = 128             # SSD chunk length Q
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        assert self.d_inner % self.head_dim == 0
        return self.d_inner // self.head_dim

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.d_state

    # ------------------------------------------------------------------ defs
    def defs(self):
        D, Din, N, H = self.d_model, self.d_inner, self.d_state, self.n_heads
        lin = ini.fan_in()
        d_in_proj = 2 * Din + 2 * N + H   # [z, x, B, C, dt]
        return {
            "in_proj": ParamDef((D, d_in_proj), self.dtype, lin, ("embed", "mlp")),
            "conv_w": ParamDef(
                (self.conv_width, self.conv_channels), self.dtype,
                ini.normal(0.1), (None, "mlp"),
            ),
            "conv_b": ParamDef((self.conv_channels,), self.dtype, ini.zeros, ("mlp",)),
            "A_log": ParamDef((H,), jnp.float32, ini.ones, ("heads",)),
            "D": ParamDef((H,), jnp.float32, ini.ones, ("heads",)),
            "dt_bias": ParamDef((H,), jnp.float32, ini.zeros, ("heads",)),
            "norm_scale": ParamDef((Din,), self.dtype, ini.ones, ("mlp",)),
            "out_proj": ParamDef((Din, D), self.dtype, lin, ("mlp", "embed")),
        }

    # ------------------------------------------------------------- pieces
    def _split_proj(self, p, x):
        Din, N, H = self.d_inner, self.d_state, self.n_heads
        proj = jnp.einsum(
            "bsd,de->bse", x, use(p["in_proj"], self.rcfg),
            preferred_element_type=jnp.float32,
        ).astype(self.dtype)
        z = proj[..., :Din]
        xBC = proj[..., Din : Din + Din + 2 * N]
        dt_raw = proj[..., Din + Din + 2 * N :]                 # (B,S,H)
        return z, xBC, dt_raw

    def _conv(self, p, xBC):
        """Causal depthwise conv over (B,S,C) with width W."""
        W = self.conv_width
        w = use(p["conv_w"], self.rcfg).astype(jnp.float32)      # (W,C)
        b = use(p["conv_b"], self.rcfg).astype(jnp.float32)
        xf = xBC.astype(jnp.float32)
        pad = jnp.pad(xf, ((0, 0), (W - 1, 0), (0, 0)))
        out = sum(
            pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :]
            for i in range(W)
        )
        return jax.nn.silu(out + b).astype(self.dtype)

    def _gated_norm(self, p, y, z):
        scale = use(p["norm_scale"], self.rcfg).astype(jnp.float32)
        yf = y.astype(jnp.float32)
        var = jnp.mean(yf * yf, axis=-1, keepdims=True)
        yn = yf * jax.lax.rsqrt(var + 1e-6) * scale
        return (yn * jax.nn.silu(z.astype(jnp.float32))).astype(self.dtype)

    # ------------------------------------------------------- full-sequence
    def __call__(self, p, x: jax.Array) -> jax.Array:
        B, S, _ = x.shape
        N, H, P, Q = self.d_state, self.n_heads, self.head_dim, self.chunk
        z, xBC, dt_raw = self._split_proj(p, x)
        xBC = self._conv(p, xBC)
        xs = xBC[..., : self.d_inner].reshape(B, S, H, P)
        Bm = xBC[..., self.d_inner : self.d_inner + N]           # (B,S,N)
        Cm = xBC[..., self.d_inner + N :]                        # (B,S,N)

        A = -jnp.exp(use(p["A_log"], self.rcfg))                 # (H,) < 0
        dt = jax.nn.softplus(
            dt_raw.astype(jnp.float32) + use(p["dt_bias"], self.rcfg)
        )                                                        # (B,S,H)
        y = _chunked_ssd(
            xs.astype(jnp.float32),
            Bm.astype(jnp.float32),
            Cm.astype(jnp.float32),
            dt,
            A,
            chunk=Q,
        )                                                        # (B,S,H,P) f32
        y = y + use(p["D"], self.rcfg)[None, None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(B, S, self.d_inner).astype(self.dtype)
        y = self._gated_norm(p, y, z)
        return jnp.einsum(
            "bse,ed->bsd", y, use(p["out_proj"], self.rcfg),
            preferred_element_type=jnp.float32,
        ).astype(self.dtype)

    # -------------------------------------------------------------- decode
    def cache_defs(self, batch: int):
        N, H, P, W, C = (
            self.d_state, self.n_heads, self.head_dim,
            self.conv_width, self.conv_channels,
        )
        return {
            "conv": ParamDef((batch, W - 1, C), self.dtype, ini.zeros,
                             ("batch", None, "mlp")),
            "ssm": ParamDef((batch, H, N, P), jnp.float32, ini.zeros,
                            ("batch", "heads", None, None)),
        }

    def decode_step(self, p, x, cache):
        """x: (B,1,D) -> (y (B,1,D), new cache).  O(1) in context length."""
        B = x.shape[0]
        N, H, P, W = self.d_state, self.n_heads, self.head_dim, self.conv_width
        z, xBC, dt_raw = self._split_proj(p, x)

        conv_state = use(cache["conv"], self.rcfg)               # (B,W-1,C)
        w = use(p["conv_w"], self.rcfg).astype(jnp.float32)
        b = use(p["conv_b"], self.rcfg).astype(jnp.float32)
        window = jnp.concatenate(
            [conv_state.astype(jnp.float32), xBC.astype(jnp.float32)], axis=1
        )                                                        # (B,W,C)
        conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w) + b)
        new_conv = window[:, 1:, :].astype(self.dtype)

        xs = conv_out[:, : self.d_inner].reshape(B, H, P)
        Bm = conv_out[:, self.d_inner : self.d_inner + N]        # (B,N)
        Cm = conv_out[:, self.d_inner + N :]

        A = -jnp.exp(use(p["A_log"], self.rcfg))
        dt = jax.nn.softplus(
            dt_raw[:, 0].astype(jnp.float32) + use(p["dt_bias"], self.rcfg)
        )                                                        # (B,H)
        a = jnp.exp(dt * A)                                      # (B,H)
        h = use(cache["ssm"], self.rcfg)                         # (B,H,N,P)
        h = a[..., None, None] * h + jnp.einsum(
            "bn,bh,bhp->bhnp", Bm, dt, xs
        )
        y = jnp.einsum("bn,bhnp->bhp", Cm, h)
        y = y + use(p["D"], self.rcfg)[None, :, None] * xs
        y = y.reshape(B, 1, self.d_inner).astype(self.dtype)
        y = self._gated_norm(p, y, z)
        out = jnp.einsum(
            "bse,ed->bsd", y, use(p["out_proj"], self.rcfg),
            preferred_element_type=jnp.float32,
        ).astype(self.dtype)
        return out, {"conv": new_conv, "ssm": h}


# ---------------------------------------------------------------------------
# Chunked SSD core (shared with xLSTM's mLSTM, which is the same recurrence
# plus a normalizer).
# ---------------------------------------------------------------------------


def _chunked_ssd(x, Bm, Cm, dt, A, *, chunk: int) -> jax.Array:
    """Chunked scan for  h_t = a_t h_{t-1} + (dt_t B_t) ⊗ x_t,  y_t = C_t·h_t.

    x: (B,S,H,P) f32; Bm/Cm: (B,S,N); dt: (B,S,H); A: (H,).
    Returns y (B,S,H,P) f32.
    """
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    xs = x.reshape(B, nc, Q, H, P)
    Bs = Bm.reshape(B, nc, Q, N)
    Cs = Cm.reshape(B, nc, Q, N)
    dts = dt.reshape(B, nc, Q, H)

    log_a = dts * A[None, None, None, :]                 # (B,nc,Q,H) ≤ 0
    La = jnp.cumsum(log_a, axis=2)                       # inclusive cumsum
    u = xs * dts[..., None]                              # Δ_t x_t

    # ---- intra-chunk: M_{iq,jk} = (C_i·B_j) exp(La_i - La_j), j ≤ i ----
    CB = jnp.einsum("bcqn,bckn->bcqk", Cs, Bs)           # (B,nc,Q,Q)
    dLa = La[:, :, :, None, :] - La[:, :, None, :, :]    # (B,nc,q,k,H)
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(dLa), 0.0)
    M = CB[..., None] * decay                            # (B,nc,q,k,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, u)

    # ---- chunk summaries ----
    La_end = La[:, :, -1, :]                             # (B,nc,H)
    decay_to_end = jnp.exp(La_end[:, :, None, :] - La)   # (B,nc,Q,H)
    S_c = jnp.einsum("bckn,bckh,bckhp->bchnp", Bs, decay_to_end, u)
    a_chunk = jnp.exp(La_end)                            # (B,nc,H)

    # ---- cross-chunk state scan ----
    def step(h_prev, inp):
        a_c, s_c = inp                                   # (B,H), (B,H,N,P)
        h = a_c[..., None, None] * h_prev + s_c
        return h, h_prev                                 # emit state *before* chunk

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_prevs = jax.lax.scan(
        step,
        h0,
        (a_chunk.transpose(1, 0, 2), S_c.transpose(1, 0, 2, 3, 4)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)           # (B,nc,H,N,P)

    # ---- inter-chunk contribution: exp(La_i) decays h_start to step i ----
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchnp->bcqhp", Cs, jnp.exp(La), h_prevs
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    return y
