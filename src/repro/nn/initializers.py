"""Parameter initializers (jax.nn.initializers-compatible signatures)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def normal(stddev: float = 0.02):
    def init(key, shape, dtype):
        return (jax.random.normal(key, shape, jnp.float32) * stddev).astype(dtype)

    return init


def zeros(key, shape, dtype):
    return jnp.zeros(shape, dtype)


def ones(key, shape, dtype):
    return jnp.ones(shape, dtype)


def fan_in(scale: float = 1.0):
    """LeCun-style 1/sqrt(fan_in); fan-in = second-to-last dim for matrices,
    last dim for embeddings used as (vocab, d)."""

    def init(key, shape, dtype):
        fi = shape[-2] if len(shape) >= 2 else shape[-1]
        std = scale / math.sqrt(max(fi, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init


def scaled_out(n_layers: int, scale: float = 1.0):
    """GPT-2-style output-projection scaling: 1/sqrt(2*L) on residual writes."""

    def init(key, shape, dtype):
        fi = shape[-2] if len(shape) >= 2 else shape[-1]
        std = scale / math.sqrt(max(fi, 1)) / math.sqrt(2.0 * max(n_layers, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)

    return init
