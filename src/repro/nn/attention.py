"""Grouped-query attention with RoPE, decode caches, and a chunked
(flash-style, online-softmax) path for long sequences.

Three execution paths:

  * ``direct``  — materializes (B,H,S,T) scores; used for short seqs/tests.
  * ``chunked`` — double ``lax.scan`` over query/kv blocks with running
    (max, denom) — O(S·blk) memory; auto-selected for seq ≥ 8192.  This is
    the jnp reference of the Pallas flash kernel in repro.kernels.
  * ``decode``  — single query position against a (possibly seq-sharded)
    KV cache; softmax collectives over the sharded axis are inserted by XLA.

The KV cache is a *protected approximate-memory resident* (the decode-shape
cells hold 100s of GB of it): reads go through ``core.repair.use`` in
register mode and the scrubbed-cache path in memory mode, exactly like
weights.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.repair import RepairConfig, use
from ..distributed.sharding import constrain
from . import initializers as ini
from .module import ParamDef
from .rotary import apply_rope

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class Attention:
    d_model: int
    n_heads: int
    n_kv: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0
    use_rope: bool = True
    causal: bool = True
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")
    # parameter-path prefix for per-path on-read rules (README §RepairRule);
    # "" keeps the pathless read-rule binding
    path: str = ""
    q_block: int = 512
    kv_block: int = 1024
    # Repeat KV heads to full H inside full-sequence attention: standard TP
    # practice when the model axis exceeds n_kv — the score einsums become
    # MHA-shaped and shard H-ways instead of capping at n_kv.  Identical
    # math; costs a G× widening of the K/V *activations* only (never the
    # cache).  Decode keeps the GQA form + seq-sharded cache instead.
    repeat_kv_for_tp: bool = True

    def _path(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else ""

    @property
    def groups(self) -> int:
        assert self.n_heads % self.n_kv == 0, "GQA requires n_kv | n_heads"
        return self.n_heads // self.n_kv

    # ------------------------------------------------------------------ defs
    def defs(self):
        H, K, Dh, D = self.n_heads, self.n_kv, self.head_dim, self.d_model
        lin = ini.fan_in()
        d = {
            "wq": ParamDef((D, H * Dh), self.dtype, lin, ("embed", "heads")),
            "wk": ParamDef((D, K * Dh), self.dtype, lin, ("embed", "kv")),
            "wv": ParamDef((D, K * Dh), self.dtype, lin, ("embed", "kv")),
            "wo": ParamDef((H * Dh, D), self.dtype, lin, ("heads", "embed")),
        }
        if self.qkv_bias:
            d["bq"] = ParamDef((H * Dh,), self.dtype, ini.zeros, ("heads",))
            d["bk"] = ParamDef((K * Dh,), self.dtype, ini.zeros, ("kv",))
            d["bv"] = ParamDef((K * Dh,), self.dtype, ini.zeros, ("kv",))
        return d

    # ------------------------------------------------------------- helpers
    def _qkv(self, p, x, kv_x=None):
        """Project to q,k,v.  (B,S,D) -> (B,S,H,Dh)/(B,T,K,Dh)."""
        kv_x = x if kv_x is None else kv_x
        B, S, _ = x.shape
        T = kv_x.shape[1]
        wq = use(p["wq"], self.rcfg, path=self._path("wq"))
        wk = use(p["wk"], self.rcfg, path=self._path("wk"))
        wv = use(p["wv"], self.rcfg, path=self._path("wv"))
        q = jnp.einsum("bsd,dh->bsh", x, wq, preferred_element_type=jnp.float32)
        k = jnp.einsum("btd,dh->bth", kv_x, wk, preferred_element_type=jnp.float32)
        v = jnp.einsum("btd,dh->bth", kv_x, wv, preferred_element_type=jnp.float32)
        if self.qkv_bias:
            q = q + use(p["bq"], self.rcfg, path=self._path("bq")).astype(q.dtype)
            k = k + use(p["bk"], self.rcfg, path=self._path("bk")).astype(k.dtype)
            v = v + use(p["bv"], self.rcfg, path=self._path("bv")).astype(v.dtype)
        q = q.astype(self.dtype).reshape(B, S, self.n_heads, self.head_dim)
        k = k.astype(self.dtype).reshape(B, T, self.n_kv, self.head_dim)
        v = v.astype(self.dtype).reshape(B, T, self.n_kv, self.head_dim)
        # head-sharded attention compute (the kv spec degrades to replicated
        # when n_kv doesn't divide the model axis — GQA small-kv case)
        act = ("act_batch", "act_seq", "act_heads", None)
        return constrain(q, act), constrain(k, act), constrain(v, act)

    def _rope(self, q, k, q_pos, k_pos):
        if not self.use_rope:
            return q, k
        q = apply_rope(q, q_pos, theta=self.rope_theta, rotary_pct=self.rotary_pct)
        k = apply_rope(k, k_pos, theta=self.rope_theta, rotary_pct=self.rotary_pct)
        return q, k

    def _out(self, p, ctx):
        B, S = ctx.shape[:2]
        wo = use(p["wo"], self.rcfg, path=self._path("wo"))
        ctx = ctx.reshape(B, S, self.n_heads * self.head_dim)
        return jnp.einsum(
            "bsh,hd->bsd", ctx, wo, preferred_element_type=jnp.float32
        ).astype(self.dtype)

    # ------------------------------------------------------- full-seq paths
    def __call__(
        self,
        p,
        x: jax.Array,                      # (B, S, D)
        positions: Optional[jax.Array] = None,
        kv_x: Optional[jax.Array] = None,  # cross-attention source
        impl: str = "auto",
    ) -> jax.Array:
        B, S, _ = x.shape
        if positions is None:
            positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        q, k, v = self._qkv(p, x, kv_x)
        if kv_x is None:
            q, k = self._rope(q, k, positions, positions)
        if self.repeat_kv_for_tp and self.groups > 1:
            k = jnp.repeat(k, self.groups, axis=2)
            v = jnp.repeat(v, self.groups, axis=2)
            act = ("act_batch", "act_seq", "act_heads", None)
            k, v = constrain(k, act), constrain(v, act)
        causal = self.causal and kv_x is None
        T = k.shape[1]
        if impl == "auto":
            # chunked (flash-style) is the production path: it never
            # materializes the (S,T) score matrix (3 GiB/device at 4k seen
            # with direct).  direct remains for short sequences and oracles.
            impl = "chunked" if max(S, T) >= 2048 else "direct"
        if impl == "chunked":
            ctx = _chunked_attention(
                q, k, v, causal=causal, q_block=self.q_block,
                kv_block=self.kv_block,
            )
        else:
            ctx = _direct_attention(q, k, v, causal=causal)
        return self._out(p, ctx)

    # -------------------------------------------------------------- decode
    def cache_defs(self, batch: int, max_seq: int):
        """KV cache parameter-like defs (lives in approximate memory)."""
        K, Dh = self.n_kv, self.head_dim
        shape = (batch, max_seq, K, Dh)
        axes = ("batch", "kv_seq", "kv", None)
        return {
            "k": ParamDef(shape, self.dtype, ini.zeros, axes),
            "v": ParamDef(shape, self.dtype, ini.zeros, axes),
        }

    def paged_cache_defs(self, n_pages: int, page_size: int, n_layers: int = 1):
        """Paged KV layout: the pool is a block-table-indexed array of
        fixed-size pages, page axis LEADING so one page is one contiguous
        row — the unit of region accounting, BER injection, and targeted
        scrubbing in the serving engine (README §Serving engine).  A page
        holds ``page_size`` token positions across all ``n_layers`` layers."""
        K, Dh = self.n_kv, self.head_dim
        shape = (n_pages, n_layers, page_size, K, Dh)
        axes = ("kv_pages", None, "kv_seq", "kv", None)
        return {
            "k": ParamDef(shape, self.dtype, ini.zeros, axes),
            "v": ParamDef(shape, self.dtype, ini.zeros, axes),
        }

    def decode(
        self,
        p,
        x: jax.Array,        # (B, S, D) hidden; S==1 decode, S>1 chunked prefill
        cache,               # {"k","v"}: (B, S_max, K, Dh)
        pos: jax.Array,      # i32 write position: scalar (uniform batch) or (B,)
        *,
        update_cache: bool = True,
    ):
        B, S = x.shape[:2]
        q, k_new, v_new = self._qkv(p, x)
        pos = jnp.asarray(pos, jnp.int32)
        start = jnp.broadcast_to(pos.reshape(-1), (B,))      # (B,) per-request
        pos_arr = start[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
        q, k_new = self._rope(q, k_new, pos_arr, pos_arr)

        ck = use(cache["k"], self.rcfg)
        cv = use(cache["v"], self.rcfg)
        if update_cache:
            def upd(c, new, s):          # (T,K,Dh), (S,K,Dh), scalar
                return jax.lax.dynamic_update_slice(
                    c, new.astype(c.dtype), (s, 0, 0)
                )
            ck = jax.vmap(upd)(ck, k_new, start)
            cv = jax.vmap(upd)(cv, v_new, start)

        G = self.groups
        K, Dh = self.n_kv, self.head_dim
        qg = q.reshape(B, S, K, G, Dh)
        scores = jnp.einsum(
            "bqkgd,btkd->bkgqt", qg, ck, preferred_element_type=jnp.float32
        ) / math.sqrt(Dh)
        t = jnp.arange(ck.shape[1])
        # query s may attend to cache positions t <= start + s (causal within
        # the new chunk, everything before it unconditionally)
        valid = t[None, None, None, None, :] <= pos_arr[:, None, None, :, None]
        scores = jnp.where(valid, scores, NEG_INF)
        w = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum(
            "bkgqt,btkd->bqkgd", w.astype(cv.dtype), cv,
            preferred_element_type=jnp.float32,
        ).astype(self.dtype)
        ctx = ctx.reshape(B, S, self.n_heads, Dh)
        out = self._out(p, ctx)
        return out, {"k": ck, "v": cv}

    def paged_decode(
        self,
        p,
        x: jax.Array,            # (B, 1, D) hidden — single decode token
        k_pages: jax.Array,      # (P, L, pg, K, Dh) pool leaf, page-major
        v_pages: jax.Array,
        block_tables: jax.Array, # (B, M) int32, null-padded
        positions: jax.Array,    # (B,) int32 write position == last valid pos
        layer: jax.Array,        # int32 scalar — this block's L row
        *,
        detector_k=None,
        detector_v=None,
        policy: str = "zero",
        constant: float = 0.0,
        policy_k=None,
        constant_k=None,
        policy_v=None,
        constant_v=None,
        split_k: int = 1,
        update_cache: bool = True,
        shard=None,
    ):
        """Decode straight off the paged pool — no gathered view.

        The new K/V land as ONE position-slot write per request
        (``.at[page, layer, offset]`` — the surviving remnant of the old
        full-view scatter), then the Pallas paged-attention kernel consumes
        the pool leaves + block tables directly, repairing fatal KV lanes
        in VMEM as it streams them (README §Serving engine).  Detector /
        fill come from the pool leaves' assigned ``RepairRule`` (the engine
        resolves them; ``None`` disables detection for that operand).
        ``policy_k``/``policy_v`` (+ constants) override the shared fill
        per operand — mixed-fill RuleSets stay on the fused path.
        ``split_k > 1`` partitions the page walk across that many grid
        cells (flash decoding) with a log-sum-exp merge; per-page counts
        stay bit-identical to the serial walk.

        ``shard`` is ``(mesh, axis)`` when the pool's page axis is
        genuinely sharded over a mesh axis: the kernel then runs under
        ``shard_map`` with per-device block-table ownership, so the page
        walk never crosses device boundaries (README §Serving engine,
        "Sharded decode & load testing").  The new-K/V slot write above
        stays a plain GSPMD scatter.

        Returns ``(out (B,1,D), k_pages', v_pages', slot_counts (B,M),
        counts int32[8])``.
        """
        from ..kernels import paged_attention as paged_kernel

        B, S = x.shape[:2]
        assert S == 1, "paged_decode consumes exactly one token per request"
        q, k_new, v_new = self._qkv(p, x)
        pos = jnp.asarray(positions, jnp.int32).reshape(B)
        pos_arr = pos[:, None]                                # (B, 1)
        q, k_new = self._rope(q, k_new, pos_arr, pos_arr)

        if update_cache:
            pg = k_pages.shape[2]
            slot = jnp.arange(B)
            page = jnp.asarray(block_tables, jnp.int32)[slot, pos // pg]
            off = pos % pg
            k_pages = k_pages.at[page, layer, off].set(
                k_new[:, 0].astype(k_pages.dtype)
            )
            v_pages = v_pages.at[page, layer, off].set(
                v_new[:, 0].astype(v_pages.dtype)
            )

        if shard is not None:
            mesh, axis = shard
            ctx, slot_counts, counts = paged_kernel.paged_attention_sharded(
                q[:, 0], k_pages, v_pages, block_tables, pos, layer,
                mesh=mesh, axis=axis, splits=max(split_k, 1),
                policy=policy, constant=constant,
                detector_k=detector_k, detector_v=detector_v,
                policy_k=policy_k, constant_k=constant_k,
                policy_v=policy_v, constant_v=constant_v,
            )
        elif split_k > 1:
            ctx, slot_counts, counts = paged_kernel.paged_attention_splitk_raw(
                q[:, 0], k_pages, v_pages, block_tables, pos, layer,
                splits=split_k,
                policy=policy, constant=constant,
                detector_k=detector_k, detector_v=detector_v,
                policy_k=policy_k, constant_k=constant_k,
                policy_v=policy_v, constant_v=constant_v,
            )
        else:
            ctx, slot_counts, counts = paged_kernel.paged_attention_raw(
                q[:, 0], k_pages, v_pages, block_tables, pos, layer,
                policy=policy, constant=constant,
                detector_k=detector_k, detector_v=detector_v,
                policy_k=policy_k, constant_k=constant_k,
                policy_v=policy_v, constant_v=constant_v,
            )
        out = self._out(p, ctx[:, None])                      # (B, 1, D)
        return out, k_pages, v_pages, slot_counts, counts

    def paged_prefill(
        self,
        p,
        x: jax.Array,            # (B, C, D) hidden — one causal chunk
        k_pages: jax.Array,      # (P, L, pg, K, Dh) pool leaf, page-major
        v_pages: jax.Array,
        block_tables: jax.Array, # (B, M) int32, null-padded
        q_start: jax.Array,      # (B,) int32 — context position of chunk row 0
        q_len: jax.Array,        # (B,) int32 — valid rows in the chunk
        layer: jax.Array,        # int32 scalar — this block's L row
        *,
        detector_k=None,
        detector_v=None,
        policy: str = "zero",
        constant: float = 0.0,
        policy_k=None,
        constant_k=None,
        policy_v=None,
        constant_v=None,
        update_cache: bool = True,
        shard=None,
    ):
        """Chunked prefill straight off the paged pool — no gathered view.

        The chunk's K/V scatter into the request's pages position-by-
        position, then the chunked-q Pallas kernel attends over the block
        tables with the same fused on-read repair as ``paged_decode``.

        Padded chunk rows (``row >= q_len``) must not write: a write of
        zeros would silently HEAL any flip parked in an unwritten lane
        (the gathered path leaves those lanes untouched), and a write of
        garbage could fabricate detectable faults.  They are redirected to
        re-write the request's last valid position with its own value —
        duplicate scatter indices carrying identical payloads are
        deterministic, and the pool stays bit-identical to the gathered
        path's write set.

        Returns ``(out (B,C,D), k_pages', v_pages', slot_counts (B,M),
        counts int32[8])`` — out rows past ``q_len`` are garbage the caller
        discards.
        """
        from ..kernels import paged_attention as paged_kernel

        B, C = x.shape[:2]
        q, k_new, v_new = self._qkv(p, x)
        qs = jnp.asarray(q_start, jnp.int32).reshape(B)
        ql = jnp.asarray(q_len, jnp.int32).reshape(B)
        pos_arr = qs[:, None] + jnp.arange(C, dtype=jnp.int32)[None, :]
        q, k_new = self._rope(q, k_new, pos_arr, pos_arr)

        if update_cache:
            pg = k_pages.shape[2]
            rows = jnp.arange(C, dtype=jnp.int32)[None, :]     # (1, C)
            valid = rows < ql[:, None]                         # (B, C)
            last = jnp.maximum(ql - 1, 0)                      # (B,)
            safe_pos = jnp.where(valid, pos_arr, (qs + last)[:, None])
            bslot = jnp.broadcast_to(
                jnp.arange(B, dtype=jnp.int32)[:, None], (B, C)
            )
            page = jnp.asarray(block_tables, jnp.int32)[bslot, safe_pos // pg]
            off = safe_pos % pg

            def dedup(new):                                    # (B, C, K, Dh)
                lastv = jnp.take_along_axis(
                    new, last[:, None, None, None], axis=1
                )
                return jnp.where(valid[..., None, None], new, lastv)

            k_pages = k_pages.at[page, layer, off].set(
                dedup(k_new).astype(k_pages.dtype)
            )
            v_pages = v_pages.at[page, layer, off].set(
                dedup(v_new).astype(v_pages.dtype)
            )

        if shard is not None:
            mesh, axis = shard
            ctx, slot_counts, counts = paged_kernel.paged_prefill_sharded(
                q, k_pages, v_pages, block_tables, qs, layer,
                mesh=mesh, axis=axis,
                policy=policy, constant=constant,
                detector_k=detector_k, detector_v=detector_v,
                policy_k=policy_k, constant_k=constant_k,
                policy_v=policy_v, constant_v=constant_v,
            )
        else:
            ctx, slot_counts, counts = paged_kernel.paged_prefill_raw(
                q, k_pages, v_pages, block_tables, qs, layer,
                policy=policy, constant=constant,
                detector_k=detector_k, detector_v=detector_v,
                policy_k=policy_k, constant_k=constant_k,
                policy_v=policy_v, constant_v=constant_v,
            )
        out = self._out(p, ctx)                               # (B, C, D)
        return out, k_pages, v_pages, slot_counts, counts

    def decode_cross(self, p, x, cache, enc_len: Optional[int] = None):
        """Cross-attention decode against a precomputed encoder KV cache."""
        B = x.shape[0]
        wq = use(p["wq"], self.rcfg, path=self._path("wq"))
        q = jnp.einsum("bsd,dh->bsh", x, wq, preferred_element_type=jnp.float32)
        if self.qkv_bias:
            q = q + use(p["bq"], self.rcfg, path=self._path("bq")).astype(q.dtype)
        q = q.astype(self.dtype).reshape(B, 1, self.n_heads, self.head_dim)
        ck = use(cache["k"], self.rcfg)
        cv = use(cache["v"], self.rcfg)
        G, K, Dh = self.groups, self.n_kv, self.head_dim
        qg = q.reshape(B, 1, K, G, Dh)
        scores = jnp.einsum(
            "bqkgd,btkd->bkgqt", qg, ck, preferred_element_type=jnp.float32
        ) / math.sqrt(Dh)
        w = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum(
            "bkgqt,btkd->bqkgd", w.astype(cv.dtype), cv,
            preferred_element_type=jnp.float32,
        ).astype(self.dtype)
        return self._out(p, ctx.reshape(B, 1, self.n_heads, Dh))


# ---------------------------------------------------------------------------
# Attention math.
# ---------------------------------------------------------------------------


# GQA score tensors shard over (batch, kv): a single model axis caps
# attention-score TP at n_kv ways (README §Sharding; repeat-KV lifts it).
_GQA_ACT = ("act_batch", None, "act_seq", None)


def _gqa_scores(q, k):
    """(B,S,H,Dh) x (B,T,K,Dh) -> (B,K,G,S,T) f32 scaled scores."""
    B, S, H, Dh = q.shape
    K = k.shape[2]
    G = H // K
    qg = constrain(
        q.reshape(B, S, K, G, Dh), ("act_batch", "act_seq", "act_heads", None, None)
    )
    s = jnp.einsum(
        "bskgd,btkd->bkgst", qg, k, preferred_element_type=jnp.float32
    ) / math.sqrt(Dh)
    return constrain(s, ("act_batch", "act_heads", None, "act_seq", None))


def _direct_attention(q, k, v, *, causal: bool) -> jax.Array:
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    scores = _gqa_scores(q, k)                       # (B,K,G,S,T) f32
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum(
        "bkgst,btkd->bskgd", w.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return ctx.reshape(B, S, H, Dh).astype(q.dtype)


def _chunked_attention(
    q, k, v, *, causal: bool, q_block: int, kv_block: int
) -> jax.Array:
    """Online-softmax attention, O(blk²) live memory.  jnp reference of the
    Pallas flash kernel (kernels/repair_attention.py shares this oracle)."""
    B, S, H, Dh = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qb = min(q_block, S)
    kb = min(kv_block, T)
    assert S % qb == 0 and T % kb == 0, (S, qb, T, kb)
    nq, nk = S // qb, T // kb

    qg = q.reshape(B, nq, qb, K, G, Dh).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, K, G, qb, Dh)
    ks = k.reshape(B, nk, kb, K, Dh).transpose(1, 0, 3, 2, 4)  # (nk,B,K,kb,Dh)
    vs = v.reshape(B, nk, kb, K, Dh).transpose(1, 0, 3, 2, 4)

    scale = 1.0 / math.sqrt(Dh)

    def q_step(_, qi_blk):
        qi, q_blk = qi_blk

        def kv_step(carry, kj_blk):
            acc, m, l = carry
            kj, k_blk, v_blk = kj_blk
            s = jnp.einsum(
                "bkgqd,bktd->bkgqt", q_blk, k_blk,
                preferred_element_type=jnp.float32,
            ) * scale
            if causal:
                qpos = qi * qb + jnp.arange(qb)
                kpos = kj * kb + jnp.arange(kb)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum(
                "bkgqt,bktd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, K, G, qb, Dh), jnp.float32)
        m0 = jnp.full((B, K, G, qb), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, qb), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), (jnp.arange(nk), ks, vs)
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return None, (qi, out)

    _, (_, outs) = jax.lax.scan(q_step, None, (jnp.arange(nq), qg))
    # outs: (nq, B, K, G, qb, Dh) -> (B, S, H, Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, S, H, Dh)
    return out.astype(q.dtype)
