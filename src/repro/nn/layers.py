"""Basic layers: Linear, Embedding, RMSNorm, LayerNorm.

Every parameter *read* goes through ``core.repair.use`` — in register mode
that is the paper's use-site repair (detect+select on each consumption); in
memory/off modes it is the identity, so the production HLO carries zero
overhead beyond the chosen mode.  Matmuls accumulate in f32
(``preferred_element_type``) regardless of the bf16 storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.repair import RepairConfig, use
from . import initializers as ini
from .module import ParamDef


@dataclasses.dataclass(frozen=True)
class Linear:
    """y = x @ W (+ b).  Logical axes supplied by the caller."""

    d_in: int
    d_out: int
    axes: Tuple[Optional[str], Optional[str]]
    bias: bool = False
    dtype: jnp.dtype = jnp.bfloat16
    init: object = None
    rcfg: RepairConfig = RepairConfig(mode="off")
    path: str = ""

    def _path(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else ""

    def defs(self):
        init = self.init or ini.fan_in()
        d = {
            "w": ParamDef((self.d_in, self.d_out), self.dtype, init, self.axes)
        }
        if self.bias:
            d["b"] = ParamDef((self.d_out,), self.dtype, ini.zeros, (self.axes[1],))
        return d

    def __call__(self, p, x):
        w = use(p["w"], self.rcfg, path=self._path("w"))
        y = jnp.einsum(
            "...i,io->...o", x, w, preferred_element_type=jnp.float32
        ).astype(x.dtype)
        if self.bias:
            y = y + use(p["b"], self.rcfg, path=self._path("b")).astype(y.dtype)
        return y


@dataclasses.dataclass(frozen=True)
class Embedding:
    """Token embedding (vocab, d).  Also provides the tied readout."""

    vocab: int
    d_model: int
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")
    path: str = ""

    def _path(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else ""

    def defs(self):
        return {
            "table": ParamDef(
                (self.vocab, self.d_model),
                self.dtype,
                ini.normal(0.02),
                ("vocab", "embed"),
            )
        }

    def __call__(self, p, tokens):
        table = use(p["table"], self.rcfg, path=self._path("table"))
        return jnp.take(table, tokens, axis=0)

    def attend(self, p, x):
        """Tied readout: logits = x @ table.T  (f32 accumulation)."""
        table = use(p["table"], self.rcfg, path=self._path("table"))
        return jnp.einsum(
            "...d,vd->...v", x, table, preferred_element_type=jnp.float32
        )


@dataclasses.dataclass(frozen=True)
class RMSNorm:
    d: int
    eps: float = 1e-6
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")
    path: str = ""

    def _path(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else ""

    def defs(self):
        return {"scale": ParamDef((self.d,), self.dtype, ini.ones, ("embed",))}

    def __call__(self, p, x):
        scale = use(p["scale"], self.rcfg, path=self._path("scale"))
        xf = x.astype(jnp.float32)
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + self.eps)
        return (y * scale.astype(jnp.float32)).astype(x.dtype)


@dataclasses.dataclass(frozen=True)
class LayerNorm:
    d: int
    eps: float = 1e-5
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")
    path: str = ""

    def _path(self, name: str) -> str:
        return f"{self.path}/{name}" if self.path else ""

    def defs(self):
        return {
            "scale": ParamDef((self.d,), self.dtype, ini.ones, ("embed",)),
            "bias": ParamDef((self.d,), self.dtype, ini.zeros, ("embed",)),
        }

    def __call__(self, p, x):
        scale = use(p["scale"], self.rcfg, path=self._path("scale"))
        bias = use(p["bias"], self.rcfg, path=self._path("bias"))
        xf = x.astype(jnp.float32)
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + self.eps)
        return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(
            x.dtype
        )
