"""xLSTM blocks: mLSTM (matrix memory, chunked-parallel) and sLSTM (scalar
memory, sequential scan).

mLSTM recurrence (per head, d_k×d_v matrix memory — arXiv:2405.04517 §2.3):
    C_t = f_t C_{t-1} + i_t k_t v_tᵀ          n_t = f_t n_{t-1} + i_t k_t
    y_t = (q_tᵀ C_t) / max(|q_tᵀ n_t|, 1)
with exponential gating stabilized by the running max m_t:
    m_t = max(log f_t + m_{t-1}, log i_t)
    i'_t = exp(log i_t − m_t),  f'_t = exp(log f_t + m_{t-1} − m_t)

The chunked form mirrors Mamba2's SSD (nn/ssm.py): within-chunk terms are
einsums over a decay matrix, cross-chunk state is a short scan.  We use the
log-sigmoid forget parametrization (always ≤ 0, unconditionally stable) and
per-chunk max-stabilization of the input gates — the variant recommended for
inference-stable xLSTM.

sLSTM is inherently sequential (recurrent weights feed h_{t-1} back through a
nonlinearity — no parallel form exists); it runs as ``lax.scan`` over time
with per-head block-diagonal recurrent weights.  Its FLOPs are O(S·d²_head·H)
— negligible next to mLSTM blocks at our ratios (1 sLSTM per 8 blocks).

Approximate-memory note: the mLSTM matrix memory C is the arch's long-lived
decode state (the KV-cache analogue) — protected and scrubbed like the SSM
state in nn/ssm.py.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.repair import RepairConfig, use
from ..distributed.sharding import constrain
from . import initializers as ini
from .module import ParamDef

# Activation constraint sites (§Perf iteration 1, xlstm-1.3b train_4k):
# without them XLA's propagation loses the batch sharding through the
# reshape/moveaxis churn of the chunked forms — measured 16× replicated
# compute and full-batch all-gathers inside every mLSTM block.
_BSE = ("act_batch", "act_seq", "act_heads")          # (B, S, d_inner-ish)
_BSHP = ("act_batch", "act_seq", None, "act_heads")   # (B, S, H, P): shard P


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLSTM:
    d_model: int
    n_heads: int
    proj_factor: float = 2.0
    conv_width: int = 4
    chunk: int = 128
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")

    @property
    def d_inner(self) -> int:
        return int(self.d_model * self.proj_factor)

    @property
    def head_dim(self) -> int:
        assert self.d_inner % self.n_heads == 0
        return self.d_inner // self.n_heads

    def defs(self):
        D, Din, H = self.d_model, self.d_inner, self.n_heads
        lin = ini.fan_in()
        return {
            "w_up": ParamDef((D, 2 * Din), self.dtype, lin, ("embed", "mlp")),
            "conv_w": ParamDef((self.conv_width, Din), self.dtype,
                               ini.normal(0.1), (None, "mlp")),
            "conv_b": ParamDef((Din,), self.dtype, ini.zeros, ("mlp",)),
            "w_q": ParamDef((Din, Din), self.dtype, lin, ("mlp", "heads")),
            "w_k": ParamDef((Din, Din), self.dtype, lin, ("mlp", "heads")),
            "w_v": ParamDef((Din, Din), self.dtype, lin, ("mlp", "heads")),
            "w_if": ParamDef((Din, 2 * H), jnp.float32, ini.normal(0.02),
                             ("mlp", "heads")),
            "b_if": ParamDef((2 * H,), jnp.float32, ini.zeros, ("heads",)),
            "norm_scale": ParamDef((Din,), self.dtype, ini.ones, ("mlp",)),
            "w_down": ParamDef((Din, D), self.dtype, lin, ("mlp", "embed")),
        }

    def _conv(self, p, x):
        W = self.conv_width
        w = use(p["conv_w"], self.rcfg).astype(jnp.float32)
        b = use(p["conv_b"], self.rcfg).astype(jnp.float32)
        xf = x.astype(jnp.float32)
        pad = jnp.pad(xf, ((0, 0), (W - 1, 0), (0, 0)))
        out = sum(
            pad[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(W)
        )
        return jax.nn.silu(out + b).astype(self.dtype)

    def _qkvif(self, p, xc, x_inner):
        B, S, _ = xc.shape
        H, P = self.n_heads, self.head_dim
        # bf16 partial sums for q/k/v: these projections are row-parallel
        # (contraction dim model-sharded), so their per-shard partials are
        # ALL-REDUCED — the wire-dominant collective of the xlstm train cell
        # (§Perf iteration 3).  An f32 preferred type put f32 on the wire
        # (the cast can't be hoisted above the collective); per-shard bf16
        # partials halve it.  Each shard's 256-long contraction still
        # accumulates in f32 inside the MXU.
        q = jnp.einsum("bse,eh->bsh", xc, use(p["w_q"], self.rcfg),
                       preferred_element_type=self.dtype)
        k = jnp.einsum("bse,eh->bsh", xc, use(p["w_k"], self.rcfg),
                       preferred_element_type=self.dtype)
        v = jnp.einsum("bse,eh->bsh", x_inner, use(p["w_v"], self.rcfg),
                       preferred_element_type=self.dtype)
        gif = jnp.einsum("bse,eh->bsh", xc.astype(jnp.float32),
                         use(p["w_if"], self.rcfg)) + use(p["b_if"], self.rcfg)
        q = (q.reshape(B, S, H, P) / (P ** 0.5)).astype(self.dtype)
        k = k.reshape(B, S, H, P)
        v = v.reshape(B, S, H, P)
        log_i = gif[..., :H]                              # input gate, pre-exp
        log_f = jax.nn.log_sigmoid(gif[..., H:])          # forget gate ≤ 0
        return q, k, v, log_i, log_f

    def __call__(self, p, x: jax.Array) -> jax.Array:
        B, S, D = x.shape
        up = jnp.einsum("bsd,de->bse", x, use(p["w_up"], self.rcfg),
                        preferred_element_type=jnp.float32).astype(self.dtype)
        up = constrain(up, _BSE)
        x_inner, z = up[..., : self.d_inner], up[..., self.d_inner :]
        xc = self._conv(p, x_inner)
        q, k, v, log_i, log_f = self._qkvif(p, xc, x_inner)
        q, k, v = (constrain(t, _BSHP) for t in (q, k, v))
        y = _chunked_mlstm(q, k, v, log_i, log_f, chunk=self.chunk)
        y = constrain(y, _BSHP)                           # (B,S,H,P) f32
        y = y.reshape(B, S, self.d_inner)
        scale = use(p["norm_scale"], self.rcfg).astype(jnp.float32)
        var = jnp.mean(y * y, axis=-1, keepdims=True)
        y = y * jax.lax.rsqrt(var + 1e-6) * scale
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(self.dtype)
        return jnp.einsum("bse,ed->bsd", y, use(p["w_down"], self.rcfg),
                          preferred_element_type=jnp.float32).astype(self.dtype)

    # -------------------------------------------------------------- decode
    def cache_defs(self, batch: int):
        H, P, W = self.n_heads, self.head_dim, self.conv_width
        return {
            "conv": ParamDef((batch, W - 1, self.d_inner), self.dtype,
                             ini.zeros, ("batch", None, "mlp")),
            "C": ParamDef((batch, H, P, P), jnp.float32, ini.zeros,
                          ("batch", "heads", None, None)),
            "n": ParamDef((batch, H, P), jnp.float32, ini.zeros,
                          ("batch", "heads", None)),
            "m": ParamDef((batch, H), jnp.float32, ini.zeros,
                          ("batch", "heads")),
        }

    def decode_step(self, p, x, cache):
        B = x.shape[0]
        H, P = self.n_heads, self.head_dim
        up = jnp.einsum("bsd,de->bse", x, use(p["w_up"], self.rcfg),
                        preferred_element_type=jnp.float32).astype(self.dtype)
        x_inner, z = up[..., : self.d_inner], up[..., self.d_inner :]

        conv_state = use(cache["conv"], self.rcfg)
        w = use(p["conv_w"], self.rcfg).astype(jnp.float32)
        b = use(p["conv_b"], self.rcfg).astype(jnp.float32)
        window = jnp.concatenate(
            [conv_state.astype(jnp.float32), x_inner.astype(jnp.float32)], axis=1
        )
        xc = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w) + b)[:, None, :]
        xc = xc.astype(self.dtype)
        new_conv = window[:, 1:, :].astype(self.dtype)

        q, k, v, log_i, log_f = self._qkvif(p, xc, x_inner)
        q, k, v = q[:, 0], k[:, 0], v[:, 0]               # (B,H,P)
        log_i, log_f = log_i[:, 0], log_f[:, 0]           # (B,H)

        C = use(cache["C"], self.rcfg)
        n = use(cache["n"], self.rcfg)
        m = use(cache["m"], self.rcfg)
        m_new = jnp.maximum(log_f + m, log_i)
        i_s = jnp.exp(log_i - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        C = f_s[..., None, None] * C + i_s[..., None, None] * (
            k[..., :, None] * v[..., None, :]
        )
        n = f_s[..., None] * n + i_s[..., None] * k
        num = jnp.einsum("bhp,bhpq->bhq", q, C)
        # stabilized normalizer: true den = q·n~·exp(m); clamp |den|≥1 becomes
        # max(|q·n~|, exp(−m)) after factoring exp(m) out of num/den.
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhp,bhp->bh", q, n)), jnp.exp(-m_new)
        )
        y = (num / den[..., None]).reshape(B, 1, self.d_inner)

        scale = use(p["norm_scale"], self.rcfg).astype(jnp.float32)
        var = jnp.mean(y * y, axis=-1, keepdims=True)
        y = y * jax.lax.rsqrt(var + 1e-6) * scale
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(self.dtype)
        out = jnp.einsum("bse,ed->bsd", y, use(p["w_down"], self.rcfg),
                         preferred_element_type=jnp.float32).astype(self.dtype)
        return out, {"conv": new_conv, "C": C, "n": n, "m": m_new}


def _chunked_mlstm(q, k, v, log_i, log_f, *, chunk: int) -> jax.Array:
    """Chunked-parallel mLSTM with per-chunk max stabilization.

    q,k,v: (B,S,H,P) f32;  log_i/log_f: (B,S,H).
    Unstabilized target, with F = within-chunk cumsum(log_f):

        w_tj = exp(F_t − F_j + log_i_j)(q_t·k_j)           (j ≤ t, same chunk)
        y_t  = (Σ_j w_tj v_j + exp(F_t) q_t·C_start)
             / max(|Σ_j w_tj + exp(F_t) q_t·n_start| , 1)

    Factoring exp(F_t + m*) out of both numerator and denominator, where
    m* = max(m_prev, max_j b_j) and b_j = log_i_j − F_j, leaves every
    remaining exponent ≤ 0:

        W~_tj   = (q_t·k_j) exp(b_j − m*)                  (tril-masked)
        state   = exp(m_prev − m*) scaling on (C~, n~)
        y_t     = num~_t / max(|den~_t|, exp(−F_t − m*))
        C~_end  = exp(m_prev − m*) C~_start + Σ_j exp(b_j − m*) k_j v_jᵀ
        m_end   = F_end + m*        (carried to the next chunk)

    Note F_end cancels out of the state update entirely — only the carried
    stabilizer m tracks it.
    """
    B, S, H, P = q.shape
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    def r(x):
        return x.reshape(B, nc, Q, *x.shape[2:])

    qs, ks, vs = r(q), r(k), r(v)
    li, lf = r(log_i), r(log_f)
    F = jnp.cumsum(lf, axis=2)                            # (B,nc,Q,H) ≤ 0
    F_end = F[:, :, -1, :]                                # (B,nc,H)
    b = li - F                                            # source exponents
    m_loc = jnp.max(b, axis=2)                            # (B,nc,H)

    tri = jnp.tril(jnp.ones((Q, Q), bool))

    def step(carry, xs_c):
        Cst, nst, m_prev = carry                          # (B,H,P,P),(B,H,P),(B,H)
        q_c, k_c, v_c, b_c, F_c, Fe_c, ml_c = xs_c
        m_star = jnp.maximum(m_prev, ml_c)                # (B,H)

        # --- intra-chunk (bf16 operands into the MXU, f32 accumulation) ---
        src = jnp.exp(b_c - m_star[:, None, :])           # (B,Q,H) ≤ 1, f32
        qk = jnp.einsum("bqhp,bkhp->bhqk", q_c, k_c,
                        preferred_element_type=jnp.float32)
        W = qk * src.transpose(0, 2, 1)[:, :, None, :]    # scale by source j
        W = jnp.where(tri[None, None], W, 0.0)            # (B,H,q,k) f32
        num = jnp.einsum("bhqk,bkhp->bqhp", W.astype(v_c.dtype), v_c,
                         preferred_element_type=jnp.float32)
        den = jnp.sum(W, axis=-1).transpose(0, 2, 1)      # (B,Q,H)

        # --- inter-chunk reads (state stabilized by m_prev) ---
        resc = jnp.exp(m_prev - m_star)                   # (B,H) ≤ 1
        num = num + jnp.einsum(
            "bqhp,bhpr,bh->bqhr", q_c.astype(jnp.float32), Cst, resc
        )
        den = den + jnp.einsum(
            "bqhp,bhp,bh->bqh", q_c.astype(jnp.float32), nst, resc
        )

        clamp = jnp.exp(-F_c - m_star[:, None, :])        # = exp(−m_t)
        y = num / jnp.maximum(jnp.abs(den), clamp)[..., None]

        # --- carry state to end of chunk (f32 state, bf16 rank-Q updates) ---
        C_new = resc[..., None, None] * Cst + jnp.einsum(
            "bkh,bkhp,bkhr->bhpr",
            src, k_c.astype(jnp.float32), v_c.astype(jnp.float32),
        )
        n_new = resc[..., None] * nst + jnp.einsum(
            "bkh,bkhp->bhp", src, k_c.astype(jnp.float32)
        )
        m_new = Fe_c + m_star
        return (C_new, n_new, m_new), y

    C0 = jnp.zeros((B, H, P, P), jnp.float32)
    n0 = jnp.zeros((B, H, P), jnp.float32)
    m0 = jnp.full((B, H), -1e30, jnp.float32)             # no state yet
    xs_seq = tuple(
        jnp.moveaxis(t, 1, 0) for t in (qs, ks, vs, b, F, F_end, m_loc)
    )
    _, ys = jax.lax.scan(step, (C0, n0, m0), xs_seq)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLSTM:
    """Scalar-memory LSTM with exponential gating and per-head block-diagonal
    recurrence (xLSTM §2.2).  Inherently sequential — the recurrent matrix
    feeds h_{t-1} through the gate nonlinearities, so no parallel form
    exists; runs as lax.scan over time.  At 1 sLSTM per 8 blocks its FLOPs
    are negligible next to the mLSTM stacks.
    """

    d_model: int
    n_heads: int
    ff_factor: float = 4.0 / 3.0
    dtype: jnp.dtype = jnp.bfloat16
    rcfg: RepairConfig = RepairConfig(mode="off")

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return int(self.d_model * self.ff_factor)

    def defs(self):
        D, H, P = self.d_model, self.n_heads, self.head_dim
        lin = ini.fan_in()
        return {
            # gate order: [z, i, f, o]
            "w": ParamDef((D, 4 * D), self.dtype, lin, ("embed", "mlp")),
            # RNN sharding (§Perf iteration 2): shard the recurrent weight by
            # its OUTPUT dim so the per-timestep matmul is local and only the
            # tiny hidden state (B,H,P) is gathered each step — sharding the
            # contraction dim instead costs one (B,H,4P) all-reduce per
            # timestep × S=4096 steps (measured: 7.7e11 wire bytes/device).
            "r": ParamDef((H, P, 4 * P), jnp.float32, ini.normal(0.02),
                          ("heads", None, "mlp")),
            "b": ParamDef((4 * D,), jnp.float32, ini.zeros, ("mlp",)),
            "norm_scale": ParamDef((D,), self.dtype, ini.ones, ("embed",)),
            "w_up": ParamDef((D, self.d_ff), self.dtype, lin, ("embed", "mlp")),
            "w_down": ParamDef((self.d_ff, D), self.dtype, lin, ("mlp", "embed")),
        }

    def _cell(self, p, pre, state):
        """One step.  pre: (B,H,P,4) input preactivations; state=(c,n,m,h)."""
        c, n, m, h = state
        r = use(p["r"], self.rcfg)
        rec = jnp.einsum("bhp,hpq->bhq", h, r)            # (B,H,4P)
        rec = constrain(rec, ("act_batch", None, "act_heads"))
        B, H, P = h.shape
        rec = rec.reshape(B, H, P, 4)
        z_pre, i_pre, f_pre, o_pre = [
            (pre[..., g] + rec[..., g]) for g in range(4)
        ]
        z = jnp.tanh(z_pre)
        o = jax.nn.sigmoid(o_pre)
        log_f = jax.nn.log_sigmoid(f_pre)
        m_new = jnp.maximum(log_f + m, i_pre)
        i_s = jnp.exp(i_pre - m_new)
        f_s = jnp.exp(log_f + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = f_s * n + i_s
        h_new = o * c_new / jnp.maximum(jnp.abs(n_new), jnp.exp(-m_new))
        return (c_new, n_new, m_new, h_new)

    def _pre(self, p, x):
        B, S, D = x.shape
        H, P = self.n_heads, self.head_dim
        pre = jnp.einsum(
            "bsd,de->bse", x, use(p["w"], self.rcfg),
            preferred_element_type=jnp.float32,
        ) + use(p["b"], self.rcfg)
        # (B,S,4D) -> (B,S,H,P,4): gates are blocked per head
        return pre.reshape(B, S, 4, H, P).transpose(0, 1, 3, 4, 2)

    def _ffn(self, p, y, B, S):
        scale = use(p["norm_scale"], self.rcfg).astype(jnp.float32)
        var = jnp.mean(y * y, axis=-1, keepdims=True)
        y = (y * jax.lax.rsqrt(var + 1e-6) * scale).astype(self.dtype)
        hcat = jnp.einsum("bsd,df->bsf", y, use(p["w_up"], self.rcfg),
                          preferred_element_type=jnp.float32)
        hcat = jax.nn.gelu(hcat).astype(self.dtype)
        return jnp.einsum("bsf,fd->bsd", hcat, use(p["w_down"], self.rcfg),
                          preferred_element_type=jnp.float32).astype(self.dtype)

    def __call__(self, p, x: jax.Array) -> jax.Array:
        B, S, D = x.shape
        H, P = self.n_heads, self.head_dim
        pre = self._pre(p, x)                             # (B,S,H,P,4)

        def step(state, pre_t):
            new = self._cell(p, pre_t, state)
            return new, new[3]

        init = tuple(
            jnp.zeros((B, H, P), jnp.float32) if i != 2
            else jnp.full((B, H, P), -1e30, jnp.float32)
            for i in range(3)
        ) + (jnp.zeros((B, H, P), jnp.float32),)
        _, hs = jax.lax.scan(step, init, jnp.moveaxis(pre, 1, 0))
        y = jnp.moveaxis(hs, 0, 1).reshape(B, S, D)       # f32
        return self._ffn(p, y, B, S)

    # -------------------------------------------------------------- decode
    def cache_defs(self, batch: int):
        H, P = self.n_heads, self.head_dim
        st = lambda: ParamDef((batch, H, P), jnp.float32, ini.zeros,
                              ("batch", "heads", None))
        return {"c": st(), "n": st(), "m": st(), "h": st()}

    def decode_step(self, p, x, cache):
        B = x.shape[0]
        pre = self._pre(p, x)[:, 0]                       # (B,H,P,4)
        state = tuple(use(cache[k], self.rcfg) for k in ("c", "n", "m", "h"))
        c, n, m, h = self._cell(p, pre, state)
        y = h.reshape(B, 1, self.d_model)
        out = self._ffn(p, y, B, 1)
        return out, {"c": c, "n": n, "m": m, "h": h}
