"""Checkpointing: npz shards + JSON manifest, scrub-on-save, async save,
elastic reshard on restore, preemption hook.

Fault-tolerance contract (README §Checkpointing):

  * **scrub-on-save** — state is NaN/Inf-repaired *before* serialization, so
    a checkpoint is always a clean repair source for the ``last_checkpoint``
    policy (core/checkpoint_repair.py).  A NaN that slipped into approximate
    memory between scrubs must never be persisted: the checkpoint is the
    ground truth of last resort.
  * **elastic reshard** — checkpoints store *global* arrays keyed by tree
    path plus logical-axis metadata; ``load_checkpoint`` device_puts onto
    whatever mesh/sharding the restarted job uses.  A job may come back on a
    different topology (fewer pods after a failure, more after repair) and
    restore without conversion.
  * **atomic + versioned** — write to ``step_XXXX.tmp`` then rename; the
    manifest is written last, so a torn save is invisible to ``latest``.
  * **async save** — serialization happens on a worker thread after
    ``jax.device_get`` (the only sync point); training continues during the
    filesystem write.  ``wait()`` joins before the next save or exit.
  * **preemption hook** — ``install_preemption_hook`` registers a SIGTERM
    handler that runs one synchronous save (cluster schedulers send SIGTERM
    before eviction).
  * **stateless data** — nothing about the data pipeline is stored; batches
    are pure functions of (seed, step) (data/pipeline.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from ..runtime import ApproxSpace, ScrubSchedule

_MANIFEST = "manifest.json"
_ARRAYS = "arrays.npz"


def _save_space(repair_cfg: Optional[Any], space: Optional[ApproxSpace]):
    """The runtime used for scrub-on-save: memory-forced (a checkpoint must
    be clean regardless of the run's repair mode), zero policy by default.

    A ``repair_cfg`` carrying an explicit ``RuleSet`` keeps it: save scrubs
    and restore repairs run as *forced* passes, so every non-exact rule
    fires with its own detector/fill, and exact-island leaves stay untouched
    (README §RepairRule)."""
    if space is not None:
        return space
    if repair_cfg is None:
        return ApproxSpace(mode="memory", policy="zero")
    return ApproxSpace(
        repair_cfg, mode="memory", max_magnitude=None,
        scrub=ScrubSchedule(),
    )


def _flatten_with_paths(tree: Any) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_part(p) for p in path)
        flat[key] = leaf
    return flat


def _path_part(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"[{p.idx}]"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(getattr(p, "key", p))


def save_checkpoint(
    directory: str,
    step: int,
    tree: Any,
    *,
    scrub: bool = True,
    repair_cfg: Optional[Any] = None,
    extra_meta: Optional[Dict[str, Any]] = None,
    space: Optional[ApproxSpace] = None,
) -> str:
    """Synchronous checkpoint write.  Returns the checkpoint path."""
    if scrub:
        tree = _save_space(repair_cfg, space).scrub(tree)

    host = jax.device_get(tree)
    return _write(directory, step, host, extra_meta)


def _write(directory, step, host_tree, extra_meta) -> str:
    os.makedirs(directory, exist_ok=True)
    name = f"step_{step:08d}"
    tmp = os.path.join(directory, name + ".tmp")
    final = os.path.join(directory, name)
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat = _flatten_with_paths(host_tree)
    arrays = {}
    meta_leaves = {}
    for k, v in flat.items():
        arr = np.asarray(v)
        arrays[k] = arr
        meta_leaves[k] = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
    np.savez(os.path.join(tmp, _ARRAYS), **arrays)

    manifest = {
        "step": int(step),
        "leaves": meta_leaves,
        "extra": extra_meta or {},
        "format": 1,
    }
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def _read_arrays(directory: str, step: Optional[int]) -> Tuple[Dict, Dict]:
    """One disk read: (manifest, {tree path: host ndarray})."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, _ARRAYS)) as npz:
        data = {k: npz[k] for k in npz.files}
    return manifest, data


def _materialize(data: Dict, like: Any, shardings: Any) -> Any:
    """Host arrays -> a tree shaped like ``like``: dtype-cast and (with
    ``shardings``) device_put onto the target mesh — the elastic reshard."""
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten_with_paths(like).keys())
    assert len(keys) == len(flat_like)
    flat_sh = (
        jax.tree.leaves(shardings) if shardings is not None
        else [None] * len(flat_like)
    )
    leaves = []
    for key, proto, sh in zip(keys, flat_like, flat_sh):
        arr = data[key]
        want = getattr(proto, "dtype", None)
        if want is not None and str(arr.dtype) != str(want):
            arr = arr.astype(want)
        if sh is not None:
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_checkpoint(
    directory: str,
    step: Optional[int] = None,
    *,
    like: Any = None,
    shardings: Any = None,
) -> Tuple[Any, int]:
    """Restore (tree, step).  ``like`` supplies the treedef (and target
    dtypes); ``shardings`` (same structure) triggers the elastic reshard:
    every global array is device_put onto the new mesh's sharding."""
    manifest, data = _read_arrays(directory, step)
    if like is None:
        # return a flat dict when no treedef is given
        return data, manifest["step"]
    return _materialize(data, like, shardings), manifest["step"]


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for n in os.listdir(directory):
        m = re.fullmatch(r"step_(\d{8})", n)
        if m and os.path.exists(os.path.join(directory, n, _MANIFEST)):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


class CheckpointManager:
    """Async, retention-managed checkpointing with a preemption hook."""

    def __init__(
        self,
        directory: str,
        *,
        keep: int = 3,
        scrub: bool = True,
        repair_cfg: Optional[Any] = None,
        space: Optional[ApproxSpace] = None,
    ):
        self.directory = directory
        self.keep = keep
        self.scrub = scrub
        self.repair_cfg = repair_cfg
        # One runtime for every save of this manager: the region cache is
        # shared across saves and scrub-on-save events land in its unified
        # stats stream.
        self.space = _save_space(repair_cfg, space)
        self._thread: Optional[threading.Thread] = None
        self._last_state: Optional[Tuple[int, Any]] = None

    # -------------------------------------------------------------- saving
    def save(self, step: int, tree: Any, *, blocking: bool = False):
        """device_get + scrub synchronously; serialize on a worker thread.

        Donation audit (ROADMAP leftover): for local/replicated states the
        host copy is taken EAGERLY — before any scrub — so the save scrub
        runs over the copy's freshly materialized device buffers and can
        donate them (``donate=True``: in-place repair, no second
        device-resident copy).  The live train state is never an input to
        the donated executable, so it survives untouched — including any
        fatal lanes a later reactive pass will handle; only the serialized
        bytes are guaranteed clean.

        Multi-device states keep the placement-preserving order (scrub the
        sharded device tree per-shard under GSPMD, ``donate=False`` so the
        live state survives, then one device_get): routing them through a
        host copy would commit the full unsharded state to one device —
        exactly the OOM the sharded plan exists to avoid."""
        self.wait()
        sharded = any(
            getattr(getattr(leaf, "sharding", None), "num_devices", 1) > 1
            for leaf in jax.tree.leaves(tree)
        )
        if self.scrub and sharded:
            host = jax.device_get(self.space.scrub(tree))
        else:
            host = jax.device_get(tree)
            if self.scrub:
                host = jax.device_get(self.space.scrub(host, donate=True))
        self._last_state = (step, host)

        def work():
            _write(self.directory, step, host, None)
            self._gc()

        if blocking:
            work()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        if not os.path.isdir(self.directory):
            return
        steps = sorted(
            int(m.group(1))
            for n in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d{8})", n))
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True
            )

    # ------------------------------------------------------------- restore
    def restore(
        self,
        like: Any = None,
        shardings: Any = None,
        *,
        repair: bool = False,
        step: Optional[int] = None,
    ):
        """Restore ``(tree, step)``; with ``shardings`` the elastic reshard
        device_puts every global array onto the new mesh's placements.

        ``repair=True`` additionally runs the reference repair *after* the
        device_put onto the target mesh — the ``last_checkpoint`` pass
        executes shard-local on the restored job's own shardings (one
        ``RepairPlan``, README §Distributed repair), so a flip that struck
        between serialization and restart never survives into the run.  The
        checkpoint is read from disk ONCE: the reference is materialized
        from the same host arrays as the restored tree.
        """
        if repair and like is None:
            raise ValueError(
                "repair=True needs `like` (a treedef to repair against)"
            )
        manifest, data = _read_arrays(self.directory, step)
        if like is None:
            return data, manifest["step"]
        tree = _materialize(data, like, shardings)
        if repair:
            ref = _materialize(data, like, shardings)
            tree = self.space.scrub_with_reference(tree, ref, donate=True)
        return tree, manifest["step"]

    def reference_repair(self, tree: Any, *, step: Optional[int] = None):
        """Repair ``tree`` against the checkpointed reference at ``step``
        (latest by default): the reference shards are device_put onto
        ``tree``'s *own* shardings — whatever mesh the job restored onto —
        and the compiled reference-scope scrub replaces fatal lanes
        shard-locally.  Events land in the manager's space (unified
        stream)."""
        flat, treedef = jax.tree_util.tree_flatten(tree)
        shs = [getattr(leaf, "sharding", None) for leaf in flat]
        # host-resident trees (plain numpy leaves) restore the reference
        # host-side too; any None sharding would break the leaves() pairing
        shardings = (
            None if any(s is None for s in shs)
            else jax.tree_util.tree_unflatten(treedef, shs)
        )
        ref, _ = load_checkpoint(
            self.directory, step, like=tree, shardings=shardings
        )
        return self.space.scrub_with_reference(tree, ref)

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    # ---------------------------------------------------------- preemption
    def install_preemption_hook(self, get_state: Callable[[], Tuple[int, Any]]):
        """SIGTERM → one synchronous save of ``get_state()`` then re-raise."""
        prev = signal.getsignal(signal.SIGTERM)

        def handler(signum, frame):
            step, tree = get_state()
            self.wait()
            save_checkpoint(
                self.directory, step, tree,
                scrub=self.scrub, space=self.space,
            )
            if callable(prev):
                prev(signum, frame)

        signal.signal(signal.SIGTERM, handler)
        return handler
