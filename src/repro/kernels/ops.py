"""jit'd public wrappers over the Pallas kernels, adding memory-mode
semantics (reactive write-back at the memory origin).

The mode split mirrors the paper exactly (§3.3 / §3.4):

  register mode   fused in-VMEM repair only; the stored buffer keeps its NaN
                  and every consuming call re-detects it (paper Table 3:
                  N traps).

  memory mode     fused in-VMEM repair *plus*: if the event counter is
                  non-zero, the poisoned operand is scrubbed once, in place,
                  at its memory origin (``lax.cond`` — zero cost on the
                  no-error fast path).  Subsequent calls see clean data
                  (paper Table 3: exactly 1 trap).  The caller carries the
                  returned buffer forward as the new resident state — JAX's
                  functional write-back, in-place under donation.

Every wrapper returns the (possibly scrubbed) operands so that callers can
thread the repaired state, plus the raw counters for core.stats.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import repair_attention as _ra
from . import repair_matmul as _rm
from . import scrub as _scrub

scrub = _scrub.scrub
scrub_pages = _scrub.scrub_pages

# counter-index re-exports (the package re-exports shadow the submodules)
MM_NAN_A, MM_INF_A, MM_EV_A = _rm.NAN_A, _rm.INF_A, _rm.EV_A
MM_NAN_B, MM_INF_B, MM_EV_B = _rm.NAN_B, _rm.INF_B, _rm.EV_B
MM_EV_TOTAL = _rm.EV_TOTAL
AT_NAN_K, AT_INF_K, AT_EV_K = _ra.NAN_K, _ra.INF_K, _ra.EV_K
AT_NAN_V, AT_INF_V, AT_EV_V = _ra.NAN_V, _ra.INF_V, _ra.EV_V
AT_EV_TOTAL = _ra.EV_TOTAL


class MatmulResult(NamedTuple):
    c: jax.Array
    a: jax.Array            # post-call operand state (scrubbed in memory mode)
    b: jax.Array
    counts: jax.Array       # int32[8], see repair_matmul layout


def _reactive_scrub(
    x, events, *, policy, constant, include_inf, interpret, detector=None
):
    """Scrub ``x`` at its origin only when ``events`` fired (reactive)."""
    def do(x):
        fixed, _ = _scrub.scrub(
            x, policy=policy, constant=constant,
            include_inf=include_inf, interpret=interpret, detector=detector,
        )
        return fixed
    return jax.lax.cond(events > 0, do, lambda x: x, x)


@functools.partial(
    jax.jit,
    static_argnames=(
        "mode", "policy", "constant", "include_inf", "interpret", "blocks",
        "out_dtype", "detector",
    ),
)
def repair_matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    mode: str = "memory",
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[Tuple[int, int, int]] = None,
    out_dtype=None,
    detector=None,
) -> MatmulResult:
    """c = a @ b with fused reactive NaN repair on both operands.

    ``detector`` (a ``core.rules.Detector``) overrides the fatal-pattern
    set; it is forwarded to the kernel as a scalar-prefetch operand and to
    the reactive origin scrub (README §RepairRule)."""
    if mode not in ("register", "memory"):
        raise ValueError(f"mode must be register|memory, got {mode!r}")
    c, counts = _rm.repair_matmul_raw(
        a, b, policy=policy, constant=constant, include_inf=include_inf,
        interpret=interpret, blocks=blocks, out_dtype=out_dtype,
        detector=detector,
    )
    if mode == "memory":
        kw = dict(
            policy=policy, constant=constant, include_inf=include_inf,
            interpret=interpret, detector=detector,
        )
        a = _reactive_scrub(a, counts[_rm.EV_A], **kw)
        b = _reactive_scrub(b, counts[_rm.EV_B], **kw)
    return MatmulResult(c, a, b, counts)


class AttentionResult(NamedTuple):
    out: jax.Array
    k: jax.Array            # post-call cache state (scrubbed in memory mode)
    v: jax.Array
    counts: jax.Array       # int32[8], see repair_attention layout


@functools.partial(
    jax.jit,
    static_argnames=(
        "mode", "causal", "policy", "constant", "include_inf", "interpret",
        "blocks", "detector",
    ),
)
def flash_attention(
    q: jax.Array,   # (B, H, S, D)
    k: jax.Array,   # (B, Kh, T, D)
    v: jax.Array,
    *,
    mode: str = "memory",
    causal: bool = True,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[Tuple[int, int]] = None,
    detector=None,
) -> AttentionResult:
    """Flash attention with fused reactive repair of the (cached) K/V.

    ``detector`` overrides the fatal-pattern set for the K/V tiles
    (scalar-prefetch operand; README §RepairRule)."""
    if mode not in ("register", "memory"):
        raise ValueError(f"mode must be register|memory, got {mode!r}")
    out, counts = _ra.flash_attention_raw(
        q, k, v, causal=causal, policy=policy, constant=constant,
        include_inf=include_inf, interpret=interpret, blocks=blocks,
        detector=detector,
    )
    if mode == "memory":
        kw = dict(
            policy=policy, constant=constant, include_inf=include_inf,
            interpret=interpret, detector=detector,
        )
        k = _reactive_scrub(k, counts[_ra.EV_K], **kw)
        v = _reactive_scrub(v, counts[_ra.EV_V], **kw)
    return AttentionResult(out, k, v, counts)
