"""One-shot scrub kernel: in-place NaN/Inf repair over a whole buffer.

This is the *memory-repairing mechanism* (paper §3.4) as a standalone pass:
read each tile HBM→VMEM, repair fatal lanes, write the tile back, count
events.  It is used

  * by memory-mode pytree scrubs on the hot buffers (weights / KV cache /
    optimizer state) at step boundaries,
  * by checkpoint save/restore (never persist a NaN), and
  * as the honest "proactive / ECC-analogue" baseline in §Perf: calling it
    before every consuming op doubles HBM traffic, which is exactly the
    overhead the paper's reactive design avoids — the fused repair in
    repair_matmul.py / repair_attention.py costs zero extra HBM bytes.

Memory layout: the input is viewed as (rows, cols) with cols a multiple of
the 128-lane VPU width; tiles of (block_rows, 128·k).  The write-back aliases
the input buffer (``input_output_aliases``), so on TPU the scrub is in-place
in HBM, exactly like the paper's repair of the faulting address.

Outputs: (scrubbed, counts) with counts = int32[3] = [nan, inf, events]
accumulated across all grid steps (constant index map — every grid step
revisits the same counts block, which therefore lives in VMEM for the whole
call).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import tiling
from . import common


def _scrub_kernel(
    consts_ref, x_ref, out_ref, counts_ref, *, policy: str, constant: float
):
    # consts_ref is the scalar-prefetch detector-constants operand (int32[8],
    # SMEM): detection enables/masks are data, not baked-in NaN-only logic.
    step = pl.program_id(0) * pl.num_programs(1) + pl.program_id(1)

    @pl.when(step == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    tile = x_ref[...]
    # consts[6] > 0: count-valid row bound — rows ≥ bound (the page scrub's
    # padding duplicates) are repaired like any other but masked out of the
    # lane counts, so padded and unpadded calls report identical stats
    n_valid = consts_ref[6]
    row_ids = pl.program_id(0) * tile.shape[0] + jax.lax.broadcasted_iota(
        jnp.int32, tile.shape, 0
    )
    count_mask = (n_valid == 0) | (row_ids < n_valid)
    fixed, n_nan, n_inf = common.repair_tile(
        tile, policy=policy, constant=constant, consts=consts_ref[...],
        count_mask=count_mask,
    )
    out_ref[...] = fixed
    event = ((n_nan + n_inf) > 0).astype(jnp.int32)
    counts_ref[0] += n_nan
    counts_ref[1] += n_inf
    counts_ref[2] += event


def _choose_blocks(rows: int, cols: int) -> Tuple[int, int]:
    """Pick VMEM-friendly tile sizes: lane dim a multiple of 128 (≤512),
    sublane dim a multiple of 8 (≤256), clamped to the array — the shared
    fit from ``core.tiling`` (also the neighbor_mean policy's tile)."""
    return tiling.fit_blocks(rows, cols)


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "constant", "include_inf", "interpret", "block", "detector",
    ),
)
def scrub(
    x: jax.Array,
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    block: Optional[Tuple[int, int]] = None,
    detector=None,
    n_valid_rows=None,
) -> Tuple[jax.Array, jax.Array]:
    """Repair all fatal lanes of ``x`` in place.  Returns (scrubbed, counts).

    counts = int32[3]: [nan lanes, inf lanes, tile-visits with ≥1 fatal lane].
    Arbitrary-rank inputs are viewed as 2D (leading dims folded into rows).

    ``detector`` (a ``core.rules.Detector``) selects which stored patterns
    are fatal; its constants enter the kernel as a scalar-prefetch operand
    (README §RepairRule).  Default: the legacy NaN(+Inf) pattern via
    ``include_inf``.

    ``n_valid_rows`` (traced int32 or None) bounds the lane COUNTS to the
    first that many folded-2D rows — every row is still repaired.  This is
    how bucketed page scrubs (``scrub_pages``) keep padding duplicates out
    of their stats; it rides the scalar-prefetch operand (slot 6), so a
    changing bound never retraces.
    """
    if interpret is None:
        interpret = common.default_interpret()
    det = common.resolve_detector(detector, include_inf)
    orig_shape = x.shape
    if x.ndim == 0:
        x2 = x.reshape(1, 1)
    elif x.ndim == 1:
        x2 = x.reshape(1, -1)
    else:
        x2 = x.reshape(-1, x.shape[-1])
    rows, cols = x2.shape
    br, bc = block if block is not None else _choose_blocks(rows, cols)
    grid = (rows // br, cols // bc)

    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe import

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,       # the detector-constants operand
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j, c: (i, j))],
        out_specs=[
            pl.BlockSpec((br, bc), lambda i, j, c: (i, j)),
            pl.BlockSpec((3,), lambda i, j, c: (0,)),
        ],
    )
    out, counts = pl.pallas_call(
        functools.partial(_scrub_kernel, policy=policy, constant=constant),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((rows, cols), x2.dtype),
            jax.ShapeDtypeStruct((3,), jnp.int32),
        ],
        # operand 0 is the scalar prefetch; x is operand 1 — aliased onto the
        # scrubbed output: in-place in HBM, like the paper
        input_output_aliases={1: 0},
        interpret=interpret,
    )(common.detector_operand(det, x2.dtype, n_valid_rows), x2)
    return out.reshape(orig_shape), counts


def scrub_sharded(
    x: jax.Array,
    mesh,
    spec,
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    block: Optional[Tuple[int, int]] = None,
    detector=None,
) -> Tuple[jax.Array, jax.Array]:
    """Shard-local scrub entry (README §Distributed repair): run the Pallas
    scrub kernel over each device's *local shard view* via shard_map — no
    gather, no resharding; every device repairs exactly the rows it holds,
    which is the placement the ``RepairPlan`` "sharded" path lowers to.

    ``spec`` is the PartitionSpec of ``x`` on ``mesh``.  Returns
    ``(scrubbed, counts)`` with the same int32[3] counts as ``scrub``,
    psum-reduced to GLOBAL totals (counted once, never per-replica).  NaN
    and Inf lane counts match the whole-array kernel exactly; the
    tile-visit ``events`` entry follows the per-shard tiling (a shard's
    tiles, not the global array's), the same way the fused kernels' event
    counts follow their block shapes.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if interpret is None:
        interpret = common.default_interpret()

    # reduce ONLY over the mesh axes the spec actually shards: along unused
    # axes every replica computes identical local counts, and psum-ing those
    # would multiply the global totals by the replication factor
    used = []
    for part in spec:
        if part is None:
            continue
        used.extend(part if isinstance(part, (tuple, list)) else (part,))
    used = tuple(a for a in used if a is not None)

    def local(xs: jax.Array) -> Tuple[jax.Array, jax.Array]:
        fixed, counts = scrub(
            xs, policy=policy, constant=constant, include_inf=include_inf,
            interpret=interpret, block=block, detector=detector,
        )
        if used:
            counts = jax.lax.psum(counts, axis_name=used)
        return fixed, counts

    return shard_map(
        local, mesh=mesh, in_specs=(spec,), out_specs=(spec, P()),
        check_rep=False,
    )(x)


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "constant", "include_inf", "interpret", "block", "detector",
    ),
)
def scrub_pages(
    x: jax.Array,
    page_ids: jax.Array,
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    block: Optional[Tuple[int, int]] = None,
    detector=None,
    n_valid=None,
) -> Tuple[jax.Array, jax.Array]:
    """Page-view scrub: repair only rows ``page_ids`` of ``x``'s leading
    (page) axis.  Gather the pages into one contiguous view, run the scrub
    kernel over that view, scatter the repaired pages back.  HBM traffic is
    proportional to the *scrubbed* pages, not the whole buffer.

    This is the kernel-level counterpart of the serving engine's
    page-granular repair — ``RepairPlan`` lowers pages-scope scrubs through
    it wherever the kernels are native (README §RepairPlan), with the same
    bucketed id vector the jnp path uses: ``n_valid`` (traced int32 or
    None) marks entries ``page_ids[n_valid:]`` as padding duplicates whose
    lanes are repaired but masked out of the counts (they gather to the
    trailing folded rows, so the bound lowers to ``scrub``'s
    ``n_valid_rows`` rider — slot 6 of the scalar operand, never a
    retrace).  1-D ``x`` cannot express a row bound (one page = part of one
    folded row); callers needing masked counts there keep the jnp path.

    Returns ``(x', counts)`` with the same int32[3] counts as ``scrub``.
    Without ``n_valid``, duplicate page ids are idempotent (the repaired
    rows coincide) but inflate the lane counts — pass unique ids when
    counts matter.
    """
    page_ids = jnp.asarray(page_ids, jnp.int32)
    rows = x[page_ids]
    n_valid_rows = None
    if n_valid is not None and rows.ndim >= 2:
        rows_per_page = rows[0].size // rows.shape[-1]
        n_valid_rows = jnp.asarray(n_valid, jnp.int32) * rows_per_page
    fixed, counts = scrub(
        rows, policy=policy, constant=constant, include_inf=include_inf,
        interpret=interpret, block=block, detector=detector,
        n_valid_rows=n_valid_rows,
    )
    return x.at[page_ids].set(fixed), counts
