"""Shared in-kernel repair logic for all Pallas kernels.

The detection/repair math is *identical* to ``core.detect``/``core.policies``
(single source of truth for the bit patterns); this module re-expresses it in
a form usable inside a kernel body, where the loaded VMEM tile is a jax array
and the repair must be branch-free VPU code (compare/and/select — no gather,
no data-dependent shapes).

Policy support inside kernels is the *cheap* subset of the policy lattice:

  zero              repaired lanes become 0
  constant          repaired lanes become a compile-time constant
  neighbor_mean     repaired lanes become the mean of the finite lanes of the
                    SAME VMEM tile (one extra reduction over a tile already
                    resident in VMEM — this is the fused-repair trick: the
                    statistics come for free while the MXU is busy)
  clamp_finite_max  largest finite magnitude of the dtype

The expensive ``last_checkpoint`` policy is pytree-level only
(core/checkpoint_repair.py) — it needs a reference buffer the kernel does not
have.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import detect, rules as rules_lib

# Policies expressible inside a kernel body.
KERNEL_POLICIES = ("zero", "constant", "neighbor_mean", "clamp_finite_max")

# ---------------------------------------------------------------------------
# Detector constants (README §RepairRule).
#
# Detection inside a kernel is no longer baked-in NaN-only logic: the IEEE
# layout constants and the detector's enables travel as a small int32[8]
# scalar-prefetch operand (SMEM on TPU, available before the kernel body —
# layout documented on ``core.rules.Detector.constants``):
#
#   0 exp_mask   1 man_mask   2 flags   3 range exp-field threshold (shifted)
#   4 bitpattern mask   5 bitpattern value
#   6 count-valid row bound: when > 0, the scrub kernel masks folded-2D rows
#     ≥ this bound out of its lane COUNTS (the rows are still repaired) —
#     the page-scrub bucketing's padding-duplicate mask (``RepairPlan``)
#   7 pad
#
# so swapping the detector (NaN-only vs +Inf vs range-guarded vs a custom
# bit pattern) changes an operand, not the compiled kernel.
# ---------------------------------------------------------------------------

DEFAULT_DETECTOR = rules_lib.Detector()


def kernel_fill(fill) -> Optional[Tuple[str, float]]:
    """Map a ``RepairRule`` fill onto a kernel (policy, constant) pair that
    is *bit-identical* to the jnp repair path — value-independent fills
    only.  ``neighbor_mean`` (tile statistics differ between the kernels'
    VMEM tiles and the policy layer's fit) and the sign-preserving jnp
    ``clamp_finite_max`` have kernel analogues but not bit-equal ones, so
    they return ``None``: callers fall back to the jnp lowering rather than
    silently drift.  This is the ONE eligibility definition shared by the
    fused paged-decode path and the plan-level kernel placement."""
    if isinstance(fill, (int, float)) and not isinstance(fill, bool):
        return ("constant", float(fill))
    if fill == "zero":
        return ("zero", 0.0)
    from ..core import policies as policies_lib

    if isinstance(fill, policies_lib.RepairPolicy) and fill.name == "zero":
        return ("zero", 0.0)
    return None


def resolve_detector(
    detector: Optional[rules_lib.Detector], include_inf: bool
) -> rules_lib.Detector:
    """The effective kernel detector: an explicit one wins; otherwise the
    legacy ``include_inf`` knob lifts into the equivalent detector."""
    if detector is not None:
        return detector
    return rules_lib.Detector(nan=True, inf=include_inf)


def detector_operand(
    detector: rules_lib.Detector, dtype, n_valid_rows=None
) -> jax.Array:
    """The int32[8] scalar-prefetch operand encoding ``detector`` for
    ``dtype`` (see ``Detector.constants``).  ``n_valid_rows`` (traced or
    int) rides in slot 6 — the count-valid row bound; ``None``/0 disables
    the mask.  A traced bound stays a data change: same executable."""
    import numpy as np

    consts = detector.constants(dtype)
    # masks are bit patterns: fold into int32 range via two's complement
    base = jnp.asarray(np.asarray(consts, np.uint32).astype(np.int32))
    if n_valid_rows is None:
        return base
    return base.at[6].set(jnp.asarray(n_valid_rows, jnp.int32))


def masks_from_consts(
    bits: jax.Array, consts: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """(nan_mask, inf_mask) of a tile's integer bit view, driven by the
    detector-constants operand.  Mirrors ``Detector.masks`` exactly (same
    bucket rules, so kernel counters and the jnp oracle agree): custom bit
    patterns land in the NaN bucket; the range guard owns the non-NaN
    bucket when enabled (it subsumes ±Inf)."""
    u = lambda i: consts[i].astype(jnp.uint32)                       # noqa: E731
    b = bits.astype(jnp.uint32)
    exp_mask, man_mask, flags = u(0), u(1), consts[2]
    exp_all = (b & exp_mask) == exp_mask
    man_nz = (b & man_mask) != 0
    nan_m = exp_all & man_nz & ((flags & rules_lib.FLAG_NAN) > 0)
    nan_m = nan_m | (
        ((b & u(4)) == u(5)) & ((flags & rules_lib.FLAG_BITPATTERN) > 0)
    )
    inf_m = exp_all & ~man_nz & ((flags & rules_lib.FLAG_INF) > 0)
    ext_m = ((b & exp_mask) >= u(3)) & ((flags & rules_lib.FLAG_RANGE) > 0)
    inf_m = inf_m | (ext_m & ~nan_m)
    return nan_m, inf_m


def fatal_mask(tile: jax.Array, *, include_inf: bool = True) -> jax.Array:
    """NaN (optionally +±Inf) lanes of a VMEM tile, via bit patterns.

    Uses the same layout constants as core.detect so kernel and oracle agree
    bit-for-bit.  bitcast + compare + and: pure VPU ops.
    """
    bits = jax.lax.bitcast_convert_type(
        tile, detect.layout_of(tile.dtype).int_dtype
    )
    m = detect.is_nan_bits(bits, tile.dtype)
    if include_inf:
        m = m | detect.is_inf_bits(bits, tile.dtype)
    return m


def repair_value(
    tile: jax.Array, mask: jax.Array, policy: str, constant: float
) -> jax.Array:
    """Branch-free repair value for masked lanes (same shape as tile)."""
    if policy == "zero":
        return jnp.zeros_like(tile)
    if policy == "constant":
        return jnp.full_like(tile, constant)
    if policy == "clamp_finite_max":
        return jnp.full_like(tile, jnp.finfo(tile.dtype).max)
    if policy == "neighbor_mean":
        ok = ~mask
        # f32 accumulation of the tile statistics regardless of storage dtype
        okf = ok.astype(jnp.float32)
        cnt = jnp.maximum(jnp.sum(okf), 1.0)
        total = jnp.sum(jnp.where(ok, tile.astype(jnp.float32), 0.0))
        return jnp.broadcast_to(total / cnt, tile.shape).astype(tile.dtype)
    raise ValueError(f"kernel policy must be one of {KERNEL_POLICIES}, got {policy!r}")


def repair_tile(
    tile: jax.Array,
    *,
    policy: str,
    constant: float = 0.0,
    include_inf: bool = True,
    consts: Optional[jax.Array] = None,
    count_mask: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Repair a VMEM tile.  Returns (repaired, nan_count, inf_count) where the
    counts are int32 scalars for the event counters (Table 3 analogue).

    With ``consts`` (the detector-constants scalar operand) detection is
    data-driven — NaN/Inf/range/bit-pattern enables read from SMEM; the bare
    ``include_inf`` form keeps the legacy static NaN(+Inf) pattern.
    ``count_mask`` (bool, tile-shaped) restricts the COUNTS to its True
    lanes — repair always covers the whole tile (padding-duplicate rows
    must scatter identical repaired values to stay deterministic)."""
    bits = jax.lax.bitcast_convert_type(
        tile, detect.layout_of(tile.dtype).int_dtype
    )
    if consts is not None:
        nan_m, inf_m = masks_from_consts(bits, consts)
        mask = nan_m | inf_m
        fixed = jnp.where(
            mask, repair_value(tile, mask, policy, constant), tile
        )
        if count_mask is not None:
            nan_m = nan_m & count_mask
            inf_m = inf_m & count_mask
        return (
            fixed,
            jnp.sum(nan_m.astype(jnp.int32)),
            jnp.sum(inf_m.astype(jnp.int32)),
        )
    nan_m = detect.is_nan_bits(bits, tile.dtype)
    inf_m = detect.is_inf_bits(bits, tile.dtype)
    mask = (nan_m | inf_m) if include_inf else nan_m
    fixed = jnp.where(mask, repair_value(tile, mask, policy, constant), tile)
    return (
        fixed,
        jnp.sum(nan_m.astype(jnp.int32)),
        jnp.sum(inf_m.astype(jnp.int32)) if include_inf else jnp.zeros((), jnp.int32),
    )


@functools.lru_cache(maxsize=None)
def default_interpret() -> bool:
    """Run kernels in interpret mode unless we are actually on TPU."""
    return jax.default_backend() != "tpu"
