"""Fused chunked-mLSTM kernel with reactive NaN repair on the q/k/v tiles.

The xlstm train/prefill cells' documented headroom (EXPERIMENTS.md §Perf):
the jnp chunked form materializes the (P,P) matrix memory and the per-chunk
decay tensors through HBM every chunk — at P=1024 that is 4 MB of f32 state
written+read per chunk per head, ~40 % of the cell's memory term.  This
kernel keeps the running state (C, n, m) in VMEM scratch across the chunk
grid dimension: HBM traffic is exactly the q/k/v chunk loads and the y
store, i.e. the streaming minimum.

Math is bit-compatible with nn/xlstm.py::_chunked_mlstm (the oracle —
per-chunk max-stabilized exponential gating, docstring there):

    W~_tj  = (q_t·k_j)·exp(b_j − m*)   (tril)     b_j = log_i_j − F_j
    y_t    = (W~ v + (q_t·C~)·exp(m_prev − m*)) / max(|den|, exp(−F_t − m*))
    C~,n~  ← exp(m_prev − m*)·state + Σ_j exp(b_j − m*)·k_j(·v_jᵀ)
    m      ← F_end + m*

Approximate-memory integration: q/k/v tiles are bit-pattern repaired in
VMEM right after their HBM→VMEM DMA (register semantics; the event counter
drives the reactive memory-mode scrub in ops.py, same contract as
repair_matmul).  A NaN reaching C would poison *all future tokens* (the
temporal Fig. 1) — repairing pre-consumption keeps the carried state clean
by construction.

Layout: q,k,v (B, H, nc, Q, P); log_i/log_f (B, H, nc, Q) f32;
grid (B, H, nc), chunk dim innermost (sequential recurrence).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

NEG = -1e30

# counts layout (int32[8]): nan_q, inf_q, ev_q, nan_kv, inf_kv, ev_kv, ev_total
NAN_Q, INF_Q, EV_Q, NAN_KV, INF_KV, EV_KV, EV_TOTAL = range(7)


def _mlstm_kernel(
    q_ref, k_ref, v_ref, li_ref, lf_ref, y_ref, counts_ref,
    c_ref, n_ref, m_ref,
    *, policy: str, constant: float, include_inf: bool, Q: int, P: int,
):
    b, h, c = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    step = (b * pl.num_programs(1) + h) * pl.num_programs(2) + c

    @pl.when(step == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(c == 0)
    def _init_state():
        c_ref[...] = jnp.zeros_like(c_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG)

    # ---- fused reactive repair of the chunk tiles ----
    q, nan_q, inf_q = common.repair_tile(
        q_ref[0, 0, 0], policy=policy, constant=constant,
        include_inf=include_inf,
    )
    k, nan_k, inf_k = common.repair_tile(
        k_ref[0, 0, 0], policy=policy, constant=constant,
        include_inf=include_inf,
    )
    v, nan_v, inf_v = common.repair_tile(
        v_ref[0, 0, 0], policy=policy, constant=constant,
        include_inf=include_inf,
    )
    ev_q = ((nan_q + inf_q) > 0).astype(jnp.int32)
    ev_kv = ((nan_k + inf_k + nan_v + inf_v) > 0).astype(jnp.int32)
    counts_ref[NAN_Q] += nan_q
    counts_ref[INF_Q] += inf_q
    counts_ref[EV_Q] += ev_q
    counts_ref[NAN_KV] += nan_k + nan_v
    counts_ref[INF_KV] += inf_k + inf_v
    counts_ref[EV_KV] += ev_kv
    counts_ref[EV_TOTAL] += ((ev_q + ev_kv) > 0).astype(jnp.int32)

    li = li_ref[0, 0, 0].astype(jnp.float32)          # (Q,)
    lf = lf_ref[0, 0, 0].astype(jnp.float32)
    F = jnp.cumsum(lf)                                # (Q,) ≤ 0
    F_end = F[Q - 1]
    bsrc = li - F                                     # source exponents
    m_loc = jnp.max(bsrc)

    m_prev = m_ref[0, 0]
    m_star = jnp.maximum(m_prev, m_loc)
    src = jnp.exp(bsrc - m_star)                      # (Q,) ≤ 1
    resc = jnp.exp(m_prev - m_star)                   # scalar ≤ 1

    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)

    # intra-chunk
    qk = jax.lax.dot_general(
        qf, kf, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # (Q, Q)
    tril = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )
    W = jnp.where(tril, qk * src[None, :], 0.0)       # (Q, Q)
    num = jax.lax.dot_general(
        W, vf, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                 # (Q, P)
    den = jnp.sum(W, axis=1)                          # (Q,)

    # inter-chunk reads from the VMEM-resident state
    num = num + resc * jax.lax.dot_general(
        qf, c_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    den = den + resc * jnp.sum(qf * n_ref[0:1, :], axis=1)

    clamp = jnp.exp(-F - m_star)                      # (Q,)
    y = num / jnp.maximum(jnp.abs(den), clamp)[:, None]
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    # state update (stays in VMEM)
    c_ref[...] = resc * c_ref[...] + jax.lax.dot_general(
        kf * src[:, None], vf, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    n_ref[...] = resc * n_ref[...] + jnp.sum(
        kf * src[:, None], axis=0, keepdims=True
    )
    m_ref[...] = jnp.full_like(m_ref, F_end + m_star)


@functools.partial(
    jax.jit,
    static_argnames=("policy", "constant", "include_inf", "interpret"),
)
def mlstm_chunk_raw(
    q: jax.Array,        # (B, H, nc, Q, P)
    k: jax.Array,
    v: jax.Array,
    log_i: jax.Array,    # (B, H, nc, Q) f32
    log_f: jax.Array,
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Fused chunked mLSTM.  Returns (y (B,H,nc,Q,P) f32, counts int32[8])."""
    if interpret is None:
        interpret = common.default_interpret()
    B, H, nc, Q, P = q.shape
    grid = (B, H, nc)

    from jax.experimental.pallas import tpu as pltpu  # CPU-safe import

    tile5 = lambda: pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0))
    gate = lambda: pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0))

    y, counts = pl.pallas_call(
        functools.partial(
            _mlstm_kernel,
            policy=policy, constant=constant, include_inf=include_inf,
            Q=Q, P=P,
        ),
        grid=grid,
        in_specs=[tile5(), tile5(), tile5(), gate(), gate()],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((8,), lambda b, h, c: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), jnp.float32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((P, P), jnp.float32),   # C — never leaves VMEM
            pltpu.VMEM((1, P), jnp.float32),   # n
            pltpu.VMEM((1, 1), jnp.float32),   # m
        ],
        interpret=interpret,
    )(q, k, v, log_i, log_f)
    return y, counts


def mlstm_chunked(
    q: jax.Array,        # (B, S, H, P) — nn/xlstm.py layout
    k: jax.Array,
    v: jax.Array,
    log_i: jax.Array,    # (B, S, H) f32
    log_f: jax.Array,
    *,
    chunk: int = 128,
    policy: str = "zero",
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Layout adapter over mlstm_chunk_raw matching nn.xlstm._chunked_mlstm.

    Returns (y (B,S,H,P) f32, counts)."""
    B, S, H, P = q.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q

    def to5(x):
        return x.reshape(B, nc, Q, H, P).transpose(0, 3, 1, 2, 4)

    def gates(x):
        return x.reshape(B, nc, Q, H).transpose(0, 3, 1, 2)

    y, counts = mlstm_chunk_raw(
        to5(q), to5(k), to5(v), gates(log_i), gates(log_f),
        policy=policy, interpret=interpret,
    )
    y = y.transpose(0, 2, 3, 1, 4).reshape(B, S, H, P)
    return y, counts
