"""Tiled MXU matmul with *fused reactive NaN repair* on the operand tiles.

This is the paper's mechanism relocated to where a TPU can afford it
(README §Runtime).  There is no per-instruction trap on a systolic array, and
post-consumption repair is useless (one NaN operand poisons a whole output
row — Fig. 1), so detection must happen **pre-consumption, on the operand
tile the kernel already loaded**:

  * Every a/b tile is bit-pattern checked and repaired *in VMEM* right after
    its HBM→VMEM DMA, before it enters the MXU.  The check is a handful of
    VPU compare/select ops on data that is already resident — it adds zero
    HBM traffic and hides under the MXU's O(bm·bn·bk) work.  This replaces
    the paper's SIGFPE *detection* step.

  * Event counters (the Table 3 analogue) accumulate per-operand NaN/Inf lane
    counts and tile-visit events into a tiny VMEM-resident output.  A visit
    of a poisoned tile == one "trap".

  * **register mode** stops there: the stored buffer keeps its NaN, so every
    visit of that tile re-detects and re-repairs — exactly the paper's
    register-repairing mechanism (N traps for an N×N matmul, Table 3).

  * **memory mode** (in ops.py) reacts to a non-zero event counter by
    scrubbing the poisoned operand *at its memory origin* (kernels/scrub.py,
    in-place aliased write-back), so every later consumption is clean — the
    paper's memory-repairing mechanism (exactly 1 repair).  The scrub runs
    under ``lax.cond``: when no event fired (the overwhelmingly common case)
    it costs nothing.  This is the precise TPU translation of "the signal is
    stolen and the NaN is repaired in main memory" — repair work happens only
    on an actual error, never proactively.

Provenance note: the paper back-traces the binary to find the faulting
address (>95 % success, Fig. 6).  Here the kernel *knows* the HBM tile it
loaded — origin recovery is structural and always succeeds (the counters
record which operand), which is the Fig. 6 number going to 100 % by
construction (see core/provenance.py for the jaxpr-level analysis).

Grid: (M/bm, N/bn, K/bk), k innermost, f32 VMEM scratch accumulator,
bf16/f32 operands, MXU-aligned default tiles (multiples of 128).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import tiling
from . import common

# counts layout (int32[8]):
#   0 nan_a   1 inf_a   2 ev_a (a-tile visits with ≥1 fatal lane)
#   3 nan_b   4 inf_b   5 ev_b
#   6 ev_total (visits where either operand had a fatal lane)   7 pad
NAN_A, INF_A, EV_A, NAN_B, INF_B, EV_B, EV_TOTAL = range(7)


def _mm_kernel(
    consts_ref, a_ref, b_ref, c_ref, counts_ref, acc_ref,
    *, policy: str, constant: float, nk: int,
    out_dtype,
):
    # consts_ref: scalar-prefetch detector constants (int32[8], SMEM) — the
    # fatal-pattern definition is an operand, not baked-in NaN-only logic.
    i, j, k = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    step = (i * pl.num_programs(1) + j) * pl.num_programs(2) + k

    @pl.when(step == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(k == 0)
    def _init_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- fused reactive repair: operand tiles, pre-MXU ----
    # row 0: a's dtype constants; row 1: b's (operands may differ in dtype)
    a_fixed, nan_a, inf_a = common.repair_tile(
        a_ref[...], policy=policy, constant=constant, consts=consts_ref[0]
    )
    b_fixed, nan_b, inf_b = common.repair_tile(
        b_ref[...], policy=policy, constant=constant, consts=consts_ref[1]
    )
    ev_a = ((nan_a + inf_a) > 0).astype(jnp.int32)
    ev_b = ((nan_b + inf_b) > 0).astype(jnp.int32)
    counts_ref[NAN_A] += nan_a
    counts_ref[INF_A] += inf_a
    counts_ref[EV_A] += ev_a
    counts_ref[NAN_B] += nan_b
    counts_ref[INF_B] += inf_b
    counts_ref[EV_B] += ev_b
    counts_ref[EV_TOTAL] += ((ev_a + ev_b) > 0).astype(jnp.int32)

    # ---- MXU work ----
    acc_ref[...] += jnp.dot(
        a_fixed, b_fixed, preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        c_ref[...] = acc_ref[...].astype(out_dtype)


_pick = tiling.fit      # MXU-aligned block fit — one definition repo-wide


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "constant", "include_inf", "interpret", "blocks",
        "out_dtype", "detector",
    ),
)
def repair_matmul_raw(
    a: jax.Array,
    b: jax.Array,
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[Tuple[int, int, int]] = None,
    out_dtype=None,
    detector=None,
) -> Tuple[jax.Array, jax.Array]:
    """c = repair(a) @ repair(b), plus event counters.  Register-mode core;
    ops.repair_matmul adds the reactive memory-mode write-back on top.

    ``detector`` (a ``core.rules.Detector``) picks the fatal-pattern set;
    its constants ride into the kernel as a scalar-prefetch operand."""
    if interpret is None:
        interpret = common.default_interpret()
    det = common.resolve_detector(detector, include_inf)
    (M, K), (K2, N) = a.shape, b.shape
    assert K == K2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    if blocks is None:
        blocks = (_pick(M, 256), _pick(N, 256), _pick(K, 512))
    bm, bn, bk = blocks
    nk = K // bk
    grid = (M // bm, N // bn, nk)

    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe import

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,       # the detector-constants operand
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k, c: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k, c: (k, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k, c: (i, j)),
            pl.BlockSpec((8,), lambda i, j, k, c: (0,)),
        ],
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    c, counts = pl.pallas_call(
        functools.partial(
            _mm_kernel,
            policy=policy,
            constant=constant,
            nk=nk,
            out_dtype=out_dtype,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((M, N), out_dtype),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ],
        interpret=interpret,
    )(
        jnp.stack([
            common.detector_operand(det, a.dtype),
            common.detector_operand(det, b.dtype),
        ]),
        a, b,
    )
    return c, counts
