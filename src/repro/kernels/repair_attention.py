"""Flash attention (online softmax) with fused reactive KV repair.

The serving-path hot spot: in long-context decode/prefill the KV cache is by
far the largest approximate-memory resident (hundreds of GB at the
decode_32k/long_500k cells), and a NaN in one cached key poisons the softmax
of *every future query* that attends to it — the temporal version of the
paper's Fig. 1 row-poisoning.  As with repair_matmul, there is no trap to
catch on TPU, so the repair is fused into the tile load the kernel performs
anyway:

  * K/V tiles are bit-pattern checked + repaired in VMEM right after the
    HBM→VMEM DMA, before the q·kᵀ MXU op.  Zero extra HBM traffic.
  * Event counters per operand (Table 3 analogue).
  * register mode: cache keeps its NaN, every attention call re-repairs.
  * memory mode (ops.py): non-zero event count triggers one in-place scrub
    of the cache at its origin (reactive write-back) — one repair, ever.

Layout: q (B, H, S, D), k/v (B, Kh, T, D) with GQA mapping h → h // group.
Grid (B, H, S/bq, T/bk), kv-block innermost; scratch carries the online
softmax state (acc, running max m, running denom l) across the kv dimension.
Causal masking by global block positions; fully-masked tiles are skipped
(their DMA still happens — the skip saves VPU/MXU work, matching how a real
flash kernel prunes the upper triangle).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..core import tiling
from . import common

NEG_INF = -1e30

# counts layout (int32[8]): nan_k, inf_k, ev_k, nan_v, inf_v, ev_v, ev_total, pad
NAN_K, INF_K, EV_K, NAN_V, INF_V, EV_V, EV_TOTAL = range(7)


def _flash_kernel(
    consts_ref, q_ref, k_ref, v_ref, o_ref, counts_ref, acc_ref, m_ref, l_ref,
    *, causal: bool, sm_scale: float, policy: str, constant: float,
    bq: int, bk: int, nk: int, out_dtype,
):
    # consts_ref: scalar-prefetch detector constants (int32[2, 8], SMEM) —
    # row 0 for K tiles, row 1 for V tiles (dtypes may differ).
    b, h = pl.program_id(0), pl.program_id(1)
    qi, kj = pl.program_id(2), pl.program_id(3)
    step = (
        (b * pl.num_programs(1) + h) * pl.num_programs(2) + qi
    ) * pl.num_programs(3) + kj

    @pl.when(step == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(kj == 0)
    def _init_state():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Causal pruning: is any (q, k) pair in this tile pair unmasked?
    q_last = qi * bq + bq - 1
    k_first = kj * bk
    live = (not causal) or (k_first <= q_last)

    @pl.when(live)
    def _attend():
        q = q_ref[0, 0].astype(jnp.float32)                     # (bq, D)
        # ---- fused reactive repair of the cached K/V tiles ----
        k_fixed, nan_k, inf_k = common.repair_tile(
            k_ref[0, 0], policy=policy, constant=constant,
            consts=consts_ref[0],
        )
        v_fixed, nan_v, inf_v = common.repair_tile(
            v_ref[0, 0], policy=policy, constant=constant,
            consts=consts_ref[1],
        )
        ev_k = ((nan_k + inf_k) > 0).astype(jnp.int32)
        ev_v = ((nan_v + inf_v) > 0).astype(jnp.int32)
        counts_ref[NAN_K] += nan_k
        counts_ref[INF_K] += inf_k
        counts_ref[EV_K] += ev_k
        counts_ref[NAN_V] += nan_v
        counts_ref[INF_V] += inf_v
        counts_ref[EV_V] += ev_v
        counts_ref[EV_TOTAL] += ((ev_k + ev_v) > 0).astype(jnp.int32)

        s = jax.lax.dot_general(
            q, k_fixed.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * sm_scale                                             # (bq, bk)
        if causal:
            qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            kpos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[:, 0]                                     # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])                          # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)                          # (bq,)
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v_fixed.astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(kj == nk - 1)
    def _flush():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(out_dtype)


_pick = tiling.fit      # block fit — one definition repo-wide


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "policy", "constant", "include_inf", "interpret", "blocks",
        "detector",
    ),
)
def flash_attention_raw(
    q: jax.Array,   # (B, H, S, D)
    k: jax.Array,   # (B, Kh, T, D)
    v: jax.Array,   # (B, Kh, T, D)
    *,
    causal: bool = True,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    blocks: Optional[Tuple[int, int]] = None,
    detector=None,
) -> Tuple[jax.Array, jax.Array]:
    """Online-softmax attention with fused K/V tile repair (register-mode
    core; ops.flash_attention adds reactive memory-mode write-back).

    ``detector`` (a ``core.rules.Detector``) picks the fatal-pattern set for
    the cached K/V tiles; its constants ride in as a scalar-prefetch
    operand.  Returns (out (B,H,S,D), counts int32[8])."""
    if interpret is None:
        interpret = common.default_interpret()
    det = common.resolve_detector(detector, include_inf)
    B, H, S, D = q.shape
    _, Kh, T, _ = k.shape
    assert H % Kh == 0, (H, Kh)
    group = H // Kh
    bq, bk = blocks if blocks is not None else (_pick(S, 512), _pick(T, 512))
    nk = T // bk
    grid = (B, H, S // bq, nk)
    sm_scale = 1.0 / math.sqrt(D)

    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe import

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,       # the detector-constants operand
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j, c: (b, h, i, 0)),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda b, h, i, j, c, g=group: (b, h // g, j, 0),
            ),
            pl.BlockSpec(
                (1, 1, bk, D),
                lambda b, h, i, j, c, g=group: (b, h // g, j, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j, c: (b, h, i, 0)),
            pl.BlockSpec((8,), lambda b, h, i, j, c: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
        ],
    )
    out, counts = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal,
            sm_scale=sm_scale,
            policy=policy,
            constant=constant,
            bq=bq,
            bk=bk,
            nk=nk,
            out_dtype=q.dtype,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ],
        interpret=interpret,
    )(
        jnp.stack([
            common.detector_operand(det, k.dtype),
            common.detector_operand(det, v.dtype),
        ]),
        q, k, v,
    )
    return out, counts
