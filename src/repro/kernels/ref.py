"""Pure-jnp oracles for every Pallas kernel (bit-exact counter semantics).

Each oracle replays the kernel's *tiling* where it matters (neighbor_mean is
a per-tile statistic; event counters are per-tile-visit), so tests can assert
exact equality on counters and allclose on values across shape/dtype sweeps.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core import detect


def _masks(x):
    bits = detect.bits_of(x)
    return detect.is_nan_bits(bits, x.dtype), detect.is_inf_bits(bits, x.dtype)


def repair_array_ref(
    x: jax.Array,
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    block: Optional[Tuple[int, int]] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Repair ``x`` exactly as the kernels do, tile-by-tile.

    Returns (fixed, nan_count, inf_count, tiles_with_fatal).  ``block`` is the
    kernel's 2D tile over the trailing-dim-flattened view; None means one tile
    = whole array (policy statistics over everything).
    """
    orig = x.shape
    x2 = x.reshape(-1, x.shape[-1]) if x.ndim >= 2 else x.reshape(1, -1)
    rows, cols = x2.shape
    br, bc = block if block is not None else (rows, cols)
    assert rows % br == 0 and cols % bc == 0, (x2.shape, block)

    nan_m, inf_m = _masks(x2)
    mask = (nan_m | inf_m) if include_inf else nan_m

    # tile view: (nr, nc, br, bc)
    t = x2.reshape(rows // br, br, cols // bc, bc).transpose(0, 2, 1, 3)
    tm = mask.reshape(rows // br, br, cols // bc, bc).transpose(0, 2, 1, 3)

    if policy == "zero":
        rep = jnp.zeros_like(t)
    elif policy == "constant":
        rep = jnp.full_like(t, constant)
    elif policy == "clamp_finite_max":
        rep = jnp.full_like(t, jnp.finfo(x.dtype).max)
    elif policy == "neighbor_mean":
        ok = (~tm).astype(jnp.float32)
        cnt = jnp.maximum(ok.sum(axis=(2, 3), keepdims=True), 1.0)
        tot = jnp.where(~tm, t.astype(jnp.float32), 0.0).sum(
            axis=(2, 3), keepdims=True
        )
        rep = jnp.broadcast_to(tot / cnt, t.shape).astype(x.dtype)
    else:
        raise ValueError(policy)

    fixed = jnp.where(tm, rep, t)
    fixed = fixed.transpose(0, 2, 1, 3).reshape(rows, cols).reshape(orig)
    tiles_fatal = jnp.sum(jnp.any(tm, axis=(2, 3)).astype(jnp.int32))
    return (
        fixed,
        jnp.sum(nan_m.astype(jnp.int32)),
        jnp.sum(inf_m.astype(jnp.int32)) if include_inf else jnp.zeros((), jnp.int32),
        tiles_fatal,
    )


def scrub_ref(
    x, *, policy="zero", constant=0.0, include_inf=True, block=None
):
    """Oracle of kernels.scrub: (fixed, counts[3] = [nan, inf, events])."""
    fixed, n, i, ev = repair_array_ref(
        x, policy=policy, constant=constant, include_inf=include_inf,
        block=block,
    )
    return fixed, jnp.stack([n, i, ev])


def repair_matmul_ref(
    a, b, *, policy="zero", constant=0.0, include_inf=True,
    blocks: Optional[Tuple[int, int, int]] = None, out_dtype=None,
):
    """Oracle of repair_matmul_raw: (c, counts[8]).

    Event counts replay the kernel's visit schedule: each a-tile is visited
    once per j (N/bn times), each b-tile once per i (M/bm times).
    """
    (M, K), (_, N) = a.shape, b.shape
    out_dtype = out_dtype or a.dtype
    if blocks is None:
        bm = bn = bk = None
        a_blk = b_blk = None
        nj = ni = 1
    else:
        bm, bn, bk = blocks
        a_blk, b_blk = (bm, bk), (bk, bn)
        nj, ni = N // bn, M // bm

    fa, nan_a, inf_a, ta = repair_array_ref(
        a, policy=policy, constant=constant, include_inf=include_inf,
        block=a_blk,
    )
    fb, nan_b, inf_b, tb = repair_array_ref(
        b, policy=policy, constant=constant, include_inf=include_inf,
        block=b_blk,
    )
    c = jnp.dot(
        fa.astype(jnp.float32), fb.astype(jnp.float32)
    ).astype(out_dtype)
    counts = jnp.stack([
        nan_a * nj, inf_a * nj, ta * nj,
        nan_b * ni, inf_b * ni, tb * ni,
        jnp.zeros((), jnp.int32),       # ev_total needs the joint schedule
        jnp.zeros((), jnp.int32),
    ])
    return c, counts


def _paged_masks(x, detector, include_inf):
    """Fatal masks of one operand under the paged kernel's detector grammar:
    a ``core.rules.Detector``, the "default" sentinel (legacy NaN(+Inf)),
    or ``None`` — detection disabled."""
    if detector is None:
        z = jnp.zeros(x.shape, jnp.bool_)
        return z, z
    if isinstance(detector, str):          # the "default" sentinel
        from ..core import rules as rules_lib

        detector = rules_lib.Detector(nan=True, inf=include_inf)
    return detector.masks(x)


def _repair_paged_rows(rows, detector, policy, constant, include_inf):
    """Repair (B, M, pg, Kh, Dh) page rows, one (b, m) row per kernel tile,
    with the paged family's per-operand fill grammar.  Returns the repaired
    rows and the per-slot fatal-lane counts (B, M)."""
    nan_m, inf_m = _paged_masks(rows, detector, include_inf)
    mask = nan_m | inf_m
    if policy == "zero":
        rep = jnp.zeros_like(rows)
    elif policy == "constant":
        rep = jnp.full_like(rows, constant)
    elif policy == "clamp_finite_max":
        rep = jnp.full_like(rows, jnp.finfo(rows.dtype).max)
    elif policy == "neighbor_mean":
        ok = (~mask).astype(jnp.float32)
        cnt = jnp.maximum(ok.sum(axis=(2, 3, 4), keepdims=True), 1.0)
        tot = jnp.where(mask, 0.0, rows.astype(jnp.float32)).sum(
            axis=(2, 3, 4), keepdims=True
        )
        rep = jnp.broadcast_to(tot / cnt, rows.shape).astype(rows.dtype)
    else:
        raise ValueError(policy)
    fixed = jnp.where(mask, rep, rows)
    n_fatal = (nan_m | inf_m).astype(jnp.int32).sum(axis=(2, 3, 4))
    return fixed, n_fatal                                      # (B, M)


def paged_attention_ref(
    q,                 # (B, H, Dh)
    k_pages,           # (P, pg, Kh, Dh) or (P, L, pg, Kh, Dh)
    v_pages,
    block_tables,      # (B, M) int32
    positions,         # (B,) int32, inclusive
    *,
    layer: int = 0,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    detector_k="default",
    detector_v="default",
    policy_k=None,
    constant_k=None,
    policy_v=None,
    constant_v=None,
):
    """Oracle of kernels.paged_attention: gather the block-table pages (the
    very copy the kernel avoids), repair each (page, layer) row as one tile
    — the kernel's repair unit — then full-softmax decode attention over
    the masked positions.  ``policy_k``/``policy_v`` (+ constants) override
    the shared fill per operand, mirroring the kernel's per-tile
    operand-indexed fill selection.  Returns ``(out (B,H,Dh), slot_counts
    (B,M))`` with bit-exact count semantics."""
    if k_pages.ndim == 4:
        k_pages = k_pages[:, None]
        v_pages = v_pages[:, None]
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B, H, Dh = q.shape
    P, L, pg, Kh, _ = k_pages.shape
    G = H // Kh
    bt = jnp.asarray(block_tables, jnp.int32)
    M = bt.shape[1]
    pos = jnp.asarray(positions, jnp.int32)

    k_rows = k_pages[bt, layer]                                # (B, M, pg, Kh, Dh)
    v_rows = v_pages[bt, layer]
    fk, cnt_k = _repair_paged_rows(
        k_rows, detector_k, policy_k, constant_k, include_inf
    )
    fv, cnt_v = _repair_paged_rows(
        v_rows, detector_v, policy_v, constant_v, include_inf
    )
    slot_counts = cnt_k + cnt_v

    T = M * pg
    fk = fk.reshape(B, T, Kh, Dh)
    fv = fv.reshape(B, T, Kh, Dh)
    qg = q.reshape(B, Kh, G, Dh).astype(jnp.float32)
    s = jnp.einsum(
        "bkgd,btkd->bkgt", qg, fk.astype(jnp.float32)
    ) / math.sqrt(Dh)
    t = jnp.arange(T)
    s = jnp.where(t[None, None, None, :] <= pos[:, None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    # weights quantize to the cache dtype before the value contraction,
    # like the gathered decode and the fused kernel
    out = jnp.einsum(
        "bkgt,btkd->bkgd", w.astype(fv.dtype), fv,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, H, Dh).astype(q.dtype), slot_counts


def paged_prefill_ref(
    q,                 # (B, C, H, Dh) — one causal chunk per request
    k_pages,           # (P, pg, Kh, Dh) or (P, L, pg, Kh, Dh)
    v_pages,
    block_tables,      # (B, M) int32
    q_start,           # (B,) int32 — context position of chunk row 0
    *,
    layer: int = 0,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    detector_k="default",
    detector_v="default",
    policy_k=None,
    constant_k=None,
    policy_v=None,
    constant_v=None,
):
    """Oracle of kernels.paged_prefill: gather, tile-repair, then full
    causal softmax — chunk row ``c`` reads key positions ``<= q_start + c``.
    Rows past the caller's real chunk length are computed like any other
    (the kernel's garbage-row contract); callers compare valid rows only.
    Returns ``(out (B, C, H, Dh), slot_counts (B, M))``."""
    if k_pages.ndim == 4:
        k_pages = k_pages[:, None]
        v_pages = v_pages[:, None]
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B, C, H, Dh = q.shape
    P, L, pg, Kh, _ = k_pages.shape
    G = H // Kh
    bt = jnp.asarray(block_tables, jnp.int32)
    M = bt.shape[1]
    qs = jnp.asarray(q_start, jnp.int32)

    fk, cnt_k = _repair_paged_rows(
        k_pages[bt, layer], detector_k, policy_k, constant_k, include_inf
    )
    fv, cnt_v = _repair_paged_rows(
        v_pages[bt, layer], detector_v, policy_v, constant_v, include_inf
    )
    slot_counts = cnt_k + cnt_v

    T = M * pg
    fk = fk.reshape(B, T, Kh, Dh)
    fv = fv.reshape(B, T, Kh, Dh)
    qg = q.reshape(B, C, Kh, G, Dh).astype(jnp.float32)
    s = jnp.einsum(
        "bckgd,btkd->bckgt", qg, fk.astype(jnp.float32)
    ) / math.sqrt(Dh)
    tq = qs[:, None] + jnp.arange(C)[None, :]                  # (B, C)
    t = jnp.arange(T)
    s = jnp.where(
        t[None, None, None, None, :] <= tq[:, :, None, None, None], s, -1e30
    )
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bckgt,btkd->bckgd", w.astype(fv.dtype), fv,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(B, C, H, Dh).astype(q.dtype), slot_counts


def paged_splitk_ref(
    q,                 # (B, H, Dh)
    k_pages,           # (P, pg, Kh, Dh) or (P, L, pg, Kh, Dh)
    v_pages,
    block_tables,      # (B, M) int32
    positions,         # (B,) int32, inclusive
    *,
    splits: int,
    layer: int = 0,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    detector_k="default",
    detector_v="default",
    policy_k=None,
    constant_k=None,
    policy_v=None,
    constant_v=None,
):
    """Oracle of kernels.paged_attention_splitk: per-split softmax partials
    merged by log-sum-exp, with the null-tail guard made explicit — a split
    whose slice holds no valid position carries ``(m, l) = (-inf, 0)`` and
    zero weight into the merge, never its fill values.  Returns
    ``(out (B, H, Dh), slot_counts (B, M))``."""
    if k_pages.ndim == 4:
        k_pages = k_pages[:, None]
        v_pages = v_pages[:, None]
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B, H, Dh = q.shape
    P, L, pg, Kh, _ = k_pages.shape
    G = H // Kh
    bt = jnp.asarray(block_tables, jnp.int32)
    M = bt.shape[1]
    assert splits >= 1 and M % splits == 0, (splits, M)
    ns = M // splits
    pos = jnp.asarray(positions, jnp.int32)

    fk, cnt_k = _repair_paged_rows(
        k_pages[bt, layer], detector_k, policy_k, constant_k, include_inf
    )
    fv, cnt_v = _repair_paged_rows(
        v_pages[bt, layer], detector_v, policy_v, constant_v, include_inf
    )
    slot_counts = cnt_k + cnt_v

    # (B, splits, ns*pg, Kh, Dh): each split sees its contiguous page slice
    fk = fk.reshape(B, splits, ns * pg, Kh, Dh)
    fv = fv.reshape(B, splits, ns * pg, Kh, Dh)
    qg = q.reshape(B, Kh, G, Dh).astype(jnp.float32)
    s = jnp.einsum(
        "bkgd,bstkd->bskgt", qg, fk.astype(jnp.float32)
    ) / math.sqrt(Dh)
    t = (
        jnp.arange(splits)[:, None] * ns * pg + jnp.arange(ns * pg)[None, :]
    )                                                          # (splits, ns*pg)
    valid = t[None, :, None, None, :] <= pos[:, None, None, None, None]
    s = jnp.where(valid, s, -1e30)
    m = jnp.max(s, axis=-1)                                    # (B, s, Kh, G)
    p = jnp.where(valid, jnp.exp(s - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)                                    # (B, s, Kh, G)
    acc = jnp.einsum(
        "bskgt,bstkd->bskgd", p.astype(fv.dtype).astype(jnp.float32), fv.astype(jnp.float32)
    )                                                          # (B, s, Kh, G, Dh)
    m_star = jnp.max(m, axis=1)                                # (B, Kh, G)
    live = m > -5e29
    w = jnp.where(live, jnp.exp(m - m_star[:, None]), 0.0)     # (B, s, Kh, G)
    l_tot = jnp.sum(w * l, axis=1)
    out = jnp.sum(w[..., None] * acc, axis=1) / jnp.maximum(
        l_tot, 1e-30
    )[..., None]
    return out.reshape(B, H, Dh).astype(q.dtype), slot_counts


def flash_attention_ref(
    q, k, v, *, causal=True, policy="zero", constant=0.0, include_inf=True,
    kv_block: Optional[int] = None,
):
    """Oracle of flash_attention_raw: full-softmax attention over the
    tile-repaired K/V.  Returns out only (counter schedule is asserted
    separately in tests via repair_array_ref)."""
    B, H, S, D = q.shape
    _, Kh, T, _ = k.shape
    G = H // Kh
    blk = (kv_block, D) if kv_block else None
    fk, *_ = repair_array_ref(
        k.reshape(-1, D), policy=policy, constant=constant,
        include_inf=include_inf, block=blk,
    )
    fv, *_ = repair_array_ref(
        v.reshape(-1, D), policy=policy, constant=constant,
        include_inf=include_inf, block=blk,
    )
    fk = fk.reshape(k.shape)
    fv = fv.reshape(v.shape)

    kx = jnp.repeat(fk, G, axis=1).astype(jnp.float32)   # (B,H,T,D)
    vx = jnp.repeat(fv, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhsd,bhtd->bhst", q.astype(jnp.float32), kx)
    s = s / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, T), bool), k=T - S)
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhst,bhtd->bhsd", w, vx)
    return out.astype(q.dtype)
