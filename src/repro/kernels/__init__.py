"""Pallas TPU kernels for the paper's compute hot spots.

The paper's evaluation target is a matrix-matrix multiplication with an
injected NaN (Fig. 7 / Table 3); the framework's serving hot spot is
attention over a cached KV.  Both get a fused-reactive-repair kernel:

  scrub.py              one-shot in-place NaN/Inf repair + event counters
  repair_matmul.py      tiled MXU matmul, fused operand-tile repair
  repair_attention.py   flash attention, fused KV-tile repair
  paged_attention.py    block-table paged decode attention straight off the
                        serving pool, fused on-read repair + per-page counts
  mlstm_chunk.py        fused chunked-mLSTM, (P,P) state resident in VMEM
  ops.py                jit wrappers adding memory-mode reactive write-back
  ref.py                pure-jnp oracles (bit-exact counter semantics)

All kernels use explicit BlockSpec VMEM tiling and are validated on CPU in
interpret mode; on TPU they lower natively (default_interpret() switches).
"""
from . import common, mlstm_chunk, ops, paged_attention, ref  # noqa: F401
from .ops import flash_attention, repair_matmul, scrub, scrub_pages  # noqa: F401
from .paged_attention import paged_attention as paged_attention_call  # noqa: F401
