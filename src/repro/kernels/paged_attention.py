"""Paged decode attention with fused on-read repair — the trap, in the read.

This is the serving engine's decode hot path run *straight off the pool*:
the kernel consumes the pool's page-major KV leaves plus per-request block
tables (the layout vLLM's PagedAttention popularized), so the engine never
gathers pages into a contiguous per-step view and never scatters one back.
The per-step full-KV copy — the #1 ROADMAP open item after PR 3 — is gone;
the page-axis sharding of the pool finally pays off end to end, and (per
EDEN) the approximate data stays in place instead of round-tripping.

Repair semantics are the truest realization of the paper's trap-on-read
design this repo has: each (page, layer) row is bit-pattern checked and
repaired in VMEM right after the HBM→VMEM DMA the attention performs
anyway — detection and repair fused into the read, zero extra HBM traffic —
and the kernel emits *per-page-slot fatal counts*, so the reactive repair
manager knows exactly which resident pages hold a fatal lane without any
separate detection scan over the pages the step touched.

Layout:

  q             (B, H, Dh)          one query token per decode slot
  k/v pages     (P, L, pg, Kh, Dh)  the pool leaves, page axis LEADING
                                    (``Model.paged_cache_defs``); ``layer``
                                    selects the L row via scalar prefetch
  block_tables  (B, M) int32        per-request page lists, null-padded
  positions     (B) int32           last valid context position (inclusive)

Grid (B, M): request-major, one physical page per inner step.  The page's
pool row is selected *by the block table* through the k/v BlockSpec index
maps — the block table is a scalar-prefetch operand, available before the
kernel body, which is exactly what PrefetchScalarGridSpec exists for.
Online-softmax state (acc, m, l) lives in scratch across the page axis.
Null-padded tail slots are masked by position (a request's real pages cover
positions ``0..pos``; padding covers positions beyond it), but their DMA
and detection still run: a NaN parked in the null page would otherwise
poison the context through ``0 * NaN`` in the value contraction — here it
is repaired in VMEM and *reported*, like any other page.

Outputs: (out (B, H, Dh), slot_counts (B, M) int32, counts int32[8]).
``slot_counts[b, j]`` is the fatal-lane count of the page visited by block
slot (b, j) — scatter-added over the block table this becomes the
``(n_pages,)`` per-page vector the serving repair manager consumes (pages
visited by several slots, i.e. the null page, accumulate per visit; the
manager only needs the >0 predicate).  ``counts`` is the shared AT_* event
layout of ``repair_attention`` so the unified stats routing is identical.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

NEG_INF = -1e30

# counts layout (int32[8]) — identical to repair_attention's AT_* layout
NAN_K, INF_K, EV_K, NAN_V, INF_V, EV_V, EV_TOTAL = range(7)

# sentinel default for the detector kwargs: "the legacy NaN(+Inf) pattern
# via include_inf".  ``None`` is a *meaningful* value (detection disabled
# for that operand), so the default cannot be None.
DEFAULT_DETECTOR = "default"

# per-slot chunk-start sentinel for the sharded prefill walk: a slot whose
# q_start carries this value belongs to another device's shard — every
# causal comparison fails (tq is hugely negative) and the count gate is off
NO_SLOT = -(1 << 30)


def _repair_and_count(
    consts_ref, k_ref, v_ref, slot_ref, counts_ref,
    *, policy_k: str, constant_k: float, policy_v: str, constant_v: float,
    gate=None,
):
    """Fused on-read repair of one page's K/V rows (the trap) — shared by
    every kernel in the paged family.  Per-operand fill selection: each
    tile repairs with ITS operand's rule fill (row 0 = K, row 1 = V), so a
    mixed-fill RuleSet compiles into one kernel instead of forcing the
    gathered fallback.  Accumulates the AT_* event counts and writes the
    per-page-slot fatal count the reactive repair manager consumes.

    ``gate`` (int32 0/1, default 1) masks the *counting* side only: under
    the sharded walk a device visits every block-table slot but owns only
    the pages of its shard — non-owned slots are remapped to a local row
    whose faults belong to another device, so their detections must not be
    reported here (the VMEM repair itself is harmless: the slot's scores
    are fully masked).  Each page is thus counted by exactly one device."""
    if gate is None:
        gate = jnp.int32(1)
    k_fixed, nan_k, inf_k = common.repair_tile(
        k_ref[0, 0], policy=policy_k, constant=constant_k,
        consts=consts_ref[0],
    )
    v_fixed, nan_v, inf_v = common.repair_tile(
        v_ref[0, 0], policy=policy_v, constant=constant_v,
        consts=consts_ref[1],
    )
    ev_k = ((nan_k + inf_k) > 0).astype(jnp.int32)
    ev_v = ((nan_v + inf_v) > 0).astype(jnp.int32)
    counts_ref[NAN_K] += gate * nan_k
    counts_ref[INF_K] += gate * inf_k
    counts_ref[EV_K] += gate * ev_k
    counts_ref[NAN_V] += gate * nan_v
    counts_ref[INF_V] += gate * inf_v
    counts_ref[EV_V] += gate * ev_v
    counts_ref[EV_TOTAL] += gate * ((ev_k + ev_v) > 0).astype(jnp.int32)
    slot_ref[0, 0] = gate * (nan_k + inf_k + nan_v + inf_v)
    return k_fixed, v_fixed


def _detector_consts(detector_k, detector_v, dtype, include_inf: bool):
    """The int32[2, 8] scalar-prefetch constants (row 0 = K, row 1 = V)
    shared by every kernel in the paged family."""

    def operand_row(det):
        if det is None:
            # all detection flags off: the kernel loads, never repairs
            return jnp.zeros((8,), jnp.int32)
        if det == DEFAULT_DETECTOR:
            det = common.resolve_detector(None, include_inf)
        return common.detector_operand(det, dtype)

    return jnp.stack([operand_row(detector_k), operand_row(detector_v)])


def _lse_merge(out_dtype, o_part, m_part, l_part):
    """Log-sum-exp merge of unnormalized partials along axis 1 — the
    reduce stage shared by split-K flash decoding (partials = splits) and
    the sharded walk (partials = devices × splits).  Partials whose slice
    was pure null padding / not owned carry ``m = -inf``: their exp()
    weight is forced to zero rather than trusting exp(-inf - m*)
    arithmetic, which would turn into exp(0) = 1 when every partial of a
    row is empty."""
    m_star = jnp.max(m_part, axis=1)                         # (B, H)
    live = m_part > NEG_INF * 0.5                            # (B, S, H)
    w = jnp.where(live, jnp.exp(m_part - m_star[:, None, :]), 0.0)
    l_tot = jnp.sum(w * l_part, axis=1)                      # (B, H)
    acc = jnp.sum(w[..., None] * o_part, axis=1)             # (B, H, Dh)
    return (acc / jnp.maximum(l_tot, 1e-30)[..., None]).astype(out_dtype)


def _paged_kernel(
    consts_ref,      # int32[2, 8]  detector constants: row 0 K, row 1 V
    bt_ref,          # int32[B, M]  block tables (also drives the index maps)
    pos_ref,         # int32[B]     last valid position per request
    layer_ref,       # int32[1]     which L row of the pool leaves
    q_ref, k_ref, v_ref,
    o_ref, slot_ref, counts_ref,
    acc_ref, m_ref, l_ref,
    *, sm_scale: float,
    policy_k: str, constant_k: float, policy_v: str, constant_v: float,
    pg: int, n_kv: int, group: int, nm: int, out_dtype,
):
    b, j = pl.program_id(0), pl.program_id(1)
    step = b * pl.num_programs(1) + j

    @pl.when(step == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(j == 0)
    def _init_state():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    k_fixed, v_fixed = _repair_and_count(
        consts_ref, k_ref, v_ref, slot_ref, counts_ref,
        policy_k=policy_k, constant_k=constant_k,
        policy_v=policy_v, constant_v=constant_v,
    )

    # ---- online softmax over this page ----
    H = n_kv * group
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, group, q_ref.shape[-1])
    kb = jnp.moveaxis(k_fixed.astype(jnp.float32), 1, 0)     # (Kh, pg, Dh)
    s = jax.lax.dot_general(
        q, kb, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * sm_scale                                             # (Kh, G, pg)
    t = j * pg + jax.lax.broadcasted_iota(jnp.int32, (1, 1, pg), 2)
    s = jnp.where(t <= pos_ref[b], s, NEG_INF)
    s2 = s.reshape(H, pg)

    m_prev = m_ref[:, 0]                                     # (H,)
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=-1))
    p = jnp.exp(s2 - m_new[:, None])                         # (H, pg)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
    # softmax weights quantize to the cache dtype before the value
    # contraction — the gathered decode's `w.astype(cv.dtype)` and the
    # flash kernel's `p.astype(v_blk.dtype)`, kept here so the fused path
    # matches the gathered one (bit-exact for f32 pools; for bf16 the
    # online-softmax alpha-rescale happens after quantization, so parity
    # is approximate at the value level, token-level in practice)
    vb = jnp.moveaxis(v_fixed, 1, 0)                         # (Kh, pg, Dh)
    pv = jax.lax.dot_general(
        p.reshape(n_kv, group, pg).astype(v_fixed.dtype), vb,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                        # (Kh, G, Dh)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.reshape(acc_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == nm - 1)
    def _flush():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "constant", "include_inf", "interpret",
        "detector_k", "detector_v",
        "policy_k", "constant_k", "policy_v", "constant_v",
    ),
)
def paged_attention_raw(
    q: jax.Array,              # (B, H, Dh)
    k_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    v_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    block_tables: jax.Array,   # (B, M) int32
    positions: jax.Array,      # (B,) int32, inclusive
    layer: jax.Array,          # int32 scalar — L row of the pool leaves
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR,
    detector_v=DEFAULT_DETECTOR,
    policy_k: Optional[str] = None,
    constant_k: Optional[float] = None,
    policy_v: Optional[str] = None,
    constant_v: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One layer of paged decode attention with fused on-read repair.

    ``detector_k`` / ``detector_v`` pick the fatal-pattern set per operand:
    a ``core.rules.Detector``, the default sentinel (legacy NaN(+Inf) via
    ``include_inf``), or ``None`` — detection disabled for that operand
    entirely (a zeroed-flags constants row; the exact-region /
    non-reactive-rule case), which keeps the read bit-transparent.
    ``policy_k``/``constant_k`` and ``policy_v``/``constant_v`` pick the
    fill per operand the same way (``None`` inherits the shared
    ``policy``/``constant``) — a mixed-fill RuleSet compiles into ONE
    kernel, each tile repairing with its operand's own fill.  Returns
    ``(out (B, H, Dh), slot_counts (B, M) int32, counts int32[8])``.
    """
    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B, H, Dh = q.shape
    P, L, pg, Kh, _ = k_pages.shape
    assert v_pages.shape == k_pages.shape, (k_pages.shape, v_pages.shape)
    assert H % Kh == 0, (H, Kh)
    group = H // Kh
    M = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(Dh)
    consts = _detector_consts(detector_k, detector_v, k_pages.dtype, include_inf)

    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe import

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,    # detector consts, block tables, positions, layer
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, H, Dh), lambda b, j, c, bt, pos, lay: (b, 0, 0)),
            # the block table IS the index map: page (b, j) of the request's
            # table selects the pool row — no gather ever materializes
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, j, c, bt, pos, lay: (bt[b, j], lay[0], 0, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, j, c, bt, pos, lay: (bt[b, j], lay[0], 0, 0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, H, Dh), lambda b, j, c, bt, pos, lay: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j, c, bt, pos, lay: (b, j)),
            pl.BlockSpec((8,), lambda b, j, c, bt, pos, lay: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, Dh), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
        ],
    )
    out, slot_counts, counts = pl.pallas_call(
        functools.partial(
            _paged_kernel,
            sm_scale=sm_scale,
            policy_k=policy_k,
            constant_k=constant_k,
            policy_v=policy_v,
            constant_v=constant_v,
            pg=pg,
            n_kv=Kh,
            group=group,
            nm=M,
            out_dtype=q.dtype,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, M), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ],
        interpret=interpret,
    )(
        consts,
        jnp.asarray(block_tables, jnp.int32),
        jnp.asarray(positions, jnp.int32),
        jnp.asarray(layer, jnp.int32).reshape(1),
        q, k_pages, v_pages,
    )
    return out, slot_counts, counts


def paged_attention(
    q: jax.Array,              # (B, H, Dh)
    k_pages: jax.Array,        # (P, pg, Kh, Dh) or (P, L, pg, Kh, Dh)
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, M) int32
    positions: jax.Array,      # (B,) int32, inclusive
    *,
    layer: int = 0,
    **kw,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Convenience entry: accepts layer-free ``(P, pg, Kh, Dh)`` pools (the
    single-layer tests/bench shape) and returns ``(out, page_counts,
    counts)`` with ``page_counts`` already scatter-added to the pool's page
    axis — the ``(n_pages,)`` per-page fatal vector."""
    if k_pages.ndim == 4:
        k_pages = k_pages[:, None]
        v_pages = v_pages[:, None]
    out, slot_counts, counts = paged_attention_raw(
        q, k_pages, v_pages, block_tables, positions,
        jnp.asarray(layer, jnp.int32), **kw,
    )
    page_counts = jnp.zeros((k_pages.shape[0],), jnp.int32).at[
        jnp.asarray(block_tables, jnp.int32)
    ].add(slot_counts)
    return out, page_counts, counts


# --------------------------------------------------------------------------
# Chunked-q paged prefill: admission attends straight off the pool too.
# --------------------------------------------------------------------------
def _paged_prefill_kernel(
    consts_ref,      # int32[2, 8]  detector constants: row 0 K, row 1 V
    bt_ref,          # int32[B, M]  block tables (also drives the index maps)
    qstart_ref,      # int32[B, M]  chunk-row-0 position, per block slot
    layer_ref,       # int32[1]     which L row of the pool leaves
    q_ref, k_ref, v_ref,
    o_ref, mo_ref, lo_ref, slot_ref, counts_ref,
    acc_ref, m_ref, l_ref,
    *, sm_scale: float,
    policy_k: str, constant_k: float, policy_v: str, constant_v: float,
    pg: int, n_kv: int, group: int, nm: int, nc: int,
):
    b, j = pl.program_id(0), pl.program_id(1)
    step = b * pl.num_programs(1) + j

    @pl.when(step == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(j == 0)
    def _init_state():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # per-SLOT q_start: on a single device every slot carries the request's
    # chunk start; under the sharded walk non-owned slots carry NO_SLOT,
    # which kills every causal comparison below and gates the counts off
    qs = qstart_ref[b, j]
    k_fixed, v_fixed = _repair_and_count(
        consts_ref, k_ref, v_ref, slot_ref, counts_ref,
        policy_k=policy_k, constant_k=constant_k,
        policy_v=policy_v, constant_v=constant_v,
        gate=(qs >= 0).astype(jnp.int32),
    )

    # ---- online softmax: the whole q chunk against this page ----
    Dh = q_ref.shape[-1]
    R = nc * n_kv * group                                    # (C, H) rows
    q = q_ref[0].astype(jnp.float32).reshape(nc, n_kv, group, Dh)
    qh = jnp.moveaxis(q, 1, 0).reshape(n_kv, nc * group, Dh)
    kb = jnp.moveaxis(k_fixed.astype(jnp.float32), 1, 0)     # (Kh, pg, Dh)
    s = jax.lax.dot_general(
        qh, kb, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * sm_scale                                             # (Kh, C*G, pg)
    s = s.reshape(n_kv, nc, group, pg)
    # causal mask, per chunk row: row c sits at context position
    # q_start + c and may read keys at positions <= that
    tq = qs + jax.lax.broadcasted_iota(
        jnp.int32, (1, nc, 1, 1), 1
    )
    tk = j * pg + jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, pg), 3)
    s = jnp.where(tk <= tq, s, NEG_INF)
    # scratch rows ordered (C, Kh, G) so the flush is a plain reshape
    s2 = jnp.moveaxis(s, 0, 1).reshape(R, pg)

    m_prev = m_ref[:, 0]                                     # (R,)
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=-1))
    # same empty-walk guard as split-K: a shard owning none of a request's
    # pages keeps (m, l, acc) = (-inf, 0, 0) exactly, which the LSE merge
    # drops.  For the serial walk this is a bit-exact no-op — slot 0 always
    # yields a real row max, so masked lanes underflow to 0.0 either way.
    p = jnp.where(
        s2 > NEG_INF * 0.5, jnp.exp(s2 - m_new[:, None]), 0.0
    )                                                        # (R, pg)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
    # quantize the softmax weights to the cache dtype before the value
    # contraction, matching the decode kernel and the gathered path
    pk = jnp.moveaxis(p.reshape(nc, n_kv, group, pg), 1, 0)
    pk = pk.reshape(n_kv, nc * group, pg).astype(v_fixed.dtype)
    vb = jnp.moveaxis(v_fixed, 1, 0)                         # (Kh, pg, Dh)
    pv = jax.lax.dot_general(
        pk, vb, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                        # (Kh, C*G, Dh)
    pv = jnp.moveaxis(pv.reshape(n_kv, nc, group, Dh), 0, 1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.reshape(acc_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == nm - 1)
    def _flush():
        # raw partials — normalization happens in the caller / LSE merge
        o_ref[0] = acc_ref[...].reshape(nc, n_kv * group, Dh)
        mo_ref[0] = m_ref[:, 0]
        lo_ref[0] = l_ref[:, 0]


def _prefill_partials(
    q, k_pages, v_pages, block_tables, qs_slot, layer,
    *, consts, policy_k, constant_k, policy_v, constant_v, interpret,
):
    """Unnormalized chunked-q prefill partials over the block-table walk.

    ``qs_slot`` is (B, M) int32 — the chunk-row-0 context position carried
    *per block slot*.  On a single device every slot of request ``b`` holds
    the same value; under the sharded walk non-owned slots hold ``NO_SLOT``
    (fully masked, counts gated).  Returns ``(acc (B, C, H, Dh) f32,
    m (B, C*H) f32, l (B, C*H) f32, slot_counts, counts)``.
    """
    B, C, H, Dh = q.shape
    P, L, pg, Kh, _ = k_pages.shape
    assert v_pages.shape == k_pages.shape, (k_pages.shape, v_pages.shape)
    assert H % Kh == 0, (H, Kh)
    group = H // Kh
    M = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(Dh)

    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe import

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # detector consts, block tables, q_start, layer
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, C, H, Dh), lambda b, j, c, bt, qs, lay: (b, 0, 0, 0)),
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, j, c, bt, qs, lay: (bt[b, j], lay[0], 0, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, j, c, bt, qs, lay: (bt[b, j], lay[0], 0, 0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, C, H, Dh), lambda b, j, c, bt, qs, lay: (b, 0, 0, 0)),
            pl.BlockSpec((1, C * H), lambda b, j, c, bt, qs, lay: (b, 0)),
            pl.BlockSpec((1, C * H), lambda b, j, c, bt, qs, lay: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, j, c, bt, qs, lay: (b, j)),
            pl.BlockSpec((8,), lambda b, j, c, bt, qs, lay: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((C * H, Dh), jnp.float32),
            pltpu.VMEM((C * H, 128), jnp.float32),
            pltpu.VMEM((C * H, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_prefill_kernel,
            sm_scale=sm_scale,
            policy_k=policy_k,
            constant_k=constant_k,
            policy_v=policy_v,
            constant_v=constant_v,
            pg=pg,
            n_kv=Kh,
            group=group,
            nm=M,
            nc=C,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, C, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, C * H), jnp.float32),
            jax.ShapeDtypeStruct((B, C * H), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ],
        interpret=interpret,
    )(
        consts,
        jnp.asarray(block_tables, jnp.int32),
        jnp.asarray(qs_slot, jnp.int32),
        jnp.asarray(layer, jnp.int32).reshape(1),
        q, k_pages, v_pages,
    )


def _prefill_normalize(out_dtype, acc, l):
    """The serial prefill epilogue: divide the f32 accumulator by the row
    sums and cast — the same ops, in the same row order, the kernel used to
    run in its flush, so moving it out of the kernel is bit-transparent."""
    B, C, H, Dh = acc.shape
    denom = jnp.maximum(l, 1e-30)                            # (B, C*H)
    out = acc.reshape(B, C * H, Dh) / denom[..., None]
    return out.astype(out_dtype).reshape(B, C, H, Dh)


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "constant", "include_inf", "interpret",
        "detector_k", "detector_v",
        "policy_k", "constant_k", "policy_v", "constant_v",
    ),
)
def paged_prefill_raw(
    q: jax.Array,              # (B, C, H, Dh) one causal chunk per request
    k_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    v_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    block_tables: jax.Array,   # (B, M) int32
    q_start: jax.Array,        # (B,) int32 — context position of chunk row 0
    layer: jax.Array,          # int32 scalar — L row of the pool leaves
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR,
    detector_v=DEFAULT_DETECTOR,
    policy_k: Optional[str] = None,
    constant_k: Optional[float] = None,
    policy_v: Optional[str] = None,
    constant_v: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One layer of chunked-q paged prefill with fused on-read repair.

    The q chunk (already written into the pool by the caller) attends over
    the request's pages via the block-table index maps — same grid walk,
    per-operand detector constants, and per-tile fills as decode, with the
    chunk's causal mask (`key position <= q_start + row`) instead of a
    single decode position.  Chunk row ``c`` must sit at context position
    ``q_start[b] + c``; rows past the real chunk length produce garbage the
    caller discards (they read positions beyond their causal horizon, which
    is harmless — detection counts are per *page tile* and q-independent).
    Returns ``(out (B, C, H, Dh), slot_counts (B, M) int32, counts
    int32[8])``.
    """
    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B = q.shape[0]
    M = block_tables.shape[1]
    consts = _detector_consts(detector_k, detector_v, k_pages.dtype, include_inf)
    qs_slot = jnp.broadcast_to(
        jnp.asarray(q_start, jnp.int32)[:, None], (B, M)
    )
    acc, m, l, slot_counts, counts = _prefill_partials(
        q, k_pages, v_pages, block_tables, qs_slot, layer,
        consts=consts,
        policy_k=policy_k, constant_k=constant_k,
        policy_v=policy_v, constant_v=constant_v,
        interpret=interpret,
    )
    return _prefill_normalize(q.dtype, acc, l), slot_counts, counts


def paged_prefill(
    q: jax.Array,              # (B, C, H, Dh)
    k_pages: jax.Array,        # (P, pg, Kh, Dh) or (P, L, pg, Kh, Dh)
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, M) int32
    q_start: jax.Array,        # (B,) int32
    *,
    layer: int = 0,
    **kw,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Convenience entry mirroring ``paged_attention``: layer-free pools,
    ``page_counts`` scatter-added to the pool's page axis."""
    if k_pages.ndim == 4:
        k_pages = k_pages[:, None]
        v_pages = v_pages[:, None]
    out, slot_counts, counts = paged_prefill_raw(
        q, k_pages, v_pages, block_tables, q_start,
        jnp.asarray(layer, jnp.int32), **kw,
    )
    page_counts = jnp.zeros((k_pages.shape[0],), jnp.int32).at[
        jnp.asarray(block_tables, jnp.int32)
    ].add(slot_counts)
    return out, page_counts, counts


# --------------------------------------------------------------------------
# Split-K flash decoding: the page walk parallelized across grid cells.
# --------------------------------------------------------------------------
def _paged_splitk_kernel(
    consts_ref,      # int32[2, 8]  detector constants: row 0 K, row 1 V
    bt_ref,          # int32[B, M]  block tables (also drives the index maps)
    pos_ref,         # int32[B, M]  last valid position, per block slot
    layer_ref,       # int32[1]     which L row of the pool leaves
    q_ref, k_ref, v_ref,
    o_ref, mo_ref, lo_ref, slot_ref, counts_ref,
    acc_ref, m_ref, l_ref,
    *, sm_scale: float,
    policy_k: str, constant_k: float, policy_v: str, constant_v: float,
    pg: int, n_kv: int, group: int, ns: int,
):
    b, g, jj = pl.program_id(0), pl.program_id(1), pl.program_id(2)
    step = (b * pl.num_programs(1) + g) * pl.num_programs(2) + jj

    @pl.when(step == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(jj == 0)
    def _init_state():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # per-SLOT position bound: on a single device every slot of request b
    # carries pos[b]; under the sharded walk non-owned slots carry -1 —
    # every key position fails `t <= bound` and the count gate is off
    bound = pos_ref[b, g * ns + jj]
    k_fixed, v_fixed = _repair_and_count(
        consts_ref, k_ref, v_ref, slot_ref, counts_ref,
        policy_k=policy_k, constant_k=constant_k,
        policy_v=policy_v, constant_v=constant_v,
        gate=(bound >= 0).astype(jnp.int32),
    )

    # ---- online softmax over this split's slice of the page walk ----
    H = n_kv * group
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, group, q_ref.shape[-1])
    kb = jnp.moveaxis(k_fixed.astype(jnp.float32), 1, 0)     # (Kh, pg, Dh)
    s = jax.lax.dot_general(
        q, kb, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * sm_scale                                             # (Kh, G, pg)
    t = (g * ns + jj) * pg + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, pg), 2
    )
    s = jnp.where(t <= bound, s, NEG_INF)
    s2 = s.reshape(H, pg)

    m_prev = m_ref[:, 0]                                     # (H,)
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=-1))
    # null-tail guard: unlike the serial walk (whose slot 0 always holds a
    # valid position), a split can land on NOTHING but null padding.  Its
    # running max then never leaves NEG_INF, and a bare exp(s - m) would be
    # exp(0) = 1 per fill lane — fill values leaking probability mass into
    # the merge.  Masking p on score validity keeps such splits at exactly
    # (m, l, acc) = (-inf, 0, 0), which the LSE merge drops.
    p = jnp.where(
        s2 > NEG_INF * 0.5, jnp.exp(s2 - m_new[:, None]), 0.0
    )                                                        # (H, pg)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
    vb = jnp.moveaxis(v_fixed, 1, 0)                         # (Kh, pg, Dh)
    pv = jax.lax.dot_general(
        p.reshape(n_kv, group, pg).astype(v_fixed.dtype), vb,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                        # (Kh, G, Dh)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.reshape(acc_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(jj == ns - 1)
    def _flush():
        # raw partials — normalization happens in the LSE merge stage
        o_ref[0, 0] = acc_ref[...]
        mo_ref[0, 0] = m_ref[:, 0]
        lo_ref[0, 0] = l_ref[:, 0]


def _splitk_partials(
    q, k_pages, v_pages, block_tables, pos_slot, layer,
    *, splits, consts, policy_k, constant_k, policy_v, constant_v, interpret,
):
    """Unnormalized split-K decode partials over the block-table walk.

    ``pos_slot`` is (B, M) int32 — the inclusive position bound carried
    *per block slot*.  On a single device every slot of request ``b`` holds
    ``positions[b]``; under the sharded walk non-owned slots hold ``-1``
    (fully masked, counts gated).  Returns ``(o_part (B, splits, H, Dh)
    f32, m_part (B, splits, H) f32, l_part (B, splits, H) f32,
    slot_counts, counts)``.
    """
    B, H, Dh = q.shape
    P, L, pg, Kh, _ = k_pages.shape
    assert v_pages.shape == k_pages.shape, (k_pages.shape, v_pages.shape)
    assert H % Kh == 0, (H, Kh)
    group = H // Kh
    M = block_tables.shape[1]
    assert splits >= 1 and M % splits == 0, (
        f"splits={splits} must divide the block-table width M={M}"
    )
    ns = M // splits
    sm_scale = 1.0 / math.sqrt(Dh)

    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe import

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,  # detector consts, block tables, positions, layer
        grid=(B, splits, ns),
        in_specs=[
            pl.BlockSpec((1, H, Dh), lambda b, g, jj, c, bt, pos, lay: (b, 0, 0)),
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, g, jj, c, bt, pos, lay: (
                    bt[b, g * ns + jj], lay[0], 0, 0, 0
                ),
            ),
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, g, jj, c, bt, pos, lay: (
                    bt[b, g * ns + jj], lay[0], 0, 0, 0
                ),
            ),
        ],
        out_specs=[
            pl.BlockSpec(
                (1, 1, H, Dh), lambda b, g, jj, c, bt, pos, lay: (b, g, 0, 0)
            ),
            pl.BlockSpec((1, 1, H), lambda b, g, jj, c, bt, pos, lay: (b, g, 0)),
            pl.BlockSpec((1, 1, H), lambda b, g, jj, c, bt, pos, lay: (b, g, 0)),
            pl.BlockSpec(
                (1, 1), lambda b, g, jj, c, bt, pos, lay: (b, g * ns + jj)
            ),
            pl.BlockSpec((8,), lambda b, g, jj, c, bt, pos, lay: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, Dh), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(
            _paged_splitk_kernel,
            sm_scale=sm_scale,
            policy_k=policy_k,
            constant_k=constant_k,
            policy_v=policy_v,
            constant_v=constant_v,
            pg=pg,
            n_kv=Kh,
            group=group,
            ns=ns,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, splits, H, Dh), jnp.float32),
            jax.ShapeDtypeStruct((B, splits, H), jnp.float32),
            jax.ShapeDtypeStruct((B, splits, H), jnp.float32),
            jax.ShapeDtypeStruct((B, M), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ],
        interpret=interpret,
    )(
        consts,
        jnp.asarray(block_tables, jnp.int32),
        jnp.asarray(pos_slot, jnp.int32),
        jnp.asarray(layer, jnp.int32).reshape(1),
        q, k_pages, v_pages,
    )


@functools.partial(
    jax.jit,
    static_argnames=(
        "splits", "policy", "constant", "include_inf", "interpret",
        "detector_k", "detector_v",
        "policy_k", "constant_k", "policy_v", "constant_v",
    ),
)
def paged_attention_splitk_raw(
    q: jax.Array,              # (B, H, Dh)
    k_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    v_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    block_tables: jax.Array,   # (B, M) int32
    positions: jax.Array,      # (B,) int32, inclusive
    layer: jax.Array,          # int32 scalar — L row of the pool leaves
    *,
    splits: int,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR,
    detector_v=DEFAULT_DETECTOR,
    policy_k: Optional[str] = None,
    constant_k: Optional[float] = None,
    policy_v: Optional[str] = None,
    constant_v: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Split-K paged decode: flash-decoding for the block-table page walk.

    The M block-table slots are partitioned into ``splits`` contiguous
    groups, each walked by its own grid cell into an unnormalized partial
    ``(acc, m, l)``; a log-sum-exp merge reduce stage combines the partials
    (colossal-ai ``flash_decoding.py``'s mid_o/mid_o_lse staging).  Splits
    whose slice is pure null padding carry ``m = -inf`` and zero weight into
    the merge — see the null-tail guard in the kernel body.  Detection and
    per-page counts are identical to the serial kernel: every slot is
    visited exactly once, so ``slot_counts`` is bit-identical.  Returns
    ``(out (B, H, Dh), slot_counts (B, M) int32, counts int32[8])``.
    """
    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B = q.shape[0]
    M = block_tables.shape[1]
    consts = _detector_consts(detector_k, detector_v, k_pages.dtype, include_inf)
    pos_slot = jnp.broadcast_to(
        jnp.asarray(positions, jnp.int32)[:, None], (B, M)
    )
    o_part, m_part, l_part, slot_counts, counts = _splitk_partials(
        q, k_pages, v_pages, block_tables, pos_slot, layer,
        splits=splits, consts=consts,
        policy_k=policy_k, constant_k=constant_k,
        policy_v=policy_v, constant_v=constant_v,
        interpret=interpret,
    )
    out = _lse_merge(q.dtype, o_part, m_part, l_part)
    return out, slot_counts, counts


def paged_attention_splitk(
    q: jax.Array,              # (B, H, Dh)
    k_pages: jax.Array,        # (P, pg, Kh, Dh) or (P, L, pg, Kh, Dh)
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, M) int32
    positions: jax.Array,      # (B,) int32, inclusive
    *,
    splits: int,
    layer: int = 0,
    **kw,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Convenience entry mirroring ``paged_attention`` for the split-K
    variant: layer-free pools, page-axis ``page_counts``."""
    if k_pages.ndim == 4:
        k_pages = k_pages[:, None]
        v_pages = v_pages[:, None]
    out, slot_counts, counts = paged_attention_splitk_raw(
        q, k_pages, v_pages, block_tables, positions,
        jnp.asarray(layer, jnp.int32), splits=splits, **kw,
    )
    page_counts = jnp.zeros((k_pages.shape[0],), jnp.int32).at[
        jnp.asarray(block_tables, jnp.int32)
    ].add(slot_counts)
    return out, page_counts, counts


# --------------------------------------------------------------------------
# Device-local sharded walk: page ownership follows the pool's "page"→axis
# sharding rule, so decode/prefill/split-K reads never cross device
# boundaries (the scrub_sharded pattern, applied to the serving hot path).
# --------------------------------------------------------------------------
#
#   global block table (B, M)       device d owns pool rows [lo, lo + P/nd)
#   ┌──────────────────────┐
#   │ 5  2  9  null  ...   │ ──►  d0: slots with page ∈ [0, P/nd)   others
#   └──────────────────────┘       d1: slots with page ∈ [P/nd, …)  masked
#                                   ⋮   (bound/-qstart sentinel, gate off)
#   each device walks its OWN shard rows only → partials (acc, m, l)
#   all_gather(device-major) → LSE merge;  psum(slot_counts, counts)
#
# Every block-table slot is owned by exactly one device (the null page by
# the device holding the pool's last row), so the psum'd integer counts are
# bit-identical to the serial kernel's, and the merged output is
# bit-identical to `paged_*_shard_ref` — the same partition computed shard
# by shard on one device.


def _owned_remap(block_tables, lo, p_local):
    """Ownership mask + shard-local row remap for one device's page range.
    Non-owned slots are remapped to local row 0: their DMA and VMEM repair
    still run (harmless — scores fully masked, counts gated), which keeps
    the grid walk shape identical on every device."""
    owned = (block_tables >= lo) & (block_tables < lo + p_local)
    return owned, jnp.where(owned, block_tables - lo, 0)


def _device_major_merge(out_dtype, o, m, l, axis):
    """all_gather each device's partials and LSE-merge them device-major:
    device d's partial s lands at merge slot ``d * splits + s`` — the same
    order `paged_*_shard_ref` concatenates, so parity is bitwise."""
    B = o.shape[0]
    o_all = jnp.moveaxis(jax.lax.all_gather(o, axis), 0, 1)
    m_all = jnp.moveaxis(jax.lax.all_gather(m, axis), 0, 1)
    l_all = jnp.moveaxis(jax.lax.all_gather(l, axis), 0, 1)
    nd = o_all.shape[1]
    s = o_all.shape[2]
    o_all = o_all.reshape(B, nd * s, *o.shape[2:])
    m_all = m_all.reshape(B, nd * s, m.shape[-1])
    l_all = l_all.reshape(B, nd * s, l.shape[-1])
    return _lse_merge(out_dtype, o_all, m_all, l_all)


def paged_attention_sharded(
    q: jax.Array,              # (B, H, Dh)
    k_pages: jax.Array,        # (P, L, pg, Kh, Dh), page axis sharded
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, M) int32 — GLOBAL page ids
    positions: jax.Array,      # (B,) int32, inclusive
    layer: jax.Array,          # int32 scalar
    *,
    mesh,
    axis: str,
    splits: int = 1,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR,
    detector_v=DEFAULT_DETECTOR,
    policy_k: Optional[str] = None,
    constant_k: Optional[float] = None,
    policy_v: Optional[str] = None,
    constant_v: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Device-local paged decode over a page-axis-sharded pool.

    Each device walks the full (B, M) block table but attends only to the
    slots whose page lives in its shard (non-owned slots: position bound
    ``-1`` → fully masked, counts gated off, local row 0 DMA'd as a
    placeholder).  ``splits > 1`` composes split-K *within* each device's
    walk, yielding ``nd × splits`` partials.  Counts are psum'd (each slot
    counted exactly once, bit-identical to the serial kernel); the output
    is the device-major LSE merge (bit-identical to
    ``paged_attention_shard_ref``).  Returns the same triple as
    ``paged_attention_raw``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    P_pages = k_pages.shape[0]
    nd = mesh.shape[axis]
    assert P_pages % nd == 0, (
        f"page axis {P_pages} must divide the '{axis}' mesh axis ({nd})"
    )
    consts = _detector_consts(detector_k, detector_v, k_pages.dtype, include_inf)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos = jnp.asarray(positions, jnp.int32)
    lay = jnp.asarray(layer, jnp.int32)

    def local(qd, kl, vl, btd, posd, layd, cd):
        p_local = kl.shape[0]
        lo = jax.lax.axis_index(axis) * p_local
        owned, bt_local = _owned_remap(btd, lo, p_local)
        pos_slot = jnp.where(owned, posd[:, None], -1)
        o, m, l, slot, counts = _splitk_partials(
            qd, kl, vl, bt_local, pos_slot, layd,
            splits=splits, consts=cd,
            policy_k=policy_k, constant_k=constant_k,
            policy_v=policy_v, constant_v=constant_v,
            interpret=interpret,
        )
        out = _device_major_merge(qd.dtype, o, m, l, axis)
        return out, jax.lax.psum(slot, axis), jax.lax.psum(counts, axis)

    spec = PartitionSpec(axis)
    rep = PartitionSpec()
    return shard_map(
        local, mesh=mesh,
        in_specs=(rep, spec, spec, rep, rep, rep, rep),
        out_specs=(rep, rep, rep),
        check_rep=False,
    )(q, k_pages, v_pages, bt, pos, lay, consts)


def paged_attention_shard_ref(
    q, k_pages, v_pages, block_tables, positions, layer,
    *, n_shards: int, splits: int = 1,
    policy: str = "zero", constant: float = 0.0, include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR, detector_v=DEFAULT_DETECTOR,
    policy_k=None, constant_k=None, policy_v=None, constant_v=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-device oracle of ``paged_attention_sharded``: the identical
    ownership partition and device-major merge, computed shard by shard on
    one device.  The sharded entry must match this bit for bit — it is the
    parity target of the multidev lane (the *serial* kernel differs in
    accumulation grouping, so its float output is only allclose)."""
    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    P_pages = k_pages.shape[0]
    assert P_pages % n_shards == 0, (P_pages, n_shards)
    p_local = P_pages // n_shards
    consts = _detector_consts(detector_k, detector_v, k_pages.dtype, include_inf)
    bt = jnp.asarray(block_tables, jnp.int32)
    pos = jnp.asarray(positions, jnp.int32)
    lay = jnp.asarray(layer, jnp.int32)
    os_, ms_, ls_ = [], [], []
    slot_tot = None
    counts_tot = None
    for d in range(n_shards):
        lo = d * p_local
        owned, bt_local = _owned_remap(bt, lo, p_local)
        pos_slot = jnp.where(owned, pos[:, None], -1)
        o, m, l, slot, counts = _splitk_partials(
            q, k_pages[lo:lo + p_local], v_pages[lo:lo + p_local],
            bt_local, pos_slot, lay,
            splits=splits, consts=consts,
            policy_k=policy_k, constant_k=constant_k,
            policy_v=policy_v, constant_v=constant_v,
            interpret=interpret,
        )
        os_.append(o)
        ms_.append(m)
        ls_.append(l)
        slot_tot = slot if slot_tot is None else slot_tot + slot
        counts_tot = counts if counts_tot is None else counts_tot + counts
    out = _lse_merge(
        q.dtype,
        jnp.concatenate(os_, axis=1),
        jnp.concatenate(ms_, axis=1),
        jnp.concatenate(ls_, axis=1),
    )
    return out, slot_tot, counts_tot


def paged_prefill_sharded(
    q: jax.Array,              # (B, C, H, Dh)
    k_pages: jax.Array,        # (P, L, pg, Kh, Dh), page axis sharded
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, M) int32 — GLOBAL page ids
    q_start: jax.Array,        # (B,) int32
    layer: jax.Array,          # int32 scalar
    *,
    mesh,
    axis: str,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR,
    detector_v=DEFAULT_DETECTOR,
    policy_k: Optional[str] = None,
    constant_k: Optional[float] = None,
    policy_v: Optional[str] = None,
    constant_v: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Device-local chunked-q paged prefill over a page-axis-sharded pool.

    The sharded analogue of ``paged_prefill_raw``: non-owned block slots
    carry the ``NO_SLOT`` q_start sentinel (every causal comparison fails,
    counts gated), each device emits one unnormalized chunk partial, and
    the device-major LSE merge normalizes — bit-identical to
    ``paged_prefill_shard_ref``.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec

    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B, C, H, Dh = q.shape
    P_pages = k_pages.shape[0]
    nd = mesh.shape[axis]
    assert P_pages % nd == 0, (
        f"page axis {P_pages} must divide the '{axis}' mesh axis ({nd})"
    )
    consts = _detector_consts(detector_k, detector_v, k_pages.dtype, include_inf)
    bt = jnp.asarray(block_tables, jnp.int32)
    qs = jnp.asarray(q_start, jnp.int32)
    lay = jnp.asarray(layer, jnp.int32)

    def local(qd, kl, vl, btd, qsd, layd, cd):
        p_local = kl.shape[0]
        lo = jax.lax.axis_index(axis) * p_local
        owned, bt_local = _owned_remap(btd, lo, p_local)
        qs_slot = jnp.where(owned, qsd[:, None], NO_SLOT)
        acc, m, l, slot, counts = _prefill_partials(
            qd, kl, vl, bt_local, qs_slot, layd,
            consts=cd,
            policy_k=policy_k, constant_k=constant_k,
            policy_v=policy_v, constant_v=constant_v,
            interpret=interpret,
        )
        # one partial per device: rows are the (C, H) chunk rows
        merged = _device_major_merge(
            qd.dtype,
            acc.reshape(B, 1, C * H, Dh), m[:, None], l[:, None], axis,
        )
        out = merged.reshape(B, C, H, Dh)
        return out, jax.lax.psum(slot, axis), jax.lax.psum(counts, axis)

    spec = PartitionSpec(axis)
    rep = PartitionSpec()
    return shard_map(
        local, mesh=mesh,
        in_specs=(rep, spec, spec, rep, rep, rep, rep),
        out_specs=(rep, rep, rep),
        check_rep=False,
    )(q, k_pages, v_pages, bt, qs, lay, consts)


def paged_prefill_shard_ref(
    q, k_pages, v_pages, block_tables, q_start, layer,
    *, n_shards: int,
    policy: str = "zero", constant: float = 0.0, include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR, detector_v=DEFAULT_DETECTOR,
    policy_k=None, constant_k=None, policy_v=None, constant_v=None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Single-device oracle of ``paged_prefill_sharded`` (see
    ``paged_attention_shard_ref``)."""
    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B, C, H, Dh = q.shape
    P_pages = k_pages.shape[0]
    assert P_pages % n_shards == 0, (P_pages, n_shards)
    p_local = P_pages // n_shards
    consts = _detector_consts(detector_k, detector_v, k_pages.dtype, include_inf)
    bt = jnp.asarray(block_tables, jnp.int32)
    qs = jnp.asarray(q_start, jnp.int32)
    lay = jnp.asarray(layer, jnp.int32)
    os_, ms_, ls_ = [], [], []
    slot_tot = None
    counts_tot = None
    for d in range(n_shards):
        lo = d * p_local
        owned, bt_local = _owned_remap(bt, lo, p_local)
        qs_slot = jnp.where(owned, qs[:, None], NO_SLOT)
        acc, m, l, slot, counts = _prefill_partials(
            q, k_pages[lo:lo + p_local], v_pages[lo:lo + p_local],
            bt_local, qs_slot, lay,
            consts=consts,
            policy_k=policy_k, constant_k=constant_k,
            policy_v=policy_v, constant_v=constant_v,
            interpret=interpret,
        )
        os_.append(acc.reshape(B, 1, C * H, Dh))
        ms_.append(m[:, None])
        ls_.append(l[:, None])
        slot_tot = slot if slot_tot is None else slot_tot + slot
        counts_tot = counts if counts_tot is None else counts_tot + counts
    merged = _lse_merge(
        q.dtype,
        jnp.concatenate(os_, axis=1),
        jnp.concatenate(ms_, axis=1),
        jnp.concatenate(ls_, axis=1),
    )
    return merged.reshape(B, C, H, Dh), slot_tot, counts_tot
