"""Paged decode attention with fused on-read repair — the trap, in the read.

This is the serving engine's decode hot path run *straight off the pool*:
the kernel consumes the pool's page-major KV leaves plus per-request block
tables (the layout vLLM's PagedAttention popularized), so the engine never
gathers pages into a contiguous per-step view and never scatters one back.
The per-step full-KV copy — the #1 ROADMAP open item after PR 3 — is gone;
the page-axis sharding of the pool finally pays off end to end, and (per
EDEN) the approximate data stays in place instead of round-tripping.

Repair semantics are the truest realization of the paper's trap-on-read
design this repo has: each (page, layer) row is bit-pattern checked and
repaired in VMEM right after the HBM→VMEM DMA the attention performs
anyway — detection and repair fused into the read, zero extra HBM traffic —
and the kernel emits *per-page-slot fatal counts*, so the reactive repair
manager knows exactly which resident pages hold a fatal lane without any
separate detection scan over the pages the step touched.

Layout:

  q             (B, H, Dh)          one query token per decode slot
  k/v pages     (P, L, pg, Kh, Dh)  the pool leaves, page axis LEADING
                                    (``Model.paged_cache_defs``); ``layer``
                                    selects the L row via scalar prefetch
  block_tables  (B, M) int32        per-request page lists, null-padded
  positions     (B) int32           last valid context position (inclusive)

Grid (B, M): request-major, one physical page per inner step.  The page's
pool row is selected *by the block table* through the k/v BlockSpec index
maps — the block table is a scalar-prefetch operand, available before the
kernel body, which is exactly what PrefetchScalarGridSpec exists for.
Online-softmax state (acc, m, l) lives in scratch across the page axis.
Null-padded tail slots are masked by position (a request's real pages cover
positions ``0..pos``; padding covers positions beyond it), but their DMA
and detection still run: a NaN parked in the null page would otherwise
poison the context through ``0 * NaN`` in the value contraction — here it
is repaired in VMEM and *reported*, like any other page.

Outputs: (out (B, H, Dh), slot_counts (B, M) int32, counts int32[8]).
``slot_counts[b, j]`` is the fatal-lane count of the page visited by block
slot (b, j) — scatter-added over the block table this becomes the
``(n_pages,)`` per-page vector the serving repair manager consumes (pages
visited by several slots, i.e. the null page, accumulate per visit; the
manager only needs the >0 predicate).  ``counts`` is the shared AT_* event
layout of ``repair_attention`` so the unified stats routing is identical.
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

NEG_INF = -1e30

# counts layout (int32[8]) — identical to repair_attention's AT_* layout
NAN_K, INF_K, EV_K, NAN_V, INF_V, EV_V, EV_TOTAL = range(7)

# sentinel default for the detector kwargs: "the legacy NaN(+Inf) pattern
# via include_inf".  ``None`` is a *meaningful* value (detection disabled
# for that operand), so the default cannot be None.
DEFAULT_DETECTOR = "default"


def _paged_kernel(
    consts_ref,      # int32[2, 8]  detector constants: row 0 K, row 1 V
    bt_ref,          # int32[B, M]  block tables (also drives the index maps)
    pos_ref,         # int32[B]     last valid position per request
    layer_ref,       # int32[1]     which L row of the pool leaves
    q_ref, k_ref, v_ref,
    o_ref, slot_ref, counts_ref,
    acc_ref, m_ref, l_ref,
    *, sm_scale: float,
    policy_k: str, constant_k: float, policy_v: str, constant_v: float,
    pg: int, n_kv: int, group: int, nm: int, out_dtype,
):
    b, j = pl.program_id(0), pl.program_id(1)
    step = b * pl.num_programs(1) + j

    @pl.when(step == 0)
    def _init_counts():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(j == 0)
    def _init_state():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # ---- fused on-read repair of this page's K/V rows (the trap) ----
    # per-operand fill selection: each tile repairs with ITS operand's
    # rule fill (row 0 = K, row 1 = V), so a mixed-fill RuleSet compiles
    # into one kernel instead of forcing the gathered-decode fallback
    k_fixed, nan_k, inf_k = common.repair_tile(
        k_ref[0, 0], policy=policy_k, constant=constant_k,
        consts=consts_ref[0],
    )
    v_fixed, nan_v, inf_v = common.repair_tile(
        v_ref[0, 0], policy=policy_v, constant=constant_v,
        consts=consts_ref[1],
    )
    ev_k = ((nan_k + inf_k) > 0).astype(jnp.int32)
    ev_v = ((nan_v + inf_v) > 0).astype(jnp.int32)
    counts_ref[NAN_K] += nan_k
    counts_ref[INF_K] += inf_k
    counts_ref[EV_K] += ev_k
    counts_ref[NAN_V] += nan_v
    counts_ref[INF_V] += inf_v
    counts_ref[EV_V] += ev_v
    counts_ref[EV_TOTAL] += ((ev_k + ev_v) > 0).astype(jnp.int32)
    # the per-page detection the reactive repair manager consumes
    slot_ref[0, 0] = nan_k + inf_k + nan_v + inf_v

    # ---- online softmax over this page ----
    H = n_kv * group
    q = q_ref[0].astype(jnp.float32).reshape(n_kv, group, q_ref.shape[-1])
    kb = jnp.moveaxis(k_fixed.astype(jnp.float32), 1, 0)     # (Kh, pg, Dh)
    s = jax.lax.dot_general(
        q, kb, (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * sm_scale                                             # (Kh, G, pg)
    t = j * pg + jax.lax.broadcasted_iota(jnp.int32, (1, 1, pg), 2)
    s = jnp.where(t <= pos_ref[b], s, NEG_INF)
    s2 = s.reshape(H, pg)

    m_prev = m_ref[:, 0]                                     # (H,)
    m_new = jnp.maximum(m_prev, jnp.max(s2, axis=-1))
    p = jnp.exp(s2 - m_new[:, None])                         # (H, pg)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
    # softmax weights quantize to the cache dtype before the value
    # contraction — the gathered decode's `w.astype(cv.dtype)` and the
    # flash kernel's `p.astype(v_blk.dtype)`, kept here so the fused path
    # matches the gathered one (bit-exact for f32 pools; for bf16 the
    # online-softmax alpha-rescale happens after quantization, so parity
    # is approximate at the value level, token-level in practice)
    vb = jnp.moveaxis(v_fixed, 1, 0)                         # (Kh, pg, Dh)
    pv = jax.lax.dot_general(
        p.reshape(n_kv, group, pg).astype(v_fixed.dtype), vb,
        (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                        # (Kh, G, Dh)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + pv.reshape(acc_ref.shape)
    m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == nm - 1)
    def _flush():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "policy", "constant", "include_inf", "interpret",
        "detector_k", "detector_v",
        "policy_k", "constant_k", "policy_v", "constant_v",
    ),
)
def paged_attention_raw(
    q: jax.Array,              # (B, H, Dh)
    k_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    v_pages: jax.Array,        # (P, L, pg, Kh, Dh)
    block_tables: jax.Array,   # (B, M) int32
    positions: jax.Array,      # (B,) int32, inclusive
    layer: jax.Array,          # int32 scalar — L row of the pool leaves
    *,
    policy: str = "zero",
    constant: float = 0.0,
    include_inf: bool = True,
    interpret: Optional[bool] = None,
    detector_k=DEFAULT_DETECTOR,
    detector_v=DEFAULT_DETECTOR,
    policy_k: Optional[str] = None,
    constant_k: Optional[float] = None,
    policy_v: Optional[str] = None,
    constant_v: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One layer of paged decode attention with fused on-read repair.

    ``detector_k`` / ``detector_v`` pick the fatal-pattern set per operand:
    a ``core.rules.Detector``, the default sentinel (legacy NaN(+Inf) via
    ``include_inf``), or ``None`` — detection disabled for that operand
    entirely (a zeroed-flags constants row; the exact-region /
    non-reactive-rule case), which keeps the read bit-transparent.
    ``policy_k``/``constant_k`` and ``policy_v``/``constant_v`` pick the
    fill per operand the same way (``None`` inherits the shared
    ``policy``/``constant``) — a mixed-fill RuleSet compiles into ONE
    kernel, each tile repairing with its operand's own fill.  Returns
    ``(out (B, H, Dh), slot_counts (B, M) int32, counts int32[8])``.
    """
    if interpret is None:
        interpret = common.default_interpret()
    policy_k = policy if policy_k is None else policy_k
    constant_k = constant if constant_k is None else constant_k
    policy_v = policy if policy_v is None else policy_v
    constant_v = constant if constant_v is None else constant_v
    B, H, Dh = q.shape
    P, L, pg, Kh, _ = k_pages.shape
    assert v_pages.shape == k_pages.shape, (k_pages.shape, v_pages.shape)
    assert H % Kh == 0, (H, Kh)
    group = H // Kh
    M = block_tables.shape[1]
    sm_scale = 1.0 / math.sqrt(Dh)

    def operand_row(det):
        if det is None:
            # all detection flags off: the kernel loads, never repairs
            return jnp.zeros((8,), jnp.int32)
        if det == DEFAULT_DETECTOR:
            det = common.resolve_detector(None, include_inf)
        return common.detector_operand(det, k_pages.dtype)

    consts = jnp.stack([operand_row(detector_k), operand_row(detector_v)])

    from jax.experimental.pallas import tpu as pltpu  # local: CPU-safe import

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,    # detector consts, block tables, positions, layer
        grid=(B, M),
        in_specs=[
            pl.BlockSpec((1, H, Dh), lambda b, j, c, bt, pos, lay: (b, 0, 0)),
            # the block table IS the index map: page (b, j) of the request's
            # table selects the pool row — no gather ever materializes
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, j, c, bt, pos, lay: (bt[b, j], lay[0], 0, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, pg, Kh, Dh),
                lambda b, j, c, bt, pos, lay: (bt[b, j], lay[0], 0, 0, 0),
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, H, Dh), lambda b, j, c, bt, pos, lay: (b, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, j, c, bt, pos, lay: (b, j)),
            pl.BlockSpec((8,), lambda b, j, c, bt, pos, lay: (0,)),
        ],
        scratch_shapes=[
            pltpu.VMEM((H, Dh), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
            pltpu.VMEM((H, 128), jnp.float32),
        ],
    )
    out, slot_counts, counts = pl.pallas_call(
        functools.partial(
            _paged_kernel,
            sm_scale=sm_scale,
            policy_k=policy_k,
            constant_k=constant_k,
            policy_v=policy_v,
            constant_v=constant_v,
            pg=pg,
            n_kv=Kh,
            group=group,
            nm=M,
            out_dtype=q.dtype,
        ),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Dh), q.dtype),
            jax.ShapeDtypeStruct((B, M), jnp.int32),
            jax.ShapeDtypeStruct((8,), jnp.int32),
        ],
        interpret=interpret,
    )(
        consts,
        jnp.asarray(block_tables, jnp.int32),
        jnp.asarray(positions, jnp.int32),
        jnp.asarray(layer, jnp.int32).reshape(1),
        q, k_pages, v_pages,
    )
    return out, slot_counts, counts


def paged_attention(
    q: jax.Array,              # (B, H, Dh)
    k_pages: jax.Array,        # (P, pg, Kh, Dh) or (P, L, pg, Kh, Dh)
    v_pages: jax.Array,
    block_tables: jax.Array,   # (B, M) int32
    positions: jax.Array,      # (B,) int32, inclusive
    *,
    layer: int = 0,
    **kw,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Convenience entry: accepts layer-free ``(P, pg, Kh, Dh)`` pools (the
    single-layer tests/bench shape) and returns ``(out, page_counts,
    counts)`` with ``page_counts`` already scatter-added to the pool's page
    axis — the ``(n_pages,)`` per-page fatal vector."""
    if k_pages.ndim == 4:
        k_pages = k_pages[:, None]
        v_pages = v_pages[:, None]
    out, slot_counts, counts = paged_attention_raw(
        q, k_pages, v_pages, block_tables, positions,
        jnp.asarray(layer, jnp.int32), **kw,
    )
    page_counts = jnp.zeros((k_pages.shape[0],), jnp.int32).at[
        jnp.asarray(block_tables, jnp.int32)
    ].add(slot_counts)
    return out, page_counts, counts
