"""Roofline-term computation from the compiled dry-run artifacts.

Three terms, all in seconds-per-step, per chip (the HLO is already the
per-device SPMD partition):

  compute    = HLO_dot_FLOPs / peak_FLOP/s          (197 TF bf16, v5e)
  memory     = HLO_HBM_bytes / HBM_bw               (819 GB/s)
  collective = wire_bytes   / ICI_link_bw           (50 GB/s/link)

FLOPs/bytes come from launch/hlo.py (instruction-level accounting with
while-trip multipliers — see that module for why cost_analysis alone is not
usable).  MODEL_FLOPS is the analytic 6·N·D (dense) / 6·N_active·D (MoE)
useful-work number; the ratio MODEL_FLOPS / HLO_FLOPs flags remat/redundancy
waste (ratio < 1 means the compiled program does extra compute, e.g. remat;
ratio > 1 means the analytic model over-counts, e.g. causal-attention skips).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax

from ..configs.base import ArchConfig, ShapeCell
from ..nn import module as module_lib
from . import hlo
from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class RooflineReport:
    arch: str
    cell: str
    mesh: str
    n_devices: int
    # per-device, per-step:
    hlo_flops: float
    hlo_bytes: float
    wire_bytes: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops_total: float
    useful_ratio: float            # MODEL_FLOPS / (HLO_FLOPs × devices)
    bound_s: float                 # max of the three terms
    roofline_fraction: float       # compute_s / bound_s (how compute-bound)
    per_collective: Dict[str, float]
    memory_stats: Optional[Dict[str, float]] = None
    cost_analysis_flops: Optional[float] = None
    cost_analysis_bytes: Optional[float] = None

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        return (
            f"{self.arch:26s} {self.cell:12s} {self.mesh:9s} "
            f"comp={self.compute_s*1e3:9.3f}ms mem={self.memory_s*1e3:9.3f}ms "
            f"coll={self.collective_s*1e3:9.3f}ms -> {self.dominant:10s} "
            f"useful={self.useful_ratio:6.3f} frac={self.roofline_fraction:5.3f}"
        )


def terms_from_costs(costs: hlo.Costs) -> Dict[str, float]:
    return {
        "compute_s": costs.flops / PEAK_FLOPS_BF16,
        "memory_s": costs.hbm_bytes / HBM_BW,
        "collective_s": costs.collective_wire_bytes / ICI_BW,
    }


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS.
# ---------------------------------------------------------------------------


def matmul_param_count(model) -> float:
    """Parameters participating in matmuls, with MoE experts weighted by
    their activation fraction top_k/E.  The (tied) embedding counts once —
    the readout logits matmul is real compute; the lookup is not."""
    cfg: ArchConfig = model.cfg
    frac = (cfg.top_k / cfg.n_experts) if cfg.n_experts else 1.0

    total = 0.0

    def acc(path: str, d: module_lib.ParamDef):
        import numpy as np

        n = float(np.prod(d.shape))
        if "expert" in (d.axes or ()) or (
            cfg.n_experts and any(s == cfg.n_experts for s in d.shape)
        ):
            n *= frac
        nonlocal total
        total += n
        return None

    module_lib._traverse(model.defs(), acc)
    return total


def model_flops(model, cell: ShapeCell) -> float:
    """Analytic useful FLOPs for one step of ``cell`` (whole job, all chips)."""
    cfg: ArchConfig = model.cfg
    N = matmul_param_count(model)
    B, S = cell.global_batch, cell.seq_len
    H, Dh = cfg.n_heads, cfg.resolved_head_dim

    if cell.kind == "train":
        tokens = B * S
        attn = 6.0 * B * S * S * H * Dh * cfg.n_layers / 2  # causal half
        if cfg.family in ("hybrid",):
            attn *= (cfg.n_layers // cfg.mamba_per_attn) / cfg.n_layers
        if cfg.family in ("ssm",):
            attn = 0.0          # mLSTM chunked form ~ linear, folded into N
        return 6.0 * N * tokens + attn
    if cell.kind == "prefill":
        tokens = B * S
        attn = 2.0 * B * S * S * H * Dh * cfg.n_layers / 2
        if cfg.family in ("hybrid",):
            attn *= (cfg.n_layers // cfg.mamba_per_attn) / cfg.n_layers
        if cfg.family in ("ssm",):
            attn = 0.0
        return 2.0 * N * tokens + attn
    # decode: one token over a cache of depth S
    layers_attn = cfg.n_layers
    if cfg.family == "hybrid":
        layers_attn = cfg.n_layers // cfg.mamba_per_attn
    if cfg.family == "ssm":
        layers_attn = 0
    attn = 4.0 * B * S * H * Dh * layers_attn
    return 2.0 * N * B + attn


def build_report(
    *,
    arch: str,
    cell: ShapeCell,
    mesh_name: str,
    n_devices: int,
    costs: hlo.Costs,
    model,
    memory_stats=None,
    cost_analysis=None,
) -> RooflineReport:
    t = terms_from_costs(costs)
    dominant = max(t, key=t.get).replace("_s", "")
    mf = model_flops(model, cell)
    bound = max(t.values())
    return RooflineReport(
        arch=arch,
        cell=cell.name,
        mesh=mesh_name,
        n_devices=n_devices,
        hlo_flops=costs.flops,
        hlo_bytes=costs.hbm_bytes,
        wire_bytes=costs.collective_wire_bytes,
        compute_s=t["compute_s"],
        memory_s=t["memory_s"],
        collective_s=t["collective_s"],
        dominant=dominant,
        model_flops_total=mf,
        useful_ratio=mf / max(costs.flops * n_devices, 1.0),
        bound_s=bound,
        roofline_fraction=t["compute_s"] / bound if bound else 0.0,
        per_collective=dict(costs.per_collective),
        memory_stats=memory_stats,
        cost_analysis_flops=(cost_analysis or {}).get("flops"),
        cost_analysis_bytes=(cost_analysis or {}).get("bytes accessed"),
    )
