"""Static HLO analysis for the roofline terms.

Why not ``compiled.cost_analysis()`` alone: XLA's HLO cost analysis visits
every instruction **once** — a ``lax.scan`` body (all our models scan over
layers, and train steps scan over microbatches) is counted a single time, so
FLOPs/bytes are understated by the trip count (~88× for mistral-large), and
there is no collective accounting at all.  This module parses the
post-optimization HLO text and:

  * builds the computation call graph (while bodies, fusions, calls,
    conditionals) and assigns every computation an **execution multiplier**
    — while bodies get the trip count recovered from the loop condition's
    comparison constant (verified against the known scan lengths);
  * counts **dot FLOPs** (2·prod(result)·prod(contracted)) per computation,
    including inside fused computations;
  * counts **HBM bytes** as operand+output buffer sizes of memory-touching
    instructions (fusion boundaries = actual buffer reads/writes; fused
    temporaries are free, matching how XLA materializes buffers);
  * counts **collective wire bytes per device** with the standard ring
    models: all-gather out·(n−1)/n, all-reduce 2·size·(n−1)/n,
    reduce-scatter in·(n−1)/n, all-to-all size·(n−1)/n, permute size.

Everything is per-device (the HLO is the post-SPMD-partition module).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16, "token": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _shape_bytes_and_dims(type_str: str) -> Tuple[int, List[List[int]]]:
    """Total bytes and dim lists of a (possibly tuple) HLO type string."""
    total = 0
    dims_list = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dims_v = [int(d) for d in dims.split(",") if d] if dims else []
        n = int(np.prod(dims_v)) if dims_v else 1
        total += n * _DTYPE_BYTES[dtype]
        dims_list.append(dims_v)
    return total, dims_list


@dataclasses.dataclass
class Instruction:
    name: str
    result_type: str
    op: str
    operands: List[str]
    attrs: str
    raw: str

    @property
    def result_bytes(self) -> int:
        return _shape_bytes_and_dims(self.result_type)[0]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    by_name: Dict[str, Instruction]


_COMP_HEADER = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_ASSIGN = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
# the first `opcode(` token in the RHS: a lowercase word preceded by neither
# a word char nor a bracket (rules out layouts, types and /*index=N*/)
_OPCODE = re.compile(r"(?<![\w\)\]\}/])([a-z][\w\-]*)\(")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    """Parse HLO text into computations.  Returns (comps, entry_name)."""
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER.match(stripped)
            # an instruction line (`%x = ...`) must not open a computation;
            # "=" inside signatures is legal (/*index=N*/ comments, layouts)
            is_instr = re.match(r"(ROOT\s+)?%?[\w\.\-]+\s*=\s", stripped)
            if m and not is_instr and stripped.endswith("{"):
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _ASSIGN.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        mo = _OPCODE.search(rhs)
        if not mo:
            continue
        rtype, op, rest = rhs[: mo.start()], mo.group(1), rhs[mo.end():]
        # split the operand list (inside the first balanced parens) from attrs
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str, attrs = rest[: i - 1], rest[i:]
        operands = re.findall(r"%([\w\.\-]+)", operand_str)
        instr = Instruction(name, rtype.strip(), op, operands, attrs, line)
        cur.instructions.append(instr)
        cur.by_name[name] = instr
    return comps, entry


# ---------------------------------------------------------------------------
# Execution multipliers (while trip counts).
# ---------------------------------------------------------------------------

_CONST_INT = re.compile(r"constant\((\d+)\)")


def _trip_count(cond: Computation) -> int:
    """Heuristic: largest integer constant in the loop condition.  XLA loop
    conditions compare the induction variable against the trip count."""
    best = 1
    for ins in cond.instructions:
        for c in _CONST_INT.findall(ins.raw):
            best = max(best, int(c))
    return best


_CALLED = re.compile(r"(?:body|condition|to_apply|calls|branches)=\{?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)\}?")


def multipliers(comps: Dict[str, Computation], entry: str) -> Dict[str, float]:
    """comp name -> times executed per step (product of enclosing loops)."""
    mult: Dict[str, float] = {entry: 1.0}
    order = [entry]
    seen = {entry}
    while order:
        cname = order.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instructions:
            if ins.op == "while":
                body = _attr_comp(ins.attrs, "body")
                cond = _attr_comp(ins.attrs, "condition")
                trips = _trip_count(comps[cond]) if cond in comps else 1
                for sub, factor in ((body, trips), (cond, trips + 1)):
                    if sub and sub in comps:
                        mult[sub] = mult.get(sub, 0.0) + m * factor
                        if sub not in seen:
                            seen.add(sub)
                            order.append(sub)
            else:
                for key in ("calls", "to_apply", "branches"):
                    subnames = _attr_comps(ins.attrs, key)
                    for sub in subnames:
                        if sub in comps:
                            mult[sub] = mult.get(sub, 0.0) + m
                            if sub not in seen:
                                seen.add(sub)
                                order.append(sub)
    return mult


def _attr_comp(attrs: str, key: str) -> Optional[str]:
    m = re.search(rf"{key}=%?([\w\.\-]+)", attrs)
    return m.group(1) if m else None


def _attr_comps(attrs: str, key: str) -> List[str]:
    m = re.search(rf"{key}=\{{([^}}]*)\}}", attrs)
    if m:
        return re.findall(r"%?([\w\.\-]+)", m.group(1))
    one = _attr_comp(attrs, key)
    return [one] if one else []


# ---------------------------------------------------------------------------
# Per-instruction costs.
# ---------------------------------------------------------------------------


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    """2 · prod(result dims) · prod(lhs contracting dims)."""
    _, rdims = _shape_bytes_and_dims(ins.result_type)
    result_n = float(np.prod(rdims[0])) if rdims and rdims[0] else 1.0
    lhs = comp.by_name.get(ins.operands[0]) if ins.operands else None
    contract = 1.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.attrs)
    if lhs is not None and m:
        _, ldims = _shape_bytes_and_dims(lhs.result_type)
        if ldims and ldims[0]:
            for d in m.group(1).split(","):
                if d:
                    contract *= ldims[0][int(d)]
    return 2.0 * result_n * contract


def _group_size(attrs: str, default: int) -> int:
    # new format: replica_groups=[G,S]<=[...]
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", attrs)
    if m:
        return int(m.group(2))
    # old format: replica_groups={{0,1,2,3},{...}}
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return default


def _collective_wire_bytes(ins: Instruction, comp: Computation, n_dev: int) -> float:
    size = float(ins.result_bytes)
    n = max(_group_size(ins.attrs, n_dev), 1)
    frac = (n - 1) / n
    if ins.op.startswith("all-gather"):
        return size * frac                      # ring: out·(n−1)/n
    if ins.op.startswith("all-reduce"):
        return 2.0 * size * frac                # RS + AG
    if ins.op.startswith("reduce-scatter"):
        return size * (n - 1)                   # in = out·n; in·(n−1)/n
    if ins.op.startswith("all-to-all"):
        return size * frac
    if ins.op.startswith("collective-permute"):
        return size
    return 0.0


# Buffer-materializing ops only: raw elementwise / select / broadcast / iota
# / compare / convert are FUSED on TPU (kLoop fusions) — counting them as
# standalone HBM traffic would model the CPU backend's fusion decisions, not
# the target's.  Fusion boundaries, dots, layout ops and collectives are the
# real reads/writes.
_MEMORY_OPS = {
    "fusion", "dot", "convolution", "copy", "transpose",
    "gather", "scatter", "concatenate", "sort", "reduce",
    "dynamic-slice", "dynamic-update-slice", "slice",
} | set(_COLLECTIVES)

# ops whose operand-0 is a large aliased buffer touched only on a slice
_SLICE_OPS = {"dynamic-update-slice", "dynamic-slice", "gather", "scatter",
              "slice"}

_SKIP_OPERAND_OPS = {"constant", "parameter", "get-tuple-element", "tuple",
                     "iota", "broadcast"}


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_raw_bytes: float = 0.0
    per_collective: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_collectives: int = 0

    def add(self, other: "Costs", scale: float = 1.0):
        self.flops += other.flops * scale
        self.hbm_bytes += other.hbm_bytes * scale
        self.collective_wire_bytes += other.collective_wire_bytes * scale
        self.collective_raw_bytes += other.collective_raw_bytes * scale
        self.n_collectives += int(other.n_collectives * scale)
        for k, v in other.per_collective.items():
            self.per_collective[k] = self.per_collective.get(k, 0.0) + v * scale


def _instr_hbm_bytes(ins: Instruction, comp: Computation,
                     comps: Optional[Dict[str, Computation]] = None) -> float:
    """HBM bytes attributable to one memory-touching instruction.

    In-place accumulation patterns (dynamic-update-slice, directly or as the
    ROOT of a fused computation — XLA's loop-carried ys-stacking inside
    scans) touch only the updated slice, not the whole buffer: counting the
    full buffer inflated the xlstm train_4k memory term 300× (S=4096
    timestep scan × full stacked output per step).
    """
    if ins.op == "dynamic-update-slice":
        upd = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
        return 2.0 * (upd.result_bytes if upd else 0)
    if ins.op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * ins.result_bytes
    if ins.op == "fusion" and comps is not None:
        called = _attr_comp(ins.attrs, "calls")
        sub = comps.get(called) if called else None
        if sub is not None and sub.instructions:
            def _unwrap(r):
                # see through converts/bitcasts/copies around the root: the
                # CPU backend wraps loop-carried dus in bf16<->f32 converts
                # (it cannot execute mixed-precision dots) — a pure host
                # artifact that must not count as TPU HBM traffic
                while r is not None and r.op in ("convert", "bitcast", "copy"):
                    r = sub.by_name.get(r.operands[0]) if r.operands else None
                return r

            root = sub.instructions[-1]
            roots = [root]
            if root.op == "tuple":      # multi-output fusion (e.g. k&v dus)
                roots = [sub.by_name[o] for o in root.operands
                         if o in sub.by_name]
            roots = [_unwrap(r) for r in roots]
            if roots and all(
                r is not None and r.op == "dynamic-update-slice" for r in roots
            ):
                # in-place slice update(s): aliased full-size operands (and
                # their host-side convert copies) are free; the true traffic
                # is the update payloads, read+written
                small = [
                    comp.by_name[o].result_bytes for o in ins.operands
                    if o in comp.by_name
                    and comp.by_name[o].result_bytes < ins.result_bytes / 2
                ]
                return 2.0 * sum(small)
    total = float(ins.result_bytes)
    for o in ins.operands:
        src = comp.by_name.get(o)
        if src is not None and (
            src.op == "parameter" or src.op not in _SKIP_OPERAND_OPS
        ):
            total += src.result_bytes
    return total


def _comp_costs(comp: Computation, n_dev: int,
                comps: Optional[Dict[str, Computation]] = None) -> Costs:
    c = Costs()
    for ins in comp.instructions:
        if ins.op == "dot":
            c.flops += _dot_flops(ins, comp)
        if ins.op in _COLLECTIVES or any(
            ins.op.startswith(p) for p in _COLLECTIVES
        ):
            wire = _collective_wire_bytes(ins, comp, n_dev)
            c.collective_wire_bytes += wire
            c.collective_raw_bytes += ins.result_bytes
            base = next(p for p in _COLLECTIVES if ins.op.startswith(p))
            c.per_collective[base] = c.per_collective.get(base, 0.0) + wire
            c.n_collectives += 1
        if ins.op in _MEMORY_OPS:
            c.hbm_bytes += _instr_hbm_bytes(ins, comp, comps)
    return c


def analyze_hlo(text: str, n_devices_in_group: int) -> Costs:
    """Total per-device costs for one execution of the entry computation."""
    comps, entry = parse_module(text)
    if entry is None:
        raise ValueError("no ENTRY computation found in HLO text")
    mult = multipliers(comps, entry)
    per_comp = {
        name: _comp_costs(c, n_devices_in_group, comps)
        for name, c in comps.items()
    }
    # fused computations' bytes are already represented by the fusion op;
    # but dots inside fused computations need their flops counted.
    total = Costs()
    for name, m in mult.items():
        cc = per_comp.get(name)
        if cc is None:
            continue
        fused = name.startswith("fused_") or ".fused" in name
        contrib = Costs(
            flops=cc.flops,
            hbm_bytes=0.0 if fused else cc.hbm_bytes,
            collective_wire_bytes=cc.collective_wire_bytes,
            collective_raw_bytes=cc.collective_raw_bytes,
            per_collective=cc.per_collective,
            n_collectives=cc.n_collectives,
        )
        total.add(contrib, m)
    return total
