"""Launch layer: production mesh, train/serve step builders, multi-pod
dry-run.  ``dryrun.py`` is the only entry point that touches the
host-platform device-count flag; everything else sees real devices."""
