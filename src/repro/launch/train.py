"""Train-step builder: the paper's technique as a first-class training-loop
feature, plus microbatching, sharding, injection (simulation), and the
restartable training loop.

Step anatomy (memory mode, the paper-faithful default):

  1. **step-boundary scrub** of the approximate-region state (params +
     optimizer moments), installed by ``ApproxSpace.wrap_train_step``: the
     memory-repairing mechanism as a functional write-back — the scrubbed
     tree *is* the new resident state, donated buffers make it in-place
     under jit.  Cost: one detect+select pass over resident state, fully
     parallel, no HBM traffic beyond what the step reads anyway when fused
     (kernels/) — the jnp path used for lowering keeps it a separate
     fused-by-XLA region.
  2. forward/backward with per-use repair (`register` mode) or clean reads
     (`memory` mode — state was scrubbed at the boundary).
  3. AdamW update (f32 moments, exact-region step counter).

Injection (`ber > 0`) is the *simulation* of approximate memory and runs
OUTSIDE the production step, exactly as real bit flips would strike between
steps — `ApproxSpace.inject` is that simulation boundary, and it records the
ground-truth flip count into the unified stats.

Per-region repair semantics come from the config's ``RuleSet``
(README §RepairRule): the boundary scrub is a "boundary"-tagged pass, so an
``"opt/.*"`` rule can range-guard optimizer moments while a reactive-only
rule skips the per-step scrub entirely, and exact-island rules exclude their
leaves from injection and repair alike — all resolved by the same
``ApproxSpace`` the serving engine and checkpoint manager use.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import stats as stats_lib
from ..distributed import sharding as sh
from ..models.base import Model
from ..optim import AdamW, cosine_with_warmup
from ..runtime import ApproxSpace


# ---------------------------------------------------------------------------
# Train state.
# ---------------------------------------------------------------------------


def make_optimizer(
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total: int = 10000,
    weight_decay: float = 0.1,
) -> AdamW:
    return AdamW(
        lr=cosine_with_warmup(peak_lr, warmup, total),
        weight_decay=weight_decay,
    )


def init_train_state(
    model: Model, opt: AdamW, key: jax.Array,
    space: Optional[ApproxSpace] = None,
) -> Dict[str, Any]:
    """The canonical train state.  With ``space`` it additionally carries a
    ``"rule_counts"`` int32[n_rules, 3] block: the per-rule [nan, inf,
    events] ledger the in-jit boundary scrub accumulates (rule vectors
    cannot escape a trace — this threads them through the state instead;
    ``train_loop`` folds them into ``space.rule_stats()``)."""
    params = model.init(key)
    state = {
        "params": params,
        "opt": opt.init(params),
        "stats": stats_lib.zeros(),
    }
    if space is not None:
        state["rule_counts"] = jnp.zeros(
            (space.ruleset.n_rules, 3), jnp.int32
        )
    return state


def abstract_train_state(
    model: Model, opt: AdamW, space: Optional[ApproxSpace] = None
) -> Dict[str, Any]:
    params = model.abstract_params()
    state = {
        "params": params,
        "opt": opt.abstract_state(params),
        "stats": {
            k: jax.ShapeDtypeStruct((), jnp.int32) for k in stats_lib.zeros()
        },
    }
    if space is not None:
        state["rule_counts"] = jax.ShapeDtypeStruct(
            (space.ruleset.n_rules, 3), jnp.int32
        )
    return state


def train_state_logical_axes(
    model: Model, opt: AdamW, space: Optional[ApproxSpace] = None
) -> Dict[str, Any]:
    axes = model.logical_axes()
    state = {
        "params": axes,
        "opt": opt.state_logical_axes(axes),
        "stats": {k: None for k in stats_lib.zeros()},
    }
    if space is not None:
        state["rule_counts"] = None          # replicated, like the stats
    return state


def train_state_shardings(
    model: Model, opt: AdamW, mesh: Mesh, rules=None,
    space: Optional[ApproxSpace] = None,
) -> Dict[str, Any]:
    rules = rules or sh.rules_for_mesh(mesh)
    return sh.tree_shardings(
        abstract_train_state(model, opt, space),
        train_state_logical_axes(model, opt, space),
        mesh,
        rules,
    )


# ---------------------------------------------------------------------------
# The step.
# ---------------------------------------------------------------------------


def build_train_step(
    model: Model,
    opt: AdamW,
    *,
    n_micro: int = 1,
    space: Optional[ApproxSpace] = None,
) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    The step is the raw compute (forward/backward/update) wrapped by
    ``space.wrap_train_step`` — the boundary scrub (memory-repairing
    mechanism, write-back of params + optimizer state) is installed by the
    runtime, not hand-threaded here.
    """
    space = space or ApproxSpace(model.cfg.repair)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state, batch):
        params, opt_state, stats = state["params"], state["opt"], state["stats"]

        # forward/backward (microbatched)
        if n_micro == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(
                    n_micro, x.shape[0] // n_micro, *x.shape[1:]
                ),
                batch,
            )

            def acc(carry, mb_i):
                g_acc, l_acc = carry
                (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb_i
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g
                )
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (grads, loss_sum), _ = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {"loss": loss}

        # update (extra state entries — e.g. the per-rule boundary-scrub
        # ledger "rule_counts" — ride through untouched)
        new_params, new_opt, opt_metrics = opt.update(grads, opt_state, params)
        new_state = {
            **state, "params": new_params, "opt": new_opt, "stats": stats,
        }
        return new_state, {**metrics, **opt_metrics}

    return space.wrap_train_step(train_step)


def jit_train_step(
    model: Model,
    opt: AdamW,
    mesh: Mesh,
    *,
    n_micro: int = 1,
    rules=None,
    donate: bool = True,
    space: Optional[ApproxSpace] = None,
):
    """pjit'd train step with explicit in/out shardings for ``mesh``.

    The owning ``ApproxSpace`` (created here if not passed) is handed the
    mesh + rules: the boundary scrub inside the step runs sharded through
    the jit's state shardings, and the *host-side* mechanisms between steps
    (injection windows, checkpoint scrubs) compile against the same
    placements — one repair pipeline for both sides of the step boundary.
    """
    rules = rules or sh.rules_for_mesh(mesh)
    state_sh = train_state_shardings(model, opt, mesh, rules)
    space = space or ApproxSpace(model.cfg.repair)
    space.use_mesh(mesh, rules)
    step = build_train_step(model, opt, n_micro=n_micro, space=space)
    cell_inputs = model.input_specs  # noqa: F841  (for symmetry with serve)
    batch_sh = None  # resolved per-call below

    def batch_shardings(batch_tree):
        return sh.batch_specs_for_inputs(batch_tree, mesh, rules)

    def compile_for(batch_specs):
        return jax.jit(
            step,
            in_shardings=(state_sh, batch_shardings(batch_specs)),
            out_shardings=(state_sh, None),
            donate_argnums=(0,) if donate else (),
        )

    return compile_for, state_sh


# ---------------------------------------------------------------------------
# Simulation wrapper + loop (CPU-scale runs: examples, e2e tests).
# ---------------------------------------------------------------------------


def inject_state(state, key: jax.Array, ber: float,
                 space: Optional[ApproxSpace] = None):
    """One approximate-memory window of bit flips over the approx region of
    params + moments (simulation only — production repair path never calls
    this).  The ground-truth flip count lands in the state's stats stream
    (``flips`` in the Table-3 analogue) through the space's one injection
    entry point — the same stats-threading path the serving engine uses, so
    train and serve cannot drift.  The resident buffers are donated: the
    flipped tree *replaces* ``state``, exactly as physical flips would."""
    space = space or ApproxSpace(ber=ber)
    resident = {"params": state["params"], "opt": state["opt"]}
    resident, stats = space.inject(
        resident, key, ber, stats=state["stats"], donate=True
    )
    return {
        **state,
        "params": resident["params"],
        "opt": resident["opt"],
        "stats": stats,
    }


def train_loop(
    model: Model,
    opt: AdamW,
    data_fn: Callable[[int], Dict[str, jax.Array]],
    *,
    steps: int,
    key: jax.Array,
    ber: float = 0.0,
    state: Optional[Dict[str, Any]] = None,
    start_step: int = 0,
    checkpoint_manager=None,
    checkpoint_every: int = 0,
    log_every: int = 10,
    n_micro: int = 1,
    space: Optional[ApproxSpace] = None,
    mesh: Optional[Mesh] = None,
    rules=None,
) -> Tuple[Dict[str, Any], list]:
    """Restartable CPU-scale loop used by examples/ and e2e tests.

    One ``ApproxSpace`` owns the whole run: the boundary scrub inside the
    step, the injection window between steps (simulation), and the region
    cache shared by both.

    With ``mesh`` the loop goes multi-device: the state is device_put onto
    its ``train_state_shardings``, the space is handed the mesh (injection
    windows and host-side scrubs compile per-shard against those
    placements), and the step donates the sharded state.
    """
    space = space or ApproxSpace(model.cfg.repair, ber=ber if ber > 0 else None)
    if state is None:
        # the default state threads the per-rule boundary-scrub ledger
        # (int32[n_rules, 3]) through the jitted step; folded into
        # space.rule_stats() below
        state = init_train_state(model, opt, key, space=space)
    rc_space = space if "rule_counts" in state else None
    guard = None
    if space.config.autopilot is not None:
        from ..autopilot.guard import OnlineGuard  # deferred: launch has no
        guard = OnlineGuard(space, space.config.autopilot)  # autopilot dep
    if mesh is not None:
        rules = rules or sh.rules_for_mesh(mesh)
        space.use_mesh(mesh, rules)
        state = jax.device_put(
            state, train_state_shardings(model, opt, mesh, rules, space=rc_space)
        )
        step_fn = jax.jit(
            build_train_step(model, opt, n_micro=n_micro, space=space),
            donate_argnums=(0,),
        )
    else:
        step_fn = jax.jit(
            build_train_step(model, opt, n_micro=n_micro, space=space)
        )
    history = []
    for i in range(start_step, steps):
        if ber > 0.0:
            state = inject_state(
                state, jax.random.fold_in(key, 10_000 + i), ber, space
            )
        state, metrics = step_fn(state, data_fn(i))
        if guard is not None and (i + 1) % guard.cfg.window == 0:
            # the in-jit scrub ledger must land in space.rule_stats() before
            # the guard reads its window delta
            state = _fold_rule_counts(space, state)
            decisions = guard.observe()
            if decisions:
                # the step closes over the old rules' detectors/fills —
                # rebuild against the tightened RuleSet (labels and n_rules
                # are preserved by the guard, so the state's ledger block
                # stays shape-compatible)
                step_fn = jax.jit(
                    build_train_step(model, opt, n_micro=n_micro, space=space),
                    donate_argnums=(0,) if mesh is not None else (),
                )
                history.append({"step": i, "autopilot": decisions})
        if log_every and (i % log_every == 0 or i == steps - 1):
            history.append(
                {"step": i, **{k: float(v) for k, v in metrics.items()},
                 **stats_lib.as_dict(state["stats"])}
            )
        if checkpoint_manager and checkpoint_every and (i + 1) % checkpoint_every == 0:
            # fold-and-zero BEFORE the save: checkpoints carry a zeroed
            # block, so restoring one and resuming (same space or fresh)
            # can never re-fold deltas the ledger already has.  The rule
            # ledger is process-lifetime observability (like the space's
            # scrubbed_bytes), not durable state — the cumulative Table-3
            # stream stays in state["stats"] as before.
            state = _fold_rule_counts(space, state)
            checkpoint_manager.save(i + 1, state)
    if checkpoint_manager:
        checkpoint_manager.wait()
    # fold the tail since the last checkpoint (or the whole run) exactly
    # once; the returned state's block is zeroed for the same reason
    state = _fold_rule_counts(space, state)
    return state, history


def _fold_rule_counts(space: ApproxSpace, state: Dict[str, Any]) -> Dict[str, Any]:
    """Fold the state's in-jit per-rule boundary-scrub deltas into the
    space's ledger and zero the block (no-op for states without one)."""
    if "rule_counts" not in state:
        return state
    space.record_rule_counts(state["rule_counts"])
    return {**state, "rule_counts": jnp.zeros_like(state["rule_counts"])}
