import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Hillclimb microscope: lower one (arch × shape) cell and attribute the
roofline terms to specific HLO instructions (with while-trip multipliers).

    PYTHONPATH=src python -m repro.launch.inspect_cell --arch xlstm-1.3b \
        --shape train_4k [--top 12]
"""

import argparse

from .dryrun import lower_cell  # noqa: E402  (sets nothing global)
from . import hlo  # noqa: E402


def inspect(cfg, cell, *, multi_pod=False, n_micro=None, top=12, rules=None):
    import jax
    from ..configs import get_config
    from ..models import build_model
    from ..distributed import sharding as sh
    from .dryrun import rules_for_cell, N_MICRO, DEFAULT_N_MICRO
    from .mesh import make_production_mesh
    from .serve import build_serve_step, serve_shardings
    from .train import (
        abstract_train_state, build_train_step, make_optimizer,
        train_state_shardings,
    )
    import jax.numpy as jnp

    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or rules_for_cell(mesh, cfg, cell, n_micro)

    with mesh, sh.use_rules(mesh, rules):
        if cell.kind == "train":
            opt = make_optimizer()
            nm = n_micro or N_MICRO.get((cfg.name, cell.name), DEFAULT_N_MICRO)
            step = build_train_step(model, opt, n_micro=nm)
            state_sds = abstract_train_state(model, opt)
            state_sh = train_state_shardings(model, opt, mesh, rules)
            batch_sds = model.input_specs(cell)
            batch_sh = sh.batch_specs_for_inputs(batch_sds, mesh, rules)
            compiled = jax.jit(
                step, in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None), donate_argnums=(0,),
            ).lower(state_sds, batch_sds).compile()
        elif cell.kind == "prefill":
            params_sds = model.abstract_params()
            params_sh = sh.tree_shardings(
                params_sds, model.logical_axes(), mesh, rules)
            batch_sds = model.input_specs(cell)
            batch_sh = sh.batch_specs_for_inputs(batch_sds, mesh, rules)
            compiled = jax.jit(
                model.forward, in_shardings=(params_sh, batch_sh),
            ).lower(params_sds, batch_sds).compile()
        else:
            B, T = cell.global_batch, cell.seq_len
            params_sds = model.abstract_params()
            cache_sds = model.abstract_cache(B, T)
            params_sh, cache_sh = serve_shardings(model, mesh, B, T, rules)
            batch_sds = model.input_specs(cell)
            batch_sh = sh.batch_specs_for_inputs(batch_sds, mesh, rules)
            step = build_serve_step(model)
            compiled = jax.jit(
                step, in_shardings=(params_sh, cache_sh, batch_sh, None),
                out_shardings=(None, None, cache_sh), donate_argnums=(1,),
            ).lower(params_sds, cache_sds, batch_sds,
                    jax.ShapeDtypeStruct((), jnp.int32)).compile()
    return compiled


def report(compiled, n_dev, top=12):
    txt = compiled.as_text()
    comps, entry = hlo.parse_module(txt)
    mult = hlo.multipliers(comps, entry)

    mem_rows, coll_rows, flop_rows = [], [], []
    for name, comp in comps.items():
        m = mult.get(name, 0)
        if not m:
            continue
        fused = name.startswith("fused_") or ".fused" in name
        for ins in comp.instructions:
            if ins.op == "dot":
                flop_rows.append(
                    (hlo._dot_flops(ins, comp) * m, m, ins.result_type.strip()[:44], name[:38]))
            if any(ins.op.startswith(p) for p in hlo._COLLECTIVES):
                w = hlo._collective_wire_bytes(ins, comp, n_dev)
                coll_rows.append((w * m, m, ins.op, ins.result_type.strip()[:44], name[:38]))
            if fused or ins.op not in hlo._MEMORY_OPS:
                continue
            b = hlo._instr_hbm_bytes(ins, comp, comps)
            mem_rows.append((b * m, m, ins.op, ins.result_type.strip()[:44], name[:38]))

    costs = hlo.analyze_hlo(txt, n_dev)
    print(f"TOTALS/device: flops={costs.flops:.3e} hbm={costs.hbm_bytes:.3e} "
          f"wire={costs.collective_wire_bytes:.3e}")
    print(f"terms: comp={costs.flops/197e12:.2f}s mem={costs.hbm_bytes/819e9:.2f}s "
          f"coll={costs.collective_wire_bytes/50e9:.2f}s")
    for title, rows in (("MEMORY", mem_rows), ("COLLECTIVE", coll_rows),
                        ("FLOPS", flop_rows)):
        rows.sort(reverse=True)
        print(f"-- top {title} --")
        for r in rows[:top]:
            print("  " + " ".join(
                f"{x:.3e}" if isinstance(x, float) else str(x) for x in r))
    return costs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--top", type=int, default=12)
    args = ap.parse_args()

    from ..configs import SHAPES, get_config
    cfg = get_config(args.arch)
    cell = SHAPES[args.shape]
    compiled = inspect(cfg, cell, multi_pod=args.multi_pod, n_micro=args.n_micro)
    n_dev = 512 if args.multi_pod else 256
    report(compiled, n_dev, args.top)


if __name__ == "__main__":
    main()
