"""Production mesh construction (lazy — importing this module never touches
jax device state; the dry-run sets the host-device-count flag before any
jax import, see dryrun.py).

Topology model: TPU v5e pods of 256 chips in a 16×16 2D torus.  Single-pod
mesh (data=16, model=16); multi-pod adds a leading "pod" axis (pure DP
across pods — the slowest links carry only gradient all-reduces).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: Optional[int] = None, model: int = 1) -> Mesh:
    """Mesh over whatever devices exist (tests / examples on CPU)."""
    n = jax.device_count()
    data = data if data is not None else n // model
    return jax.make_mesh((data, model), ("data", "model"))


# Hardware constants (TPU v5e) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (conservative: 1 link/hop)
