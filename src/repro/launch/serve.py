"""Serve-step builder: batched decode with protected KV/recurrent state.

The decode cells lower exactly this: one new token against a seq_len-deep
cache.  The cache is the approximate-memory resident; reads inside the model
go through the repair machinery (register mode), and ``scrub_cache`` is the
memory-repairing mechanism for serving (invoked reactively from the stats
counters, or at a configurable interval — both cheaper than the per-step
cost of leaving a NaN resident, which re-fires repairs every token, Table 3).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import stats as stats_lib
from ..distributed import sharding as sh
from ..models.base import Model
from ..runtime import ApproxSpace, ScrubSchedule


def build_serve_step(model: Model, *, greedy: bool = True) -> Callable:
    """serve_step(params, cache, batch, pos) -> (next_token, logits, cache).

    Dispatches on the (trace-time static) token width: multi-token inputs
    take the batched prefill path (``model.prefill`` — the whole prompt in
    one pass), single tokens the decode step.  One builder serves both
    ``generate`` and the serving engine, so the greedy step cannot drift
    between them.
    """

    def serve_step(params, cache, batch, pos):
        multi = batch["tokens"].shape[1] > 1
        fn = model.prefill if multi else model.serve_step
        logits, new_cache = fn(params, cache, batch, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, new_cache

    return serve_step


def scrub_cache(model: Model, cache, stats=None, space: Optional[ApproxSpace] = None):
    """Memory-repairing mechanism over the decode cache (one-shot).

    Deprecated shim: delegates to a memory-forced ``ApproxSpace.scrub``.
    """
    stats = stats if stats is not None else stats_lib.zeros()
    space = space or serve_space(model)
    return space.scrub(cache, stats)


# One serving space per (model config, cadence): the space's treedef-cached
# region trees survive across calls, so repeated scrub_cache / generate runs
# never rerun `annotate` (rebuilding a fresh space per call discarded them).
_SPACE_CACHE: Dict[Any, ApproxSpace] = {}


def serve_space(
    model: Model, scrub_every: int = 0, *, memoize: bool = True
) -> ApproxSpace:
    """The serving runtime for ``model``: its repair config, memory-forced
    scrubbing (a poisoned cache must be repairable even in register-mode
    runs), and the periodic-scrub cadence.  Memoized per (model config,
    cadence) — callers share one long-lived runtime whose region cache and
    unified stats stream persist across calls.  ``memoize=False`` returns a
    private space (the serving engine isolates stats per engine).

    A model config carrying an explicit ``RuleSet`` keeps it: per-path
    rules already say how cache leaves are protected, so the scalar
    ``max_magnitude=None`` override below only applies to single-knob
    configs (README §RepairRule)."""
    key = (model.cfg, scrub_every) if memoize else None
    try:
        space = _SPACE_CACHE.get(key) if key is not None else None
    except TypeError:           # unhashable custom config — skip memoization
        key = None
        space = None
    if space is None:
        space = ApproxSpace(
            model.cfg.repair,
            mode="memory",
            # NaN/Inf-only for cache scrubs: activations/KV lanes are not
            # O(1) like weights, so the training-side magnitude clamp does
            # not apply.
            max_magnitude=None,
            scrub=ScrubSchedule(boundary=False, interval=scrub_every),
        )
        if key is not None:
            _SPACE_CACHE[key] = space
    return space


def serve_shardings(
    model: Model,
    mesh: Mesh,
    batch: int,
    max_seq: int,
    rules=None,
):
    """(params_sharding, cache_sharding) for the decode cells."""
    rules = rules or sh.rules_for_mesh(mesh)
    params_sh = sh.tree_shardings(
        model.abstract_params(), model.logical_axes(), mesh, rules
    )
    cache_sh = sh.tree_shardings(
        model.abstract_cache(batch, max_seq),
        model.cache_logical_axes(batch, max_seq),
        mesh,
        rules,
    )
    return params_sh, cache_sh


def jit_serve_step(
    model: Model,
    mesh: Mesh,
    batch: int,
    max_seq: int,
    *,
    rules=None,
    donate_cache: bool = True,
):
    rules = rules or sh.rules_for_mesh(mesh)
    params_sh, cache_sh = serve_shardings(model, mesh, batch, max_seq, rules)
    token_sh = sh.batch_specs_for_inputs(
        model.input_specs_decode_placeholder(batch)
        if hasattr(model, "input_specs_decode_placeholder")
        else {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)},
        mesh,
        rules,
    )
    step = build_serve_step(model)
    return jax.jit(
        step,
        in_shardings=(params_sh, cache_sh, token_sh, None),
        out_shardings=(None, None, cache_sh),
        donate_argnums=(1,) if donate_cache else (),
    ), (params_sh, cache_sh, token_sh)


def generate(
    model: Model,
    params,
    prompt: jax.Array,          # (B, S0) i32
    *,
    max_new: int,
    max_seq: int,
    scrub_every: int = 0,
    space: Optional[ApproxSpace] = None,
    paged: bool = False,
    page_size: int = 16,
) -> Tuple[jax.Array, Dict[str, int]]:
    """CPU-scale greedy generation loop (examples/tests).

    Prefill is one batched ``model.prefill`` call — the whole prompt in a
    single pass that populates the cache — for architectures whose decode
    path is length-generic; recurrent decode cells (xLSTM/SSM) fall back to
    the token-by-token warmup.  One ``ApproxSpace`` owns the run: its scrub
    schedule drives the periodic cache scrub and its unified stats stream is
    returned.  Pass ``space`` to accumulate this run's events into a
    longer-lived runtime (the default space is memoized per model config).

    ``paged=True`` rebases the run onto the serving engine as its
    single-request-per-row degenerate case: each prompt row becomes one
    engine request over a paged KV pool (README §Serving engine).  Requires
    a paged KV layout (``model.supports_paged_kv``) and uniform greedy
    decoding, which this loop already assumes.
    """
    B, S0 = prompt.shape
    if max_new <= 0:
        return prompt, stats_lib.as_dict(stats_lib.zeros())
    if paged:
        return _generate_paged(
            model, params, prompt, max_new=max_new, max_seq=max_seq,
            page_size=page_size, scrub_every=scrub_every, space=space,
        )
    space = space or serve_space(model, scrub_every)
    cache = model.init_cache(B, max_seq)
    step_fn = jax.jit(space.wrap_serve_step(build_serve_step(model)))
    stats = stats_lib.zeros()

    tokens = prompt
    if model.supports_batched_prefill:
        # batched prefill: one pass over the whole prompt, cache populated
        if space.config.scrub.due(0):
            cache, stats = space.scrub(cache, stats, trigger="interval")
        nxt_flat, _, cache, stats = step_fn(
            params, cache, {"tokens": prompt}, jnp.zeros((), jnp.int32), stats
        )
        nxt = nxt_flat[:, None]
        tokens = jnp.concatenate([tokens, nxt], axis=1)
        t0 = S0
    else:
        t0 = 0
        nxt = prompt[:, :1]
    for t in range(t0, S0 + max_new - 1):
        tok = tokens[:, t : t + 1] if t < S0 else nxt
        if space.config.scrub.due(t):
            cache, stats = space.scrub(cache, stats, trigger="interval")
        nxt_flat, _, cache, stats = step_fn(
            params, cache, {"tokens": tok}, jnp.asarray(t, jnp.int32), stats
        )
        nxt = nxt_flat[:, None]
        if t >= S0 - 1:
            tokens = jnp.concatenate([tokens, nxt], axis=1)
    space.record(stats)
    return tokens, stats_lib.as_dict(stats)


def _generate_paged(
    model: Model,
    params,
    prompt: jax.Array,
    *,
    max_new: int,
    max_seq: int,
    page_size: int,
    scrub_every: int = 0,
    space: Optional[ApproxSpace] = None,
) -> Tuple[jax.Array, Dict[str, int]]:
    """``generate`` rebased onto the serving engine (one request per prompt
    row, pool sized so nothing ever waits — the degenerate case).

    ``scrub_every`` becomes the engine's background sweep cadence with a
    whole-pool sweep window — the same "additionally scrub every k steps"
    semantics as the contiguous loop.  A caller-provided ``space`` receives
    the run's unified stats, keeping the longer-lived-runtime contract.
    """
    from ..serving import Engine, ServingConfig  # deferred: serving imports us

    B, S0 = prompt.shape
    page_size = min(page_size, max_seq)
    while max_seq % page_size:
        page_size -= 1
    pages_per_req = max_seq // page_size
    n_pages = B * pages_per_req
    eng = Engine(
        model,
        params,
        ServingConfig(
            page_size=page_size,
            n_pages=n_pages,
            max_batch=B,
            max_pages_per_request=pages_per_req,
            sweep_interval=scrub_every,
            sweep_pages=n_pages,
        ),
    )
    rids = [eng.add_request(prompt[b], max_new=max_new) for b in range(B)]
    results = eng.run()
    if space is not None:
        space.record(eng.unified_stats())
    out = jnp.asarray(
        [results[rid]["tokens"] for rid in rids], jnp.int32
    )
    return out, eng.stats_dict()
