"""Serve-step builder: batched decode with protected KV/recurrent state.

The decode cells lower exactly this: one new token against a seq_len-deep
cache.  The cache is the approximate-memory resident; reads inside the model
go through the repair machinery (register mode), and ``scrub_cache`` is the
memory-repairing mechanism for serving (invoked reactively from the stats
counters, or at a configurable interval — both cheaper than the per-step
cost of leaving a NaN resident, which re-fires repairs every token, Table 3).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import stats as stats_lib
from ..distributed import sharding as sh
from ..models.base import Model
from ..runtime import ApproxSpace, ScrubSchedule


def build_serve_step(model: Model, *, greedy: bool = True) -> Callable:
    """serve_step(params, cache, batch, pos) -> (next_token, logits, cache)."""

    def serve_step(params, cache, batch, pos):
        logits, new_cache = model.serve_step(params, cache, batch, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, new_cache

    return serve_step


def scrub_cache(model: Model, cache, stats=None, space: Optional[ApproxSpace] = None):
    """Memory-repairing mechanism over the decode cache (one-shot).

    Deprecated shim: delegates to a memory-forced ``ApproxSpace.scrub``.
    """
    stats = stats if stats is not None else stats_lib.zeros()
    space = space or serve_space(model)
    return space.scrub(cache, stats)


def serve_space(model: Model, scrub_every: int = 0) -> ApproxSpace:
    """The serving runtime for ``model``: its repair config, memory-forced
    scrubbing (a poisoned cache must be repairable even in register-mode
    runs), and the periodic-scrub cadence."""
    return ApproxSpace(
        model.cfg.repair,
        mode="memory",
        # NaN/Inf-only for cache scrubs: activations/KV lanes are not O(1)
        # like weights, so the training-side magnitude clamp does not apply.
        max_magnitude=None,
        scrub=ScrubSchedule(boundary=False, interval=scrub_every),
    )


def serve_shardings(
    model: Model,
    mesh: Mesh,
    batch: int,
    max_seq: int,
    rules=None,
):
    """(params_sharding, cache_sharding) for the decode cells."""
    rules = rules or sh.rules_for_mesh(mesh)
    params_sh = sh.tree_shardings(
        model.abstract_params(), model.logical_axes(), mesh, rules
    )
    cache_sh = sh.tree_shardings(
        model.abstract_cache(batch, max_seq),
        model.cache_logical_axes(batch, max_seq),
        mesh,
        rules,
    )
    return params_sh, cache_sh


def jit_serve_step(
    model: Model,
    mesh: Mesh,
    batch: int,
    max_seq: int,
    *,
    rules=None,
    donate_cache: bool = True,
):
    rules = rules or sh.rules_for_mesh(mesh)
    params_sh, cache_sh = serve_shardings(model, mesh, batch, max_seq, rules)
    token_sh = sh.batch_specs_for_inputs(
        model.input_specs_decode_placeholder(batch)
        if hasattr(model, "input_specs_decode_placeholder")
        else {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)},
        mesh,
        rules,
    )
    step = build_serve_step(model)
    return jax.jit(
        step,
        in_shardings=(params_sh, cache_sh, token_sh, None),
        out_shardings=(None, None, cache_sh),
        donate_argnums=(1,) if donate_cache else (),
    ), (params_sh, cache_sh, token_sh)


def generate(
    model: Model,
    params,
    prompt: jax.Array,          # (B, S0) i32
    *,
    max_new: int,
    max_seq: int,
    scrub_every: int = 0,
    space: Optional[ApproxSpace] = None,
) -> Tuple[jax.Array, Dict[str, int]]:
    """CPU-scale greedy generation loop (examples/tests).

    Prefill is run token-by-token through serve_step (simple and exercises
    the cache path); production prefill uses model.forward + cache build.
    One ``ApproxSpace`` owns the run: its scrub schedule drives the periodic
    cache scrub and its unified stats stream is returned.  Pass ``space`` to
    accumulate this run's events into a longer-lived runtime (the default
    space dies with the call).
    """
    B, S0 = prompt.shape
    space = space or serve_space(model, scrub_every)
    cache = model.init_cache(B, max_seq)
    step_fn = jax.jit(space.wrap_serve_step(build_serve_step(model)))
    stats = stats_lib.zeros()

    tokens = prompt
    nxt = prompt[:, :1]
    for t in range(S0 + max_new - 1):
        tok = tokens[:, t : t + 1] if t < S0 else nxt
        if space.config.scrub.due(t):
            cache, stats = space.scrub(cache, stats)
        nxt_flat, _, cache, stats = step_fn(
            params, cache, {"tokens": tok}, jnp.asarray(t, jnp.int32), stats
        )
        nxt = nxt_flat[:, None]
        if t >= S0 - 1:
            tokens = jnp.concatenate([tokens, nxt], axis=1)
    space.record(stats)
    return tokens, stats_lib.as_dict(stats)
