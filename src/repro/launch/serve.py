"""Serve-step builder: batched decode with protected KV/recurrent state.

The decode cells lower exactly this: one new token against a seq_len-deep
cache.  The cache is the approximate-memory resident; reads inside the model
go through the repair machinery (register mode), and ``scrub_cache`` is the
memory-repairing mechanism for serving (invoked reactively from the stats
counters, or at a configurable interval — both cheaper than the per-step
cost of leaving a NaN resident, which re-fires repairs every token, Table 3).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from ..core import repair as repair_lib
from ..core import stats as stats_lib
from ..core.regions import annotate
from ..distributed import sharding as sh
from ..models.base import Model


def build_serve_step(model: Model, *, greedy: bool = True) -> Callable:
    """serve_step(params, cache, batch, pos) -> (next_token, logits, cache)."""

    def serve_step(params, cache, batch, pos):
        logits, new_cache = model.serve_step(params, cache, batch, pos)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt, logits, new_cache

    return serve_step


def scrub_cache(model: Model, cache, stats=None):
    """Memory-repairing mechanism over the decode cache (one-shot)."""
    stats = stats if stats is not None else stats_lib.zeros()
    rcfg = model.cfg.repair
    cfg = repair_lib.RepairConfig(
        mode="memory", policy=rcfg.policy, include_inf=rcfg.include_inf
    )
    return repair_lib.scrub_pytree(cache, cfg, stats, annotate(cache))


def serve_shardings(
    model: Model,
    mesh: Mesh,
    batch: int,
    max_seq: int,
    rules=None,
):
    """(params_sharding, cache_sharding) for the decode cells."""
    rules = rules or sh.rules_for_mesh(mesh)
    params_sh = sh.tree_shardings(
        model.abstract_params(), model.logical_axes(), mesh, rules
    )
    cache_sh = sh.tree_shardings(
        model.abstract_cache(batch, max_seq),
        model.cache_logical_axes(batch, max_seq),
        mesh,
        rules,
    )
    return params_sh, cache_sh


def jit_serve_step(
    model: Model,
    mesh: Mesh,
    batch: int,
    max_seq: int,
    *,
    rules=None,
    donate_cache: bool = True,
):
    rules = rules or sh.rules_for_mesh(mesh)
    params_sh, cache_sh = serve_shardings(model, mesh, batch, max_seq, rules)
    token_sh = sh.batch_specs_for_inputs(
        model.input_specs_decode_placeholder(batch)
        if hasattr(model, "input_specs_decode_placeholder")
        else {"tokens": jax.ShapeDtypeStruct((batch, 1), jnp.int32)},
        mesh,
        rules,
    )
    step = build_serve_step(model)
    return jax.jit(
        step,
        in_shardings=(params_sh, cache_sh, token_sh, None),
        out_shardings=(None, None, cache_sh),
        donate_argnums=(1,) if donate_cache else (),
    ), (params_sh, cache_sh, token_sh)


def generate(
    model: Model,
    params,
    prompt: jax.Array,          # (B, S0) i32
    *,
    max_new: int,
    max_seq: int,
    scrub_every: int = 0,
) -> Tuple[jax.Array, Dict[str, int]]:
    """CPU-scale greedy generation loop (examples/tests).

    Prefill is run token-by-token through serve_step (simple and exercises
    the cache path); production prefill uses model.forward + cache build.
    """
    B, S0 = prompt.shape
    cache = model.init_cache(B, max_seq)
    step_fn = jax.jit(build_serve_step(model))
    stats = stats_lib.zeros()

    tokens = prompt
    nxt = prompt[:, :1]
    for t in range(S0 + max_new - 1):
        tok = tokens[:, t : t + 1] if t < S0 else nxt
        if scrub_every and t % scrub_every == 0:
            cache, stats = scrub_cache(model, cache, stats)
        nxt_flat, _, cache = step_fn(
            params, cache, {"tokens": tok}, jnp.asarray(t, jnp.int32)
        )
        nxt = nxt_flat[:, None]
        if t >= S0 - 1:
            tokens = jnp.concatenate([tokens, nxt], axis=1)
    return tokens, stats_lib.as_dict(stats)
