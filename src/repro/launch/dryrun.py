import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell on the host-emulated production mesh and extract the roofline terms.

The two lines above MUST stay the first statements of this module (before
any jax-importing import): jax locks the device count at first backend init.
Nothing else in the repo sets this flag — smoke tests and benchmarks see the
single real CPU device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch starcoder2-15b \
        --shape train_4k                       # one cell, single-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes \
        --out benchmarks/results              # the full 32×2 sweep

Per cell this prints compiled.memory_analysis() (proof it fits HBM) and
writes a JSON record with cost_analysis + the instruction-level roofline
terms (launch/hlo.py) for EXPERIMENTS.md §Dry-run/§Roofline.
"""
# (no `from __future__ import annotations` here: the XLA_FLAGS lines must be
# the first statements of the module, which Python forbids before a
# __future__ import)

import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import REGISTRY, SHAPES, cells_for, get_config
from ..configs.base import ArchConfig, ShapeCell
from ..distributed import sharding as sh
from ..models import build_model
from . import hlo, roofline
from .mesh import make_production_mesh
from .serve import build_serve_step, serve_shardings
from .train import (
    abstract_train_state,
    build_train_step,
    make_optimizer,
    train_state_shardings,
)

# Per-cell gradient-accumulation depth: keeps activation bytes/device inside
# v5e HBM for the big configs (microbatch global = batch / n_micro).
N_MICRO = {
    ("mistral-large-123b", "train_4k"): 16,
    ("starcoder2-15b", "train_4k"): 8,
    ("phi3.5-moe-42b-a6.6b", "train_4k"): 8,
    ("qwen3-moe-30b-a3b", "train_4k"): 8,
    ("zamba2-7b", "train_4k"): 8,
    ("llava-next-mistral-7b", "train_4k"): 8,
    ("seamless-m4t-large-v2", "train_4k"): 8,
}
DEFAULT_N_MICRO = 4


def rules_for_cell(mesh, cfg: ArchConfig, cell: ShapeCell,
                   n_micro: Optional[int] = None):
    """Sharding-rule overrides per cell kind (README §Sharding)."""
    overrides: Dict[str, object] = {}
    if cell.kind == "decode":
        # The KV cache dominates decode.  Shard its sequence dim over every
        # mesh axis the other cache dims can't use: the data axis when the
        # batch doesn't cover it (long-context B=1), the model axis when
        # n_kv is too small for it.
        if cell.global_batch % mesh.shape["data"] != 0:
            overrides["kv_seq"] = "data"
            if cfg.n_kv < mesh.shape["model"]:
                overrides["kv_seq"] = ("data", "model")
        elif cfg.n_kv < mesh.shape["model"]:
            overrides["kv_seq"] = "model"
    if cell.kind in ("decode", "prefill"):
        # FSDP weight-gathers are pure loss for serving (each weight is read
        # once per token; there is no optimizer state to shard) — keep
        # params TP-sharded-only whenever they fit HBM that way (§Perf
        # iteration: starcoder2 decode spent 70% of its wire on per-layer
        # weight all-gathers).  mistral-large (15.4 GB/chip TP-only) keeps
        # FSDP.
        from ..models import build_model
        if build_model(cfg).param_count() * 2 / mesh.shape["model"] < 8e9:
            overrides["embed"] = None
    if cell.kind == "train":
        # Sequence parallelism for the residual stream when the layer-scan
        # carry (L × S × B_local × D, saved for backward) would blow HBM.
        nm = n_micro or N_MICRO.get((cfg.name, cell.name), DEFAULT_N_MICRO)
        b_local = max(cell.global_batch // nm // mesh.shape["data"], 1)
        carry = 2.0 * cfg.n_layers * cell.seq_len * b_local * cfg.d_model
        if carry > 4e9 and cell.seq_len % mesh.shape["model"] == 0:
            overrides["act_seq"] = "model"
        # FSDP is a *memory* trick with a collective cost (per-layer weight
        # all-gathers, fwd+bwd+remat).  Below ~5 B params the TP-sharded
        # state fits one chip's HBM comfortably and pure DP over the data
        # axis is strictly cheaper (§Perf iteration 2: dropping FSDP on
        # xlstm-1.3b removed the full-batch activation all-gathers XLA chose
        # to avoid touching the data-sharded weights).
        from ..models import build_model
        if build_model(cfg).param_count() < 5e9:
            overrides["embed"] = None
    return sh.rules_for_mesh(mesh, overrides)


@dataclasses.dataclass
class CellResult:
    arch: str
    cell: str
    mesh: str
    ok: bool
    seconds: float
    error: Optional[str] = None
    report: Optional[dict] = None
    memory_stats: Optional[dict] = None


def _memory_stats(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        return {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


def lower_cell(
    cfg: ArchConfig,
    cell: ShapeCell,
    *,
    multi_pod: bool,
    n_micro: Optional[int] = None,
    rules=None,
    verbose: bool = True,
    skip_analysis: bool = False,
):
    """Lower + compile one (arch × shape × mesh) cell; return CellResult."""
    mesh_name = "2x16x16" if multi_pod else "16x16"
    t0 = time.time()
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules or rules_for_cell(mesh, cfg, cell, n_micro)

    with mesh, sh.use_rules(mesh, rules):
        if cell.kind == "train":
            opt = make_optimizer()
            nm = n_micro or N_MICRO.get((cfg.name, cell.name), DEFAULT_N_MICRO)
            step = build_train_step(model, opt, n_micro=nm)
            state_sds = abstract_train_state(model, opt)
            state_sh = train_state_shardings(model, opt, mesh, rules)
            batch_sds = model.input_specs(cell)
            batch_sh = sh.batch_specs_for_inputs(batch_sds, mesh, rules)
            lowered = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,),
            ).lower(state_sds, batch_sds)
        elif cell.kind == "prefill":
            params_sds = model.abstract_params()
            params_sh = sh.tree_shardings(
                params_sds, model.logical_axes(), mesh, rules
            )
            batch_sds = model.input_specs(cell)
            batch_sh = sh.batch_specs_for_inputs(batch_sds, mesh, rules)
            lowered = jax.jit(
                model.forward,
                in_shardings=(params_sh, batch_sh),
                out_shardings=None,
            ).lower(params_sds, batch_sds)
        else:  # decode
            B, T = cell.global_batch, cell.seq_len
            params_sds = model.abstract_params()
            cache_sds = model.abstract_cache(B, T)
            params_sh, cache_sh = serve_shardings(model, mesh, B, T, rules)
            batch_sds = model.input_specs(cell)
            batch_sh = sh.batch_specs_for_inputs(batch_sds, mesh, rules)
            step = build_serve_step(model)
            lowered = jax.jit(
                step,
                in_shardings=(params_sh, cache_sh, batch_sh, None),
                out_shardings=(None, None, cache_sh),
                donate_argnums=(1,),
            ).lower(
                params_sds, cache_sds, batch_sds,
                jax.ShapeDtypeStruct((), jnp.int32),
            )

        compiled = lowered.compile()

    mem = _memory_stats(compiled)
    result = CellResult(
        arch=cfg.name, cell=cell.name, mesh=mesh_name, ok=True,
        seconds=time.time() - t0, memory_stats=mem,
    )
    if not skip_analysis:
        try:
            ca = compiled.cost_analysis()
        except Exception:
            ca = {}
        costs = hlo.analyze_hlo(compiled.as_text(), mesh.size)
        report = roofline.build_report(
            arch=cfg.name, cell=cell, mesh_name=mesh_name,
            n_devices=mesh.size, costs=costs, model=model,
            memory_stats=mem, cost_analysis=ca,
        )
        result.report = report.as_dict()
        if verbose:
            print(report.summary())
    if verbose:
        print(
            f"  [{mesh_name}] {cfg.name} × {cell.name}: compiled in "
            f"{result.seconds:.1f}s; per-device bytes: args="
            f"{mem.get('argument_bytes', 0)/2**30:.3f}GiB "
            f"temp={mem.get('temp_bytes', 0)/2**30:.3f}GiB"
        )
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="architecture id (or 'all')")
    ap.add_argument("--shape", default=None, help="shape cell (or 'all')")
    ap.add_argument("--all", action="store_true", help="every arch × shape")
    ap.add_argument("--multi-pod", action="store_true", help="2×16×16 mesh")
    ap.add_argument(
        "--both-meshes", action="store_true", help="run 16×16 AND 2×16×16"
    )
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--skip-analysis", action="store_true")
    args = ap.parse_args(argv)

    archs = (
        list(REGISTRY) if (args.all or args.arch in (None, "all"))
        else [args.arch]
    )
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    failed = 0
    for name in archs:
        cfg = get_config(name)
        cells = (
            cells_for(cfg) if (args.all or args.shape in (None, "all"))
            else [SHAPES[args.shape]]
        )
        for cell in cells:
            for mp in meshes:
                try:
                    r = lower_cell(
                        cfg, cell, multi_pod=mp, n_micro=args.n_micro,
                        skip_analysis=args.skip_analysis,
                    )
                except Exception as e:
                    traceback.print_exc()
                    r = CellResult(
                        arch=name, cell=cell.name,
                        mesh="2x16x16" if mp else "16x16",
                        ok=False, seconds=0.0, error=f"{type(e).__name__}: {e}",
                    )
                    failed += 1
                results.append(r)
                if args.out:
                    os.makedirs(args.out, exist_ok=True)
                    fn = f"dryrun_{name}_{cell.name}_{r.mesh}.json"
                    with open(os.path.join(args.out, fn), "w") as f:
                        json.dump(dataclasses.asdict(r), f, indent=1)

    print(f"\n== dry-run: {len(results) - failed}/{len(results)} cells OK ==")
    for r in results:
        status = "ok " if r.ok else "FAIL"
        print(f"  {status} {r.arch:26s} {r.cell:12s} {r.mesh:9s} {r.error or ''}")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
