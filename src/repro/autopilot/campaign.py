"""Profiling campaign — per-region error-tolerance curves (EDEN's
measurement step, README §Autopilot).

The paper repairs NaNs reactively at a *given* BER; EDEN's observation is
that the energy win lives in choosing a *different* DRAM parameter point per
data structure.  This module measures what each structure can afford:

  RegionGroup        one named data-structure class — a path regex over the
                     state tree (the same binding grammar as ``RuleSet``)
                     plus the repair rule the group deploys with while it
                     is approximate
  CampaignConfig     the sweep: groups × refresh-interval points, episode
                     kind (short injected serve or train runs), lengths,
                     and the seed every key in the campaign derives from
  ProfileCell        one (group, refresh point) measurement: BER + energy
                     saving from ``ApproxMemoryModel.from_refresh``, the
                     quality metric, ground-truth flips, and the observed
                     fatal-fault rate (the guard's expectation)
  ToleranceProfile   the full grid, JSON round-trippable and
                     seed-deterministic — ``frontier.solve_frontier``
                     consumes it

Episode mechanics: each cell runs a short episode with flips confined to
ONE group — ``ApproxSpace.inject(..., regions=...)`` takes a masked region
tree (every leaf not matching the group's pattern pinned EXACT), so the
cell's quality delta is attributable to that group alone.  Each injection
window is followed by a boundary scrub under the campaign's RuleSet (the
groups' own deployed rules, labeled per group so the per-rule counters
separate), then the production step runs — the same
inject → repair → compute cycle as deployment.

Quality is measured against a clean (BER = 0) episode with identical seeds,
prompts, and batches:

  serve   token-divergence rate — the fraction of next-token predictions
          that differ from the clean run's, decoded teacher-forced on the
          clean trajectory so the metric grades per position instead of
          locking in after the first flipped argmax (greedy, token by
          token, so recurrent models profile without batched prefill)
  train   loss delta — mean loss over the episode's second half minus the
          clean run's (the first half is warmup noise)

Determinism: every key derives from ``PRNGKey(seed)`` via ``fold_in`` of
(group index, point index, step) — repeated campaigns are bit-identical,
and the eager/compiled injection paths agree by construction (both funnel
through ``inject_tree``'s per-leaf-position key split).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import regions as regions_lib
from ..core.injection import ApproxMemoryModel
from ..core.rules import Detector, RepairRule, RuleSet
from ..launch.serve import build_serve_step
from ..launch.train import build_train_step, init_train_state, make_optimizer
from ..runtime import ApproxConfig, ApproxSpace, ScrubSchedule

__all__ = [
    "RegionGroup", "CampaignConfig", "ProfileCell", "ToleranceProfile",
    "campaign_space", "group_regions", "run_campaign",
    "rule_to_json", "rule_from_json",
]

_EPISODES = ("serve", "train")
_METRICS = {"serve": "token_divergence", "train": "loss_delta"}


# ---------------------------------------------------------------------------
# Rule (de)serialization — ToleranceProfile JSON round trip.
# ---------------------------------------------------------------------------


def rule_to_json(rule: RepairRule) -> Dict[str, Any]:
    """JSON-able dict for a ``RepairRule`` (str/float fills only — callable
    fills have no stable serialization and raise)."""
    fill = rule.fill
    if not isinstance(fill, (str, int, float)):
        raise TypeError(
            f"only str/float fills serialize to JSON, got {type(fill).__name__}"
        )
    return {
        "detect": {
            "nan": rule.detect.nan,
            "inf": rule.detect.inf,
            "max_magnitude": rule.detect.max_magnitude,
            "bitpatterns": [list(bp) for bp in rule.detect.bitpatterns],
        },
        "fill": fill,
        "trigger": rule.trigger,
        "exact": rule.exact,
        "label": rule.label,
    }


def rule_from_json(d: Dict[str, Any]) -> RepairRule:
    det = d["detect"]
    return RepairRule(
        detect=Detector(
            nan=bool(det["nan"]),
            inf=bool(det["inf"]),
            max_magnitude=det["max_magnitude"],
            bitpatterns=tuple(tuple(bp) for bp in det["bitpatterns"]),
        ),
        fill=d["fill"],
        trigger=d["trigger"],
        exact=bool(d["exact"]),
        label=d["label"],
    )


# ---------------------------------------------------------------------------
# The campaign surface.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RegionGroup:
    """One named data-structure class: a path regex (``RuleSet`` binding
    grammar, searched against ``a/b/c`` renderings) plus the repair rule the
    group deploys with while approximate.  The default rule is the serving
    posture — NaN/Inf-only zero fill, no magnitude clamp (activations and
    recurrent state are not O(1) like weights); weight groups typically pass
    the training rule (``neighbor_mean`` + range guard) instead."""

    name: str
    pattern: str
    rule: RepairRule = RepairRule(
        detect=Detector(nan=True, inf=True), fill="zero", trigger="boundary"
    )

    def labeled_rule(self) -> RepairRule:
        """The deployed rule labeled with the group's name — per-rule
        counters and guard expectations key on it."""
        return dataclasses.replace(self.rule, label=self.name)

    def to_json(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "pattern": self.pattern,
            "rule": rule_to_json(self.rule),
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "RegionGroup":
        return RegionGroup(
            name=d["name"], pattern=d["pattern"],
            rule=rule_from_json(d["rule"]),
        )


@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    """The sweep: ``groups`` × ``refresh_points``, measured with ``episode``
    runs of ``steps`` production steps each."""

    groups: Tuple[RegionGroup, ...]
    refresh_points: Tuple[float, ...]
    episode: str = "serve"          # "serve" | "train"
    steps: int = 12
    batch: int = 2
    prompt_len: int = 8             # serve episodes: greedy-decoded prompt
    seq_len: int = 16               # train episodes: tokens per batch row
    seed: int = 0

    def __post_init__(self):
        if self.episode not in _EPISODES:
            raise ValueError(
                f"bad episode {self.episode!r}; expected one of {_EPISODES}"
            )
        if not self.groups:
            raise ValueError("a campaign needs at least one RegionGroup")
        if not self.refresh_points:
            raise ValueError("a campaign needs at least one refresh point")
        names = [g.name for g in self.groups]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate group names: {names}")
        if self.steps < 2:
            raise ValueError("episodes need at least 2 steps")


@dataclasses.dataclass(frozen=True)
class ProfileCell:
    """One (group, refresh point) measurement."""

    group: str
    refresh_s: float
    ber: float
    energy_saving: float            # refresh model's saving at this point
    quality: float                  # token_divergence | loss_delta
    flips: int                      # ground-truth injected bit flips
    faults_per_step: float          # group-rule fatal detections / step
    approx_bytes: int               # bytes the group's mask exposes


@dataclasses.dataclass(frozen=True)
class ToleranceProfile:
    """The campaign's output grid — JSON round-trippable, seed-deterministic
    (same config + params → bit-identical cells)."""

    model: str
    episode: str
    metric: str
    steps: int
    seed: int
    groups: Tuple[RegionGroup, ...]
    refresh_points: Tuple[float, ...]
    cells: Tuple[ProfileCell, ...]

    def group_cells(self, name: str) -> Tuple[ProfileCell, ...]:
        return tuple(c for c in self.cells if c.group == name)

    def cell(self, name: str, refresh_s: float) -> ProfileCell:
        for c in self.cells:
            if c.group == name and c.refresh_s == refresh_s:
                return c
        raise KeyError(f"no cell for group {name!r} at refresh {refresh_s}")

    def to_json(self) -> str:
        return json.dumps({
            "model": self.model,
            "episode": self.episode,
            "metric": self.metric,
            "steps": self.steps,
            "seed": self.seed,
            "groups": [g.to_json() for g in self.groups],
            "refresh_points": list(self.refresh_points),
            "cells": [dataclasses.asdict(c) for c in self.cells],
        }, indent=2)

    @staticmethod
    def from_json(text: str) -> "ToleranceProfile":
        d = json.loads(text)
        return ToleranceProfile(
            model=d["model"],
            episode=d["episode"],
            metric=d["metric"],
            steps=d["steps"],
            seed=d["seed"],
            groups=tuple(RegionGroup.from_json(g) for g in d["groups"]),
            refresh_points=tuple(d["refresh_points"]),
            cells=tuple(ProfileCell(**c) for c in d["cells"]),
        )


# ---------------------------------------------------------------------------
# Campaign runtime pieces.
# ---------------------------------------------------------------------------


def campaign_space(groups: Tuple[RegionGroup, ...]) -> ApproxSpace:
    """The campaign's runtime: memory mode, the groups' deployed rules bound
    in group order (labels = group names, so ``rule_stats()`` separates the
    groups' fault counters), host-driven boundary scrubs (the episode loop
    scrubs between injection and compute — no in-step scrub, so per-rule
    counters stay host-visible)."""
    entries = tuple((g.pattern, g.labeled_rule()) for g in groups)
    return ApproxSpace(ApproxConfig(
        mode="memory",
        rules=RuleSet(entries),
        scrub=ScrubSchedule(boundary=False),
    ))


def group_regions(space: ApproxSpace, tree: Any, pattern: str) -> Any:
    """The masked region tree confining one injection window to the group:
    leaves matching ``pattern`` keep the space's region classification,
    everything else is pinned EXACT (never flipped)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    base = jax.tree.leaves(space.regions_for(tree))
    rx = re.compile(pattern)
    masked = [
        region if rx.search(regions_lib.path_str(path)) else
        regions_lib.Region.EXACT
        for (path, _), region in zip(flat, base)
    ]
    return jax.tree_util.tree_unflatten(treedef, masked)


def _group_faults(space: ApproxSpace, name: str) -> int:
    """Cumulative fatal detections (nan + inf) charged to the group's rule."""
    row = space.rule_stats().get(name)
    return 0 if row is None else row["nan_found"] + row["inf_found"]


def _inject_and_scrub(
    space: ApproxSpace, resident: Any, regions: Any, ber: float, key,
) -> Tuple[Any, int]:
    """One deployment cycle prefix: a masked injection window followed by
    the boundary scrub under the campaign rules.  Returns the (repaired)
    resident and the window's ground-truth flip count."""
    resident, flips = space.inject(
        resident, key, ber, record=False, regions=regions
    )
    resident = space.scrub(resident, trigger="boundary")
    return resident, int(flips)


# ---------------------------------------------------------------------------
# Episodes.
# ---------------------------------------------------------------------------


def _serve_episode(
    model: Any,
    params: Any,
    space: ApproxSpace,
    cfg: CampaignConfig,
    pattern: Optional[str],
    ber: float,
    ep_key: jax.Array,
    force: Optional[np.ndarray] = None,
) -> Tuple[np.ndarray, int, int]:
    """One greedy serve episode (token by token — recurrent decode cells
    need the warmup anyway).  Returns (emitted tokens [steps, batch],
    total flips, group approx bytes).  ``pattern=None`` → clean run.

    With ``force`` (the clean run's emitted stream) the decode is
    teacher-forced on the clean trajectory: every step sees the clean
    context, so ``emitted != clean`` counts positions whose next-token
    prediction the faults actually changed — one early argmax flip does
    not lock every later position into disagreement, which would square-
    wave the metric and hide the per-group dose-response the frontier
    solver needs."""
    vocab = model.cfg.vocab
    prompts = jax.random.randint(
        jax.random.PRNGKey(cfg.seed + 7),
        (cfg.batch, cfg.prompt_len), 1, vocab,
    )
    cache = model.init_cache(cfg.batch, cfg.prompt_len + cfg.steps + 1)
    step_fn = jax.jit(build_serve_step(model))
    resident = {"params": params, "cache": cache}
    masked = (
        group_regions(space, resident, pattern) if pattern is not None
        else None
    )
    approx_bytes = (
        regions_lib.count_bytes(resident, masked)[0] if masked is not None
        else 0
    )
    flips_total = 0
    emitted: List[np.ndarray] = []
    S0 = cfg.prompt_len
    nxt = prompts[:, :1]
    for t in range(S0 + cfg.steps - 1):
        if t < S0:
            tok = prompts[:, t:t + 1]
        elif force is not None:
            tok = jnp.asarray(force[t - S0])[:, None]
        else:
            tok = nxt
        if masked is not None and ber > 0.0:
            resident, flips = _inject_and_scrub(
                space, resident, masked, ber, jax.random.fold_in(ep_key, t)
            )
            flips_total += flips
        nxt_flat, _, new_cache = step_fn(
            resident["params"], resident["cache"], {"tokens": tok},
            jnp.asarray(t, jnp.int32),
        )
        resident = {"params": resident["params"], "cache": new_cache}
        nxt = nxt_flat[:, None]
        if t >= S0 - 1:
            emitted.append(np.asarray(nxt_flat))
    return np.stack(emitted), flips_total, approx_bytes


def _train_episode(
    model: Any,
    space: ApproxSpace,
    cfg: CampaignConfig,
    pattern: Optional[str],
    ber: float,
    ep_key: jax.Array,
) -> Tuple[np.ndarray, int, int]:
    """One injected train episode.  Returns (per-step losses, total flips,
    group approx bytes).  ``pattern=None`` → clean run."""
    vocab = model.cfg.vocab
    opt = make_optimizer(warmup=2, total=cfg.steps)
    state = init_train_state(model, opt, jax.random.PRNGKey(cfg.seed))
    # the campaign scrubs host-side between steps; the step itself runs raw
    step_fn = jax.jit(build_train_step(model, opt, space=ApproxSpace(mode="off")))
    resident = {"params": state["params"], "opt": state["opt"]}
    masked = (
        group_regions(space, resident, pattern) if pattern is not None
        else None
    )
    approx_bytes = (
        regions_lib.count_bytes(resident, masked)[0] if masked is not None
        else 0
    )
    flips_total = 0
    losses: List[float] = []
    for i in range(cfg.steps):
        if masked is not None and ber > 0.0:
            resident = {"params": state["params"], "opt": state["opt"]}
            resident, flips = _inject_and_scrub(
                space, resident, masked, ber, jax.random.fold_in(ep_key, i)
            )
            flips_total += flips
            state = {**state, **resident}
        batch = {
            "tokens": jax.random.randint(
                jax.random.fold_in(jax.random.PRNGKey(cfg.seed + 11), i),
                (cfg.batch, cfg.seq_len), 1, vocab,
            )
        }
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return np.asarray(losses), flips_total, approx_bytes


# ---------------------------------------------------------------------------
# The campaign driver.
# ---------------------------------------------------------------------------


def run_campaign(
    model: Any,
    cfg: CampaignConfig,
    params: Any = None,
) -> ToleranceProfile:
    """Sweep ``cfg.groups`` × ``cfg.refresh_points`` and return the measured
    ``ToleranceProfile``.  ``params`` defaults to ``model.init(seed)``; pass
    trained params to profile a real deployment."""
    space = campaign_space(cfg.groups)
    if params is None:
        params = model.init(jax.random.PRNGKey(cfg.seed))

    if cfg.episode == "serve":
        clean, _, _ = _serve_episode(
            model, params, space, cfg, None, 0.0, jax.random.PRNGKey(0)
        )
    else:
        clean, _, _ = _train_episode(
            model, space, cfg, None, 0.0, jax.random.PRNGKey(0)
        )
    half = cfg.steps // 2

    cells: List[ProfileCell] = []
    for gi, group in enumerate(cfg.groups):
        for pi, refresh_s in enumerate(cfg.refresh_points):
            mm = ApproxMemoryModel.from_refresh(refresh_s)
            ep_key = jax.random.fold_in(
                jax.random.fold_in(jax.random.PRNGKey(cfg.seed), gi), pi
            )
            faults0 = _group_faults(space, group.name)
            if cfg.episode == "serve":
                emitted, flips, nbytes = _serve_episode(
                    model, params, space, cfg, group.pattern, mm.ber, ep_key,
                    force=clean,
                )
                quality = float(np.mean(emitted != clean))
            else:
                losses, flips, nbytes = _train_episode(
                    model, space, cfg, group.pattern, mm.ber, ep_key
                )
                quality = float(
                    np.mean(losses[half:]) - np.mean(clean[half:])
                )
            faults = _group_faults(space, group.name) - faults0
            cells.append(ProfileCell(
                group=group.name,
                refresh_s=float(refresh_s),
                ber=float(mm.ber),
                energy_saving=float(mm.energy_saving),
                quality=quality,
                flips=int(flips),
                faults_per_step=faults / float(cfg.steps),
                approx_bytes=int(nbytes),
            ))

    return ToleranceProfile(
        model=str(getattr(model.cfg, "name", type(model).__name__)),
        episode=cfg.episode,
        metric=_METRICS[cfg.episode],
        steps=cfg.steps,
        seed=cfg.seed,
        groups=cfg.groups,
        refresh_points=tuple(float(r) for r in cfg.refresh_points),
        cells=tuple(cells),
    )
