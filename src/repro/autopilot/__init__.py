"""EDEN-style BER autopilot: profile → solve → guard (README §Autopilot).

Three layers close the loop between the approximate-memory model and the
application's measured error tolerance:

  campaign   per-region-group refresh sweeps under injection — emits a
             ``ToleranceProfile`` of quality-vs-BER cells
  frontier   solves the profile against a quality budget — per-group
             refresh map, deployment ``RuleSet`` (exact-ECC islands for
             collapsed groups), and the online guard's expectations
  guard      runtime monitor over ``ApproxSpace.rule_stats()`` that
             tightens drifting groups' rules with hysteresis
"""
from .campaign import (
    CampaignConfig,
    ProfileCell,
    RegionGroup,
    ToleranceProfile,
    campaign_space,
    group_regions,
    run_campaign,
)
from .frontier import (
    NOMINAL_REFRESH_S,
    FrontierAssignment,
    GroupAssignment,
    solve_frontier,
)
from .guard import OnlineGuard

__all__ = [
    "CampaignConfig",
    "FrontierAssignment",
    "GroupAssignment",
    "NOMINAL_REFRESH_S",
    "OnlineGuard",
    "ProfileCell",
    "RegionGroup",
    "ToleranceProfile",
    "campaign_space",
    "group_regions",
    "run_campaign",
    "solve_frontier",
]
