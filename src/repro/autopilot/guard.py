"""Online guard — the loop-closing runtime monitor (README §Autopilot).

The frontier's assignment is only as good as the conditions it profiled
under: a hotter DRAM part, a workload whose values sit closer to the
exponent cliff, or simple profile staleness all push a group's *observed*
fault rate above the profiled expectation.  The guard watches for that
drift and tightens the drifting group's rule — measurement flowing back
into policy, with hysteresis so one noisy window cannot cascade.

Mechanics: every ``window`` steps the guard reads the per-rule fatal
counters (``ApproxSpace.rule_stats()``), takes each guarded label's delta
since the last window, and compares it against

    tolerance × expected_faults_per_step × window + floor

(``AutopilotConfig.threshold``).  ``patience`` consecutive over-threshold
windows trip the label; a trip tightens its rule ONE stage and starts a
``cooldown`` (windows ignored for that label), and a clean window resets
the strike count.

The tightening ladder (stages per label):

  1. **stricter rule** — detection widened to NaN+Inf and the trigger
     promoted to ``boundary`` (fires on every scheduled pass); if the rule
     is already that strict, a range guard (``max_magnitude``) is added so
     legal-float exponent drift — invisible to the NaN/Inf detector that is
     under-counting relative to the profile — becomes repairable.
  2. **exact demotion** — ``RepairRule.exact_rule``: the group moves to the
     exact-ECC island (nominal refresh), leaving injection and repair
     entirely.

Rules are swapped via ``ApproxSpace.set_rules`` with the label preserved
(``RuleSet.with_rule``), so counter ledgers and expectations stay keyed
identically across a tighten.  Consumers holding executables compiled
against the old rules (the train loop's step, the engine's fused paged
steps) must rebuild them when ``observe()`` returns decisions — the wired
call sites in ``launch.train.train_loop`` and ``serving.Engine.step`` do.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from ..core.rules import RepairRule
from ..runtime.config import AutopilotConfig

__all__ = ["OnlineGuard"]

_RANGE_GUARD = 1e3      # the training default's drift/corruption separatrix


def _stricter(rule: RepairRule) -> Optional[RepairRule]:
    """One stage stricter than ``rule``, or ``None`` when only the exact
    demotion is left."""
    det = rule.detect
    if not (det.nan and det.inf) or rule.trigger != "boundary":
        return dataclasses.replace(
            rule,
            detect=dataclasses.replace(det, nan=True, inf=True),
            trigger="boundary",
        )
    if det.max_magnitude is None:
        return dataclasses.replace(
            rule, detect=dataclasses.replace(det, max_magnitude=_RANGE_GUARD)
        )
    return None


class OnlineGuard:
    """Per-window fault monitor over one ``ApproxSpace``.

    Drive it either with ``tick()`` once per production step (it observes
    every ``cfg.window`` ticks) or with ``observe()`` directly at window
    boundaries the caller schedules.  Both return the window's tightening
    decisions — empty when nothing drifted."""

    def __init__(self, space: Any, cfg: AutopilotConfig):
        self.space = space
        self.cfg = cfg
        self._steps = 0
        self._windows = 0
        self._last: Dict[str, int] = {}
        self._strikes: Dict[str, int] = {}
        self._cooldown: Dict[str, int] = {}
        self._stage: Dict[str, int] = {}
        self.trips: List[Dict[str, Any]] = []
        # baseline snapshot: counters accumulated before the guard armed
        # belong to no window
        for label, _ in cfg.expected:
            self._last[label] = self._observed(label)

    # ------------------------------------------------------------------ drive
    def tick(self) -> List[Dict[str, Any]]:
        """One production step; observes every ``cfg.window`` ticks."""
        self._steps += 1
        if self._steps % self.cfg.window == 0:
            return self.observe()
        return []

    def observe(self) -> List[Dict[str, Any]]:
        """Close one observation window: compare each guarded label's fault
        delta against its threshold, apply hysteresis, tighten trippers.
        Returns the tightening decisions (also appended to ``trips``)."""
        self._windows += 1
        decisions: List[Dict[str, Any]] = []
        for label, _ in self.cfg.expected:
            observed = self._observed(label)
            delta = observed - self._last.get(label, 0)
            self._last[label] = observed
            if self._cooldown.get(label, 0) > 0:
                self._cooldown[label] -= 1
                continue
            if self._stage.get(label, 0) >= 2:
                continue            # already exact — nothing left to tighten
            threshold = self.cfg.threshold(label)
            if delta > threshold:
                self._strikes[label] = self._strikes.get(label, 0) + 1
                if self._strikes[label] >= self.cfg.patience:
                    decisions.append(self._tighten(label, delta, threshold))
                    self._strikes[label] = 0
            else:
                self._strikes[label] = 0
        return decisions

    # -------------------------------------------------------------- internals
    def _observed(self, label: str) -> int:
        row = self.space.rule_stats().get(label)
        return 0 if row is None else row["nan_found"] + row["inf_found"]

    def _tighten(
        self, label: str, observed: int, threshold: float
    ) -> Dict[str, Any]:
        ruleset = self.space.ruleset
        current = None
        for _, rule in ruleset.entries:
            if rule.label == label:
                current = rule
                break
        if current is None:
            raise KeyError(f"guarded label {label!r} not bound in RuleSet")
        nxt = _stricter(current) if self._stage.get(label, 0) == 0 else None
        if nxt is None:
            nxt = RepairRule.exact_rule(label=label)
            action = "exact"
            self._stage[label] = 2
        else:
            action = "stricter"
            self._stage[label] = self._stage.get(label, 0) + 1
        self.space.set_rules(ruleset.with_rule(label, nxt))
        self._cooldown[label] = self.cfg.cooldown
        decision = {
            "label": label,
            "action": action,
            "window": self._windows,
            "observed": int(observed),
            "threshold": float(threshold),
            "stage": self._stage[label],
        }
        self.trips.append(decision)
        return decision

    def summary(self) -> Dict[str, Any]:
        return {
            "windows": self._windows,
            "trips": len(self.trips),
            "stages": dict(self._stage),
        }
