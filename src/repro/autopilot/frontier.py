"""Frontier solver — EDEN's assignment step (README §Autopilot).

Given a ``ToleranceProfile`` and a stated quality budget, pick the most
aggressive (longest) refresh interval each region group tolerates:

  * a group whose measured quality at some profiled point stays within the
    budget is assigned the longest such refresh — its deployed rule from the
    profile binds at that point;
  * a group whose curve **collapses** (no profiled point within budget)
    is demoted to an **exact-ECC island** at nominal refresh —
    ``RepairRule.exact_rule`` removes its leaves from injection and repair
    alike (recurrent SSM/xLSTM state is the expected case: its errors
    compound across steps with no attention-style amortization).

The assignment emits three deployment artifacts:

  ``refresh_map()``   per-group pattern → refresh interval (the DRAM
                      controller's per-allocation parameter table)
  ``ruleset()``       the concrete ``RuleSet`` — exact islands for collapsed
                      groups, the groups' relaxed rules elsewhere, in the
                      profile's binding order
  ``autopilot()``     the ``AutopilotConfig`` contract for the online guard:
                      per-group expected fault rates at the assigned points

plus ``energy_saving`` — the byte-weighted refresh-model saving over the
profiled bytes (collapsed groups contribute the nominal point's 0%).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Dict, List, Tuple

from ..core.rules import RepairRule, RuleSet
from ..runtime.config import AutopilotConfig
from .campaign import RegionGroup, ToleranceProfile

__all__ = ["GroupAssignment", "FrontierAssignment", "solve_frontier"]

NOMINAL_REFRESH_S = 0.064           # JEDEC-compliant anchor (BER ~1e-17)


@dataclasses.dataclass(frozen=True)
class GroupAssignment:
    """One group's point on the frontier."""

    group: str
    pattern: str
    refresh_s: float
    ber: float
    energy_saving: float
    quality: float                  # measured quality at the assigned point
    collapsed: bool                 # True → exact-ECC island at nominal
    expected_faults_per_step: float
    approx_bytes: int


@dataclasses.dataclass(frozen=True)
class FrontierAssignment:
    """The solved frontier: per-group refresh + the deployment artifacts."""

    budget: float
    metric: str
    groups: Tuple[RegionGroup, ...]
    assignments: Tuple[GroupAssignment, ...]

    def assignment(self, name: str) -> GroupAssignment:
        for a in self.assignments:
            if a.group == name:
                return a
        raise KeyError(f"no assignment for group {name!r}")

    def refresh_map(self) -> Dict[str, float]:
        """pattern → assigned refresh interval (seconds)."""
        return {a.pattern: a.refresh_s for a in self.assignments}

    def ruleset(self) -> RuleSet:
        """The concrete deployment ``RuleSet``: collapsed groups become
        exact-ECC islands, the rest keep their profiled rules — bound in
        the profile's group order (first match wins, like the campaign)."""
        entries = []
        by_name = {a.group: a for a in self.assignments}
        for g in self.groups:
            a = by_name[g.name]
            rule = (
                RepairRule.exact_rule(label=g.name) if a.collapsed
                else g.labeled_rule()
            )
            entries.append((g.pattern, rule))
        return RuleSet(tuple(entries))

    def autopilot(
        self,
        window: int = 8,
        tolerance: float = 4.0,
        floor: float = 4.0,
        patience: int = 2,
        cooldown: int = 2,
    ) -> AutopilotConfig:
        """The online-guard contract: each non-collapsed group's profiled
        fault rate at its assigned point becomes the guard's expectation
        (collapsed groups are exact — nothing to guard, expectation 0)."""
        expected = tuple(
            (a.group, 0.0 if a.collapsed else a.expected_faults_per_step)
            for a in self.assignments
        )
        return AutopilotConfig(
            window=window, tolerance=tolerance, floor=floor,
            patience=patience, cooldown=cooldown, expected=expected,
        )

    @property
    def energy_saving(self) -> float:
        """Byte-weighted refresh-model saving over the profiled bytes."""
        total = sum(a.approx_bytes for a in self.assignments)
        if total == 0:
            return 0.0
        return sum(
            a.energy_saving * a.approx_bytes for a in self.assignments
        ) / total

    def to_json(self) -> str:
        from .campaign import rule_to_json  # deferred: avoid cycle noise

        return json.dumps({
            "budget": self.budget,
            "metric": self.metric,
            "groups": [g.to_json() for g in self.groups],
            "assignments": [dataclasses.asdict(a) for a in self.assignments],
            "ruleset": [
                {"pattern": p, "rule": rule_to_json(r)}
                for p, r in self.ruleset().entries
            ],
            "energy_saving": self.energy_saving,
        }, indent=2)

    @staticmethod
    def from_json(text: str) -> "FrontierAssignment":
        d = json.loads(text)
        return FrontierAssignment(
            budget=d["budget"],
            metric=d["metric"],
            groups=tuple(RegionGroup.from_json(g) for g in d["groups"]),
            assignments=tuple(
                GroupAssignment(**a) for a in d["assignments"]
            ),
        )


def solve_frontier(
    profile: ToleranceProfile, budget: float
) -> FrontierAssignment:
    """Pick, per group, the longest profiled refresh whose measured quality
    stays within ``budget`` (non-finite quality — a diverged episode —
    never qualifies).  Groups with no qualifying point collapse to the
    exact island at nominal refresh."""
    assignments: List[GroupAssignment] = []
    for g in profile.groups:
        cells = profile.group_cells(g.name)
        ok = [
            c for c in cells
            if math.isfinite(c.quality) and c.quality <= budget
        ]
        if ok:
            best = max(ok, key=lambda c: c.refresh_s)
            assignments.append(GroupAssignment(
                group=g.name,
                pattern=g.pattern,
                refresh_s=best.refresh_s,
                ber=best.ber,
                energy_saving=best.energy_saving,
                quality=best.quality,
                collapsed=False,
                expected_faults_per_step=best.faults_per_step,
                approx_bytes=best.approx_bytes,
            ))
        else:
            nbytes = max((c.approx_bytes for c in cells), default=0)
            assignments.append(GroupAssignment(
                group=g.name,
                pattern=g.pattern,
                refresh_s=NOMINAL_REFRESH_S,
                ber=0.0,
                energy_saving=0.0,
                quality=0.0,
                collapsed=True,
                expected_faults_per_step=0.0,
                approx_bytes=int(nbytes),
            ))
    return FrontierAssignment(
        budget=float(budget),
        metric=profile.metric,
        groups=profile.groups,
        assignments=tuple(assignments),
    )
